#!/usr/bin/env bash
# Tier-1 verify: the fast suite, one command (see ROADMAP.md).
# Slow multi-device subprocess tests can be skipped with:
#   scripts/tier1.sh -m "not multidevice"
# TIER1_BUDGET_S (optional) enforces a hard wall-clock budget: the run fails
# with exit 124 when the suite outgrows it (CI sets 1800s), keeping "tier-1
# stays fast" an enforced property rather than a hope.
set -euo pipefail
cd "$(dirname "$0")/.."
cmd=(python -m pytest -x -q "$@")
if [[ -n "${TIER1_BUDGET_S:-}" ]]; then
  cmd=(timeout --foreground "${TIER1_BUDGET_S}" "${cmd[@]}")
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} "${cmd[@]}"
