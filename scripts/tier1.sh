#!/usr/bin/env bash
# Tier-1 verify: the fast suite, one command (see ROADMAP.md).
# Slow multi-device subprocess tests can be skipped with:
#   scripts/tier1.sh -m "not multidevice"
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
