"""Theorem 1 (Appendix A) empirical validation on the quadratic model."""
import numpy as np
import pytest

from repro.core import theory
from repro.core.outer import OuterConfig


def test_expected_phi_spectrum_matches_eq53():
    d = theory.expected_phi_spectrum(0.5, 0.7, 0.1, 10, [1.0])
    expect = 1 + 0.5 - (1 - 0.9 ** 10) * 0.7
    assert d[0] == pytest.approx(expect)


def test_convergence_condition_beta_gt_alpha():
    assert theory.expected_phi_converges(0.5, 0.7, 0.1, 20, [1.0, 0.3])
    assert not theory.expected_phi_converges(0.5, 0.7, 0.0, 20, [1.0])  # no inner progress


def test_variance_coefficient_band():
    # inside Eq. 74 band -> |d_V| < 1; outside -> >= 1
    assert theory.variance_bounded(0.5, 1.0)
    assert not theory.variance_bounded(0.5, 0.3)


def test_theorem1_expected_value_converges():
    """E(φ_t) → 0 (Thm. 2): the trajectory decays from the initial condition
    to a stationary noise floor of scale O(ω σ) — it does NOT reach machine
    zero (V(φ) ∝ ω², Thm. 1), so the converged expected value is estimated
    by a tail AVERAGE (the seed-era single-sample-vs-5%-of-post-step-1 check
    was a miscalibrated measurement of exactly this floor)."""
    omega = 0.1
    model = theory.QuadraticModel()
    res = theory.simulate_quadratic(
        model, world=8, outer_steps=150, inner_steps=5, omega=omega
    )
    tail = res["mean_norm"][-30:].mean()
    # transient: decayed at least 10x below the true initial ||mean phi||
    assert tail < 0.1 * res["mean_norm"][0], (tail, res["mean_norm"][0])
    # stationarity: the remaining level is the omega-scaled noise floor
    floor = 1.5 * omega * model.sigma * np.sqrt(model.dim)
    assert tail < floor, (tail, floor)


def test_theorem1_variance_scales_with_omega_squared():
    """V(φ) ∝ ω² (Thm. 1): halving ω should roughly quarter the stationary
    variance (Monte-Carlo: accept 2.5-6x)."""
    kw = dict(world=8, outer_steps=150, inner_steps=5, seed=1)
    v1 = theory.simulate_quadratic(theory.QuadraticModel(), omega=0.1, **kw)["var"][-75:].mean()
    v2 = theory.simulate_quadratic(theory.QuadraticModel(), omega=0.05, **kw)["var"][-75:].mean()
    ratio = v1 / v2
    assert 2.0 < ratio < 8.0, ratio


def _async_tails(rates, stale, *, seeds=3, metric="mean_norm"):
    """Tail-averaged trajectories under the merged-tick clock, plus the
    trace-mean staleness, averaged over seeds (Monte-Carlo estimator — same
    idiom as the synchronous Thm. 1 checks above)."""
    kw = dict(world=8, outer_steps=200, inner_steps=5, omega=0.1)
    model = theory.QuadraticModel()
    tails, taus = [], []
    for s in range(seeds):
        res = theory.simulate_quadratic(
            model, rates=rates, cfg=OuterConfig(stale=stale), seed=s, **kw
        )
        tails.append(res[metric][-80:].mean())
        taus.append(float(np.mean(res["staleness"])) if len(res["staleness"]) else 0.0)
    return float(np.mean(tails)), float(np.mean(taus))


def test_async_all_ones_rates_is_exactly_synchronous():
    """rates=(1,)*n must run the synchronous code path bit-for-bit and report
    an all-zero staleness trace."""
    kw = dict(world=8, outer_steps=40, inner_steps=5, omega=0.1, seed=3)
    model = theory.QuadraticModel()
    sync = theory.simulate_quadratic(model, **kw)
    asyn = theory.simulate_quadratic(model, rates=(1.0,) * 8, **kw)
    np.testing.assert_array_equal(sync["mean_norm"], asyn["mean_norm"])
    np.testing.assert_array_equal(sync["var"], asyn["var"])
    assert not np.any(asyn["staleness"])


def test_staleness_floor_two_x_straggler():
    """The acceptance regime: one 2x straggler in an 8-replica world.  Both
    stale rules stay under their :func:`theory.staleness_floor` prediction,
    and the momentum discount stays under the SYNCHRONOUS base floor — the
    'recovered' claim — while matching or beating naive."""
    omega, model = 0.1, theory.QuadraticModel()
    rates = (0.5,) + (1.0,) * 7
    naive, tau_bar = _async_tails(rates, "naive")
    mom, _ = _async_tails(rates, "momentum")
    base = theory.staleness_floor(omega, model.sigma, model.dim, 0.0)
    assert naive < theory.staleness_floor(
        omega, model.sigma, model.dim, tau_bar, stale="naive"
    ), (naive, tau_bar)
    assert mom < base, (mom, base)
    assert mom <= naive + 0.01, (mom, naive)


def test_naive_floor_grows_with_staleness():
    """O(ω σ · (1+τ)) degradation of the naive rule: with half the world at a
    10x slowdown (per-replica τ up to 1/ρ − 1 = 9), the stationary tail rises
    ABOVE the synchronous base floor — the τ=0 bound genuinely fails — while
    staying inside the (1+τ_max)-scaled band the predictor gives."""
    omega, model = 0.1, theory.QuadraticModel()
    harsh, _ = _async_tails((0.1,) * 4 + (1.0,) * 4, "naive")
    base = theory.staleness_floor(omega, model.sigma, model.dim, 0.0)
    tau_max = 1.0 / 0.1 - 1.0
    assert harsh > base, (harsh, base)
    assert harsh < theory.staleness_floor(
        omega, model.sigma, model.dim, tau_max, stale="naive"
    ), harsh


def test_diloco_also_converges_on_quadratic():
    """Same tail-average estimator as the NoLoCo check: DiLoCo's all-reduce
    outer Nesterov drives ‖E(φ)‖ to the same ω-scaled stochastic floor."""
    omega = 0.1
    model = theory.QuadraticModel()
    res = theory.simulate_quadratic(
        model, world=8, outer_steps=150, inner_steps=5, omega=omega,
        cfg=OuterConfig(method="diloco", alpha=0.3, beta=0.7),
    )
    tail = res["mean_norm"][-30:].mean()
    assert tail < 0.1 * res["mean_norm"][0], (tail, res["mean_norm"][0])
    assert tail < 1.5 * omega * model.sigma * np.sqrt(model.dim), tail
