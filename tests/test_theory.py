"""Theorem 1 (Appendix A) empirical validation on the quadratic model."""
import numpy as np
import pytest

from repro.core import theory
from repro.core.outer import OuterConfig


def test_expected_phi_spectrum_matches_eq53():
    d = theory.expected_phi_spectrum(0.5, 0.7, 0.1, 10, [1.0])
    expect = 1 + 0.5 - (1 - 0.9 ** 10) * 0.7
    assert d[0] == pytest.approx(expect)


def test_convergence_condition_beta_gt_alpha():
    assert theory.expected_phi_converges(0.5, 0.7, 0.1, 20, [1.0, 0.3])
    assert not theory.expected_phi_converges(0.5, 0.7, 0.0, 20, [1.0])  # no inner progress


def test_variance_coefficient_band():
    # inside Eq. 74 band -> |d_V| < 1; outside -> >= 1
    assert theory.variance_bounded(0.5, 1.0)
    assert not theory.variance_bounded(0.5, 0.3)


def test_theorem1_expected_value_converges():
    res = theory.simulate_quadratic(
        theory.QuadraticModel(), world=8, outer_steps=150, inner_steps=5, omega=0.1
    )
    assert res["mean_norm"][-1] < 0.05 * res["mean_norm"][0]


def test_theorem1_variance_scales_with_omega_squared():
    """V(φ) ∝ ω² (Thm. 1): halving ω should roughly quarter the stationary
    variance (Monte-Carlo: accept 2.5-6x)."""
    kw = dict(world=8, outer_steps=150, inner_steps=5, seed=1)
    v1 = theory.simulate_quadratic(theory.QuadraticModel(), omega=0.1, **kw)["var"][-75:].mean()
    v2 = theory.simulate_quadratic(theory.QuadraticModel(), omega=0.05, **kw)["var"][-75:].mean()
    ratio = v1 / v2
    assert 2.0 < ratio < 8.0, ratio


def test_diloco_also_converges_on_quadratic():
    res = theory.simulate_quadratic(
        theory.QuadraticModel(), world=8, outer_steps=150, inner_steps=5, omega=0.1,
        cfg=OuterConfig(method="diloco", alpha=0.3, beta=0.7),
    )
    assert res["mean_norm"][-1] < 0.05 * res["mean_norm"][0]
