"""Theorem 1 (Appendix A) empirical validation on the quadratic model."""
import numpy as np
import pytest

from repro.core import theory
from repro.core.outer import OuterConfig


def test_expected_phi_spectrum_matches_eq53():
    d = theory.expected_phi_spectrum(0.5, 0.7, 0.1, 10, [1.0])
    expect = 1 + 0.5 - (1 - 0.9 ** 10) * 0.7
    assert d[0] == pytest.approx(expect)


def test_convergence_condition_beta_gt_alpha():
    assert theory.expected_phi_converges(0.5, 0.7, 0.1, 20, [1.0, 0.3])
    assert not theory.expected_phi_converges(0.5, 0.7, 0.0, 20, [1.0])  # no inner progress


def test_variance_coefficient_band():
    # inside Eq. 74 band -> |d_V| < 1; outside -> >= 1
    assert theory.variance_bounded(0.5, 1.0)
    assert not theory.variance_bounded(0.5, 0.3)


def test_theorem1_expected_value_converges():
    """E(φ_t) → 0 (Thm. 2): the trajectory decays from the initial condition
    to a stationary noise floor of scale O(ω σ) — it does NOT reach machine
    zero (V(φ) ∝ ω², Thm. 1), so the converged expected value is estimated
    by a tail AVERAGE (the seed-era single-sample-vs-5%-of-post-step-1 check
    was a miscalibrated measurement of exactly this floor)."""
    omega = 0.1
    model = theory.QuadraticModel()
    res = theory.simulate_quadratic(
        model, world=8, outer_steps=150, inner_steps=5, omega=omega
    )
    tail = res["mean_norm"][-30:].mean()
    # transient: decayed at least 10x below the true initial ||mean phi||
    assert tail < 0.1 * res["mean_norm"][0], (tail, res["mean_norm"][0])
    # stationarity: the remaining level is the omega-scaled noise floor
    floor = 1.5 * omega * model.sigma * np.sqrt(model.dim)
    assert tail < floor, (tail, floor)


def test_theorem1_variance_scales_with_omega_squared():
    """V(φ) ∝ ω² (Thm. 1): halving ω should roughly quarter the stationary
    variance (Monte-Carlo: accept 2.5-6x)."""
    kw = dict(world=8, outer_steps=150, inner_steps=5, seed=1)
    v1 = theory.simulate_quadratic(theory.QuadraticModel(), omega=0.1, **kw)["var"][-75:].mean()
    v2 = theory.simulate_quadratic(theory.QuadraticModel(), omega=0.05, **kw)["var"][-75:].mean()
    ratio = v1 / v2
    assert 2.0 < ratio < 8.0, ratio


def test_diloco_also_converges_on_quadratic():
    """Same tail-average estimator as the NoLoCo check: DiLoCo's all-reduce
    outer Nesterov drives ‖E(φ)‖ to the same ω-scaled stochastic floor."""
    omega = 0.1
    model = theory.QuadraticModel()
    res = theory.simulate_quadratic(
        model, world=8, outer_steps=150, inner_steps=5, omega=omega,
        cfg=OuterConfig(method="diloco", alpha=0.3, beta=0.7),
    )
    tail = res["mean_norm"][-30:].mean()
    assert tail < 0.1 * res["mean_norm"][0], (tail, res["mean_norm"][0])
    assert tail < 1.5 * omega * model.sigma * np.sqrt(model.dim), tail
