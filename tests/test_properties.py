"""Hypothesis property-based tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import latency, pairing
from repro.core.outer import gamma_band
from repro.core.theory import variance_bounded
from repro.data import pack_documents
from repro.kernels import ops, ref
from repro.kernels.dispatch import KernelConfig


@given(world=st.integers(2, 64), step=st.integers(0, 1000), seed=st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_pairing_always_involution(world, step, seed):
    pt = pairing.partner_table(step, world, seed=seed)
    assert (pt[pt] == np.arange(world)).all()
    assert int((pt == np.arange(world)).sum()) == world % 2


@given(alpha=st.floats(0.0, 0.99), n=st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_gamma_band_always_stabilizes_variance(alpha, n):
    """Any γ strictly inside the Eq. 74 band gives |d_V| < 1."""
    lo, hi = gamma_band(alpha, n)
    for frac in (0.01, 0.5, 0.99):
        g = lo + frac * (hi - lo)
        if lo < g < hi:
            assert variance_bounded(alpha, g, n)
    # and ε outside the band fails
    assert not variance_bounded(alpha, lo * 0.99, n)


@given(
    doc_lens=st.lists(st.integers(1, 60), min_size=2, max_size=8),
    seq_len=st.integers(4, 32),
)
@settings(max_examples=30, deadline=None)
def test_packing_preserves_stream(doc_lens, seq_len):
    docs = [np.arange(1, n + 1) for n in doc_lens]
    total = sum(doc_lens) + len(docs)
    if total < seq_len + 1:
        return
    tokens, labels, mask = pack_documents(docs, seq_len, eos_id=0)
    # labels are tokens shifted by one within each row
    stream = []
    for d in docs:
        stream.extend(d.tolist())
        stream.append(0)
    n = tokens.shape[0]
    row = seq_len + 1
    arr = np.asarray(stream[: n * row]).reshape(n, row)
    np.testing.assert_array_equal(tokens, arr[:, :-1])
    np.testing.assert_array_equal(labels, arr[:, 1:])


@given(
    sq=st.integers(8, 96),
    h=st.sampled_from([1, 2, 4]),
    kv=st.sampled_from([1, 2]),
    d=st.sampled_from([16, 32]),
)
@settings(max_examples=15, deadline=None)
def test_flash_attention_property_sweep(sq, h, kv, d):
    if h % kv:
        return
    key = jax.random.PRNGKey(sq * 131 + h)
    q = jax.random.normal(key, (1, sq, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, sq, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, sq, kv, d))
    out = ops.flash_attention(q, k, v, mode="causal", block_q=32, block_kv=32,
                              config=KernelConfig("pallas", interpret=True))
    hm = (jnp.arange(h) * kv) // h
    qf = q.transpose(0, 2, 1, 3).reshape(h, sq, d)
    kf = jnp.take(k, hm, 2).transpose(0, 2, 1, 3).reshape(h, sq, d)
    vf = jnp.take(v, hm, 2).transpose(0, 2, 1, 3).reshape(h, sq, d)
    gold = ref.reference_attention(qf, kf, vf, mode="causal")
    gold = gold.reshape(1, h, sq, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=5e-5, rtol=1e-3)


@given(mu=st.floats(-1.0, 1.0), sigma=st.floats(0.05, 1.5), n=st.sampled_from([4, 16, 64, 256]))
@settings(max_examples=30, deadline=None)
def test_gossip_always_beats_tree_allreduce_in_expectation(mu, sigma, n):
    """The paper's headline latency claim holds for ALL lognormal params:
    ratio ≈ log2(n) ≥ 2 for n ≥ 4."""
    s = latency.speedup_closed_form(n, mu, sigma)
    assert s >= np.log2(n) - 1e-9
