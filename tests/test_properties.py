"""Hypothesis property-based tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import latency, pairing
from repro.core.outer import gamma_band
from repro.core.theory import variance_bounded
from repro.data import pack_documents
from repro.kernels import ops, ref
from repro.kernels.dispatch import KernelConfig


@given(world=st.integers(2, 64), step=st.integers(0, 1000), seed=st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_pairing_always_involution(world, step, seed):
    pt = pairing.partner_table(step, world, seed=seed)
    assert (pt[pt] == np.arange(world)).all()
    assert int((pt == np.arange(world)).sum()) == world % 2


@given(alpha=st.floats(0.0, 0.99), n=st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_gamma_band_always_stabilizes_variance(alpha, n):
    """Any γ strictly inside the Eq. 74 band gives |d_V| < 1."""
    lo, hi = gamma_band(alpha, n)
    for frac in (0.01, 0.5, 0.99):
        g = lo + frac * (hi - lo)
        if lo < g < hi:
            assert variance_bounded(alpha, g, n)
    # and ε outside the band fails
    assert not variance_bounded(alpha, lo * 0.99, n)


@given(
    doc_lens=st.lists(st.integers(1, 60), min_size=2, max_size=8),
    seq_len=st.integers(4, 32),
)
@settings(max_examples=30, deadline=None)
def test_packing_preserves_stream(doc_lens, seq_len):
    docs = [np.arange(1, n + 1) for n in doc_lens]
    total = sum(doc_lens) + len(docs)
    if total < seq_len + 1:
        return
    tokens, labels, mask = pack_documents(docs, seq_len, eos_id=0)
    # labels are tokens shifted by one within each row
    stream = []
    for d in docs:
        stream.extend(d.tolist())
        stream.append(0)
    n = tokens.shape[0]
    row = seq_len + 1
    arr = np.asarray(stream[: n * row]).reshape(n, row)
    np.testing.assert_array_equal(tokens, arr[:, :-1])
    np.testing.assert_array_equal(labels, arr[:, 1:])


@given(
    sq=st.integers(8, 96),
    h=st.sampled_from([1, 2, 4]),
    kv=st.sampled_from([1, 2]),
    d=st.sampled_from([16, 32]),
)
@settings(max_examples=15, deadline=None)
def test_flash_attention_property_sweep(sq, h, kv, d):
    if h % kv:
        return
    key = jax.random.PRNGKey(sq * 131 + h)
    q = jax.random.normal(key, (1, sq, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, sq, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, sq, kv, d))
    out = ops.flash_attention(q, k, v, mode="causal", block_q=32, block_kv=32,
                              config=KernelConfig("pallas", interpret=True))
    hm = (jnp.arange(h) * kv) // h
    qf = q.transpose(0, 2, 1, 3).reshape(h, sq, d)
    kf = jnp.take(k, hm, 2).transpose(0, 2, 1, 3).reshape(h, sq, d)
    vf = jnp.take(v, hm, 2).transpose(0, 2, 1, 3).reshape(h, sq, d)
    gold = ref.reference_attention(qf, kf, vf, mode="causal")
    gold = gold.reshape(1, h, sq, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=5e-5, rtol=1e-3)


@given(mu=st.floats(-1.0, 1.0), sigma=st.floats(0.05, 1.5), n=st.sampled_from([4, 16, 64, 256]))
@settings(max_examples=30, deadline=None)
def test_gossip_always_beats_tree_allreduce_in_expectation(mu, sigma, n):
    """The paper's headline latency claim holds for ALL lognormal params:
    ratio ≈ log2(n) ≥ 2 for n ≥ 4."""
    s = latency.speedup_closed_form(n, mu, sigma)
    assert s >= np.log2(n) - 1e-9


# ---------------------------------------------------------------------------
# Elastic (membership-aware) pairing under churn
# ---------------------------------------------------------------------------


@st.composite
def memberships(draw, min_world=2, max_world=24):
    world = draw(st.integers(min_world, max_world))
    mask = list(draw(st.lists(st.booleans(), min_size=world, max_size=world)))
    if not any(mask):
        mask[draw(st.integers(0, world - 1))] = True
    epoch = draw(st.integers(0, 3))
    return pairing.Membership(world=world, mask=tuple(mask), epoch=epoch)


@given(mem=memberships(), step=st.integers(0, 500), seed=st.integers(0, 5))
@settings(max_examples=50, deadline=None)
def test_elastic_pairing_churn_invariants(mem, step, seed):
    """For arbitrary membership masks: the table is an involution, every
    active replica is in exactly one group (pair or self sit-out, with
    exactly ``num_active % 2`` active self-pairs), actives only pair with
    actives, and inactive replicas never appear in anyone's group."""
    pt = pairing.elastic_partner_table(step, mem, seed=seed)
    world = mem.world
    assert (pt[pt] == np.arange(world)).all()
    active = set(mem.active_ids)
    for i in range(world):
        if i in active:
            assert int(pt[i]) in active  # partner of an active is active
        else:
            assert pt[i] == i  # inactive sits out...
            assert not ((pt == i) & (np.arange(world) != i)).any()  # ...unreferenced
    self_paired_active = sum(1 for i in active if pt[i] == i)
    assert self_paired_active == mem.num_active % 2


@given(mem=memberships(), step=st.integers(0, 500), seed=st.integers(0, 5))
@settings(max_examples=30, deadline=None)
def test_elastic_pairs_roundtrip_ppermute(mem, step, seed):
    """elastic_ppermute_pairs is a TOTAL permutation of the world (ppermute
    needs every device addressed) and reconstructs the partner table."""
    pairs = pairing.elastic_ppermute_pairs(step, mem, seed=seed)
    srcs = sorted(p[0] for p in pairs)
    dsts = sorted(p[1] for p in pairs)
    assert srcs == list(range(mem.world)) == dsts
    table = np.arange(mem.world)
    for src, dst in pairs:
        table[src] = dst
    np.testing.assert_array_equal(table, pairing.elastic_partner_table(step, mem, seed=seed))


@given(step=st.integers(0, 500), seed=st.integers(0, 5), world=st.integers(2, 24))
@settings(max_examples=30, deadline=None)
def test_elastic_full_membership_matches_static_schedule(step, seed, world):
    """Elasticity costs nothing when nobody churns: the full-membership
    elastic table is bit-identical to the static partner_table."""
    mem = pairing.Membership.full(world)
    np.testing.assert_array_equal(
        pairing.elastic_partner_table(step, mem, seed=seed),
        pairing.partner_table(step, world, seed=seed),
    )


@given(
    seed=st.integers(0, 10),
    num_active=st.sampled_from([3, 5, 7, 9]),
    dropped=st.integers(0, 3),
)
@settings(max_examples=10, deadline=None)
def test_elastic_sitouts_fair_across_steps(seed, num_active, dropped):
    """Odd active count: exactly one active sits out per step, chosen
    uniformly — over 40·k steps every active sits out at least once and no
    replica hoards the sit-outs (Binomial concentration, margin 4x mean)."""
    world = num_active + dropped
    mask = [True] * num_active + [False] * dropped
    mem = pairing.Membership(world=world, mask=tuple(mask))
    steps = 40 * num_active
    counts = np.zeros(world, dtype=int)
    for t in range(steps):
        pt = pairing.elastic_partner_table(t, mem, seed=seed)
        for i in mem.active_ids:
            if pt[i] == i:
                counts[i] += 1
    active = np.asarray(mem.active_ids)
    assert counts[active].sum() == steps  # exactly one sit-out per step
    assert (counts[active] >= 1).all(), counts
    assert counts[active].max() <= 4 * steps / num_active, counts


@given(
    step=st.integers(0, 200),
    seed=st.integers(0, 5),
    world=st.sampled_from([6, 8, 12, 16]),
    cut=st.integers(1, 5),
)
@settings(max_examples=30, deadline=None)
def test_elastic_partition_never_pairs_across_components(step, seed, world, cut):
    """Under a network partition no pair crosses a component boundary."""
    cut = min(cut, world - 1)
    groups = [tuple(range(cut)), tuple(range(cut, world))]
    mem = pairing.Membership.full(world)
    pt = pairing.elastic_partner_table(step, mem, seed=seed, groups=groups)
    assert (pt[pt] == np.arange(world)).all()
    for i in range(world):
        assert (i < cut) == (int(pt[i]) < cut)


# ---------------------------------------------------------------------------
# Elastic shard_map program pool (ISSUE 5): pure key/pairing invariants
# ---------------------------------------------------------------------------


def _pure_pool(world, schedule, pool=16, seed=0):
    """OuterProgramPool with mesh-free stand-ins: the key/pairing derivation
    under test is pure (compilation paths are covered by the multidevice
    tests)."""
    import types

    from repro.core.outer import OuterConfig
    from repro.parallel.steps import OuterProgramPool

    return OuterProgramPool(
        types.SimpleNamespace(replicas=world), None, None,
        OuterConfig(method="noloco"), schedule=schedule, pairing_pool=pool,
        seed=seed,
    )


@given(mem=memberships(), step=st.integers(0, 500), seed=st.integers(0, 5))
@settings(max_examples=50, deadline=None)
def test_membership_epoch_is_schedule_irrelevant(mem, step, seed):
    """Epoch determinism: the pairing is a pure function of (seed, step,
    MASK) — two epochs with identical masks schedule identically, so a node
    that left and came right back changes nothing."""
    bumped = pairing.Membership(world=mem.world, mask=mem.mask, epoch=mem.epoch + 7)
    np.testing.assert_array_equal(
        pairing.elastic_partner_table(step, mem, seed=seed),
        pairing.elastic_partner_table(step, bumped, seed=seed),
    )
    pool = _pure_pool(mem.world, "random", seed=seed)
    assert pool.view_key(mem) == pool.view_key(bumped)
    assert pool.pairs_for(step, mem) == pool.pairs_for(step, bumped)


@st.composite
def pow2_memberships(draw):
    world = draw(st.sampled_from([2, 4, 8, 16]))
    mask = list(draw(st.lists(st.booleans(), min_size=world, max_size=world)))
    if not any(mask):
        mask[draw(st.integers(0, world - 1))] = True
    return pairing.Membership(world=world, mask=tuple(mask))


@given(mem=pow2_memberships(), step=st.integers(0, 500), seed=st.integers(0, 5))
@settings(max_examples=50, deadline=None)
def test_elastic_hypercube_involution_and_membership(mem, step, seed):
    """The hypercube-pool table is an involution for ANY membership mask;
    actives only pair with actives, inactives self-loop unreferenced, and
    full membership is bit-identical to the static hypercube schedule."""
    world = mem.world
    pt = pairing.elastic_hypercube_partner_table(step, mem, seed=seed)
    assert (pt[pt] == np.arange(world)).all()
    active = set(mem.active_ids)
    for i in range(world):
        if i in active:
            assert int(pt[i]) in active
        else:
            assert pt[i] == i
            assert not ((pt == i) & (np.arange(world) != i)).any()
    if mem.is_full and world >= 2:
        np.testing.assert_array_equal(
            pt, pairing.hypercube_partner_table(step, world, seed=seed)
        )


@given(
    world=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 5),
    pool=st.sampled_from([4, 16]),
    horizon=st.integers(1, 300),
)
@settings(max_examples=30, deadline=None)
def test_pool_slots_bounded(world, seed, pool, horizon):
    """Pool hit/miss bound: over ANY run horizon the set of pool slots —
    the bounded half of the program key — never exceeds ``pairing_pool``
    (random) / log2(world) (hypercube), so compiles per membership view are
    bounded by ``max_programs_per_view``."""
    for schedule in ("random", "hypercube"):
        p = _pure_pool(world, schedule, pool=pool, seed=seed)
        slots = {p.pool_slot(k) for k in range(horizon)}
        assert len(slots) <= p.max_programs_per_view
        # and the same slot always yields the same pairs for the same view
        mem = pairing.Membership.full(world).drop([0])
        for k in range(min(horizon, 40)):
            s1, pairs1 = p.pairs_for(k, mem)
            for j in range(k + 1, min(horizon, 40)):
                if p.pool_slot(j) == s1 and schedule == "random":
                    assert p.pairs_for(j, mem)[1] == pairs1


@given(mem=memberships(), step=st.integers(0, 300), seed=st.integers(0, 5))
@settings(max_examples=50, deadline=None)
def test_elastic_route_restricts_to_active_bijection(mem, step, seed):
    """Pipeline routing under churn: the route permutation is the identity on
    inactives and a bijection on actives; full membership reproduces the
    static routing draw bit for bit."""
    route = pairing.elastic_route_permutation(step, mem, seed=seed)
    active = sorted(mem.active_ids)
    assert sorted(int(route[i]) for i in active) == active
    for i in range(mem.world):
        if i not in set(active):
            assert route[i] == i
    if mem.is_full:
        np.testing.assert_array_equal(
            route, np.asarray(pairing.pairing_permutation(step, mem.world, seed=seed))
        )


@given(mem=memberships(min_world=2, max_world=16), horizon=st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_stream_assignment_covers_all_streams(mem, horizon):
    """Elastic data reassignment: at every step each survivor reads exactly
    one stream, no stream is read twice in a step, and over a full cycle the
    survivors' reads cover EVERY stream (dropped data is consumed, not
    lost)."""
    from repro.core.elastic import stream_assignment

    world = mem.world
    actives = list(mem.active_ids)
    seen = set()
    # world steps always exceed the longest per-survivor pool cycle
    for t in range(max(horizon, world)):
        table = stream_assignment(mem, t)
        picks = [int(table[a]) for a in actives]
        assert len(picks) == len(set(picks))  # no stream read twice
        seen.update(picks)
    assert seen == set(range(world))
    if mem.is_full:
        np.testing.assert_array_equal(stream_assignment(mem, 3), np.arange(world))
