"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _qkv(b, sq, sk, h, kv, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, d), jnp.float32).astype(dtype)
    return q, k, v


def _gold_attention(q, k, v, mode, window):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    hm = (jnp.arange(h) * kvh) // h
    ke, ve = jnp.take(k, hm, 2), jnp.take(v, hm, 2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = ke.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = ve.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    g = ref.reference_attention(qf, kf, vf, mode=mode, window=window)
    return g.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("shape", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 4, 2, 64),   # GQA
    (1, 256, 256, 2, 1, 128),  # MQA, d=128
    (1, 200, 200, 2, 2, 64),   # non-block-multiple
    (1, 128, 384, 2, 2, 64),   # cross lengths
])
@pytest.mark.parametrize("mode,window", [("causal", 0), ("local", 64), ("full", 0)])
def test_flash_attention_sweep(shape, mode, window):
    b, sq, sk, h, kv, d = shape
    q, k, v = _qkv(b, sq, sk, h, kv, d, jnp.float32)
    out = ops.flash_attention(q, k, v, mode=mode, window=window)
    gold = _gold_attention(q, k, v, mode, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q, k, v = _qkv(1, 128, 128, 4, 2, 64, dtype)
    out = ops.flash_attention(q, k, v, mode="causal")
    gold = _gold_attention(q, k, v, "causal", 0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(gold, np.float32), atol=atol, rtol=1e-2
    )


@pytest.mark.parametrize("n", [100, 4096, 10_000, 50_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_noloco_update_sweep(n, dtype):
    args = [
        jax.random.normal(jax.random.fold_in(KEY, i), (n,), jnp.float32).astype(dtype)
        for i in range(5)
    ]
    p1, d1 = ops.noloco_update_pytree(
        {"w": args[0]}, {"w": args[1]}, {"w": args[2]}, {"w": args[3]}, {"w": args[4]},
        alpha=0.5, beta=0.7, gamma=1.0,
    )
    p2, d2 = ref.reference_noloco_update(*args, alpha=0.5, beta=0.7, gamma=1.0)
    atol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(p1["w"], np.float32), np.asarray(p2, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(d1["w"], np.float32), np.asarray(d2, np.float32), atol=atol)


def test_noloco_kernel_matches_outer_module():
    """Kernel must agree with the core outer optimizer (same Eq. 1-3)."""
    from repro.core import outer as outer_lib

    n = 1000
    args = [jax.random.normal(jax.random.fold_in(KEY, 10 + i), (n,)) for i in range(5)]
    theta, phi, dmom, theta_p, phi_p = args
    p1, d1 = ops.noloco_update_pytree(
        {"w": theta}, {"w": phi}, {"w": dmom}, {"w": theta_p}, {"w": phi_p},
        alpha=0.5, beta=0.7, gamma=1.0,
    )
    mean_d = {"w": 0.5 * ((theta - phi) + (theta_p - phi_p))}
    mean_phi = {"w": 0.5 * (phi + phi_p)}
    p2, d2 = outer_lib.noloco_momentum_update(
        {"w": phi}, {"w": dmom}, mean_d, mean_phi, alpha=0.5, beta=0.7, gamma=1.0
    )
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(d1["w"]), np.asarray(d2["w"]), atol=1e-5)


@pytest.mark.parametrize("shape", [
    (1, 64, 2, 16, 8, 32),
    (2, 96, 2, 16, 8, 32),    # pad (96 = 3 chunks of 32)
    (1, 130, 1, 8, 4, 64),    # non-multiple length
])
def test_ssd_chunk_kernel_sweep(shape):
    b, s, h, p, n, chunk = shape
    x = jax.random.normal(jax.random.fold_in(KEY, 20), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 21), (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 22), (h,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(KEY, 23), (b, s, n)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(KEY, 24), (b, s, n)) * 0.5
    y1, f1 = ops.ssd_chunk(x, dt, a, bm, cm, chunk=chunk)
    y2, f2 = ref.reference_ssd(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=2e-4, rtol=1e-3)


def test_models_ssd_matches_oracle_too():
    """The jnp production path (models/ssd.ssd_chunked) is the kernel's
    shape-twin; it must match the token-recurrence oracle as well."""
    from repro.models.ssd import ssd_chunked

    b, s, h, p, n = 2, 64, 2, 16, 8
    x = jax.random.normal(jax.random.fold_in(KEY, 30), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 31), (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 32), (h,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(KEY, 33), (b, s, n)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(KEY, 34), (b, s, n)) * 0.5
    y1, f1 = ssd_chunked(x, dt, a, bm, cm, 16)
    y2, f2 = ref.reference_ssd(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=1e-3)
