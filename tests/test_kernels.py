"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle.

Dispatch-level parity (pallas vs jnp twin per registered op, gradients,
end-to-end toy-LM) lives in tests/test_dispatch.py; here each Pallas kernel
is pinned explicitly and checked against the naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dispatch import KernelConfig

KEY = jax.random.PRNGKey(0)
PALLAS = KernelConfig(impl="pallas", interpret=True)


def _qkv(b, sq, sk, h, kv, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, d), jnp.float32).astype(dtype)
    return q, k, v


def _gold_attention(q, k, v, mode, window):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    hm = (jnp.arange(h) * kvh) // h
    ke, ve = jnp.take(k, hm, 2), jnp.take(v, hm, 2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = ke.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = ve.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    g = ref.reference_attention(qf, kf, vf, mode=mode, window=window)
    return g.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("shape", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 4, 2, 64),   # GQA (grouped fold, no K/V expansion)
    (1, 256, 256, 2, 1, 128),  # MQA, d=128
    (1, 200, 200, 2, 2, 64),   # non-block-multiple
    (1, 128, 384, 2, 2, 64),   # cross lengths
])
@pytest.mark.parametrize("mode,window", [("causal", 0), ("local", 64), ("full", 0)])
def test_flash_attention_sweep(shape, mode, window):
    b, sq, sk, h, kv, d = shape
    q, k, v = _qkv(b, sq, sk, h, kv, d, jnp.float32)
    out = ops.flash_attention(q, k, v, mode=mode, window=window, config=PALLAS)
    gold = _gold_attention(q, k, v, mode, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q, k, v = _qkv(1, 128, 128, 4, 2, 64, dtype)
    out = ops.flash_attention(q, k, v, mode="causal", config=PALLAS)
    gold = _gold_attention(q, k, v, "causal", 0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(gold, np.float32), atol=atol, rtol=1e-2
    )


@pytest.mark.parametrize("n", [100, 4096, 10_000, 50_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_noloco_update_sweep(n, dtype):
    phi, dmom, mean_d, mean_phi = [
        jax.random.normal(jax.random.fold_in(KEY, i), (n,), jnp.float32).astype(dtype)
        for i in range(4)
    ]
    p1, d1 = ops.noloco_update_pytree(
        {"w": phi}, {"w": dmom}, {"w": mean_d}, {"w": mean_phi},
        alpha=0.5, beta=0.7, gamma=1.0, config=PALLAS,
    )
    p2, d2 = ref.reference_noloco_update(
        phi, dmom, mean_d, mean_phi, alpha=0.5, beta=0.7, gamma=1.0
    )
    atol = 1e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(p1["w"], np.float32), np.asarray(p2, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(d1["w"], np.float32), np.asarray(d2, np.float32), atol=atol)


def test_noloco_kernel_matches_outer_module():
    """Kernel must agree with the core outer optimizer (same Eqs. 2-3)."""
    from repro.core import outer as outer_lib

    n = 1000
    args = [jax.random.normal(jax.random.fold_in(KEY, 10 + i), (n,)) for i in range(5)]
    theta, phi, dmom, theta_p, phi_p = args
    mean_d = {"w": 0.5 * ((theta - phi) + (theta_p - phi_p))}
    mean_phi = {"w": 0.5 * (phi + phi_p)}
    p1, d1 = ops.noloco_update_pytree(
        {"w": phi}, {"w": dmom}, mean_d, mean_phi,
        alpha=0.5, beta=0.7, gamma=1.0, config=PALLAS,
    )
    p2, d2 = outer_lib.noloco_momentum_update(
        {"w": phi}, {"w": dmom}, mean_d, mean_phi, alpha=0.5, beta=0.7, gamma=1.0
    )
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(d1["w"]), np.asarray(d2["w"]), atol=1e-5)


@pytest.mark.parametrize("shape", [
    (1, 64, 2, 16, 8, 32),
    (2, 96, 2, 16, 8, 32),    # pad (96 = 3 chunks of 32)
    (1, 130, 1, 8, 4, 64),    # non-multiple length
])
def test_ssd_chunk_kernel_sweep(shape):
    b, s, h, p, n, chunk = shape
    x = jax.random.normal(jax.random.fold_in(KEY, 20), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 21), (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 22), (h,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(KEY, 23), (b, s, n)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(KEY, 24), (b, s, n)) * 0.5
    y1, f1 = ops.ssd_chunk(x, dt, a, bm, cm, chunk=chunk, config=PALLAS)
    y2, f2 = ref.reference_ssd(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("shape", [
    (2, 64, 32),
    (1, 300, 128),   # seq pad (300 -> 2 chunks of 256)
    (2, 257, 130),   # seq + width pad
])
def test_rglru_scan_kernel_sweep(shape):
    b, s, w = shape
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 40), (b, s, w))) * 0.5 + 0.45
    bb = jax.random.normal(jax.random.fold_in(KEY, 41), (b, s, w)) * 0.3
    h1 = ops.rglru_scan(a, bb, config=PALLAS)
    h2 = ref.jnp_rglru_scan(a, bb)
    # serial oracle
    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h
    _, h3 = jax.lax.scan(step, jnp.zeros((b, w)), (a.transpose(1, 0, 2), bb.transpose(1, 0, 2)))
    h3 = h3.transpose(1, 0, 2)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h3), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h3), atol=1e-5, rtol=1e-5)


def test_int8_kernel_roundtrip():
    x = jax.random.normal(jax.random.fold_in(KEY, 50), (37, 256))
    q, scale, lo = ops.int8_quantize(x, config=PALLAS)
    qj, sj, lj = ref.jnp_int8_quantize(x)
    # reduction-order float differences may flip a rounding boundary: q within
    # one level, metadata tight, decode within one quantization step
    assert int(jnp.abs(q.astype(jnp.int32) - qj.astype(jnp.int32)).max()) <= 1
    np.testing.assert_allclose(np.asarray(scale), np.asarray(sj), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(lj), rtol=1e-6, atol=1e-6)
    dec = ops.int8_dequantize(q, scale, lo, config=PALLAS)
    err = jnp.abs(dec - x)
    assert float((err - 1.01 * scale[:, None]).max()) <= 0.0


def test_models_ssd_matches_oracle_too():
    """The model-level wrapper (models/ssd.ssd_chunked) delegates to the
    dispatched op; it must match the token-recurrence oracle as well."""
    from repro.models.ssd import ssd_chunked

    b, s, h, p, n = 2, 64, 2, 16, 8
    x = jax.random.normal(jax.random.fold_in(KEY, 30), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 31), (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 32), (h,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(KEY, 33), (b, s, n)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(KEY, 34), (b, s, n)) * 0.5
    y1, f1 = ssd_chunked(x, dt, a, bm, cm, 16)
    y2, f2 = ref.reference_ssd(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=1e-3)
