"""Random pipeline routing (paper §3.1/§5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM
from repro.models.config import ModelConfig
from repro.pipeline import PipelineTrainer

CFG = ModelConfig(num_layers=2, d_model=48, num_heads=4, num_kv_heads=4,
                  d_ff=96, vocab_size=64, dtype="float32", remat=False)


def _batches(n, R=4, B=2, S=24, seed=0):
    lm = SyntheticLM(64, seed=seed)
    for t in range(n):
        toks = np.stack([
            lm.sample_tokens(r * 911 + t, B * (S + 1)).reshape(B, S + 1)
            for r in range(R)
        ])
        yield {"tokens": jnp.asarray(toks[:, :, :-1]), "labels": jnp.asarray(toks[:, :, 1:])}


def test_routes_are_permutations_and_vary():
    tr = PipelineTrainer(CFG, num_stages=2, replicas=4, routing="random")
    r0 = tr.routes(0)[0]
    r1 = tr.routes(1)[0]
    assert sorted(np.asarray(r0).tolist()) == [0, 1, 2, 3]
    routes = {tuple(np.asarray(tr.routes(s)[0]).tolist()) for s in range(10)}
    assert len(routes) > 3
    fixed = PipelineTrainer(CFG, num_stages=2, replicas=4, routing="fixed")
    assert (np.asarray(fixed.routes(0)[0]) == np.arange(4)).all()


def test_fixed_routing_equals_independent_runs():
    """With fixed routing and no outer sync, replica r's params depend only
    on replica r's data (the §5.2 baseline)."""
    tr = PipelineTrainer(CFG, num_stages=2, replicas=2, routing="fixed")
    st = tr.init(jax.random.PRNGKey(0))
    for batch in _batches(3, R=2):
        st, _ = tr.train_step(st, batch)
    # swap replica 1's data -> replica 0 params must be unchanged
    tr2 = PipelineTrainer(CFG, num_stages=2, replicas=2, routing="fixed")
    st2 = tr2.init(jax.random.PRNGKey(0))
    for batch in _batches(3, R=2, seed=0):
        b2 = {k: v.at[1].set(jnp.roll(v[1], 3, axis=-1)) for k, v in batch.items()}
        st2, _ = tr2.train_step(st2, b2)
    w1 = jax.tree.leaves(st["params"][0])[0]
    w2 = jax.tree.leaves(st2["params"][0])[0]
    np.testing.assert_allclose(np.asarray(w1[0]), np.asarray(w2[0]), atol=1e-6)
    assert np.abs(np.asarray(w1[1]) - np.asarray(w2[1])).max() > 1e-6


def test_random_routing_trains():
    tr = PipelineTrainer(CFG, num_stages=2, replicas=4, routing="random")
    st = tr.init(jax.random.PRNGKey(0))
    losses = []
    for batch in _batches(25):
        st, loss = tr.train_step(st, batch)
        losses.append(loss)
    assert losses[-1] < losses[0] * 0.8


def test_routing_invisible_when_replicas_identical():
    """With identical replica weights the route cannot change the loss —
    routing only mixes WHICH replica computes, not WHAT is computed."""
    tr = PipelineTrainer(CFG, num_stages=2, replicas=4, routing="random")
    st = tr.init(jax.random.PRNGKey(0))  # init broadcasts identical weights
    batch = next(_batches(1))
    l_fixed = float(tr.loss(st["params"], batch, [jnp.arange(4)]))
    l_routed = float(tr.loss(st["params"], batch, [jnp.asarray([2, 3, 0, 1])]))
    assert abs(l_fixed - l_routed) < 1e-5


def test_gradients_follow_forward_route():
    """Swapping the route permutes WHICH stage-1 replica accumulates each
    microbatch's gradient: grads under route [1,0] equal grads under identity
    with the stage-1 replica axis swapped (after making weights distinct)."""
    tr = PipelineTrainer(CFG, num_stages=2, replicas=2, routing="random")
    st = tr.init(jax.random.PRNGKey(0))
    params = st["params"]
    # make stage-1 replicas distinct so the check is non-trivial
    params[1] = jax.tree.map(
        lambda v: v * (1.0 + 0.05 * jnp.arange(2).reshape((2,) + (1,) * (v.ndim - 1))),
        params[1],
    )
    batch = next(_batches(1, R=2))
    swap = jnp.asarray([1, 0])
    g_id = jax.grad(lambda ps: tr.loss(ps, batch, [jnp.arange(2)]))(params)
    params_sw = [params[0], jax.tree.map(lambda v: v[swap], params[1])]
    g_sw = jax.grad(lambda ps: tr.loss(ps, batch, [swap]))(params_sw)
    for a, b in zip(jax.tree.leaves(g_id[1]), jax.tree.leaves(g_sw[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b[swap]), atol=1e-5)
