"""Integration: the full GossipTrainer on a real (tiny) LM — NoLoCo vs DiLoCo
vs FSDP, plus paper-claim sanity checks at micro scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GossipTrainer, OuterConfig, TrainerConfig
from repro.launch.train import run_training
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig

TINY = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.mark.parametrize("method", ["noloco", "diloco", "fsdp"])
def test_methods_train_tiny_lm(method):
    res = run_training(
        TINY, method=method, replicas=4, per_replica_batch=2, seq_len=32,
        steps=30, inner_lr=3e-3, inner_steps=10, eval_every=0,
    )
    assert res["losses"][-1] < res["losses"][0] * 0.85, res["losses"][:3] + res["losses"][-3:]


def test_noloco_controls_weight_divergence():
    """Without any sync replicas drift apart; NoLoCo's γ term plus pair
    averaging keeps the std materially smaller (paper Fig. 3B premise)."""
    kw = dict(replicas=4, per_replica_batch=2, seq_len=32, steps=40,
              inner_lr=3e-3, inner_steps=10)
    none = run_training(TINY, method="none", **kw)
    noloco = run_training(TINY, method="noloco", **kw)
    assert noloco["final_weight_std"] < 0.7 * none["final_weight_std"], (
        noloco["final_weight_std"], none["final_weight_std"]
    )


def test_fsdp_keeps_replicas_identical():
    res = run_training(TINY, method="fsdp", replicas=4, per_replica_batch=2,
                       seq_len=32, steps=10, inner_lr=3e-3)
    assert res["final_weight_std"] < 1e-6


def test_outer_state_reset_semantics():
    """After an outer step, fast weights are reset to new slow weights."""
    def loss_fn(params, batch, rng):
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2)

    cfg = TrainerConfig(outer=OuterConfig(inner_steps=2),
                        inner=AdamWConfig(lr=1e-2, weight_decay=0.0))
    tr = GossipTrainer(cfg, loss_fn)
    key = jax.random.PRNGKey(0)
    st = tr.init({"w": jax.random.normal(key, (4, 8, 1))})
    batch = (jax.random.normal(key, (4, 16, 8)), jnp.zeros((4, 16, 1)))
    for _ in range(2):
        st, _ = tr.inner_step(st, batch, key)
    st = tr.outer_step(st)
    np.testing.assert_allclose(np.asarray(st.theta["w"]), np.asarray(st.outer.phi["w"]))
