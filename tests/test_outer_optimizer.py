"""NoLoCo/DiLoCo outer optimizer math (paper §3.2, Eq. 1-3, Eq. 74)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import outer as outer_lib
from repro.core.outer import OuterConfig


def _mk_state(world=4, dim=8, seed=0):
    key = jax.random.PRNGKey(seed)
    phi = {"w": jax.random.normal(key, (world, dim))}
    theta = {"w": phi["w"] + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (world, dim))}
    return outer_lib.init_outer_state(phi), theta


def test_gamma_band_eq74():
    lo, hi = outer_lib.gamma_band(0.5, 2)
    assert lo == pytest.approx(0.5)
    assert hi == pytest.approx(np.sqrt(2.25))
    g = outer_lib.default_gamma(0.5)
    assert lo < g < hi


def test_invalid_gamma_rejected():
    with pytest.raises(ValueError):
        OuterConfig(method="noloco", alpha=0.5, gamma=0.1).validate()
    with pytest.raises(ValueError):
        OuterConfig(method="noloco", alpha=0.5, gamma=5.0).validate()


def test_beta_must_exceed_alpha():
    with pytest.raises(ValueError):
        OuterConfig(method="diloco", alpha=0.9, beta=0.5).validate()


def test_diloco_reduces_to_group_of_all():
    """With the group = ALL replicas, NoLoCo's Eq. 2 == DiLoCo (γ term
    vanishes because φ_i == mean φ when... here: check diloco directly)."""
    state, theta = _mk_state()
    cfg = OuterConfig(method="diloco", alpha=0.3, beta=0.7)
    new_state, new_theta = outer_lib.outer_step_stacked(state, theta, cfg)
    # manual: delta = beta * mean(theta - phi); phi' = phi + delta
    md = jnp.mean(theta["w"] - state.phi["w"], axis=0, keepdims=True)
    expect = state.phi["w"] + 0.7 * md
    np.testing.assert_allclose(new_state.phi["w"], expect, rtol=1e-5)
    np.testing.assert_allclose(new_theta["w"], expect, rtol=1e-5)


def test_noloco_pair_math():
    state, theta = _mk_state(world=4)
    partner = jnp.asarray([1, 0, 3, 2])
    cfg = OuterConfig(method="noloco", alpha=0.5, beta=0.7)
    g = cfg.resolved_gamma()
    new_state, _ = outer_lib.outer_step_stacked(state, theta, cfg, partner=partner)
    phi, th = state.phi["w"], theta["w"]
    d = th - phi
    i, j = 0, 1
    mean_d = 0.5 * (d[i] + d[j])
    mean_phi = 0.5 * (phi[i] + phi[j])
    delta = 0.7 * mean_d - g * (phi[i] - mean_phi)
    np.testing.assert_allclose(new_state.phi["w"][i], phi[i] + delta, rtol=1e-5)


def test_identical_replicas_stay_identical():
    """φ_{0,i} ≡ φ_0 and identical Δ ⇒ all replicas evolve identically
    (Lemma 1 sanity)."""
    key = jax.random.PRNGKey(0)
    phi0 = jax.random.normal(key, (6, 5))
    phi = {"w": jnp.broadcast_to(phi0[:1], (6, 5))}
    theta = {"w": phi["w"] + 0.3}
    state = outer_lib.init_outer_state(phi)
    cfg = OuterConfig(method="noloco")
    for t in range(3):
        state, theta = outer_lib.outer_step_stacked(state, theta, cfg)
        theta = {"w": theta["w"] + 0.1}  # same inner progress everywhere
    w = np.asarray(state.phi["w"])
    assert np.abs(w - w[0]).max() < 1e-5


def test_none_method_tracks_theta():
    state, theta = _mk_state()
    cfg = OuterConfig(method="none")
    new_state, new_theta = outer_lib.outer_step_stacked(state, theta, cfg)
    np.testing.assert_allclose(new_state.phi["w"], theta["w"])


def test_paper_sign_convention_diverges():
    """The literal '−β' of Eq. 2 diverges on the quadratic model while the
    appendix '+β' converges — this documents why we follow the appendix."""
    from repro.core import theory

    res = theory.simulate_quadratic(
        theory.QuadraticModel(), world=4, outer_steps=40, inner_steps=5, omega=0.1
    )
    assert res["mean_norm"][-1] < res["mean_norm"][0]  # + sign converges


def test_overlapped_outer_step_matches_baseline():
    """§3.2 φ-prefetch overlap: same numbers as the baseline gossip step when
    the prefetched φ equals the partner's current φ."""
    import jax
    from repro.core import pairing

    state, theta = _mk_state(world=4, seed=2)
    cfg = OuterConfig(method="noloco", alpha=0.5, beta=0.7)
    partner = jnp.asarray(pairing.partner_table(0, 4))
    base_state, _ = outer_lib.outer_step_stacked(state, theta, cfg, partner=partner)

    # stacked emulation of the overlapped variant: phi_prefetched = phi[partner]
    phi_p = {"w": jnp.take(state.phi["w"], partner, axis=0)}
    delta = outer_lib.outer_gradient(theta, state.phi)
    delta_p = {"w": jnp.take(delta["w"], partner, axis=0)}
    mean_d = {"w": 0.5 * (delta["w"] + delta_p["w"])}
    mean_phi = {"w": 0.5 * (state.phi["w"] + phi_p["w"])}
    phi_next, _ = outer_lib.noloco_momentum_update(
        state.phi, state.delta, mean_d, mean_phi,
        alpha=0.5, beta=0.7, gamma=cfg.resolved_gamma(),
    )
    np.testing.assert_allclose(
        np.asarray(base_state.phi["w"]), np.asarray(phi_next["w"]), rtol=1e-6
    )


def test_traced_step_without_partner_raises_clearly():
    """Partner derivation is host-side: under jit with no explicit partner
    table the old code died inside int(traced step); now it must raise a
    clear, actionable error (and work when the table IS passed)."""
    state, theta = _mk_state(world=4)
    cfg = OuterConfig(method="noloco")
    with pytest.raises(ValueError, match="traced step counter"):
        jax.jit(lambda s, t: outer_lib.outer_step_stacked(s, t, cfg))(state, theta)
    # explicit partner: jit-compatible
    partner = jnp.asarray([1, 0, 3, 2])
    new_state, _ = jax.jit(
        lambda s, t: outer_lib.outer_step_stacked(s, t, cfg, partner=partner)
    )(state, theta)
    assert int(new_state.step) == 1


def test_trainer_outer_step_traced_raises_clearly():
    """Same footgun through GossipTrainer.outer_step (used to call
    int(state.outer.step) unconditionally)."""
    from repro.core import GossipTrainer, TrainerConfig
    from repro.optim import AdamWConfig

    def loss_fn(params, batch, rng):
        return jnp.mean(params["w"] ** 2)

    tr = GossipTrainer(
        TrainerConfig(outer=OuterConfig(inner_steps=1),
                      inner=AdamWConfig(lr=1e-2, weight_decay=0.0)),
        loss_fn,
    )
    st = tr.init({"w": jax.random.normal(jax.random.PRNGKey(0), (4, 3))})
    with pytest.raises(ValueError, match="traced step counter"):
        jax.jit(tr.outer_step)(st)
    # eager (host-side step counter) still derives the pairing itself
    st2 = tr.outer_step(st)
    assert int(st2.outer.step) == 1


def test_fused_payload_matches_per_leaf(monkeypatch):
    """_fused_ppermute must be a pure re-layout: same values as per-leaf
    permutes (validated without devices by substituting a fake permute)."""
    import jax

    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": [jnp.ones((4,), jnp.float32) * 2, jnp.zeros((2, 2), jnp.float32)],
    }

    def fake_ppermute(x, axis_names, perm):
        return x + 100.0  # stand-in for "partner's values"

    monkeypatch.setattr(outer_lib.jax.lax, "ppermute", fake_ppermute)
    out = outer_lib._fused_ppermute(tree, ("data",), [(0, 1), (1, 0)])
    ref = jax.tree.map(lambda x: x + 100.0, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
