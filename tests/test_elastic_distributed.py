"""Elastic shard_map runtime: the per-membership-view program pool drives
``train_distributed`` through churn (ISSUE 5 acceptance).

Subprocess tests on 8 XLA-forced host devices (like test_multidevice.py);
the pure pool-key/pairing logic is tested in-process below them."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

pytestmark = pytest.mark.multidevice

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


PRELUDE = """
import json
import jax, jax.numpy as jnp, numpy as np
from repro.comm import CommConfig
from repro.core.elastic import ElasticContext
from repro.core.outer import OuterConfig
from repro.core.pairing import Membership
from repro.core import pairing
from repro.data import LoaderConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train_distributed import DistributedTrainer
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.parallel import plans as PL, steps as ST
from repro.parallel.compat import set_mesh
from repro.sim import FaultPlan, SimCluster
from repro.train import DistributedProgram, LoopConfig, make_loop

CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=128, dtype="float32", remat=False)

def make_trainer(elastic=None, schedule="random", inner_steps=4, seed=0,
                 stale="naive"):
    mesh = make_test_mesh(8, 1)
    plan = PL.make_plan("gossip_dp", mesh, shape_kind="train")
    return DistributedTrainer(
        cfg=CFG, mesh=mesh, plan=plan,
        outer_cfg=OuterConfig(method="noloco", inner_steps=inner_steps,
                              stale=stale),
        inner_cfg=AdamWConfig(lr=3e-3, weight_decay=0.0),
        schedule=schedule, seed=seed, elastic=elastic,
    )

def make_run(trainer, plan_events, steps, ckpt_dir=None, resume=False,
             eval_every=0, reassign=False, ckpt_every=0, async_clock=None):
    program = DistributedProgram(trainer)
    sim = None
    if plan_events is not None:
        sim = SimCluster(program, FaultPlan.build(plan_events),
                         reassign_data=reassign, async_clock=async_clock)
    loop = make_loop(
        sim or program,
        LoaderConfig(vocab_size=CFG.vocab_size, seq_len=32,
                     per_replica_batch=2, replicas=8, seed=0),
        LoopConfig(steps=steps, eval_every=eval_every, seed=0,
                   ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, resume=resume),
    )
    return loop, sim
"""


def test_full_membership_bit_identical_elastic_vs_static_vs_stacked():
    """At full membership the elastic pool program IS the static program
    (same compiled path), and both match the stacked outer step bit for bit
    where fp allows — the ISSUE 5 equality acceptance."""
    out = _run(PRELUDE + """
from repro.core import outer as outer_lib
from repro.models import model as M
from repro.models.common import unzip

mesh = make_test_mesh(8, 1)
plan = PL.make_plan("gossip_dp", mesh, shape_kind="train")
params = M.init_params(jax.random.PRNGKey(0), CFG)
stacked = ST.stack_replicas(params, plan.replicas)
vals, _ = unzip(stacked)
pspecs = PL.param_pspecs(plan, mesh, stacked)
ocfg = OuterConfig(method="noloco", inner_steps=4)

pool = ST.OuterProgramPool(plan, mesh, pspecs, ocfg, seed=0)
full = Membership.full(8)
# elastic pairs at full membership == static pairs, same pool key
slot, pairs_e = pool.pairs_for(3, full)
slot_s, pairs_s = pool.pairs_for(3, None)
assert slot == slot_s and pairs_e == pairs_s
# the full-membership view key is the STATIC key: same compiled program object
fn_e, info_e = pool.program(3, full)
fn_s, info_s = pool.program(3, None)
assert fn_e is fn_s and info_s["compiled"] is False

key = jax.random.PRNGKey(5)
theta_v = jax.tree.map(lambda x: x + jax.random.normal(key, x.shape) * 0.1, vals)
sh = PL.shardings(mesh, pspecs)
import jax.sharding as jsh
step_sh = jsh.NamedSharding(mesh, jsh.PartitionSpec("data"))
with set_mesh(mesh):
    theta = jax.device_put(theta_v, sh)
    phi = jax.device_put(vals, sh)
    delta = jax.tree.map(jnp.zeros_like, phi)
    stepc = jax.device_put(jnp.full((8,), 3, jnp.int32), step_sh)
    th2, phi2, d2, _ = fn_e(theta, phi, delta, stepc)

# stacked reference with the SAME pairing (pool slot 3)
partner = jnp.asarray(pairing.partner_table(slot, 8))
state = outer_lib.OuterState(phi=jax.device_get(vals),
                             delta=jax.tree.map(np.zeros_like, jax.device_get(vals)),
                             step=jnp.asarray(3, jnp.int32))
new_state, new_theta = outer_lib.outer_step_stacked(state, theta_v, ocfg, partner=partner)
for a, b in zip(jax.tree.leaves(jax.device_get(phi2)), jax.tree.leaves(new_state.phi)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(jax.tree.leaves(jax.device_get(th2)), jax.tree.leaves(new_theta)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("BIT IDENTICAL")
""")
    assert "BIT IDENTICAL" in out


def test_elastic_pool_program_freezes_inactive_and_matches_stacked():
    """Under churn the pool compiles a membership-view program whose result
    matches the stacked elastic outer step bit for bit: participants gossip
    over the elastic pairing, dropped replicas' (θ, φ, δ) pass through."""
    out = _run(PRELUDE + """
from repro.core import outer as outer_lib
from repro.models import model as M
from repro.models.common import unzip

mesh = make_test_mesh(8, 1)
plan = PL.make_plan("gossip_dp", mesh, shape_kind="train")
params = M.init_params(jax.random.PRNGKey(0), CFG)
stacked = ST.stack_replicas(params, plan.replicas)
vals, _ = unzip(stacked)
pspecs = PL.param_pspecs(plan, mesh, stacked)
ocfg = OuterConfig(method="noloco", inner_steps=4)
pool = ST.OuterProgramPool(plan, mesh, pspecs, ocfg, seed=0)

mem = Membership.full(8).drop([3, 5])
slot, pairs = pool.pairs_for(2, mem)
fn, info = pool.program(2, mem)
assert info["compiled"] is True

key = jax.random.PRNGKey(7)
theta_v = jax.tree.map(lambda x: x + jax.random.normal(key, x.shape) * 0.1, vals)
delta_v = jax.tree.map(lambda x: jnp.zeros_like(x), vals)
sh = PL.shardings(mesh, pspecs)
import jax.sharding as jsh
step_sh = jsh.NamedSharding(mesh, jsh.PartitionSpec("data"))
with set_mesh(mesh):
    th2, phi2, d2, _ = fn(
        jax.device_put(theta_v, sh), jax.device_put(vals, sh),
        jax.device_put(delta_v, sh),
        jax.device_put(jnp.full((8,), 2, jnp.int32), step_sh),
    )

partner = jnp.asarray(pairing.elastic_partner_table(slot, mem, seed=0))
state = outer_lib.OuterState(phi=jax.device_get(vals),
                             delta=jax.device_get(delta_v),
                             step=jnp.asarray(2, jnp.int32))
new_state, new_theta = outer_lib.outer_step_stacked(
    state, theta_v, ocfg, partner=partner,
    active=jnp.asarray(mem.active_array()))
for a, b in zip(jax.tree.leaves(jax.device_get(phi2)), jax.tree.leaves(new_state.phi)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(jax.tree.leaves(jax.device_get(th2)), jax.tree.leaves(new_theta)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# dropped rows really froze: compare against the program INPUTS
for got, orig in zip(jax.tree.leaves(jax.device_get(th2)), jax.tree.leaves(theta_v)):
    np.testing.assert_array_equal(np.asarray(got)[3], np.asarray(orig)[3])
    np.testing.assert_array_equal(np.asarray(got)[5], np.asarray(orig)[5])
print("ELASTIC MATCH")
""")
    assert "ELASTIC MATCH" in out


def test_acceptance_distributed_drop2_rejoin(tmp_path):
    """ISSUE 5 acceptance: 8-replica ``train_distributed`` under the
    drop-2/rejoin plan completes with ≤ pool-bound recompiles, lands its
    final eval within 5% of the healthy run, and resume-after-churn
    reproduces the uninterrupted trajectory exactly."""
    d = str(tmp_path / "dist_elastic")
    out = _run(PRELUDE + f"""
EVENTS = [
    {{"kind": "drop", "round": 1, "replicas": [3, 5]}},
    {{"kind": "rejoin", "round": 4, "replicas": [3, 5]}},
]
STEPS, M_INNER = 24, 4

# healthy baseline
t0 = make_trainer(elastic=ElasticContext(world=8))
loop0, _ = make_run(t0, [], STEPS, eval_every=STEPS)
healthy = loop0.run()

# faulted run (checkpointing at step 12, mid-churn — rounds 1-2 done,
# the rejoin still pending — so the resume leg below restarts from there)
t1 = make_trainer(elastic=ElasticContext(world=8))
loop1, sim1 = make_run(t1, EVENTS, STEPS, eval_every=STEPS,
                       ckpt_dir={d!r}, ckpt_every=12)
res = loop1.run()
stats = t1.pool.stats()
assert stats["misses"] <= stats["max_programs_per_view"] * 3 + 1, stats
assert np.isfinite(res["losses"]).all()
he, fe = healthy["evals"][-1][1], res["evals"][-1][1]
assert abs(fe - he) / he < 0.05, (fe, he)
rounds = sim1.rounds()
by_round = {{r["round"]: r for r in rounds}}
for k in (1, 2, 3):
    assert by_round[k]["active"] == [0, 1, 2, 4, 6, 7], by_round[k]
    assert by_round[k]["partner"][3] == 3 and by_round[k]["partner"][5] == 5
for k in (0, 4, 5):
    assert by_round[k]["active"] == list(range(8)), by_round[k]
assert sim1.membership.epoch == 2 and sim1.membership.is_full

# resume from the step-12 checkpoint (written with 6 actives): the
# continued run must reproduce the uninterrupted faulted trajectory exactly
import os, shutil
for name in os.listdir({d!r}):
    if name != "step_00000012":
        shutil.rmtree(os.path.join({d!r}, name))
t3 = make_trainer(elastic=ElasticContext(world=8))
loop3, sim3 = make_run(t3, EVENTS, STEPS, ckpt_dir={d!r}, resume=True)
cont = loop3.run()
assert cont["start_step"] == 12
np.testing.assert_array_equal(np.asarray(res["losses"][12:]),
                              np.asarray(cont["losses"]))
for a, b in zip(jax.tree.leaves(jax.device_get(res["state"]["theta"])),
                jax.tree.leaves(jax.device_get(cont["state"]["theta"]))):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert sim3.membership.epoch == 2 and sim3.membership.is_full
print("ACCEPTANCE OK", json.dumps(stats))
""")
    assert "ACCEPTANCE OK" in out


def test_hypercube_schedule_bounded_pool_under_churn():
    """The hypercube schedule compiles ≤ log2(world) programs per membership
    view while training through a drop."""
    out = _run(PRELUDE + """
EVENTS = [{"kind": "drop", "round": 1, "replicas": [2]}]
t = make_trainer(elastic=ElasticContext(world=8), schedule="hypercube",
                 inner_steps=2)
loop, sim = make_run(t, EVENTS, 16)
res = loop.run()
stats = t.pool.stats()
assert stats["max_programs_per_view"] == 3
# two views seen (full, minus-2): ≤ 3 programs each
assert stats["pool_size"] <= 6, stats
assert np.isfinite(res["losses"]).all()
# post-drop rounds never touch replica 2
for r in sim.rounds():
    if r["round"] >= 1:
        assert r["partner"][2] == 2
print("HYPERCUBE OK", json.dumps(stats))
""")
    assert "HYPERCUBE OK" in out


def test_distributed_reassign_data_deterministic():
    """Elastic data reassignment on the shard_map runtime: survivors consume
    dropped streams deterministically — two identical runs produce identical
    losses, and differ from the skip-streams default."""
    out = _run(PRELUDE + """
EVENTS = [{"kind": "drop", "round": 1, "replicas": [0, 1]}]
runs = []
for reassign in (True, True, False):
    t = make_trainer(elastic=ElasticContext(world=8), inner_steps=2)
    loop, _ = make_run(t, EVENTS, 8, reassign=reassign)
    runs.append(loop.run()["losses"])
np.testing.assert_array_equal(np.asarray(runs[0]), np.asarray(runs[1]))
assert not np.array_equal(np.asarray(runs[0][3:]), np.asarray(runs[2][3:]))
print("REASSIGN OK")
""")
    assert "REASSIGN OK" in out


def test_async_clock_distributed_tau0_bitwise_and_straggler():
    """Asynchronous round clocks on the shard_map runtime: a rate-1 async
    world reduces to the legacy synchronous program bit for bit (same pool
    fast path), and a 2x straggler syncs late with a stale Δ — zero blocked
    syncs, max τ = 1 — for both stale rules."""
    out = _run(PRELUDE + """
# legacy synchronous reference
t0 = make_trainer(elastic=ElasticContext(world=8), inner_steps=2)
loop0, _ = make_run(t0, [], 12)
ref = loop0.run()

# rate-1 async world: bitwise identical, zero staleness telemetry
t1 = make_trainer(elastic=ElasticContext(world=8), inner_steps=2)
loop1, sim1 = make_run(t1, [], 12, async_clock=True)
res = loop1.run()
np.testing.assert_array_equal(np.asarray(ref["losses"]), np.asarray(res["losses"]))
for a, b in zip(jax.tree.leaves(jax.device_get(ref["state"]["theta"])),
                jax.tree.leaves(jax.device_get(res["state"]["theta"]))):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert res["max_staleness"] == 0 and res["blocked_syncs"] == 0, res

# 2x straggler on its own clock, both stale rules
EVENTS = [{"kind": "rate", "round": 0, "replicas": [1], "rate": 0.5}]
for stale in ("naive", "momentum"):
    t2 = make_trainer(elastic=ElasticContext(world=8), inner_steps=2,
                      stale=stale)
    loop2, sim2 = make_run(t2, EVENTS, 16)
    r2 = loop2.run()
    assert np.isfinite(r2["losses"]).all()
    assert r2["blocked_syncs"] == 0, (stale, r2["blocked_syncs"])
    assert r2["max_staleness"] == 1, (stale, r2["max_staleness"])
    ticks = [h for h in sim2.history if h.get("event") == "round"]
    assert any(1 not in h["due"] for h in ticks)   # straggler skipped a tick
    assert any(1 in h["due"] and h["staleness"][1] == 1 for h in ticks)
print("ASYNC DISTRIBUTED OK")
""")
    assert "ASYNC DISTRIBUTED OK" in out


def test_partial_partition_matches_stacked_semantics():
    """A partition that covers only part of the active set: uncovered actives
    must run the self-momentum path (matching the stacked runtime bit for
    bit), NOT freeze — regression test for the participant-mask derivation."""
    out = _run(PRELUDE + """
from repro.core import outer as outer_lib
from repro.models import model as M
from repro.models.common import unzip
import jax.sharding as jsh

mesh = make_test_mesh(8, 1)
plan = PL.make_plan("gossip_dp", mesh, shape_kind="train")
stacked = ST.stack_replicas(M.init_params(jax.random.PRNGKey(0), CFG), 8)
vals, _ = unzip(stacked)
pspecs = PL.param_pspecs(plan, mesh, stacked)
ocfg = OuterConfig(method="noloco", inner_steps=4)
pool = ST.OuterProgramPool(plan, mesh, pspecs, ocfg, seed=0)

mem = Membership.full(8)
groups = ((0, 1, 2),)  # actives 3..7 uncovered: sit out, self-momentum
slot, pairs = pool.pairs_for(1, mem, groups)
fn, info = pool.program(1, mem, groups)
key = jax.random.PRNGKey(9)
theta_v = jax.tree.map(lambda x: x + jax.random.normal(key, x.shape) * 0.1, vals)
sh = PL.shardings(mesh, pspecs)
step_sh = jsh.NamedSharding(mesh, jsh.PartitionSpec("data"))
with set_mesh(mesh):
    th2, phi2, d2, _ = fn(jax.device_put(theta_v, sh), jax.device_put(vals, sh),
                          jax.device_put(jax.tree.map(jnp.zeros_like, vals), sh),
                          jax.device_put(jnp.full((8,), 1, jnp.int32), step_sh))
partner = jnp.asarray(pairing.elastic_partner_table(1, mem, seed=0, groups=groups))
state = outer_lib.OuterState(phi=jax.device_get(vals),
                             delta=jax.tree.map(np.zeros_like, jax.device_get(vals)),
                             step=jnp.asarray(1, jnp.int32))
new_state, new_theta = outer_lib.outer_step_stacked(
    state, theta_v, ocfg, partner=partner, active=jnp.asarray(mem.active_array()))
for a, b in zip(jax.tree.leaves(jax.device_get(phi2)), jax.tree.leaves(new_state.phi)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(jax.tree.leaves(jax.device_get(th2)), jax.tree.leaves(new_theta)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
got = np.asarray(jax.tree.leaves(jax.device_get(th2))[0])
orig = np.asarray(jax.tree.leaves(theta_v)[0])
assert not np.array_equal(got[4], orig[4]), "uncovered active must not freeze"
print("PARTIAL PARTITION OK")
""")
    assert "PARTIAL PARTITION OK" in out
