"""Hypothesis property suite for the stream partitioner and schedule
(streaming outer steps, DESIGN.md §2).  Skipped when hypothesis is absent —
same gating as tests/test_properties.py."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.comm import make_spec, pack, stream_partition
from repro.comm.payload import unpack_onto
from repro.core.outer import StreamSchedule


def _tree(sizes, dtypes=None):
    """Deterministic mixed-shape pytree from a list of leaf sizes (same
    helper as tests/test_streaming.py — duplicated, tests aren't a package)."""
    dtypes = dtypes or ["float32"] * len(sizes)
    key = jax.random.PRNGKey(0)
    out = {}
    for i, (n, dt) in enumerate(zip(sizes, dtypes)):
        k = jax.random.fold_in(key, i)
        shape = (n,) if n else ()
        if jnp.issubdtype(jnp.dtype(dt), jnp.floating):
            out[f"l{i:02d}"] = jax.random.normal(k, shape).astype(dt)
        else:
            out[f"l{i:02d}"] = jnp.arange(max(n, 1), dtype=dt).reshape(shape)
    return out


leaf_sizes = st.lists(st.integers(0, 64), min_size=1, max_size=12)


@given(sizes=leaf_sizes, streams=st.integers(1, 8), fuse=st.booleans())
@settings(max_examples=40, deadline=None)
def test_partition_disjoint_and_exhaustive(sizes, streams, fuse):
    """Every global leaf lands in exactly one stream, streams are contiguous
    in flatten order, and the union of per-stream specs is the whole payload."""
    tree = jax.eval_shape(lambda: _tree(sizes))
    part = stream_partition(tree, streams, fuse=fuse)
    assert part.stream_count == streams
    assert len(part.leaf_stream) == part.num_leaves == len(sizes)
    # contiguous: leaf→stream is non-decreasing in flatten order
    assert list(part.leaf_stream) == sorted(part.leaf_stream)
    covered = [i for k in range(streams) for i in part.leaf_indices(k)]
    assert sorted(covered) == list(range(len(sizes)))
    assert len(covered) == len(set(covered))
    assert part.nbytes == make_spec(tree, fuse=fuse).nbytes


@given(sizes=leaf_sizes, streams=st.integers(1, 8), fuse=st.booleans())
@settings(max_examples=30, deadline=None)
def test_partition_deterministic(sizes, streams, fuse):
    """Same (spec, stream_count) → identical partition, call after call."""
    tree = jax.eval_shape(lambda: _tree(sizes))
    a = stream_partition(tree, streams, fuse=fuse)
    b = stream_partition(tree, streams, fuse=fuse)
    assert a.leaf_stream == b.leaf_stream
    assert [s.buffers for s in a.specs] == [s.buffers for s in b.specs]


@given(sizes=leaf_sizes, fuse=st.booleans())
@settings(max_examples=30, deadline=None)
def test_partition_single_stream_is_fused_payload(sizes, fuse):
    """stream_count=1 reproduces today's whole-payload spec exactly."""
    tree = jax.eval_shape(lambda: _tree(sizes))
    part = stream_partition(tree, 1, fuse=fuse)
    assert part.specs[0].buffers == make_spec(tree, fuse=fuse).buffers
    assert part.leaf_stream == (0,) * len(sizes)


@given(sizes=leaf_sizes, streams=st.integers(1, 6), world=st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_partition_scale_invariant_under_stacking(sizes, streams, world):
    """Adding a leading replica axis to every leaf (the stacked runtime's
    layout) scales all midpoints uniformly, so the leaf→stream assignment is
    unchanged — the invariant that lets the distributed trainer key its
    partition off the stacked struct."""
    tree = jax.eval_shape(lambda: _tree(sizes))
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((world,) + s.shape, s.dtype), tree
    )
    assert (
        stream_partition(tree, streams).leaf_stream
        == stream_partition(stacked, streams).leaf_stream
    )


@given(streams=st.integers(1, 5), fuse=st.booleans())
@settings(max_examples=15, deadline=None)
def test_stream_pack_unpack_roundtrip_identity(streams, fuse):
    """Per-stream pack → unpack_onto replaces exactly that stream's leaves
    (bit-identical) and leaves every other leaf of the base untouched."""
    sizes = [7, 0, 33, 4, 16, 2]
    dtypes = ["float32", "float32", "float16", "int32", "float32", "float32"]
    tree = _tree(sizes, dtypes)
    base = jax.tree.map(jnp.zeros_like, tree)
    part = stream_partition(tree, streams, fuse=fuse)
    leaves = jax.tree.flatten(tree)[0]
    for k in range(streams):
        buffers, _ = pack(tree, spec=part.specs[k])
        merged = unpack_onto(buffers, part.specs[k], base)
        mleaves = jax.tree.flatten(merged)[0]
        mine = set(part.leaf_indices(k))
        for i, (src, got) in enumerate(zip(leaves, mleaves)):
            want = src if i in mine else jax.tree.flatten(base)[0][i]
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))



@given(m=st.integers(1, 64), s=st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_schedule_offsets_and_sync_indices(m, s):
    if s > m:
        with pytest.raises(ValueError):
            StreamSchedule(m, s)
        return
    sched = StreamSchedule(m, s)
    assert sched.offsets == tuple((k * m) // s for k in range(s))
    assert len(set(sched.offsets)) == s  # distinct ⇒ ≤1 stream per step
    # scanning inner steps: each stream fires once per round, global sync
    # indices come out 0,1,2,... consecutively, and nothing fires in round 0
    seen = []
    for t in range(3 * m):
        k = sched.due(t)
        if k is not None:
            assert t >= m
            seen.append(sched.sync_index(k, t))
    assert seen == list(range(2 * s))


