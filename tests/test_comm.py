"""repro.comm: payload packing, wire codecs, communicators, byte model.

The HLO test runs in a subprocess with forced host devices (multidevice
marker) like tests/test_multidevice.py; everything else is single-device.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    CommConfig,
    StackedGather,
    bytes_model,
    get_codec,
    make_spec,
    pack,
    unpack,
    wire_roundtrip,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _mixed_tree():
    key = jax.random.PRNGKey(0)
    return {
        "w": jax.random.normal(key, (3, 5), jnp.float32),
        "nested": [
            jax.random.normal(jax.random.fold_in(key, 1), (7,), jnp.bfloat16),
            jnp.arange(4, dtype=jnp.int32),
        ],
        "scalar": jnp.float32(2.5),
        "half": jax.random.normal(jax.random.fold_in(key, 2), (2, 2), jnp.float16),
    }


# ---------------------------------------------------------------------------
# payload
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fuse", [True, False])
def test_pack_unpack_roundtrip_identity(fuse):
    """pack→unpack must be the identity for mixed-dtype pytrees (bit-exact,
    shapes and dtypes preserved) — the invariant the exchange relies on."""
    tree = _mixed_tree()
    buffers, spec = pack(tree, fuse=fuse)
    back = unpack(buffers, spec)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_spec_groups_by_dtype():
    tree = _mixed_tree()
    spec = make_spec(tree, fuse=True)
    dtypes = [b.dtype for b in spec.buffers]
    assert len(dtypes) == len(set(dtypes)) == 4  # f32, bf16, i32, f16
    unfused = make_spec(tree, fuse=False)
    assert len(unfused.buffers) == spec.num_leaves == 5
    assert unfused.nbytes == spec.nbytes


def test_pack_is_jit_and_vmap_safe():
    tree = {"a": jnp.ones((4, 6)), "b": jnp.zeros((4, 3))}

    def rt(sub):
        bufs, spec = pack(sub)
        return unpack(bufs, spec)

    out = jax.jit(jax.vmap(rt))(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


def test_int8_codec_error_bound():
    """Affine uint8 quantization: per-chunk error ≤ half a quantization step
    ((max−min)/255/2) — the exact bound of round-to-nearest."""
    chunk = 512
    x = jax.random.normal(jax.random.PRNGKey(3), (8 * chunk,), jnp.float32) * 3.0
    codec = get_codec(CommConfig(codec="int8", chunk=chunk))
    dec = np.asarray(codec.decode(codec.encode(x), jnp.float32, x.size))
    xr = np.asarray(x).reshape(-1, chunk)
    step = (xr.max(axis=1) - xr.min(axis=1)) / 255.0
    err = np.abs(dec.reshape(-1, chunk) - xr).max(axis=1)
    assert (err <= step * 0.5 + 1e-6).all(), (err, step)
    # and the relative error on the whole vector is small
    rel = np.linalg.norm(dec - np.asarray(x)) / np.linalg.norm(np.asarray(x))
    assert rel < 0.01, rel


def test_int8_codec_non_multiple_and_constant_chunks():
    codec = get_codec(CommConfig(codec="int8", chunk=64))
    x = jnp.concatenate([jnp.full((70,), 3.25), jnp.arange(30, dtype=jnp.float32)])
    dec = np.asarray(codec.decode(codec.encode(x), jnp.float32, x.size))
    assert dec.shape == (100,)
    np.testing.assert_allclose(dec[:64], 3.25, atol=1e-6)  # zero-range chunk exact


def test_int8_tail_chunk_padding_does_not_widen_range():
    """Edge padding: a partial tail chunk of values far from zero must keep
    its own quantization range (zero padding would blow the scale up)."""
    chunk = 1024
    codec = get_codec(CommConfig(codec="int8", chunk=chunk))
    tail = 100.0 + jnp.linspace(0.0, 0.05, 6)
    x = jnp.concatenate([jnp.zeros((chunk,), jnp.float32), tail])
    dec = np.asarray(codec.decode(codec.encode(x), jnp.float32, x.size))
    err = np.abs(dec[chunk:] - np.asarray(tail)).max()
    assert err <= 0.05 / 255.0 * 0.5 + 1e-6, err  # bound from the REAL range


def test_chunk_validation_only_applies_to_int8():
    CommConfig(codec="fp16", chunk=1).validate()  # chunk unused: must not raise
    with pytest.raises(ValueError, match="chunk"):
        CommConfig(codec="int8", chunk=1).validate()


def test_cast_codec_passthrough_for_ints_and_halfs():
    codec = get_codec("fp16")
    ints = jnp.arange(5, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(codec.encode(ints)), np.asarray(ints))
    assert codec.wire_bytes(4, jnp.int32) == 16
    assert codec.wire_bytes(4, jnp.float32) == 8
    assert codec.wire_bytes(4, jnp.float16) == 8  # already half: no-op


def test_error_feedback_residual_shrinks_error():
    """Designed-for EF hook: feeding the residual back recovers what one
    round's quantization dropped (two-round mean error < one-shot error)."""
    codec = get_codec(CommConfig(codec="int8", chunk=256))
    x = jax.random.normal(jax.random.PRNGKey(4), (1024,), jnp.float32)
    res = jnp.zeros_like(x)
    wire, res = codec.encode_with_residual(x, res)
    one_shot = np.asarray(codec.decode(wire, jnp.float32, x.size))
    wire2, _ = codec.encode_with_residual(x, res)
    second = np.asarray(codec.decode(wire2, jnp.float32, x.size))
    two_round = 0.5 * (one_shot + second)
    assert np.abs(two_round - np.asarray(x)).mean() < np.abs(
        one_shot - np.asarray(x)
    ).mean()


def test_error_feedback_config_fails_loudly():
    """error_feedback=True has no trainer path carrying the residual state:
    validate() must refuse it (silently dropping each round's quantization
    residual is the bias the flag claims to remove) until the LoCo-style
    accumulation is actually threaded through the outer step."""
    with pytest.raises(NotImplementedError, match="2407.04480"):
        CommConfig(codec="int8", error_feedback=True).validate()
    with pytest.raises(NotImplementedError, match="residual"):
        CommConfig(codec="fp16", error_feedback=True).validate()
    # "none" keeps its original, more specific rejection
    with pytest.raises(ValueError, match="lossy"):
        CommConfig(codec="none", error_feedback=True).validate()


def test_wire_roundtrip_identity_for_none():
    tree = _mixed_tree()
    out = wire_roundtrip(tree, CommConfig(codec="none"))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# communicators (stacked)
# ---------------------------------------------------------------------------


def test_stacked_gather_codec_matches_manual_cast():
    key = jax.random.PRNGKey(7)
    partner = jnp.asarray([1, 0, 3, 2])
    tree = {"w": jax.random.normal(key, (4, 6, 3)), "v": jax.random.normal(key, (4, 5))}
    comm = StackedGather(partner, CommConfig(codec="fp16"))
    out = comm.exchange(tree)
    ref = jax.tree.map(
        lambda x: jnp.take(x, partner, axis=0).astype(jnp.float16).astype(x.dtype), tree
    )
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stacked_gather_mean_matches_numpy():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 3))}
    mean = StackedGather(None).allreduce_mean(tree)["w"]
    np.testing.assert_allclose(
        np.asarray(mean), np.asarray(tree["w"]).mean(0, keepdims=True).repeat(4, 0),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# bytes model (acceptance: fp16 ≥ 2x, int8 ≥ 3.5x on paper_llama shapes)
# ---------------------------------------------------------------------------


def test_bytes_model_paper_llama_reductions():
    params = bytes_model.abstract_params("paper-small-125m")
    base = bytes_model.outer_step_cost(params, CommConfig(codec="none"))
    fp16 = bytes_model.outer_step_cost(params, CommConfig(codec="fp16"))
    int8 = bytes_model.outer_step_cost(params, CommConfig(codec="int8"))
    assert base.payload_bytes / fp16.payload_bytes >= 2.0
    assert base.payload_bytes / int8.payload_bytes >= 3.5
    # fused: the whole (Δ, φ) payload is ONE message; unfused: one per leaf
    assert base.messages == 1
    unfused = bytes_model.outer_step_cost(params, CommConfig(fuse=False))
    assert unfused.messages > 10
    # overlap halves the blocking bytes (φ pre-sent), total unchanged
    ov = bytes_model.outer_step_cost(params, CommConfig(overlap=True))
    assert ov.blocking_bytes * 2 == ov.payload_bytes == base.payload_bytes


def test_bytes_model_methods():
    tree = {"w": jax.ShapeDtypeStruct((1024,), jnp.float32)}
    none_cost = bytes_model.outer_step_cost(tree, CommConfig(), method="none")
    assert none_cost.payload_bytes == 0 and none_cost.messages == 0
    diloco = bytes_model.outer_step_cost(tree, CommConfig(), method="diloco", world=4)
    # ring all-reduce: 2·(n−1)/n of the Δ payload
    assert diloco.payload_bytes == int(4096 * 2 * 3 / 4)
    # the baseline all-reduce is uncompressed: codecs must not shrink it
    diloco8 = bytes_model.outer_step_cost(
        tree, CommConfig(codec="int8"), method="diloco", world=4
    )
    assert diloco8.payload_bytes == diloco.payload_bytes
    assert diloco8.codec == "none"
    noloco = bytes_model.outer_step_cost(tree, CommConfig(), method="noloco")
    assert noloco.payload_bytes == 2 * 4096  # Δ and φ


# ---------------------------------------------------------------------------
# HLO: the paper claim, at the communicator level
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_sharded_permute_fused_hlo_collective_count():
    """A fused NoLoCo outer step must lower to ≤ 2 collective-permutes (one
    per payload dtype; a single f32 payload gives exactly one) and ZERO
    all-reduces — for the raw wire and the fp16 codec alike."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from repro.comm import CommConfig
    from repro.core import outer as outer_lib
    from repro.core.outer import OuterConfig
    from repro.launch import roofline as rf

    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    cfg = OuterConfig(method="noloco")
    tree = {
        "w": jnp.zeros((2, 8, 4), jnp.float32),
        "b": [jnp.zeros((2, 16), jnp.float32), jnp.zeros((2, 3), jnp.float32)],
    }
    specs = jax.tree.map(lambda x: P("data"), tree)

    for codec in ("none", "fp16"):
        comm_cfg = CommConfig(codec=codec, fuse=True)

        def body(theta, phi, delta):
            state = outer_lib.OuterState(phi=phi, delta=delta,
                                         step=jnp.zeros((), jnp.int32))
            new_state, new_theta = outer_lib.outer_step_sharded(
                state, theta, cfg, axis_names=("data",), perm=[(0, 1), (1, 0)],
                comm_cfg=comm_cfg,
            )
            return new_theta, new_state.phi, new_state.delta

        fn = shard_map(body, mesh=mesh, in_specs=(specs, specs, specs),
                       out_specs=(specs, specs, specs), check_rep=False)
        hlo = jax.jit(fn).lower(tree, tree, tree).compile().as_text()
        stats = rf.collective_bytes(hlo, model_size=1)
        assert stats.counts["collective-permute"] <= 2, (codec, stats.counts)
        assert stats.counts["all-reduce"] == 0, (codec, stats.counts)
        print(codec, stats.counts["collective-permute"])
    print("COMM HLO OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "COMM HLO OK" in out.stdout


# ---------------------------------------------------------------------------
# convergence: fp16 gossip matches uncompressed within 2%
# ---------------------------------------------------------------------------


def test_noloco_fp16_codec_convergence_parity():
    """NoLoCo on the toy LM (as in test_gossip_training) with a compressed
    fp16 wire must match the uncompressed final loss within 2%."""
    from repro.launch.train import run_training
    from repro.models.config import ModelConfig

    tiny = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=128, dtype="float32", remat=False)
    kw = dict(method="noloco", replicas=4, per_replica_batch=2, seq_len=32,
              steps=30, inner_lr=3e-3, inner_steps=10, eval_every=0)
    base = run_training(tiny, codec="none", **kw)
    fp16 = run_training(tiny, codec="fp16", **kw)
    l0, l1 = base["losses"][-1], fp16["losses"][-1]
    assert l1 < base["losses"][0] * 0.85  # it actually trains
    assert abs(l1 - l0) / l0 < 0.02, (l0, l1)
