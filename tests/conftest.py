# NOTE: do NOT set XLA_FLAGS here — smoke tests and benches must see ONE
# device; only launch/dryrun.py forces 512 host devices (in its own process).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
