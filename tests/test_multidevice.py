"""Distributed-runtime tests on 8 forced host devices.

XLA device count is locked at first jax init, so these run in a SUBPROCESS
with XLA_FLAGS set (conftest must NOT set it globally)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidevice

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig
from repro.models import model as M
from repro.models.common import unzip, values_of
from repro.parallel import plans as PL, steps as ST
from repro.core.outer import OuterConfig
from repro.core import pairing
from repro.optim import AdamWConfig
from repro.launch.mesh import make_test_mesh
from repro.parallel.compat import set_mesh
mesh = make_test_mesh(4, 2)
cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, dtype="float32", remat=False)
plan = PL.make_plan("gossip_dp", mesh, shape_kind="train")
params = M.init_params(jax.random.PRNGKey(0), cfg)
stacked = ST.stack_replicas(params, plan.replicas)
vals, _ = unzip(stacked)
"""


def test_sharded_train_matches_stacked_simulation():
    """The shard_map train step must produce the SAME losses as the local
    vmap simulation (same math, different distribution)."""
    out = _run(PRELUDE + """
B, S = 8, 16
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),(B,S),0,256),
         "labels": jax.random.randint(jax.random.PRNGKey(2),(B,S),0,256)}
inner = AdamWConfig(lr=1e-3, weight_decay=0.0)
with set_mesh(mesh):
    bundle = ST.build_train_step(cfg, plan, mesh, stacked, batch, inner)
    theta = jax.device_put(vals, bundle.theta_shardings)
    opt = ST.init_opt_state(theta, plan.replicas)
    opt = jax.device_put(opt, bundle.opt_shardings)
    dist_losses = []
    for i in range(3):
        theta, opt, mets = bundle.step_fn(theta, opt, batch)
        dist_losses.append(np.asarray(mets["loss"]))

# local stacked simulation of the same thing
from repro.parallel.sharding import ShardCtx
from repro.optim import adamw_init, adamw_update
ctx = ShardCtx.local()
R = plan.replicas
bt = {k: v.reshape(R, B//R, S) for k, v in batch.items()}
th = vals
opt2 = jax.vmap(adamw_init)(th)
def one(p, b):
    return M.loss_fn(p, cfg, b, ctx)[0]
for i in range(3):
    losses, grads = jax.vmap(jax.value_and_grad(one))(th, bt)
    th, opt2, _ = jax.vmap(lambda g,o,p: adamw_update(g,o,p, inner))(grads, opt2, th)
    err = np.abs(np.asarray(losses) - dist_losses[i]).max()
    assert err < 2e-4, (i, err, losses, dist_losses[i])
print("MATCH")
""")
    assert "MATCH" in out


def test_gossip_outer_step_pair_exchange_correct():
    """ppermute gossip on the mesh == stacked gather implementation."""
    out = _run(PRELUDE + """
from repro.core import outer as outer_lib
pspecs = PL.param_pspecs(plan, mesh, stacked)
perm_pairs = pairing.ppermute_pairs(0, plan.replicas)
ocfg = OuterConfig(method="noloco")
with set_mesh(mesh):
    fn = ST.build_outer_step(plan, mesh, pspecs, ocfg, perm_pairs)
    sh = PL.shardings(mesh, pspecs)
    key = jax.random.PRNGKey(5)
    theta = jax.tree.map(lambda x: x + jax.random.normal(key, x.shape)*0.1, vals)
    theta_host = jax.device_get(theta)   # donation below deletes the device copy
    theta = jax.device_put(theta, sh)
    phi = jax.device_put(vals, sh)
    delta = jax.tree.map(jnp.zeros_like, phi)
    import jax.sharding as jsh
    stepc = jax.device_put(jnp.zeros((plan.replicas,), jnp.int32),
                           jsh.NamedSharding(mesh, jsh.PartitionSpec("data")))
    th2, phi2, d2, _ = fn(theta, phi, delta, stepc)

# stacked reference
partner = jnp.asarray(pairing.partner_table(0, plan.replicas))
state = outer_lib.init_outer_state(jax.device_get(vals))
new_state, new_theta = outer_lib.outer_step_stacked(
    state, theta_host, ocfg, partner=partner)
for a, b in zip(jax.tree.leaves(jax.device_get(phi2)), jax.tree.leaves(new_state.phi)):
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-5), np.abs(a-b).max()
print("GOSSIP MATCH")
""")
    assert "GOSSIP MATCH" in out


def test_outer_hlo_has_permute_not_allreduce():
    """THE paper claim, verified on HLO: NoLoCo outer = collective-permute
    only; DiLoCo outer = all-reduce."""
    out = _run(PRELUDE + """
from repro.launch import roofline as rf
pspecs = PL.param_pspecs(plan, mesh, stacked)
perm_pairs = pairing.ppermute_pairs(0, plan.replicas)
import jax.sharding as jsh
rep_sh = jax.ShapeDtypeStruct((plan.replicas,), jnp.int32)
theta_abs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), vals)
with set_mesh(mesh):
    for method, want, forbid in (("noloco", "collective-permute", "all-reduce"),
                                 ("diloco", "all-reduce", "collective-permute")):
        ocfg = OuterConfig(method=method, alpha=0.3 if method=="diloco" else 0.5)
        fn = ST.build_outer_step(plan, mesh, pspecs, ocfg, perm_pairs)
        hlo = fn.lower(theta_abs, theta_abs, theta_abs, rep_sh).compile().as_text()
        stats = rf.collective_bytes(hlo, model_size=2)
        assert stats.counts[want] > 0, (method, stats.counts)
        assert stats.counts[forbid] == 0, (method, stats.counts)
        print(method, stats.counts)
print("HLO OK")
""")
    assert "HLO OK" in out


def test_decode_sharded_matches_local():
    """Sequence-sharded flash-decode (kv_shard_seq) == local decode."""
    out = _run(PRELUDE + """
from repro.parallel.sharding import ShardCtx
import jax.sharding as jsh
dcfg = cfg
plan_d = PL.make_plan("gossip_dp", mesh, shape_kind="decode", has_global_attention=True)
assert plan_d.kv_shard_seq
B, CACHE = 8, 32
caches = M.init_cache_tree(dcfg, B, CACHE)
cvals, _ = unzip(jax.eval_shape(lambda: caches))
caches_real = values_of(caches)
toks = jax.random.randint(jax.random.PRNGKey(3), (B, 1), 0, 256)
bspecs = ST.batch_pspecs(plan_d, {"tokens": toks})
with set_mesh(mesh):
    fn, (pspecs, cspecs) = ST.build_decode_step(dcfg, plan_d, mesh, stacked, caches, bspecs)
    theta = jax.device_put(vals, PL.shardings(mesh, pspecs))
    cache_put = jax.device_put(caches_real, PL.shardings(mesh, cspecs))
    tok_sh = jsh.NamedSharding(mesh, bspecs["tokens"])
    idx_sh = jsh.NamedSharding(mesh, jsh.PartitionSpec())
    # place a couple of tokens in the cache first via two decode calls
    lg1, cache_put = fn(theta, cache_put, jax.device_put(toks, tok_sh),
                        jax.device_put(jnp.asarray(0, jnp.int32), idx_sh))
    lg2, cache_put = fn(theta, cache_put, jax.device_put(toks + 1, tok_sh),
                        jax.device_put(jnp.asarray(1, jnp.int32), idx_sh))

# local reference: replica r serves batch rows [r*B/R:(r+1)*B/R]
ctx = ShardCtx.local()
R = plan_d.replicas
errs = []
for r in range(R):
    rows = slice(r*B//R, (r+1)*B//R)
    th_r = jax.tree.map(lambda x: x[r], vals)
    c_r = values_of(M.init_cache_tree(dcfg, B//R, CACHE))
    l1, c_r = M.decode_step(th_r, dcfg, toks[rows], jnp.asarray(0), c_r, ctx)
    l2, c_r = M.decode_step(th_r, dcfg, (toks+1)[rows], jnp.asarray(1), c_r, ctx)
    errs.append(np.abs(np.asarray(l2) - np.asarray(lg2[rows])).max())
assert max(errs) < 2e-3, errs
print("DECODE MATCH", max(errs))
""")
    assert "DECODE MATCH" in out
