"""Roofline machinery unit tests: HLO collective parsing + model FLOPs."""
import pytest

from repro.configs import registry
from repro.launch import roofline as rf

HLO_SAMPLE = """
HloModule test
fused_computation {
  %p = bf16[16,512,128]{2,1,0} parameter(0)
}
ENTRY main {
  %x = bf16[16,512,128]{2,1,0} parameter(0)
  %ag = bf16[16,8192,128]{2,1,0} all-gather(bf16[16,512,128]{2,1,0} %x), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={1}
  %ar = f32[128]{0} all-reduce(f32[128]{0} %y), replica_groups={{0,1},{2,3},{4,5},{6,7}}, to_apply=%add
  %arx = f32[64]{0} all-reduce(f32[64]{0} %z), replica_groups={{0,2,4,6},{1,3,5,7}}, to_apply=%add
  %cp = bf16[1024]{0} collective-permute(bf16[1024]{0} %w), source_target_pairs={{0,1},{1,0}}
  %rs = f32[256]{0} reduce-scatter(f32[1024]{0} %v), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = (bf16[32]{0}, bf16[32]{0}) all-to-all(bf16[32]{0} %q, bf16[32]{0} %r), replica_groups={{0,1}}
}
"""


def test_collective_parse_counts_and_bytes():
    # model axis size 2 => groups {0,1} are INTRA (same block), {0,2,...} CROSS
    stats = rf.collective_bytes(HLO_SAMPLE, model_size=2)
    assert stats.counts["all-gather"] == 1
    assert stats.counts["all-reduce"] == 2
    assert stats.counts["collective-permute"] == 1
    assert stats.counts["reduce-scatter"] == 1
    assert stats.counts["all-to-all"] == 1
    # all-gather result: 16*8192*128*2 bytes
    assert stats.bytes_by_kind["all-gather"] == 16 * 8192 * 128 * 2
    # permutes always count as cross-replica traffic
    assert stats.cross_replica_bytes >= 1024 * 2
    # the {0,1} AR is intra (within one model block), the strided one cross
    assert stats.model_axis_bytes >= 128 * 4


def test_cross_replica_classification():
    assert rf._groups_cross_replica("replica_groups={{0,1}}", 2) is False
    assert rf._groups_cross_replica("replica_groups={{0,2}}", 2) is True
    assert rf._groups_cross_replica("replica_groups={{0,1,2,3}}", 4) is False
    assert rf._groups_cross_replica("replica_groups={{0,4},{1,5}}", 4) is True


def test_model_flops_sane_for_all_archs():
    """6·N·D with N = ACTIVE params: MoE active << total; dense equal."""
    for name in registry.ASSIGNED:
        cfg = registry.get_config(name)
        act = rf.active_params(cfg)
        tot = rf.total_params(cfg)
        assert act > 0 and tot >= act * 0.99
        if cfg.arch_type == "moe":
            assert tot > 2 * act, name  # 32e top-8 / 128e top-8
    # spot check magnitudes (±40% of the nominal sizes)
    assert 0.4e9 < rf.active_params(registry.get_config("qwen3-0.6b")) < 1.2e9
    assert 5e9 < rf.active_params(registry.get_config("minitron-8b")) < 12e9
    q = registry.get_config("qwen3-moe-235b-a22b")
    assert 1.4e11 < rf.total_params(q) < 3.5e11
    assert rf.active_params(q) < 0.35e11


def test_roofline_terms_and_bottleneck():
    r = rf.analyze(1e12, 1e11, None, chips=256, model_flops=2e14,
                   cross_bytes=1e9, intra_bytes=2e9)
    assert r.compute_s == pytest.approx(1e12 / rf.PEAK_FLOPS)
    assert r.memory_s == pytest.approx(1e11 / rf.HBM_BW)
    assert r.collective_s == pytest.approx(3e9 / rf.ICI_BW)
    assert r.bottleneck == "memory"
    assert r.useful_ratio == pytest.approx(2e14 / (1e12 * 256))
