"""Serving fast path: chunked prefill must equal whole-prompt prefill for
every cache family (ONE fixed-width program, ragged tails masked, recurrent
states carried exactly across chunk boundaries), the block allocator's lease
protocol must never leak or double-own a page, speculative decode must be
invisible in the output (spec tokens == target-only tokens, bitwise, for any
draft), and the multi-replica router must not change what any request
decodes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.dispatch import KernelConfig
from repro.models import model as M
from repro.models.attention import PagedView
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardCtx
from repro.serve import (
    BlockAllocator,
    ReplicaRouter,
    Request,
    ServeConfig,
    ServeEngine,
    SpecServeEngine,
    truncate_layers,
)

try:  # hypothesis is optional in this image; fall back to seeded draws
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CTX = ShardCtx.local()
KEY = jax.random.PRNGKey(23)
PALLAS = KernelConfig(impl="pallas", interpret=True)
JNP = KernelConfig(impl="jnp")

CFGS = {
    "global": ModelConfig(num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=128, qk_norm=True,
                          dtype="float32", remat=False),
    "local": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
                         d_ff=128, vocab_size=128, attn_pattern=("local",),
                         sliding_window=6, dtype="float32", remat=False),
    "rglru": ModelConfig(arch_type="hybrid", num_layers=3, d_model=64, num_heads=4,
                         num_kv_heads=1, d_ff=128, vocab_size=128,
                         attn_pattern=("rglru", "rglru", "local"), sliding_window=6,
                         lru_width=64, dtype="float32", remat=False),
    "ssd": ModelConfig(arch_type="ssm", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=4, d_ff=0, vocab_size=128, attn_pattern=("ssd",),
                       ssm_state_dim=16, ssm_head_dim=32, ssm_chunk=4,
                       use_rope=False, dtype="float32", remat=False),
}


def _params(kind: str, seed: int = 0):
    return values_of(M.init_params(jax.random.PRNGKey(seed), CFGS[kind]))


# ---------------------------------------------------------------------------
# BlockAllocator lease protocol: reserve → commit | rollback, no leaks
# ---------------------------------------------------------------------------


def test_lease_reserve_commit_rollback():
    al = BlockAllocator(num_pages=8, page_size=4)
    lease = al.reserve(3)
    assert al.free_count == 5 and al.reserved_count == 3
    al.check_leaks()  # free + reserved == pool while the lease is pending

    blocks = al.commit(lease)
    assert sorted(blocks) == sorted(lease.blocks) and al.reserved_count == 0
    al.check_leaks(owned=3)
    with pytest.raises(ValueError, match="commit of committed"):
        al.commit(lease)
    with pytest.raises(ValueError, match="rollback of committed"):
        al.rollback(lease)

    other = al.reserve(5)
    assert al.free_count == 0 and not al.can_alloc(1)
    al.rollback(other)
    assert al.free_count == 5 and al.reserved_count == 0
    with pytest.raises(ValueError, match="rollback of rolled_back"):
        al.rollback(other)
    al.check_leaks(owned=3)

    al.free(blocks)
    al.check_leaks()
    assert al.free_count == 8


def test_lease_pages_never_doubly_owned():
    al = BlockAllocator(num_pages=6, page_size=2)
    a = al.reserve(2)
    b = al.reserve(2)
    assert not set(a.blocks) & set(b.blocks)
    with pytest.raises(MemoryError):
        al.reserve(3)  # only 2 left
    kept = al.commit(a)
    al.rollback(b)
    # rolled-back pages went home; committed ones didn't
    with pytest.raises(ValueError, match="double free"):
        al.free([b.blocks[0]])
    al.free(kept)
    al.check_leaks()


def test_check_leaks_detects_missing_pages():
    al = BlockAllocator(num_pages=4, page_size=2)
    al.alloc(1)  # owned by nobody on record
    with pytest.raises(AssertionError, match="leak"):
        al.check_leaks()
    al.check_leaks(owned=1)


# ---------------------------------------------------------------------------
# Chunked paged attention kernel: impl parity + positional masking
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kv,mode,window", [
    (4, 4, "causal", 0),   # MHA
    (4, 2, "causal", 0),   # GQA
    (4, 1, "local", 5),    # MQA sliding window
])
def test_paged_chunk_attention_impl_parity(h, kv, mode, window):
    num_pages, page_size, mb, r, c, d = 6, 4, 4, 3, 5, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (r, c, h, d))
    kp = jax.random.normal(ks[1], (num_pages, page_size, kv, d))
    vp = jax.random.normal(ks[2], (num_pages, page_size, kv, d))
    tables = jnp.asarray([[0, 1, 2, 3], [4, 5, 0, 1], [2, 3, 4, 5]], jnp.int32)
    base = jnp.asarray([0, 4, 9], jnp.int32)  # chunk token 0 positions
    op = ops.paged_chunk_attention(q, kp, vp, tables, base,
                                   mode=mode, window=window, config=PALLAS)
    oj = ops.paged_chunk_attention(q, kp, vp, tables, base,
                                   mode=mode, window=window, config=JNP)
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=2e-5, rtol=1e-4)


def test_paged_chunk_attention_masks_future_and_trash():
    """Chunk token c at base+c must only see keys j <= base+c: scrambling
    every pool entry past each slot's last chunk position (including whole
    unallocated pages) leaves the output bit-unchanged."""
    num_pages, page_size, r, c, h, d = 4, 4, 2, 3, 2, 8
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (r, c, h, d))
    kp = jax.random.normal(jax.random.fold_in(KEY, 2), (num_pages, page_size, h, d))
    vp = jax.random.normal(jax.random.fold_in(KEY, 3), (num_pages, page_size, h, d))
    # disjoint pages per slot; unallocated table entries just repeat a page
    tables = jnp.asarray([[0, 1, 0, 0], [2, 3, 2, 2]], jnp.int32)
    base = jnp.asarray([2, 4], jnp.int32)  # last chunk tokens at pos 4 and 6
    for cfg in (PALLAS, JNP):
        ref = ops.paged_chunk_attention(q, kp, vp, tables, base, config=cfg)
        kp2 = kp.at[1, 1:].set(77.0)     # slot 0: pos 5..7, all > 4
        vp2 = vp.at[1, 1:].set(-77.0)
        kp2 = kp2.at[3, 3:].set(77.0)    # slot 1: pos 7 > 6
        vp2 = vp2.at[3, 3:].set(-77.0)
        got = ops.paged_chunk_attention(q, kp2, vp2, tables, base, config=cfg)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# ---------------------------------------------------------------------------
# Chunked prefill == whole-prompt prefill, per cache family (property)
# ---------------------------------------------------------------------------


def _chunk_vs_whole(kind: str, plen: int, chunk: int) -> None:
    """Walk a prompt through paged_prefill_chunk in fixed-width chunks (last
    one ragged) and check logits match whole-prompt paged_prefill at the
    final position — then one more decode step from each cache, which fails
    if chunking corrupted ANY carried state (KV pages or recurrences)."""
    cfg = CFGS[kind]
    vals = _params(kind)
    toks = jax.random.randint(jax.random.fold_in(KEY, plen * 31 + chunk),
                              (1, plen), 0, cfg.vocab_size)
    num_pages, page_size, mb = 8, 4, 8
    tables = np.full((1, mb), num_pages, dtype=np.int32)
    n_blk = -(-(plen + 1) // page_size)
    tables[0, :n_blk] = range(n_blk)
    tables = jnp.asarray(tables)

    whole = M.init_paged_cache_tree(cfg, 1, num_pages, page_size)
    view0 = PagedView(tables, jnp.zeros((1,), jnp.int32), jnp.ones((1,), bool))
    lg_whole, whole = M.paged_prefill(vals, cfg, toks, whole, view0, CTX)

    caches = M.init_paged_cache_tree(cfg, 1, num_pages, page_size)
    cur = 0
    while cur < plen:
        n = min(chunk, plen - cur)
        buf = jnp.zeros((1, chunk), toks.dtype).at[0, :n].set(toks[0, cur:cur + n])
        view = PagedView(tables, jnp.asarray([cur], jnp.int32), jnp.ones((1,), bool))
        lg_chunk, caches = M.paged_prefill_chunk(
            vals, cfg, buf, caches, view, CTX, lengths=jnp.asarray([n], jnp.int32)
        )
        cur += n
    np.testing.assert_allclose(np.asarray(lg_chunk), np.asarray(lg_whole),
                               atol=2e-3, rtol=1e-3)

    nxt = jnp.asarray([[7]], toks.dtype)
    view = PagedView(tables, jnp.asarray([plen], jnp.int32), jnp.ones((1,), bool))
    d_whole, _ = M.paged_decode_step(vals, cfg, nxt, whole, view, CTX)
    d_chunk, _ = M.paged_decode_step(vals, cfg, nxt, caches, view, CTX)
    np.testing.assert_allclose(np.asarray(d_chunk), np.asarray(d_whole),
                               atol=2e-3, rtol=1e-3)


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("kind", list(CFGS))
    @settings(max_examples=8, deadline=None)
    @given(plen=st.integers(1, 14), chunk=st.integers(2, 6))
    def test_chunked_prefill_matches_whole_prompt(kind, plen, chunk):
        _chunk_vs_whole(kind, plen, chunk)
else:
    @pytest.mark.parametrize("kind", list(CFGS))
    def test_chunked_prefill_matches_whole_prompt(kind):
        rng = np.random.default_rng(5)
        cases = {(int(rng.integers(1, 15)), int(rng.integers(2, 7)))
                 for _ in range(4)}
        cases |= {(13, 4), (3, 6)}  # ragged tail; single under-full chunk
        for plen, chunk in sorted(cases):
            _chunk_vs_whole(kind, plen, chunk)


# ---------------------------------------------------------------------------
# Chunked engine: batched == solo, O(1) compiled programs, no page leaks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["global", "rglru", "ssd"])
def test_chunked_engine_batched_matches_solo(kind):
    cfg = CFGS[kind]
    params = _params(kind, seed=2)
    # chunk=3 forces multi-chunk admissions with ragged tails on this load
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8,
                       prefill_chunk=3)
    rng = np.random.default_rng(0)
    requests = [
        Request(rid=rid, prompt=rng.integers(0, cfg.vocab_size, size=(pl,)).tolist(),
                max_new=gl, temperature=temp)
        for rid, (pl, gl, temp) in enumerate(
            [(3, 6, 0.0), (9, 4, 0.0), (5, 8, 0.7), (2, 5, 0.0)]
        )
    ]
    engine = ServeEngine(params, cfg, scfg)
    finished = {f.rid: f for f in engine.run([dataclasses.replace(r) for r in requests])}
    assert sorted(finished) == [0, 1, 2, 3]
    engine.alloc.check_leaks()
    # the whole mixed-length run compiled exactly ONE chunk program
    assert engine._chunk_fn._cache_size() == 1

    for r in requests:
        solo = ServeEngine(params, cfg, scfg)
        [f] = solo.run([dataclasses.replace(r)])
        assert f.tokens == finished[r.rid].tokens, (
            f"{kind} rid={r.rid}: chunked batched decode diverged from solo"
        )


def test_chunked_engine_matches_single_shot_prefill():
    """Same load through chunk=3 admission and through the single-shot
    (chunk=0) per-length prefill: identical tokens out."""
    cfg = CFGS["global"]
    params = _params("global", seed=2)
    rng = np.random.default_rng(3)
    requests = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=(pl,)).tolist(),
                max_new=5, temperature=t)
        for i, (pl, t) in enumerate([(4, 0.0), (7, 0.7), (11, 0.0)])
    ]
    outs = {}
    for chunk in (3, 0):
        scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4,
                           max_new_cap=8, prefill_chunk=chunk)
        eng = ServeEngine(params, cfg, scfg)
        done = eng.run([dataclasses.replace(r) for r in requests])
        outs[chunk] = {f.rid: f.tokens for f in done}
    assert outs[3] == outs[0]


def test_prefill_budget_throttles_admission():
    """prefill_budget=chunk admits at most one chunk per tick; the run still
    finishes with identical tokens."""
    cfg = CFGS["global"]
    params = _params("global", seed=2)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=(10,)).tolist()
    outs = {}
    for budget in (0, 4):
        scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4,
                           max_new_cap=8, prefill_chunk=4, prefill_budget=budget)
        eng = ServeEngine(params, cfg, scfg)
        done = eng.run([Request(rid=0, prompt=list(prompt), max_new=6)])
        outs[budget] = done[0].tokens
        eng.alloc.check_leaks()
    assert outs[0] == outs[4]


# ---------------------------------------------------------------------------
# Speculative decode: output must be EXACTLY the target's, for any draft
# ---------------------------------------------------------------------------


def _spec_load(cfg):
    rng = np.random.default_rng(9)
    return [
        Request(rid=rid, prompt=rng.integers(0, cfg.vocab_size, size=(pl,)).tolist(),
                max_new=gl, temperature=temp)
        for rid, (pl, gl, temp) in enumerate(
            [(3, 6, 0.0), (8, 5, 0.7), (5, 7, 0.0)]
        )
    ]


def _reference(params, cfg, scfg, requests):
    eng = ServeEngine(params, cfg, scfg)
    return {f.rid: f.tokens for f in eng.run([dataclasses.replace(r) for r in requests])}


@pytest.mark.parametrize("kind", ["global", "rglru", "ssd"])
def test_spec_decode_with_self_draft_is_exact_and_fully_accepted(kind):
    cfg = CFGS[kind]
    params = _params(kind, seed=2)
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8,
                       prefill_chunk=4)
    requests = _spec_load(cfg)
    ref = _reference(params, cfg, scfg, requests)

    eng = SpecServeEngine(params, cfg, scfg, params, cfg, spec_k=3)
    got = {f.rid: f for f in eng.run([dataclasses.replace(r) for r in requests])}
    assert {r: f.tokens for r, f in got.items()} == ref
    # the draft IS the target: every proposal must be accepted
    assert eng.accept_rate == 1.0
    assert all(f.stats["accept_rate"] == 1.0 for f in got.values())
    eng.alloc.check_leaks()


@pytest.mark.parametrize("kind", ["global", "rglru"])
def test_spec_decode_with_divergent_draft_is_still_exact(kind):
    """A draft with DIFFERENT weights (another NoLoCo replica in production)
    proposes wrong tokens sometimes — rejections must roll KV + recurrent
    state back so output still equals the target-only run, bitwise."""
    cfg = CFGS[kind]
    params = _params(kind, seed=2)
    draft_params = _params(kind, seed=7)
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8,
                       prefill_chunk=4)
    requests = _spec_load(cfg)
    ref = _reference(params, cfg, scfg, requests)

    eng = SpecServeEngine(params, cfg, scfg, draft_params, cfg, spec_k=3)
    got = {f.rid: f.tokens for f in eng.run([dataclasses.replace(r) for r in requests])}
    assert got == ref
    assert 0.0 <= eng.accept_rate <= 1.0 and eng.spec_rounds > 0


def test_spec_accept_rate_well_defined_with_no_usable_proposals():
    """max_new=1 requests: every decode round has rem == 1 for every slot, so
    usable = min(spec_k-1, rem-1) = 0 and the denominator never grows.  The
    accept rate must come back as the vacuously-perfect 1.0 — not NaN, not a
    0/0-as-0.0 that would falsely read as 'draft never matched' — both on the
    engine aggregate and in every request's finish stats."""
    cfg = CFGS["global"]
    params = _params("global", seed=2)
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8,
                       prefill_chunk=4)
    rng = np.random.default_rng(11)
    requests = [
        Request(rid=rid, prompt=rng.integers(0, cfg.vocab_size, size=(pl,)).tolist(),
                max_new=1)
        for rid, pl in enumerate([3, 8])
    ]
    ref = _reference(params, cfg, scfg, requests)

    eng = SpecServeEngine(params, cfg, scfg, params, cfg, spec_k=3)
    got = {f.rid: f for f in eng.run([dataclasses.replace(r) for r in requests])}
    assert {r: f.tokens for r, f in got.items()} == ref
    assert eng.spec_prop_total == 0
    assert eng.accept_rate == 1.0
    assert all(f.stats["accept_rate"] == 1.0 for f in got.values())
    eng.alloc.check_leaks()


def test_spec_accept_rate_defined_before_any_round():
    """An engine that has not run a single spec round (empty request list —
    the 'empty final rounds' shape) must still report a finite in-[0,1]
    accept_rate for telemetry summaries."""
    cfg = CFGS["global"]
    params = _params("global", seed=2)
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8,
                       prefill_chunk=4)
    eng = SpecServeEngine(params, cfg, scfg, params, cfg, spec_k=3)
    assert eng.run([]) == []
    assert eng.accept_rate == 1.0


def test_spec_decode_with_truncated_draft_is_exact():
    cfg = CFGS["global"]
    params = _params("global", seed=2)
    draft = truncate_layers(params, cfg, 1)
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8,
                       prefill_chunk=4)
    requests = _spec_load(cfg)
    ref = _reference(params, cfg, scfg, requests)
    eng = SpecServeEngine(params, cfg, scfg, draft[0], draft[1], spec_k=3)
    got = {f.rid: f.tokens for f in eng.run([dataclasses.replace(r) for r in requests])}
    assert got == ref


def test_spec_engine_requires_chunked_prefill():
    cfg = CFGS["global"]
    params = _params("global")
    scfg = ServeConfig(max_slots=1, num_pages=8, page_size=4, max_new_cap=4,
                       prefill_chunk=0)
    with pytest.raises(ValueError, match="prefill_chunk"):
        SpecServeEngine(params, cfg, scfg, params, cfg, spec_k=2)


# ---------------------------------------------------------------------------
# truncate_layers: structure + runnable draft
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,keep", [("global", 1), ("global", 2),
                                       ("rglru", 1), ("rglru", 2), ("ssd", 1)])
def test_truncate_layers_structure_and_forward(kind, keep):
    cfg = CFGS[kind]
    params = _params(kind)
    dparams, dcfg = truncate_layers(params, cfg, keep)
    assert dcfg.num_layers == keep
    p = len(cfg.attn_pattern)
    n_full2, rem2 = keep // p, keep % p
    for s in dparams["stack"]["scan"]:
        if s is not None:
            depths = {int(l.shape[0]) for l in jax.tree.leaves(s)}
            assert depths == {n_full2}
    assert len(dparams["stack"]["rem"]) == rem2
    assert dparams["embed"] is params["embed"]  # shared, not copied

    caches = M.init_paged_cache_tree(dcfg, 1, 4, 4)
    tables = jnp.asarray([[0, 1, 2, 4]], jnp.int32)
    view = PagedView(tables, jnp.zeros((1,), jnp.int32), jnp.ones((1,), bool))
    toks = jnp.asarray([[5, 9, 2]], jnp.int32)
    lg, _ = M.paged_prefill(dparams, dcfg, toks, caches, view, CTX)
    assert lg.shape == (1, 1, dcfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg)))


def test_truncate_layers_rejects_bad_depth():
    cfg = CFGS["global"]
    params = _params("global")
    with pytest.raises(ValueError, match="num_layers"):
        truncate_layers(params, cfg, 0)
    with pytest.raises(ValueError, match="num_layers"):
        truncate_layers(params, cfg, cfg.num_layers + 1)


# ---------------------------------------------------------------------------
# Router: placement policies; routing never changes what a request decodes
# ---------------------------------------------------------------------------


def test_router_round_robin_and_output_parity():
    cfg = CFGS["global"]
    params = _params("global", seed=2)
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8,
                       prefill_chunk=4)
    rng = np.random.default_rng(1)
    requests = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=(pl,)).tolist(),
                max_new=5)
        for i, pl in enumerate([3, 7, 4, 9])
    ]
    ref = _reference(params, cfg, scfg, requests)

    router = ReplicaRouter(
        [ServeEngine(params, cfg, scfg) for _ in range(2)], policy="round_robin"
    )
    finished = router.run([dataclasses.replace(r) for r in requests])
    assert router.routed == [2, 2]
    assert {f.rid: f.tokens for _, f in finished} == ref
    replicas = {f.rid: i for i, f in finished}
    assert {replicas[0], replicas[2]} == {0} and {replicas[1], replicas[3]} == {1}


def test_router_least_loaded_prefers_idle_engine():
    cfg = CFGS["global"]
    params = _params("global", seed=2)
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8,
                       prefill_chunk=4)
    router = ReplicaRouter(
        [ServeEngine(params, cfg, scfg) for _ in range(2)], policy="least_loaded"
    )
    heavy = Request(rid=0, prompt=[1] * 9, max_new=8)
    light = Request(rid=1, prompt=[2] * 3, max_new=2)
    assert router.submit(heavy) == 0
    assert router.submit(light) == 1  # engine 0 now carries 17 tokens of work
    assert router.submit(Request(rid=2, prompt=[3] * 2, max_new=2)) == 1
    while not router.idle:
        router.step()
    for eng in router.engines:
        eng._evict_finished()
        eng.alloc.check_leaks()


def test_router_validates_inputs():
    with pytest.raises(ValueError, match="at least one"):
        ReplicaRouter([])
    cfg = CFGS["global"]
    params = _params("global")
    scfg = ServeConfig(max_slots=1, num_pages=8, page_size=4, max_new_cap=4)
    with pytest.raises(ValueError, match="policy"):
        ReplicaRouter([ServeEngine(params, cfg, scfg)], policy="random")
