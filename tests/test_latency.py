"""Section 5.3 latency model tests (Eq. 5-7, Fig. 5)."""
import math

import pytest

from repro.core import latency


def test_eq7_closed_form_matches_monte_carlo():
    mu, sigma = 0.3, 0.8
    mc = latency.simulate_pair_average(mu, sigma, rounds=20000, seed=0) / 2
    cf = latency.expected_pairwise_max(mu, sigma)
    assert mc == pytest.approx(cf, rel=0.05)


def test_speedup_grows_log2_n():
    s64 = latency.speedup_closed_form(64, 0.0, 0.5)
    s256 = latency.speedup_closed_form(256, 0.0, 0.5)
    assert s256 > s64
    assert s256 == pytest.approx(math.log2(256), rel=1e-6)


def test_tree_allreduce_simulation_close_to_closed_form():
    n, mu, sigma = 64, 0.0, 0.5
    sim = latency.simulate_tree_allreduce(n, mu, sigma, rounds=2000, seed=1)
    cf = latency.tree_allreduce_time_closed_form(n, mu, sigma)
    # closed form uses E[max of 2]; the sim takes the max over ALL pairs per
    # level, so sim >= cf and within a small factor
    assert cf * 0.9 < sim < cf * 3.0


def test_blocking_overhead_favors_noloco_and_grows_with_world():
    r64 = latency.simulate_blocking_overhead(64, outer_rounds=50, inner_steps=20)
    r512 = latency.simulate_blocking_overhead(512, outer_rounds=50, inner_steps=20)
    assert r64["ratio"] > 1.0          # DiLoCo pays the straggler barrier
    assert r512["ratio"] > r64["ratio"]  # and it worsens with world size
