"""Data pipeline determinism + checkpoint roundtrip."""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save, latest_step
from repro.data import LoaderConfig, SyntheticLM, pack_documents, shard_iterator


def test_synthetic_deterministic_and_shard_disjoint():
    lm = SyntheticLM(256, seed=9)
    a = lm.sample_tokens(3, 500)
    assert (a == lm.sample_tokens(3, 500)).all()
    assert not (a == lm.sample_tokens(4, 500)).all()
    assert a.min() >= 0 and a.max() < 256


def test_synthetic_has_learnable_structure():
    """Bigram entropy must be well below unigram entropy (else nothing to
    learn and the convergence benchmarks are meaningless)."""
    lm = SyntheticLM(64, seed=0)
    t = lm.sample_tokens(0, 20000)
    uni = np.bincount(t, minlength=64) / len(t)
    h_uni = -(uni[uni > 0] * np.log(uni[uni > 0])).sum()
    joint = np.zeros((64, 64))
    np.add.at(joint, (t[:-1], t[1:]), 1)
    joint /= joint.sum()
    marg = joint.sum(1, keepdims=True)
    cond = np.divide(joint, marg, out=np.zeros_like(joint), where=marg > 0)
    h_bi = -(joint[cond > 0] * np.log(cond[cond > 0])).sum()
    assert h_bi < 0.7 * h_uni


def test_loader_resume_reproduces_stream():
    cfg = LoaderConfig(vocab_size=64, seq_len=8, per_replica_batch=2, replicas=2)
    it1 = shard_iterator(cfg)
    batches = [next(it1) for _ in range(5)]
    it2 = shard_iterator(cfg, start_step=3)
    b3 = next(it2)
    np.testing.assert_array_equal(batches[3]["tokens"], b3["tokens"])


def test_packing_masks_document_boundaries():
    docs = [np.arange(1, 40), np.arange(1, 25)]
    t, l, m = pack_documents(docs, 16, eos_id=0)
    assert t.shape[1] == 16
    # every eos INPUT position is masked out of the loss
    assert not m[t == 0].any()


def test_checkpoint_roundtrip_nested():
    tree = {
        "theta": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4)},
        "opt": [jnp.ones((2, 2)), None],
        "count": (jnp.asarray(7, jnp.int32),),
    }
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, tree)
        save(d, 9, tree)
        assert latest_step(d) == 9
        back = restore(d, 3)
        np.testing.assert_array_equal(
            np.asarray(back["theta"]["w"], np.float32),
            np.asarray(tree["theta"]["w"], np.float32),
        )
        assert back["opt"][1] is None
        assert isinstance(back["count"], tuple)
        assert int(back["count"][0]) == 7
