"""Data pipeline determinism + checkpoint roundtrip."""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save, latest_step
from repro.data import LoaderConfig, SyntheticLM, pack_documents, shard_iterator


def test_synthetic_deterministic_and_shard_disjoint():
    lm = SyntheticLM(256, seed=9)
    a = lm.sample_tokens(3, 500)
    assert (a == lm.sample_tokens(3, 500)).all()
    assert not (a == lm.sample_tokens(4, 500)).all()
    assert a.min() >= 0 and a.max() < 256


def test_synthetic_has_learnable_structure():
    """Bigram entropy must be well below unigram entropy (else nothing to
    learn and the convergence benchmarks are meaningless)."""
    lm = SyntheticLM(64, seed=0)
    t = lm.sample_tokens(0, 20000)
    uni = np.bincount(t, minlength=64) / len(t)
    h_uni = -(uni[uni > 0] * np.log(uni[uni > 0])).sum()
    joint = np.zeros((64, 64))
    np.add.at(joint, (t[:-1], t[1:]), 1)
    joint /= joint.sum()
    marg = joint.sum(1, keepdims=True)
    cond = np.divide(joint, marg, out=np.zeros_like(joint), where=marg > 0)
    h_bi = -(joint[cond > 0] * np.log(cond[cond > 0])).sum()
    assert h_bi < 0.7 * h_uni


def test_loader_resume_reproduces_stream():
    cfg = LoaderConfig(vocab_size=64, seq_len=8, per_replica_batch=2, replicas=2)
    it1 = shard_iterator(cfg)
    batches = [next(it1) for _ in range(5)]
    it2 = shard_iterator(cfg, start_step=3)
    b3 = next(it2)
    np.testing.assert_array_equal(batches[3]["tokens"], b3["tokens"])


def test_packing_masks_document_boundaries():
    docs = [np.arange(1, 40), np.arange(1, 25)]
    t, l, m = pack_documents(docs, 16, eos_id=0)
    assert t.shape[1] == 16
    # every eos INPUT position is masked out of the loss
    assert not m[t == 0].any()


def test_checkpoint_roundtrip_nested():
    tree = {
        "theta": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4)},
        "opt": [jnp.ones((2, 2)), None],
        "count": (jnp.asarray(7, jnp.int32),),
    }
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, tree)
        save(d, 9, tree)
        assert latest_step(d) == 9
        back = restore(d, 3)
        np.testing.assert_array_equal(
            np.asarray(back["theta"]["w"], np.float32),
            np.asarray(tree["theta"]["w"], np.float32),
        )
        assert back["opt"][1] is None
        assert isinstance(back["count"], tuple)
        assert int(back["count"][0]) == 7


def _steps_on_disk(d):
    import re

    return sorted(
        int(m.group(1))
        for n in os.listdir(d)
        for m in [re.fullmatch(r"step_(\d+)", n)]
        if m
    )


def test_checkpoint_keep_prunes_oldest(tmp_path):
    """save(keep=N) retains exactly the N newest step dirs, prunes in age
    order, and each pruned dir is fully removed (no orphan files)."""
    d = str(tmp_path)
    tree = {"w": jnp.arange(4.0)}
    for step in (2, 5, 8, 11, 14):
        save(d, step, tree, keep=3)
    assert _steps_on_disk(d) == [8, 11, 14]
    assert latest_step(d) == 14
    # the survivors still restore
    np.testing.assert_array_equal(np.asarray(restore(d, 8)["w"]), np.arange(4.0))
    # pruned dirs are gone entirely
    assert not os.path.exists(os.path.join(d, "step_00000002"))
    with pytest.raises(FileNotFoundError):
        restore(d, 2)


def test_checkpoint_keep_ignores_foreign_entries(tmp_path):
    """Retention only counts step_* dirs: unrelated files and non-step names
    under the checkpoint root are never deleted."""
    d = str(tmp_path)
    tree = {"w": jnp.zeros(2)}
    os.makedirs(os.path.join(d, "notes"))
    with open(os.path.join(d, "events.jsonl"), "w") as f:
        f.write("{}\n")
    with open(os.path.join(d, "step_final.txt"), "w") as f:
        f.write("not a checkpoint dir\n")
    for step in (1, 2, 3):
        save(d, step, tree, keep=2)
    assert _steps_on_disk(d) == [2, 3]
    assert os.path.isdir(os.path.join(d, "notes"))
    assert os.path.exists(os.path.join(d, "events.jsonl"))
    assert os.path.exists(os.path.join(d, "step_final.txt"))


def test_checkpoint_keep_none_retains_everything(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.zeros(2)}
    for step in (1, 2, 3, 4):
        save(d, step, tree)  # keep=None
    assert _steps_on_disk(d) == [1, 2, 3, 4]
    # a later bounded save prunes the backlog in one pass
    save(d, 5, tree, keep=2)
    assert _steps_on_disk(d) == [4, 5]
