"""Kernel-dispatch layer: registry completeness, pallas-interpret vs jnp
parity for every registered op, gradient parity through the custom_vjp ops,
and end-to-end toy-LM loss parity with ``impl="pallas"`` interpret mode."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.compress import Int8Codec
from repro.core import outer as outer_lib
from repro.kernels import dispatch as dispatch_mod
from repro.kernels import ops, ref
from repro.kernels.dispatch import KernelConfig
from repro.models import model as model_api
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardCtx

KEY = jax.random.PRNGKey(7)
PALLAS = KernelConfig(impl="pallas", interpret=True)
JNP = KernelConfig(impl="jnp")

EXPECTED_OPS = {
    "flash_attention",
    "ssd_chunk",
    "rglru_scan",
    "noloco_update",
    "int8_quantize",
    "int8_dequantize",
    "paged_attention",
    "paged_chunk_attention",
    "rglru_decode",
    "ssd_decode",
}


# ---------------------------------------------------------------------------
# Registry / config resolution
# ---------------------------------------------------------------------------


def test_registry_is_complete():
    reg = dispatch_mod.registry()
    assert set(reg) == EXPECTED_OPS
    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    for op in reg.values():
        assert callable(op.pallas) and callable(op.jnp)
        assert op.consumers, f"{op.name} has no documented consumers"
        assert os.path.exists(os.path.join(root, op.pallas_file)), op.pallas_file


def test_config_resolution_rules():
    # this box is CPU: auto -> jnp, interpret -> True unless pinned
    assert jax.default_backend() != "tpu"
    assert KernelConfig().resolved_impl() == "jnp"
    assert KernelConfig("pallas").resolved_interpret() is True
    assert KernelConfig("pallas", interpret=False).resolved_interpret() is False
    with pytest.raises(ValueError):
        KernelConfig(impl="cuda").resolved_impl()
    # dispatch returns distinct callables per impl
    assert dispatch_mod.dispatch("rglru_scan", JNP) is ref.jnp_rglru_scan


# ---------------------------------------------------------------------------
# Per-op forward parity: pallas-interpret vs jnp twin
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape,mode,window", [
    ((1, 128, 128, 4, 4, 64), "causal", 0),   # MHA
    ((2, 64, 64, 4, 2, 32), "causal", 0),     # GQA
    ((1, 96, 96, 4, 1, 32), "local", 32),     # MQA sliding window
    ((1, 64, 96, 2, 2, 32), "full", 0),       # cross lengths
])
def test_attention_impl_parity(shape, mode, window):
    b, sq, sk, h, kv, d = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sk, kv, d))
    v = jax.random.normal(ks[2], (b, sk, kv, d))
    op = ops.flash_attention(q, k, v, mode=mode, window=window, config=PALLAS)
    oj = ops.flash_attention(q, k, v, mode=mode, window=window, config=JNP)
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=2e-5, rtol=1e-4)


def test_attention_gradient_parity_via_custom_vjp():
    """Gradients through the dispatched op (jnp online-softmax backward) must
    match differentiating the naive oracle — for BOTH forward impls."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))

    def oracle(q, k, v):
        b, sq, h, d = q.shape
        kvh = k.shape[2]
        hm = (jnp.arange(h) * kvh) // h
        ke, ve = jnp.take(k, hm, 2), jnp.take(v, hm, 2)
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
        kf = ke.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
        vf = ve.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
        g = ref.reference_attention(qf, kf, vf, mode="causal")
        return g.reshape(b, h, sq, d).transpose(0, 2, 1, 3)

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) ** 2)

    g_ref = jax.grad(loss(oracle), argnums=(0, 1, 2))(q, k, v)
    for cfg in (PALLAS, JNP):
        g = jax.grad(
            loss(lambda q, k, v: ops.flash_attention(q, k, v, config=cfg)),
            argnums=(0, 1, 2),
        )(q, k, v)
        for got, want in zip(g, g_ref):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=3e-5, rtol=1e-4
            )


def test_ssd_impl_parity():
    b, s, h, p, n, chunk = 2, 96, 2, 16, 8, 32
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 3), (h,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, n)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(KEY, 5), (b, s, n)) * 0.5
    h0 = jax.random.normal(jax.random.fold_in(KEY, 6), (b, h, p, n)) * 0.2
    y1, f1 = ops.ssd_chunk(x, dt, a, bm, cm, chunk=chunk, initial_state=h0, config=PALLAS)
    y2, f2 = ops.ssd_chunk(x, dt, a, bm, cm, chunk=chunk, initial_state=h0, config=JNP)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=2e-5, rtol=1e-4)


def test_rglru_impl_parity_and_gradients():
    b, s, w = 2, 80, 48
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 7), (b, s, w))) * 0.5 + 0.45
    bb = jax.random.normal(jax.random.fold_in(KEY, 8), (b, s, w)) * 0.3
    h1 = ops.rglru_scan(a, bb, config=PALLAS)
    h2 = ops.rglru_scan(a, bb, config=JNP)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5, rtol=1e-5)

    def loss(fn):
        return lambda a, b: jnp.sum(fn(a, b) ** 2)

    g_ref = jax.grad(loss(ref.jnp_rglru_scan), argnums=(0, 1))(a, bb)
    for cfg in (PALLAS, JNP):
        g = jax.grad(
            loss(lambda a, b: ops.rglru_scan(a, b, config=cfg)), argnums=(0, 1)
        )(a, bb)
        for got, want in zip(g, g_ref):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5
            )


def test_outer_step_stacked_kernel_parity():
    """The stacked gossip outer step must produce identical states whichever
    implementation backs the fused update."""
    world, n = 4, 257
    theta = {"w": jax.random.normal(jax.random.fold_in(KEY, 9), (world, n))}
    state = outer_lib.init_outer_state(
        {"w": jnp.broadcast_to(theta["w"][0], (world, n))}
    )
    cfg = outer_lib.OuterConfig(method="noloco")
    partner = jnp.asarray([1, 0, 3, 2])
    s1, t1 = outer_lib.outer_step_stacked(
        state, theta, cfg, partner=partner, kernel_cfg=PALLAS
    )
    s2, t2 = outer_lib.outer_step_stacked(
        state, theta, cfg, partner=partner, kernel_cfg=JNP
    )
    np.testing.assert_allclose(np.asarray(t1["w"]), np.asarray(t2["w"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1.delta["w"]), np.asarray(s2.delta["w"]), atol=1e-6)


def test_int8_codec_kernel_parity():
    """Int8Codec wired to the Pallas kernels must produce a wire the jnp
    codec decodes (and vice versa) within one quantization step."""
    buf = jax.random.normal(jax.random.fold_in(KEY, 10), (5000,)) * 2.0
    cp = Int8Codec(chunk=256, kernel_cfg=PALLAS)
    cj = Int8Codec(chunk=256, kernel_cfg=JNP)
    wire_p = cp.encode(buf)
    wire_j = cj.encode(buf)
    assert wire_p.shape == wire_j.shape and wire_p.dtype == jnp.uint8
    step = 2.0 * 4.0 / 255.0  # generous bound on the per-chunk scale
    for enc, dec in ((cp, cj), (cj, cp)):
        out = dec.decode(enc.encode(buf), jnp.float32, buf.shape[0])
        assert float(jnp.abs(out - buf).max()) < 2 * step


# ---------------------------------------------------------------------------
# End-to-end toy-LM parity: impl="pallas" (interpret) vs impl="jnp"
# ---------------------------------------------------------------------------


def _toy_cfg(**kw) -> ModelConfig:
    base = dict(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=128, dtype="float32", remat=False,
    )
    base.update(kw)
    return ModelConfig(**base)


def _toy_batch(cfg, b=2, s=32):
    return {
        "tokens": jax.random.randint(jax.random.fold_in(KEY, 11), (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(KEY, 12), (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch_kw", [
    dict(),                                                        # dense GQA
    dict(attn_pattern=("rglru", "local"), sliding_window=16, lru_width=64),
    dict(arch_type="ssm", attn_pattern=("ssd",), ssm_state_dim=16,
         ssm_head_dim=16, ssm_chunk=16, num_heads=4, num_kv_heads=4),
])
def test_toy_lm_loss_parity(arch_kw):
    cfg = _toy_cfg(**arch_kw)
    ctx = ShardCtx.local()
    params = values_of(model_api.init_params(jax.random.PRNGKey(3), cfg))
    batch = _toy_batch(cfg)
    cfg_p = dataclasses.replace(cfg, kernels=PALLAS)
    cfg_j = dataclasses.replace(cfg, kernels=JNP)
    lp = model_api.loss_fn(params, cfg_p, batch, ctx)[0]
    lj = model_api.loss_fn(params, cfg_j, batch, ctx)[0]
    np.testing.assert_allclose(float(lp), float(lj), rtol=2e-5, atol=2e-5)


def test_toy_lm_training_parity_pallas_interpret():
    """A short SGD run must follow the same loss trajectory under both
    implementations (forward impl differs, custom_vjp backward shared)."""
    cfg = _toy_cfg(attn_pattern=("global", "local"), sliding_window=16)
    ctx = ShardCtx.local()

    def run(kcfg):
        c = dataclasses.replace(cfg, kernels=kcfg)
        params = values_of(model_api.init_params(jax.random.PRNGKey(5), c))
        losses = []
        for t in range(3):
            batch = {
                "tokens": jax.random.randint(jax.random.fold_in(KEY, 100 + t), (2, 32), 0, c.vocab_size),
                "labels": jax.random.randint(jax.random.fold_in(KEY, 200 + t), (2, 32), 0, c.vocab_size),
            }
            loss, grads = jax.value_and_grad(
                lambda p: model_api.loss_fn(p, c, batch, ctx)[0]
            )(params)
            params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
            losses.append(float(loss))
        return losses

    lp = run(PALLAS)
    lj = run(JNP)
    np.testing.assert_allclose(lp, lj, rtol=5e-5, atol=5e-5)
    assert lp[-1] < lp[0]  # it actually trains
