"""Property + acceptance tests for the asynchronous per-replica round clock
(DESIGN.md §7): monotone sync indices, rate-1 bit-identity with the
synchronous engine, stale-rule reduction at τ=0, pairing involution at merged
ticks, and the 2x-straggler zero-blocked-syncs acceptance scenario.

Property tests run under hypothesis when it is installed; without it they
degrade to a deterministic seeded sweep of the same strategies (the container
does not ship hypothesis and installing packages is off the table), so the
invariants are exercised either way.
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.models.config import ModelConfig
from repro.launch.train_elastic import run_elastic_training
from repro.sim import FaultEvent, FaultPlan
from repro.sim.cluster import ReplicaClock

# --------------------------------------------------------------------------
# hypothesis shim: real strategies when available, a deterministic seeded
# sweep of equivalent draws when not
# --------------------------------------------------------------------------

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: float(lo + (hi - lo) * rng.random()))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]

            return _Strategy(draw)

    st = _St()

    def given(**strategies):
        def deco(fn):
            def wrapper(*a, **kw):
                examples = getattr(wrapper, "_max_examples", 25)
                for i in range(examples):
                    rng = np.random.default_rng(
                        abs(hash((fn.__name__, i))) % (2**32)
                    )
                    fn(*a, **{k: s.draw(rng) for k, s in strategies.items()},
                       **kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._hypothesis_inner = fn
            return wrapper

        return deco

    def settings(max_examples=25, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco


# --------------------------------------------------------------------------
# clock properties (cheap: pure host-side ReplicaClock)
# --------------------------------------------------------------------------

RATE_CHOICES = (1.0, 0.5, 1.0 / 3.0, 0.25, 0.1)


def _drive(world, rates, m, ticks):
    """Run the clock for ``ticks`` wall ticks; returns the per-merged-tick
    trace of (due mask, staleness, sync_count snapshot)."""
    clock = ReplicaClock(world, m)
    for r, rho in enumerate(rates):
        clock.set_rate([r], rho)
    member = np.ones(world, dtype=bool)
    trace = []
    for _ in range(ticks):
        clock.tick(member)
        due = clock.due_mask(member)
        if not due.any():
            continue
        tau = clock.staleness()
        clock.advance_sync(due)
        trace.append((due.copy(), tau.copy(), clock.sync_count.copy()))
    return clock, trace


@given(world=st.integers(2, 12), seed=st.integers(0, 10**6),
       m=st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_clock_sync_indices_monotone_and_consistent(world, seed, m):
    """Per-replica sync indices only ever move forward, one at a time, and a
    replica is due only once it has banked the next full inner phase."""
    rng = np.random.default_rng(seed)
    rates = [RATE_CHOICES[int(rng.integers(len(RATE_CHOICES)))]
             for _ in range(world)]
    clock, trace = _drive(world, rates, m, ticks=12 * m)
    prev = np.zeros(world, dtype=np.int64)
    for due, tau, counts in trace:
        step = counts - prev
        assert ((step == 0) | (step == 1)).all(), (prev, counts)
        np.testing.assert_array_equal(step == 1, due)  # exactly the due set
        assert (tau >= 0).all()
        prev = counts
    # every replica's banked local steps cover the syncs it has been charged
    assert (clock.local_step >= clock.sync_count * m).all()
    # and nobody is owed more than one pending sync phase of steps
    assert (clock.local_step < (clock.sync_count + 2) * m).all()


@given(world=st.integers(2, 12), m=st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_clock_rate_one_world_has_zero_staleness(world, m):
    """A homogeneous rate-1 world: every replica is due at every merged tick,
    merged ticks land exactly every m wall ticks, and τ is identically 0 —
    the precondition for the bitwise legacy fast path."""
    _, trace = _drive(world, [1.0] * world, m, ticks=8 * m)
    assert len(trace) == 8
    for due, tau, _ in trace:
        assert due.all()
        assert not tau.any()


@given(seed=st.integers(0, 10**6), m=st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_clock_staleness_stationary_at_inverse_rate(seed, m):
    """A constant-rate straggler's τ settles at 1/ρ − 1 (the 2x replica of
    the acceptance scenario skips exactly one merged tick per sync)."""
    rng = np.random.default_rng(seed)
    rho = float(rng.choice([0.5, 0.25]))
    world = int(rng.integers(3, 9))
    slow = int(rng.integers(world))
    rates = [1.0] * world
    rates[slow] = rho
    _, trace = _drive(world, rates, m, ticks=int(40 * m / rho))
    taus = [int(tau[slow]) for due, tau, _ in trace if due[slow]]
    assert taus, "straggler never synced"
    expect = round(1.0 / rho) - 1
    # discard the warm-up sync; after that the clock is periodic
    assert all(t == expect for t in taus[1:]), (taus, expect)


def test_clock_checkpoint_roundtrip_mid_flight():
    """state_dict/load_state_dict restore credits, local steps and merged-tick
    counters exactly — the continued trace equals the uninterrupted one."""
    rates = [0.5, 1.0, 1.0, 1.0 / 3.0]
    full_clock, full = _drive(4, rates, 3, ticks=60)
    half_clock, _ = _drive(4, rates, 3, ticks=30)
    resumed = ReplicaClock(4, 3)
    resumed.load_state_dict(half_clock.state_dict())
    member = np.ones(4, dtype=bool)
    cont = []
    for _ in range(30):
        resumed.tick(member)
        due = resumed.due_mask(member)
        if not due.any():
            continue
        tau = resumed.staleness()
        resumed.advance_sync(due)
        cont.append((due.copy(), tau.copy(), resumed.sync_count.copy()))
    tail = full[len(full) - len(cont):]
    assert len(cont) == len(tail)
    for (d1, t1, c1), (d2, t2, c2) in zip(tail, cont):
        np.testing.assert_array_equal(d1, d2)
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(full_clock.local_step, resumed.local_step)


# --------------------------------------------------------------------------
# engine-level: rate-1 bit identity, τ=0 reduction, pairing involution,
# and the 2x-straggler acceptance scenario
# --------------------------------------------------------------------------

TINY = ModelConfig(
    name="tiny-async", num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=128, dtype="float32", remat=False,
)

KW = dict(replicas=4, per_replica_batch=2, seq_len=32, steps=12,
          inner_steps=3, inner_lr=3e-3, eval_every=0, seed=0, total_steps=12)


@pytest.fixture(scope="module")
def legacy_sync():
    return run_elastic_training(TINY, FaultPlan(), **KW)


@pytest.mark.parametrize("stale", ["naive", "momentum"])
def test_rate_one_async_world_bitwise_identical_to_synchronous(
    legacy_sync, stale
):
    """async_clock=True with no rate events is a rate-1 world: τ ≡ 0, so BOTH
    stale rules must reduce to the legacy synchronous engine bit-for-bit
    (losses and final θ exactly equal — same compiled program, in fact)."""
    res = run_elastic_training(
        TINY, FaultPlan(), async_clock=True, stale=stale, **KW
    )
    np.testing.assert_array_equal(
        np.asarray(legacy_sync["losses"]), np.asarray(res["losses"])
    )
    for a, b in zip(
        jax.tree.leaves(legacy_sync["state"].theta),
        jax.tree.leaves(res["state"].theta),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res["max_staleness"] == 0
    assert res["blocked_syncs"] == 0


@pytest.fixture(scope="module")
def straggler_async():
    plan = FaultPlan([
        FaultEvent(kind="rate", round=0, replicas=[1], rate=0.5)
    ])
    return run_elastic_training(
        TINY, plan, **{**KW, "replicas": 8, "steps": 24, "total_steps": 24}
    )


def test_two_x_straggler_records_zero_blocked_syncs(straggler_async):
    """The acceptance scenario: a 2x straggler on its own clock syncs late
    with a stale Δ instead of forcing self-pairs on the survivors — zero
    blocked syncs, max τ = 1/ρ − 1 = 1."""
    assert straggler_async["blocked_syncs"] == 0
    assert straggler_async["max_staleness"] == 1
    # the straggler missed no round outright: it is either due or a passive
    # gossip source at every merged tick
    assert all(r["absent"] == [] for r in straggler_async["rounds"])
    # and it really did run at half rate: due at every OTHER merged tick
    due_hist = [1 in r["due"] for r in straggler_async["fault_history"]
                if r.get("event") == "round"]
    assert True in due_hist and False in due_hist


def test_round_synchronous_straggler_blocks_every_other_round():
    """The baseline the async clock is measured against: the same 2x
    slowdown modeled round-synchronously (sitting out every other round)
    forces a self-pair on an odd-man-out survivor in EVERY straggled round."""
    rounds = 6
    plan = FaultPlan([
        FaultEvent(kind="straggle", round=r, replicas=[1])
        for r in range(1, rounds, 2)
    ])
    res = run_elastic_training(
        TINY, plan, **{**KW, "replicas": 8, "steps": 24, "total_steps": 24,
                       "inner_steps": 4}
    )
    assert res["blocked_syncs"] >= len(range(1, rounds, 2))
    assert res["max_staleness"] == 0


def test_merged_tick_pairing_is_involution_over_participants(straggler_async):
    """At every merged tick the pairing is drawn over ALL participants (due
    or passive) and must be a self-inverse matching, exactly like the
    synchronous round pairing."""
    ticks = [r for r in straggler_async["fault_history"]
             if r.get("event") == "round"]
    assert ticks
    for rec in ticks:
        partner = rec["partner"]
        assert partner is not None
        participants = set(rec["active"]) - set(rec["absent"])
        for r in participants:
            assert partner[partner[r]] == r, (rec,)


def test_staleness_telemetry_present_in_async_summary(straggler_async):
    """Per-sync staleness rides the telemetry: every async event carries the
    due set and the τ vector, and the run summary aggregates them."""
    events = [r for r in straggler_async["fault_history"]
              if r.get("event") == "round"]
    for ev in events:
        assert "staleness" in ev and len(ev["staleness"]) == 8
        assert "due" in ev and ev["due"]
    assert "max_staleness" in straggler_async
    assert "blocked_syncs" in straggler_async
