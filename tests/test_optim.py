"""AdamW / clipping / schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, clip_by_global_norm, global_norm,
    warmup_cosine,
)


def test_adamw_first_step_is_lr_sized():
    """With bias correction, |Δp| ≈ lr on step 1 (ignoring eps/decay)."""
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 0.5)}
    st = adamw_init(p)
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, clip_norm=None)
    new_p, st, _ = adamw_update(g, st, p, cfg)
    np.testing.assert_allclose(np.asarray(p["w"] - new_p["w"]), 1e-2, rtol=1e-3)


def test_adamw_converges_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw_init(p)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=1.0)
    for _ in range(300):
        g = {"w": p["w"]}
        p, st, _ = adamw_update(g, st, p, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert norm == pytest.approx(np.sqrt(300), rel=1e-5)
    assert global_norm(clipped) == pytest.approx(1.0, rel=1e-5)
    g2 = {"a": jnp.full((3,), 1e-3)}
    clipped2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 1e-3)  # untouched


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, total_steps=1000, warmup_steps=100, final_ratio=0.1)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(1.0, abs=0.02)
    assert float(sched(jnp.asarray(1000))) == pytest.approx(0.1, abs=0.01)
    # monotone decay after warmup
    vals = [float(sched(jnp.asarray(s))) for s in range(100, 1001, 100)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_mixed_precision_moments_are_f32():
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw_init(p)
    assert st.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    new_p, st, _ = adamw_update(g, st, p, AdamWConfig(lr=1e-2))
    assert new_p["w"].dtype == jnp.bfloat16
