"""Per-architecture REDUCED smoke tests (assignment requirement): 2 layers,
d_model<=512, <=4 experts, one forward/train step on CPU, output shapes +
no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import model as M
from repro.models.common import values_of
from repro.parallel.sharding import ShardCtx

CTX = ShardCtx.local()
B, S = 2, 32


def _batch(cfg):
    text = S - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    key = jax.random.PRNGKey(7)
    batch = {
        "tokens": jax.random.randint(key, (B, text), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, text), 0, cfg.vocab_size),
    }
    if cfg.frontend == "audio":
        batch["encoder_embeds"] = jnp.ones(
            (B, cfg.encoder_seq, cfg.frontend_dim or cfg.d_model), jnp.float32
        )
    if cfg.frontend == "vision":
        batch["image_embeds"] = jnp.ones((B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", registry.ASSIGNED)
def test_reduced_forward_and_grad_step(arch):
    cfg = registry.get_config(arch).reduced(dtype="float32", remat=False)
    cfg.validate()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    vals = values_of(M.init_params(jax.random.PRNGKey(0), cfg))
    batch = _batch(cfg)

    loss, metrics = M.loss_fn(vals, cfg, batch, CTX)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"

    # one actual train step: grads finite, params move
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch, CTX)[0])(vals)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gn)), f"{arch}: grad not finite"
    assert float(gn) > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-9b", "mamba2-370m", "whisper-base"])
def test_reduced_decode_matches_shapes(arch):
    cfg = registry.get_config(arch).reduced(dtype="float32", remat=False)
    vals = values_of(M.init_params(jax.random.PRNGKey(0), cfg))
    caches = values_of(M.init_cache_tree(cfg, 1, 16))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "audio":
        batch["encoder_embeds"] = jnp.ones((1, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
        # enc-dec decode needs the cross cache built from encoder output
    _, caches = M.prefill(vals, cfg, batch, caches, CTX)
    logits, caches = M.decode_step(vals, cfg, toks[:, :1], jnp.asarray(8), caches, CTX)
    assert logits.shape == (1, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
