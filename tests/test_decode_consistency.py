"""Prefill+decode must reproduce the full-forward logits (cache correctness)
for every cache type: global attention, sliding window, RG-LRU, SSD."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models import model as M
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.common import values_of
from repro.models.layers import apply_norm, logits_sharded
from repro.parallel.sharding import ShardCtx

CTX = ShardCtx.local()

CFGS = {
    "global": ModelConfig(num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=128, qk_norm=True,
                          dtype="float32", remat=False),
    "local": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
                         d_ff=128, vocab_size=128, attn_pattern=("local",),
                         sliding_window=6, dtype="float32", remat=False),
    "rglru": ModelConfig(arch_type="hybrid", num_layers=3, d_model=64, num_heads=4,
                         num_kv_heads=1, d_ff=128, vocab_size=128,
                         attn_pattern=("rglru", "rglru", "local"), sliding_window=6,
                         lru_width=64, dtype="float32", remat=False),
    "ssd": ModelConfig(arch_type="ssm", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=4, d_ff=0, vocab_size=128, attn_pattern=("ssd",),
                       ssm_state_dim=16, ssm_head_dim=32, ssm_chunk=4,
                       use_rope=False, dtype="float32", remat=False),
}


def _full_logits(vals, cfg, toks):
    x, _ = M.embed_input(vals, cfg, {"tokens": toks}, CTX)
    x, _, _ = tfm.apply_stack(vals["stack"], cfg, x, CTX,
                              positions=jnp.arange(toks.shape[1]))
    x = apply_norm(vals["final_norm"], x)
    return logits_sharded(vals["embed"], cfg, x, CTX)


@pytest.mark.parametrize("kind", list(CFGS))
def test_decode_equals_full_forward(kind):
    cfg = CFGS[kind]
    vals = values_of(M.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab_size)
    full = _full_logits(vals, cfg, toks)

    caches = values_of(M.init_cache_tree(cfg, 1, 16))
    _, caches = M.prefill(vals, cfg, {"tokens": toks[:, :6]}, caches, CTX)
    errs = []
    for i in range(6, 12):
        lg, caches = M.decode_step(vals, cfg, toks[:, i:i + 1], jnp.asarray(i), caches, CTX)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    assert max(errs) < 2e-3, f"{kind}: {errs}"


def test_local_ring_buffer_wraps_correctly():
    """Decode far past the window: ring writes must keep exactly the last
    `window` positions."""
    cfg = CFGS["local"]
    vals = values_of(M.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 20), 0, cfg.vocab_size)
    full = _full_logits(vals, cfg, toks)
    caches = values_of(M.init_cache_tree(cfg, 1, 20))
    _, caches = M.prefill(vals, cfg, {"tokens": toks[:, :4]}, caches, CTX)
    for i in range(4, 20):
        lg, caches = M.decode_step(vals, cfg, toks[:, i:i + 1], jnp.asarray(i), caches, CTX)
    err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, 19])))
    assert err < 2e-3, err


def test_encdec_cross_cache_built_at_prefill():
    """Whisper-style enc-dec: prefill must BUILD the cross-attention K/V from
    the encoder output; decode logits must then match the full forward."""
    import dataclasses
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        arch_type="encdec", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=128, is_encoder_decoder=True,
        num_encoder_layers=2, encoder_seq=8, use_rope=False,
        norm_type="layernorm", frontend="audio", frontend_dim=64,
        frontend_tokens=8, dtype="float32", remat=False,
    )
    key = jax.random.PRNGKey(0)
    vals = values_of(M.init_params(key, cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, 128)
    enc = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 64))

    # full forward logits
    enc_out = M.encode(vals, cfg, enc, CTX)
    x, _ = M.embed_input(vals, cfg, {"tokens": toks}, CTX)
    x, _, _ = tfm.apply_stack(vals["stack"], cfg, x, CTX,
                              positions=jnp.arange(10), enc_out=enc_out)
    x = apply_norm(vals["final_norm"], x)
    full = logits_sharded(vals["embed"], cfg, x, CTX)

    caches = values_of(M.init_cache_tree(cfg, 1, 16))
    _, caches = M.prefill(
        vals, cfg, {"tokens": toks[:, :5], "encoder_embeds": enc}, caches, CTX
    )
    errs = []
    for i in range(5, 10):
        lg, caches = M.decode_step(vals, cfg, toks[:, i:i + 1], jnp.asarray(i), caches, CTX)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    assert max(errs) < 2e-3, errs
