"""The unified training engine (repro/train): resume correctness, telemetry,
the grad-free eval path, and the pipeline runtime's full §3.1+§3.2 method."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.outer import OuterConfig
from repro.data import LoaderConfig, eval_batches, shard_iterator
from repro.launch.train import run_training
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.pipeline import PipelineTrainer
from repro.train import LoopConfig, PipelineProgram, TrainLoop

TINY = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=128, dtype="float32", remat=False)

KW = dict(method="noloco", replicas=4, per_replica_batch=2, seq_len=32,
          inner_lr=3e-3, inner_steps=4, eval_every=0, total_steps=12)


def test_resume_matches_uninterrupted(tmp_path):
    """Interrupt at step 6, restore, continue to 12: the loss trajectory must
    be IDENTICAL to an uninterrupted 12-step run (state + loader fast-forward
    + PRNG keys all round-trip)."""
    full = run_training(TINY, steps=12, **KW)
    d = str(tmp_path / "ckpt")
    run_training(TINY, steps=6, ckpt_dir=d, **KW)
    cont = run_training(TINY, steps=12, ckpt_dir=d, resume=True, **KW)
    assert cont["start_step"] == 6
    assert cont["steps_run"] == 6
    np.testing.assert_array_equal(
        np.asarray(full["losses"][6:]), np.asarray(cont["losses"])
    )
    # final states agree too, not just the scalar losses
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(full["state"].theta)[0]),
        np.asarray(jax.tree.leaves(cont["state"].theta)[0]),
    )


def test_resume_without_checkpoint_starts_fresh(tmp_path):
    res = run_training(TINY, steps=4, ckpt_dir=str(tmp_path / "none"),
                       resume=True, **KW)
    assert res["start_step"] == 0 and len(res["losses"]) == 4


def test_periodic_checkpoints_respect_keep(tmp_path):
    d = str(tmp_path / "ckpt")
    run_training(TINY, steps=12, ckpt_dir=d, ckpt_every=3, **KW)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d))
    assert len(steps) == 3  # LoopConfig.ckpt_keep default
    assert steps[-1] == 12


def test_jsonl_telemetry_stream(tmp_path):
    path = str(tmp_path / "events.jsonl")
    res = run_training(TINY, steps=8, log_jsonl=path,
                       **{**KW, "eval_every": 4})
    events = [json.loads(l) for l in open(path)]
    kinds = [e["event"] for e in events]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end"
    assert kinds.count("step") == 8
    assert kinds.count("outer") == res["outer_syncs"] == 2
    assert kinds.count("eval") == 2
    steps = [e for e in events if e["event"] == "step"]
    assert [round(e["loss"], 6) for e in steps] == [
        round(l, 6) for l in res["losses"]
    ]
    outer = next(e for e in events if e["event"] == "outer")
    assert outer["payload_bytes"] > 0
    # run_end carries the throughput/comm accounting
    end = events[-1]
    assert end["tokens_per_s"] > 0 and end["comm_bytes"] > 0


def test_eval_is_grad_free_and_matches_training_loss_scale():
    """GossipTrainer.eval_loss (public, no grads) should agree with the loss
    the training step reports on the same batch/params."""
    from repro.core import GossipTrainer
    from repro.launch.train import method_config
    from repro.models import model as model_api
    from repro.models.common import values_of
    from repro.parallel.sharding import ShardCtx

    ctx = ShardCtx.local()
    tcfg = method_config("noloco", inner_lr=1e-3, total_steps=10)
    tr = GossipTrainer(
        tcfg, lambda p, b, r: model_api.loss_fn(p, TINY, b, ctx)[0]
    )
    one = values_of(model_api.init_params(jax.random.PRNGKey(0), TINY))
    stacked = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (4,) + v.shape), one
    )
    state = tr.init(stacked)
    it = shard_iterator(LoaderConfig(
        vocab_size=TINY.vocab_size, seq_len=32, per_replica_batch=2, replicas=4
    ))
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    rng = jax.random.PRNGKey(1)
    ev = tr.eval_loss(state.theta, batch, rng)
    assert ev.shape == (4,)
    _, metrics = tr.inner_step(state, batch, rng)
    np.testing.assert_allclose(
        np.asarray(ev), np.asarray(metrics["loss"]), rtol=1e-5
    )


def test_shared_weight_std_helper_consistency():
    from repro.core import GossipTrainer
    from repro.core.metrics import replica_weight_std

    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (4, 8, 3))}
    a = float(GossipTrainer.replica_weight_std(tree))
    b = float(replica_weight_std(tree))
    assert a == b
    # list-of-stages form averages over all leaves of all stages
    c = float(replica_weight_std([tree, tree]))
    np.testing.assert_allclose(c, a, rtol=1e-6)


# ---------------------------------------------------------------------------
# Pipeline runtime: §3.1 routing + §3.2 gossip through the same loop
# ---------------------------------------------------------------------------


def _pipeline_loop(method, steps, tmpdir=None, resume=False, ckpt_every=0):
    outer = None
    if method != "none":
        outer = OuterConfig(method=method, inner_steps=5, seed=0)
    tr = PipelineTrainer(
        TINY, num_stages=2, replicas=4,
        inner=AdamWConfig(lr=3e-3, weight_decay=0.0),
        routing="random", outer=outer, seed=0,
    )
    lcfg = LoaderConfig(vocab_size=TINY.vocab_size, seq_len=32,
                        per_replica_batch=2, replicas=4)
    loop = TrainLoop(
        PipelineProgram(tr),
        lambda start: shard_iterator(lcfg, start_step=start),
        LoopConfig(steps=steps, ckpt_dir=tmpdir, resume=resume,
                   ckpt_every=ckpt_every),
    )
    return loop.run()


def test_pipeline_noloco_reduces_weight_std_vs_none():
    """Acceptance: the pipeline runtime trains with routing AND the gossip
    outer step; cross-replica weight std decreases versus method=none."""
    none = _pipeline_loop("none", 20)
    noloco = _pipeline_loop("noloco", 20)
    assert noloco["outer_syncs"] == 4
    assert noloco["comm_bytes"] > 0
    assert noloco["final_weight_std"] < 0.7 * none["final_weight_std"], (
        noloco["final_weight_std"], none["final_weight_std"]
    )
    assert noloco["losses"][-1] < noloco["losses"][0]


def test_pipeline_resume_matches_uninterrupted(tmp_path):
    full = _pipeline_loop("noloco", 12)
    d = str(tmp_path / "pipe")
    _pipeline_loop("noloco", 6, tmpdir=d)
    cont = _pipeline_loop("noloco", 12, tmpdir=d, resume=True)
    assert cont["start_step"] == 6
    np.testing.assert_array_equal(
        np.asarray(full["losses"][6:]), np.asarray(cont["losses"])
    )


def test_pipeline_outer_state_reset_semantics():
    """After a pipeline outer step every stage's fast weights equal its new
    slow weights (look-ahead), exactly as in the stacked trainer."""
    tr = PipelineTrainer(
        TINY, num_stages=2, replicas=4,
        inner=AdamWConfig(lr=3e-3, weight_decay=0.0),
        outer=OuterConfig(method="noloco", inner_steps=2, seed=0),
    )
    state = tr.init(jax.random.PRNGKey(0))
    it = shard_iterator(LoaderConfig(
        vocab_size=TINY.vocab_size, seq_len=16, per_replica_batch=2, replicas=4
    ))
    for _ in range(2):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, _ = tr.train_step(state, batch)
    state, synced = tr.maybe_outer_step(state)
    assert synced
    for s in range(2):
        for a, b in zip(jax.tree.leaves(state["params"][s]),
                        jax.tree.leaves(state["outer"]["phi"][s])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # counter advanced, next call is a no-op until m more steps
    assert state["outer"]["step"] == 1
    _, synced = tr.maybe_outer_step(state)
    assert not synced


def test_eval_batches_helper():
    lcfg = LoaderConfig(vocab_size=64, seq_len=8, per_replica_batch=2, replicas=2)
    bs = eval_batches(lcfg, 3)
    assert len(bs) == 3
    it = shard_iterator(lcfg)
    np.testing.assert_array_equal(bs[0]["tokens"], next(it)["tokens"])


# ---------------------------------------------------------------------------
# Distributed runtime (jax-version differences handled by parallel/compat)
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_distributed_entry_resumes():
    """train_distributed drives the engine end-to-end with --resume."""
    import subprocess, sys, tempfile

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    with tempfile.TemporaryDirectory() as d:
        args = [sys.executable, "-m", "repro.launch.train_distributed",
                "--data", "4", "--model", "2", "--steps", "8",
                "--inner-steps", "4", "--ckpt-dir", d, "--ckpt-every", "4"]
        out = subprocess.run(args, capture_output=True, text=True, env=env,
                             timeout=560)
        assert out.returncode == 0, out.stdout + out.stderr
        out2 = subprocess.run(args + ["--resume"], capture_output=True,
                              text=True, env=env, timeout=560)
        assert out2.returncode == 0, out2.stdout + out2.stderr
