"""Serving stack: paged prefill+decode must reproduce the full-forward
logits for every cache family, the Pallas serving kernels must match their
jnp twins, continuous batching must be invisible to each request (batched
tokens == solo-decoded tokens, exactly), and train→serve promotion must
round-trip a checkpoint and refuse frozen replicas."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.kernels import ops
from repro.kernels.dispatch import KernelConfig
from repro.models import model as M
from repro.models import transformer as tfm
from repro.models.attention import PagedView
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, logits_sharded
from repro.parallel.sharding import ShardCtx
from repro.serve import (
    BlockAllocator,
    Request,
    ServeConfig,
    ServeEngine,
    promote,
    resolve_replica,
)

CTX = ShardCtx.local()
KEY = jax.random.PRNGKey(11)
PALLAS = KernelConfig(impl="pallas", interpret=True)
JNP = KernelConfig(impl="jnp")

CFGS = {
    "global": ModelConfig(num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=128, qk_norm=True,
                          dtype="float32", remat=False),
    "local": ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
                         d_ff=128, vocab_size=128, attn_pattern=("local",),
                         sliding_window=6, dtype="float32", remat=False),
    "rglru": ModelConfig(arch_type="hybrid", num_layers=3, d_model=64, num_heads=4,
                         num_kv_heads=1, d_ff=128, vocab_size=128,
                         attn_pattern=("rglru", "rglru", "local"), sliding_window=6,
                         lru_width=64, dtype="float32", remat=False),
    "ssd": ModelConfig(arch_type="ssm", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=4, d_ff=0, vocab_size=128, attn_pattern=("ssd",),
                       ssm_state_dim=16, ssm_head_dim=32, ssm_chunk=4,
                       use_rope=False, dtype="float32", remat=False),
}


def _full_logits(vals, cfg, toks):
    x, _ = M.embed_input(vals, cfg, {"tokens": toks}, CTX)
    x, _, _ = tfm.apply_stack(vals["stack"], cfg, x, CTX,
                              positions=jnp.arange(toks.shape[1]))
    x = apply_norm(vals["final_norm"], x)
    return logits_sharded(vals["embed"], cfg, x, CTX)


# ---------------------------------------------------------------------------
# Paged prefill + decode vs full forward (per cache family)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", list(CFGS))
def test_paged_decode_equals_full_forward(kind):
    cfg = CFGS[kind]
    vals = values_of(M.init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab_size)
    full = _full_logits(vals, cfg, toks)

    num_pages, page_size, mb = 4, 4, 4
    caches = M.init_paged_cache_tree(cfg, 1, num_pages, page_size)
    tables = np.full((1, mb), num_pages, dtype=np.int32)  # trash-filled
    tables[0, :3] = [0, 1, 2]                             # 12 tokens = 3 pages
    tables = jnp.asarray(tables)

    view = PagedView(tables, jnp.zeros((1,), jnp.int32), jnp.ones((1,), bool))
    lg, caches = M.paged_prefill(vals, cfg, toks[:, :6], caches, view, CTX)
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, 5])))]
    for i in range(6, 12):
        view = PagedView(tables, jnp.asarray([i], jnp.int32), jnp.ones((1,), bool))
        lg, caches = M.paged_decode_step(vals, cfg, toks[:, i:i + 1], caches, view, CTX)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, i]))))
    assert max(errs) < 2e-3, f"{kind}: {errs}"


def test_paged_cache_tree_rejects_encdec():
    cfg = dataclasses.replace(
        CFGS["global"], arch_type="encdec", is_encoder_decoder=True,
        num_encoder_layers=1, encoder_seq=8,
    )
    with pytest.raises(ValueError, match="paged"):
        M.init_paged_cache_tree(cfg, 1, 4, 4)


# ---------------------------------------------------------------------------
# Serving kernels: pallas-interpret vs jnp twin parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,kv,mode,window", [
    (4, 4, "causal", 0),   # MHA
    (4, 2, "causal", 0),   # GQA (folded into q tile rows)
    (4, 1, "local", 5),    # MQA sliding window
])
def test_paged_attention_impl_parity(h, kv, mode, window):
    num_pages, page_size, mb, r, d = 6, 4, 4, 3, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (r, h, d))
    kp = jax.random.normal(ks[1], (num_pages, page_size, kv, d))
    vp = jax.random.normal(ks[2], (num_pages, page_size, kv, d))
    tables = jnp.asarray([[0, 1, 2, 3], [4, 5, 0, 1], [2, 3, 4, 5]], jnp.int32)
    positions = jnp.asarray([5, 11, 2], jnp.int32)
    op = ops.paged_attention(q, kp, vp, tables, positions,
                             mode=mode, window=window, config=PALLAS)
    oj = ops.paged_attention(q, kp, vp, tables, positions,
                             mode=mode, window=window, config=JNP)
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=2e-5, rtol=1e-4)


def test_paged_attention_masks_unallocated_pages():
    """Entries past positions[r] (stale pages, trash fill) must not leak:
    scrambling them leaves the output bit-unchanged."""
    num_pages, page_size, r, h, d = 4, 4, 2, 2, 8
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (r, h, d))
    kp = jax.random.normal(jax.random.fold_in(KEY, 2), (num_pages, page_size, h, d))
    vp = jax.random.normal(jax.random.fold_in(KEY, 3), (num_pages, page_size, h, d))
    tables = jnp.asarray([[0, 1, 2, 3], [0, 1, 2, 3]], jnp.int32)
    positions = jnp.asarray([3, 6], jnp.int32)  # only the first 1-2 pages live
    for cfg in (PALLAS, JNP):
        base = ops.paged_attention(q, kp, vp, tables, positions, config=cfg)
        # scramble everything strictly after each slot's position
        kp2, vp2 = kp.at[2:].set(99.0), vp.at[2:].set(-99.0)
        kp2 = kp2.at[1, 3:].set(99.0)   # slot 1: page 1 holds pos 4..7, 7 > 6
        vp2 = vp2.at[1, 3:].set(-99.0)
        got = ops.paged_attention(q, kp2, vp2, tables, positions, config=cfg)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


def test_rglru_decode_impl_parity():
    r, w = 3, 48
    h = jax.random.normal(jax.random.fold_in(KEY, 4), (r, w))
    a = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 5), (r, w)))
    b = jax.random.normal(jax.random.fold_in(KEY, 6), (r, w))
    op = ops.rglru_decode(h, a, b, config=PALLAS)
    oj = ops.rglru_decode(h, a, b, config=JNP)
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(oj), np.asarray(a * h + b),
                               atol=1e-6, rtol=1e-6)


def test_ssd_decode_impl_parity():
    r, h, p, n = 2, 2, 8, 4
    state = jax.random.normal(jax.random.fold_in(KEY, 7), (r, h, p, n)) * 0.3
    dt1 = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 8), (r, h))) * 0.1
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 9), (h,)) * 0.3)
    b1 = jax.random.normal(jax.random.fold_in(KEY, 10), (r, n)) * 0.5
    c1 = jax.random.normal(jax.random.fold_in(KEY, 11), (r, n)) * 0.5
    x1 = jax.random.normal(jax.random.fold_in(KEY, 12), (r, h, p)) * 0.5
    sp, yp = ops.ssd_decode(state, dt1, a, b1, c1, x1, config=PALLAS)
    sj, yj = ops.ssd_decode(state, dt1, a, b1, c1, x1, config=JNP)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sj), atol=2e-6, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yj), atol=2e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# Block allocator
# ---------------------------------------------------------------------------


def test_block_allocator():
    al = BlockAllocator(num_pages=8, page_size=4)
    assert al.trash_page == 8
    assert al.blocks_for(1) == 1 and al.blocks_for(4) == 1 and al.blocks_for(5) == 2
    a = al.alloc(3)
    b = al.alloc(5)
    assert len(set(a) | set(b)) == 8 and al.free_count == 0
    assert not al.can_alloc(1)
    with pytest.raises(MemoryError):
        al.alloc(1)
    al.free(b)
    assert al.free_count == 5
    with pytest.raises(ValueError, match="double free"):
        al.free([b[0]])
    with pytest.raises(ValueError, match="invalid"):
        al.free([al.trash_page])
    al.free(a)
    assert al.free_count == 8


# ---------------------------------------------------------------------------
# Continuous batching is invisible to each request (exact token match)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["global", "rglru"])
def test_continuous_batching_matches_solo_decode(kind):
    cfg = CFGS[kind]
    params = values_of(M.init_params(jax.random.PRNGKey(2), cfg))
    scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4, max_new_cap=8)

    rng = np.random.default_rng(0)
    requests = []
    for rid, (pl, gl, temp) in enumerate(
        [(3, 6, 0.0), (7, 4, 0.0), (5, 8, 0.7), (2, 5, 0.0)]
    ):
        prompt = rng.integers(0, cfg.vocab_size, size=(pl,)).tolist()
        requests.append(Request(rid=rid, prompt=[int(t) for t in prompt],
                                max_new=gl, temperature=temp))

    engine = ServeEngine(params, cfg, scfg)
    finished = {f.rid: f for f in engine.run([dataclasses.replace(r) for r in requests])}
    assert sorted(finished) == [0, 1, 2, 3]

    for r in requests:
        solo = ServeEngine(params, cfg, scfg)
        [f] = solo.run([dataclasses.replace(r)])
        assert len(f.tokens) == r.max_new
        assert f.tokens == finished[r.rid].tokens, (
            f"{kind} rid={r.rid}: batched decode diverged from solo decode"
        )


def test_continuous_policy_beats_static_on_decode_steps():
    """Same mixed load, same slots: continuous refills freed slots mid-flight
    so it needs no more (and here strictly fewer) fused decode steps."""
    cfg = CFGS["global"]
    params = values_of(M.init_params(jax.random.PRNGKey(2), cfg))
    rng = np.random.default_rng(1)
    requests = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=(pl,)).tolist(),
                max_new=gl)
        for i, (pl, gl) in enumerate([(3, 8), (5, 2), (4, 2), (6, 8)])
    ]
    steps = {}
    for policy in ("continuous", "static"):
        scfg = ServeConfig(max_slots=2, num_pages=24, page_size=4,
                           max_new_cap=8, policy=policy)
        eng = ServeEngine(params, cfg, scfg)
        done = eng.run([dataclasses.replace(r) for r in requests])
        assert len(done) == len(requests)
        steps[policy] = eng.decode_steps
    assert steps["continuous"] < steps["static"], steps


# ---------------------------------------------------------------------------
# Train → serve promotion
# ---------------------------------------------------------------------------


def _fake_gossip_ckpt(tmp_path, world=3, n=5, mask=(True, True, True)):
    rng = np.random.default_rng(7)
    theta = {"w": rng.normal(size=(world, n)).astype(np.float32)}
    phi = {"w": rng.normal(size=(world, n)).astype(np.float32)}
    tree = {
        "program": {
            "theta": theta,
            "opt": {"mu": np.zeros((world, n), np.float32)},
            "outer": {"phi": phi, "delta": {"w": np.zeros((world, n), np.float32)},
                      "step": np.int64(4)},
            "inner_step": np.int64(40),
            "membership": {"mask": np.asarray(mask, bool), "epoch": np.int64(1),
                           "partition": np.arange(world, dtype=np.int64)},
        },
        "loop": {"step": np.int64(40)},
    }
    ckpt_lib.save(str(tmp_path), 40, tree)
    return theta, phi


def test_promote_theta_and_phi_roundtrip(tmp_path):
    theta, phi = _fake_gossip_ckpt(tmp_path)
    params, info = promote(str(tmp_path), replica=1, source="theta")
    np.testing.assert_array_equal(np.asarray(params["w"]), theta["w"][1])
    assert info == {"step": 40, "replica": 1, "source": "theta", "world": 3}
    params, info = promote(str(tmp_path), replica=2, source="phi")
    np.testing.assert_array_equal(np.asarray(params["w"]), phi["w"][2])
    assert info["source"] == "phi" and info["replica"] == 2


def test_promote_frozen_replica_falls_back(tmp_path):
    theta, _ = _fake_gossip_ckpt(tmp_path, mask=(False, True, True))
    with pytest.warns(UserWarning, match="frozen"):
        params, info = promote(str(tmp_path), replica=0)
    assert info["replica"] == 1  # first ACTIVE replica
    np.testing.assert_array_equal(np.asarray(params["w"]), theta["w"][1])
    with pytest.warns(UserWarning, match="out of range"):
        _, info = promote(str(tmp_path), replica=9)
    assert info["replica"] == 1


def test_promote_active_replica_does_not_warn(tmp_path):
    _fake_gossip_ckpt(tmp_path, mask=(False, True, True))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _, info = promote(str(tmp_path), replica=2)
    assert info["replica"] == 2
    assert resolve_replica(None, 1, world=3) == 1


def test_promote_rejects_pipeline_checkpoint(tmp_path):
    tree = {"program": {"params": [{"w": np.zeros((2, 3), np.float32)}],
                        "step": np.int64(1)}}
    ckpt_lib.save(str(tmp_path), 1, tree)
    with pytest.raises(ValueError, match="pipeline"):
        promote(str(tmp_path))
