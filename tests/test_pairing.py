"""Property tests for the gossip pairing machinery (paper §3.2)."""
import numpy as np
import pytest

from repro.core import pairing


@pytest.mark.parametrize("world", [2, 4, 8, 16, 17, 32, 33])
@pytest.mark.parametrize("step", [0, 1, 7, 100])
def test_partner_table_is_involution(world, step):
    pt = pairing.partner_table(step, world)
    # partner of my partner is me
    assert (pt[pt] == np.arange(world)).all()
    # even world: nobody is alone; odd world: exactly one self-pair
    fixed = int((pt == np.arange(world)).sum())
    assert fixed == (world % 2)


@pytest.mark.parametrize("world", [4, 8, 16])
def test_pairings_differ_across_steps(world):
    tables = {tuple(pairing.partner_table(s, world)) for s in range(20)}
    # world=4 has only 3 perfect matchings; larger worlds should show many
    expect = {4: 3, 8: 8, 16: 12}[world]
    assert len(tables) >= expect


def test_group_assignment_sizes():
    groups = pairing.group_assignment(3, 12, n=3)
    _, counts = np.unique(groups, return_counts=True)
    assert (counts == 3).all()


def test_ppermute_pairs_cover_all_sources():
    perm = pairing.ppermute_pairs(5, 8)
    srcs = sorted(p[0] for p in perm)
    dsts = sorted(p[1] for p in perm)
    assert srcs == list(range(8)) and dsts == list(range(8))


def test_epidemic_mixing():
    """Information reaches every pair in O(log N)-ish rounds (epidemic
    property the paper inherits from gossip averaging)."""
    seen = pairing.all_pairs_seen(steps=30, world=16)
    # direct-meeting coverage after k rounds ~ 1-(1-1/(n-1))^k ~ 0.87; the
    # transitive (epidemic) spread is much faster, but we check direct pairs
    assert seen.mean() > 0.8


def test_determinism_across_processes():
    a = pairing.partner_table(11, 10, seed=3)
    b = pairing.partner_table(11, 10, seed=3)
    assert (a == b).all()
    c = pairing.partner_table(11, 10, seed=4)
    assert not (a == c).all()


@pytest.mark.parametrize("world", [2, 4, 8, 16, 32])
def test_hypercube_schedule(world):
    """XOR schedule: involution, no self-pairs, only log2(world) distinct
    matchings, and every pair exchanges info within log2(world) rounds."""
    import math

    dims = int(math.log2(world))
    tables = set()
    for s in range(4 * dims):
        pt = pairing.hypercube_partner_table(s, world)
        assert (pt[pt] == np.arange(world)).all()
        assert (pt != np.arange(world)).all()
        tables.add(tuple(pt))
    assert len(tables) == dims  # exactly log2(world) compiled programs needed
    # dissemination: one epoch (dims consecutive steps) touches every dim
    touched = set()
    for s in range(dims):
        pt = pairing.hypercube_partner_table(s, world)
        touched.add(int(pt[0]) ^ 0)
    assert len(touched) == dims


def test_hypercube_rejects_non_power_of_two():
    import pytest as _pt
    with _pt.raises(ValueError):
        pairing.hypercube_partner_table(0, 12)


# ---------------------------------------------------------------------------
# Elastic hypercube schedule (the bounded-compile pool option, ISSUE 5)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("world", [4, 8, 16])
def test_elastic_hypercube_full_membership_matches_static(world):
    mem = pairing.Membership.full(world)
    for s in range(10):
        np.testing.assert_array_equal(
            pairing.elastic_hypercube_partner_table(s, mem),
            pairing.hypercube_partner_table(s, world),
        )


def test_elastic_hypercube_degrades_pairs_touching_inactive():
    """Dropping one endpoint of an XOR pair self-loops BOTH (the involution
    survives any mask), and the surviving pairs are untouched."""
    mem = pairing.Membership.full(8).drop([3])
    for s in range(12):
        full = pairing.hypercube_partner_table(s, 8)
        pt = pairing.elastic_hypercube_partner_table(s, mem)
        assert (pt[pt] == np.arange(8)).all()
        assert pt[3] == 3
        mate = int(full[3])
        assert pt[mate] == mate  # the orphaned partner self-loops
        for i in range(8):
            if i != 3 and i != mate:
                assert pt[i] == full[i]  # everyone else unchanged


def test_elastic_hypercube_respects_partition():
    mem = pairing.Membership.full(8)
    groups = [(0, 1, 2, 3), (4, 5, 6, 7)]
    for s in range(12):
        pt = pairing.elastic_hypercube_partner_table(s, mem, groups=groups)
        assert (pt[pt] == np.arange(8)).all()
        for i in range(8):
            assert (i < 4) == (int(pt[i]) < 4)


def test_hypercube_dim_is_the_pool_key():
    """hypercube_dim is bounded by log2(world) and fully determines the
    table — the program-pool key contract."""
    world = 16
    for s in range(64):
        j = pairing.hypercube_dim(s, world)
        assert 0 <= j < 4
        np.testing.assert_array_equal(
            pairing.hypercube_partner_table(s, world),
            np.arange(world) ^ (1 << j),
        )


def test_elastic_route_permutation_basics():
    mem = pairing.Membership.full(6).drop([1, 4])
    for s in range(8):
        route = pairing.elastic_route_permutation(s, mem)
        assert route[1] == 1 and route[4] == 4
        act = [0, 2, 3, 5]
        assert sorted(int(route[i]) for i in act) == act
    full = pairing.Membership.full(6)
    for s in range(8):
        np.testing.assert_array_equal(
            pairing.elastic_route_permutation(s, full),
            np.asarray(pairing.pairing_permutation(s, 6)),
        )
