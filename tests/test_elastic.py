"""Elastic gossip runtime scenarios: the fault-injecting SimCluster drives
the REAL GossipProgram/TrainLoop through dropout, stragglers, partitions and
rejoin-with-warm-start — asserting that "no blocking collective" holds up as
a tested fault-tolerance property (loss keeps descending, the active-set
weight std stays bounded and re-contracts)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.pairing import Membership
from repro.launch.train_elastic import run_elastic_training
from repro.models.config import ModelConfig
from repro.sim import FaultEvent, FaultPlan, SimCluster

TINY = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=128, dtype="float32", remat=False)

KW = dict(replicas=8, per_replica_batch=2, seq_len=32, steps=50, inner_steps=5,
          inner_lr=3e-3, eval_every=5, seed=0)


@pytest.fixture(scope="module")
def healthy8():
    """The uninterrupted 8-replica baseline the fault scenarios compare to."""
    return run_elastic_training(TINY, FaultPlan(), **KW)


# ---------------------------------------------------------------------------
# Acceptance scenario: 8 replicas lose 2 at round k, rejoin 3 rounds later
# ---------------------------------------------------------------------------


def test_acceptance_drop_two_rejoin_three_rounds_later(healthy8):
    """ISSUE 4 acceptance: an 8-replica run drops replicas {3, 5} at outer
    round 2 and rejoins them (warm-started from a live peer's φ) at round 5.
    Final eval loss must land within 5% of the uninterrupted run and the
    cross-replica weight std must re-contract after the rejoin."""
    plan = FaultPlan.build([
        {"kind": "drop", "round": 2, "replicas": [3, 5]},
        {"kind": "rejoin", "round": 5, "replicas": [3, 5]},
    ])
    res = run_elastic_training(TINY, plan, **KW)

    # loss keeps descending through the churn
    assert np.isfinite(res["losses"]).all()
    assert res["losses"][-1] < 0.7 * res["losses"][0]

    # final eval within 5% of the healthy run
    he, fe = healthy8["evals"][-1][1], res["evals"][-1][1]
    assert abs(fe - he) / he < 0.05, (fe, he)

    # weight std re-contracts after the rejoin: the final ensemble spread is
    # below the post-rejoin peak and lands in the healthy run's ballpark
    rejoin_step = 5 * KW["inner_steps"]
    post = [w for s, w in res["weight_stds"] if s > rejoin_step]
    assert res["final_weight_std"] < max(post[:-1]), (res["final_weight_std"], post)
    assert res["final_weight_std"] < 2.5 * healthy8["final_weight_std"]

    # structural: rounds 2-4 ran with 6 actives and never paired the dropped
    # replicas; round 5 onward is full again, membership epoch advanced twice
    by_round = {r["round"]: r for r in res["rounds"]}
    for k in (2, 3, 4):
        assert by_round[k]["active"] == [0, 1, 2, 4, 6, 7]
        assert by_round[k]["partner"][3] == 3 and by_round[k]["partner"][5] == 5
    for k in (0, 1, 5, 6, 7, 8, 9):
        assert by_round[k]["active"] == list(range(8))
    assert res["membership"] == {"epoch": 2, "active": list(range(8))}


# ---------------------------------------------------------------------------
# Individual fault families
# ---------------------------------------------------------------------------


def test_dropout_without_rejoin_keeps_training():
    """Losing replicas permanently degrades capacity, not correctness: the
    surviving active set keeps gossiping and descending."""
    plan = FaultPlan.build([{"kind": "drop", "round": 1, "replicas": [0, 7]}])
    res = run_elastic_training(TINY, plan, **{**KW, "steps": 30})
    assert np.isfinite(res["losses"]).all()
    assert res["losses"][-1] < 0.8 * res["losses"][0]
    assert res["membership"]["active"] == [1, 2, 3, 4, 5, 6]
    # every post-drop round pairs only survivors
    for r in res["rounds"]:
        if r["round"] >= 1:
            assert r["active"] == [1, 2, 3, 4, 5, 6]
            assert r["partner"][0] == 0 and r["partner"][7] == 7


def test_straggler_misses_one_round(healthy8):
    """A straggler misses exactly one outer round: its partner self-pairs
    (self-momentum sit-out path), it keeps inner-training, and it rejoins the
    next round's pairing with a 2m-step Δ — no divergence."""
    plan = FaultPlan.build([
        {"kind": "straggle", "round": 3, "replicas": [1], "rounds": 1},
    ])
    res = run_elastic_training(TINY, plan, **KW)
    by_round = {r["round"]: r for r in res["rounds"]}
    assert by_round[3]["absent"] == [1]
    assert by_round[3]["partner"][1] == 1  # sat out...
    assert by_round[4]["absent"] == []
    assert by_round[4]["partner"][1] != 1  # ...back in the next draw
    # membership never changed — stragglers are participation, not epoch
    assert res["membership"]["epoch"] == 0
    assert np.isfinite(res["losses"]).all()
    he, fe = healthy8["evals"][-1][1], res["evals"][-1][1]
    assert abs(fe - he) / he < 0.05, (fe, he)


def test_partition_then_heal_recontracts(healthy8):
    """A network partition splits the pairing graph into two islands that
    drift apart (weight std grows vs healthy); healing re-mixes them and the
    std re-contracts."""
    plan = FaultPlan.build([
        {"kind": "partition", "round": 1, "groups": [[0, 1, 2, 3], [4, 5, 6, 7]]},
        {"kind": "heal", "round": 5},
    ])
    res = run_elastic_training(TINY, plan, **KW)
    # structurally: rounds 1-4 never pair across the cut
    for r in res["rounds"]:
        if 1 <= r["round"] <= 4:
            assert r["partition"] == [[0, 1, 2, 3], [4, 5, 6, 7]]
            for i in range(8):
                assert (i < 4) == (r["partner"][i] < 4)
        else:
            assert r["partition"] is None
    # the islands drifted: spread at the heal point well above healthy
    heal_step = 5 * KW["inner_steps"]
    w = dict(res["weight_stds"])
    hw = dict(healthy8["weight_stds"])
    assert w[heal_step] > 1.3 * hw[heal_step], (w[heal_step], hw[heal_step])
    # ...and healing re-contracts it
    assert res["final_weight_std"] < 0.5 * max(w.values()), (
        res["final_weight_std"], w
    )
    assert np.isfinite(res["losses"]).all()


# ---------------------------------------------------------------------------
# Rejoin warm-start state surgery
# ---------------------------------------------------------------------------


def test_rejoin_warm_start_adopts_peer_phi():
    """The comeback replica adopts the source peer's φ as BOTH φ and θ, with
    zero outer momentum and fresh AdamW moments."""
    from repro.data import LoaderConfig, shard_iterator
    from repro.launch.train import method_config
    from repro.train import GossipProgram

    tcfg = method_config("noloco", inner_lr=3e-3, total_steps=8, inner_steps=2)
    prog = GossipProgram(TINY, tcfg, replicas=4, seed=0)
    plan = FaultPlan.build([
        {"kind": "drop", "step": 1, "replicas": [2]},
        {"kind": "rejoin", "step": 5, "replicas": [2], "source": 0},
    ])
    sim = SimCluster(prog, plan)
    it = shard_iterator(LoaderConfig(
        vocab_size=TINY.vocab_size, seq_len=16, per_replica_batch=2, replicas=4
    ))
    state = sim.init_state(next(it))
    rng = jax.random.PRNGKey(0)
    for t in range(5):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, _ = sim.inner_step(state, batch, jax.random.fold_in(rng, t))
        state, _ = sim.maybe_outer_step(state)
    # t=5's inner step applies the rejoin first: φ/δ/opt surgery is visible
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state, _ = sim.inner_step(state, batch, jax.random.fold_in(rng, 5))
    for leaf_phi in jax.tree.leaves(state.outer.phi):
        np.testing.assert_array_equal(np.asarray(leaf_phi[2]), np.asarray(leaf_phi[0]))
    for leaf_delta in jax.tree.leaves(state.outer.delta):
        assert not np.asarray(leaf_delta[2]).any()
    assert int(state.opt.count[2]) == 1  # reset to 0, then one post-rejoin step
    assert sim.membership.epoch == 2 and sim.membership.is_full


def test_dropped_replica_is_frozen():
    """While dropped, a replica's θ, φ, δ and AdamW moments do not move."""
    from repro.data import LoaderConfig, shard_iterator
    from repro.launch.train import method_config
    from repro.train import GossipProgram

    tcfg = method_config("noloco", inner_lr=3e-3, total_steps=8, inner_steps=2)
    prog = GossipProgram(TINY, tcfg, replicas=4, seed=0)
    sim = SimCluster(prog, FaultPlan.build(
        [{"kind": "drop", "step": 2, "replicas": [1]}]
    ))
    it = shard_iterator(LoaderConfig(
        vocab_size=TINY.vocab_size, seq_len=16, per_replica_batch=2, replicas=4
    ))
    state = sim.init_state(next(it))
    rng = jax.random.PRNGKey(0)
    snap = None
    for t in range(6):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, _ = sim.inner_step(state, batch, jax.random.fold_in(rng, t))
        state, _ = sim.maybe_outer_step(state)
        if t == 2:
            snap = jax.tree.map(lambda x: np.asarray(x[1]).copy(), {
                "theta": state.theta, "phi": state.outer.phi,
                "delta": state.outer.delta, "mu": state.opt.mu,
            })
    end = jax.tree.map(lambda x: np.asarray(x[1]), {
        "theta": state.theta, "phi": state.outer.phi,
        "delta": state.outer.delta, "mu": state.opt.mu,
    })
    for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(end)):
        np.testing.assert_array_equal(a, b)
    # the survivors did move
    assert not np.allclose(
        np.asarray(jax.tree.leaves(state.theta)[0][0]),
        np.asarray(jax.tree.leaves(state.outer.phi)[0][1]),
    )


# ---------------------------------------------------------------------------
# Resume across a membership change
# ---------------------------------------------------------------------------


def test_resume_after_membership_change(tmp_path):
    """Checkpoint AFTER a drop, restore with the smaller active set: the
    continued run reproduces the uninterrupted faulted trajectory exactly
    (membership mask + epoch ride in the checkpoint)."""
    plan = FaultPlan.build([{"kind": "drop", "round": 1, "replicas": [2, 6]}])
    kw = dict(replicas=8, per_replica_batch=2, seq_len=32, steps=24,
              inner_steps=4, inner_lr=3e-3, eval_every=0, seed=0,
              total_steps=24)
    full = run_elastic_training(TINY, plan, **kw)
    d = str(tmp_path / "elastic")
    run_elastic_training(TINY, plan, ckpt_dir=d, **{**kw, "steps": 12})
    cont = run_elastic_training(TINY, plan, ckpt_dir=d, resume=True, **kw)
    assert cont["start_step"] == 12
    np.testing.assert_array_equal(
        np.asarray(full["losses"][12:]), np.asarray(cont["losses"])
    )
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(full["state"].theta)[0]),
        np.asarray(jax.tree.leaves(cont["state"].theta)[0]),
    )
    assert cont["membership"] == {"epoch": 1,
                                  "active": [0, 1, 3, 4, 5, 7]}
    # post-resume rounds keep excluding the dropped replicas
    for r in cont["rounds"]:
        assert r["partner"][2] == 2 and r["partner"][6] == 6


# ---------------------------------------------------------------------------
# Plan plumbing
# ---------------------------------------------------------------------------


def test_resume_mid_straggle_reproduces_trajectory(tmp_path):
    """A straggler debt spanning the checkpoint boundary must survive the
    restart: the resumed run keeps the replica out of the rounds it missed
    in the uninterrupted run (straggle counters ride in the checkpoint)."""
    plan = FaultPlan.build([
        {"kind": "straggle", "round": 1, "replicas": [1], "rounds": 3},
    ])
    kw = dict(replicas=4, per_replica_batch=2, seq_len=32, steps=24,
              inner_steps=4, inner_lr=3e-3, eval_every=0, seed=0,
              total_steps=24)
    full = run_elastic_training(TINY, plan, **kw)
    d = str(tmp_path / "straggle")
    # interrupt after rounds 1-2 were missed but round 3's debt is pending
    run_elastic_training(TINY, plan, ckpt_dir=d, **{**kw, "steps": 12})
    cont = run_elastic_training(TINY, plan, ckpt_dir=d, resume=True, **kw)
    assert cont["start_step"] == 12
    # round 3 (fires at step 16, post-resume) still excludes the straggler
    by_round = {r["round"]: r for r in cont["rounds"]}
    assert by_round[3]["absent"] == [1]
    assert by_round[4]["absent"] == []
    np.testing.assert_array_equal(
        np.asarray(full["losses"][12:]), np.asarray(cont["losses"])
    )
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(full["state"].theta)[0]),
        np.asarray(jax.tree.leaves(cont["state"].theta)[0]),
    )


def test_truncated_horizon_straggle_debt_resumes_exactly(tmp_path):
    """A run whose --steps horizon ends while a straggle debt is still in
    force (plan.max_effect_step > steps) must checkpoint the in-flight debt
    at its FINAL save and resume it exactly — the debt neither vanishes nor
    re-arms from scratch when the run is extended to the full horizon."""
    plan = FaultPlan.build([
        {"kind": "straggle", "round": 1, "replicas": [1], "rounds": 3},
    ])
    kw = dict(replicas=4, per_replica_batch=2, seq_len=32, steps=24,
              inner_steps=4, inner_lr=3e-3, eval_every=0, seed=0,
              total_steps=24)
    # debt anchored at step 4, in force through step 16 — the short run's
    # steps=8 horizon truncates it mid-flight (this is exactly the shape the
    # launchers now warn about)
    assert plan.max_effect_step(4) == 16
    full = run_elastic_training(TINY, plan, **kw)
    d = str(tmp_path / "trunc")
    short = run_elastic_training(TINY, plan, ckpt_dir=d, **{**kw, "steps": 8})
    by_round = {r["round"]: r for r in short["rounds"]}
    assert by_round[1]["absent"] == [1]  # debt already biting at truncation
    cont = run_elastic_training(TINY, plan, ckpt_dir=d, resume=True, **kw)
    assert cont["start_step"] == 8
    by_round = {r["round"]: r for r in cont["rounds"]}
    # rounds 2 and 3 fire post-resume and must still exclude the straggler
    assert by_round[2]["absent"] == [1]
    assert by_round[3]["absent"] == [1]
    assert by_round[4]["absent"] == []
    np.testing.assert_array_equal(
        np.asarray(full["losses"][8:]), np.asarray(cont["losses"])
    )
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(full["state"].theta)[0]),
        np.asarray(jax.tree.leaves(cont["state"].theta)[0]),
    )


def test_membership_and_partition_checkpoint_roundtrip(tmp_path):
    """The program's membership mask/epoch AND partition view ride in the
    checkpoint pytree and restore onto a fresh program."""
    from repro.checkpoint import restore, save
    from repro.data import LoaderConfig, shard_iterator
    from repro.launch.train import method_config
    from repro.train import GossipProgram

    tcfg = method_config("noloco", inner_lr=3e-3, total_steps=4, inner_steps=2)
    prog = GossipProgram(TINY, tcfg, replicas=6, seed=0)
    prog.set_membership(prog.membership.drop([4]))
    prog.set_partition([(0, 1), (2, 3, 5)])
    it = shard_iterator(LoaderConfig(
        vocab_size=TINY.vocab_size, seq_len=16, per_replica_batch=1, replicas=6
    ))
    state = prog.init_state(next(it))
    d = str(tmp_path)
    save(d, 1, prog.state_pytree(state))
    prog2 = GossipProgram(TINY, tcfg, replicas=6, seed=0)
    st2 = prog2.load_state_pytree(prog2.init_state(next(it)), restore(d, 1))
    assert prog2.membership == prog.membership
    assert prog2.partition == ((0, 1), (2, 3, 5))
    for a, b in zip(jax.tree.leaves(st2.theta), jax.tree.leaves(state.theta)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_plan_json_roundtrip(tmp_path):
    plan = FaultPlan.build([
        {"kind": "drop", "round": 2, "replicas": [3, 5]},
        {"kind": "straggle", "step": 7, "replicas": [1], "rounds": 2},
        {"kind": "partition", "round": 4, "groups": [[0, 1], [2, 3]]},
        {"kind": "heal", "round": 6},
        {"kind": "rejoin", "round": 5, "replicas": [3], "source": 0},
    ])
    p = str(tmp_path / "plan.json")
    plan.save(p)
    loaded = FaultPlan.load(p)
    assert loaded == plan
    loaded.validate(world=8)
    # resolution: round anchors scale with m, step anchors don't
    assert loaded.events[0].resolved_step(5) == 10
    assert loaded.events[1].resolved_step(5) == 7


def test_fault_plan_validation_rejects_bad_events():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.build([{"kind": "nuke", "step": 0}]).validate(4)
    with pytest.raises(ValueError, match="exactly one of step/round"):
        FaultPlan.build([{"kind": "drop", "replicas": [0]}]).validate(4)
    with pytest.raises(ValueError, match="outside world"):
        FaultPlan.build([{"kind": "drop", "step": 0, "replicas": [9]}]).validate(4)
    with pytest.raises(ValueError, match="disjoint"):
        FaultPlan.build([
            {"kind": "partition", "step": 0, "groups": [[0, 1], [1, 2]]}
        ]).validate(4)
    with pytest.raises(ValueError, match="needs replicas"):
        FaultPlan.build([{"kind": "rejoin", "round": 1}]).validate(4)


def test_membership_api():
    m = Membership.full(6)
    assert m.is_full and m.epoch == 0 and m.num_active == 6
    d = m.drop([1, 4])
    assert d.active_ids == (0, 2, 3, 5) and d.epoch == 1
    with pytest.raises(ValueError, match="already inactive"):
        d.drop([1])
    back = d.add([1])
    assert back.epoch == 2 and back.active_ids == (0, 1, 2, 3, 5)
    with pytest.raises(ValueError, match="already active"):
        back.add([0])
    # transient straggler view: same epoch
    t = back.without([0])
    assert t.epoch == back.epoch and 0 not in t.active_ids
    with pytest.raises(ValueError, match="at least one active"):
        Membership(world=2, mask=(False, False))


# ---------------------------------------------------------------------------
# Elastic data reassignment (flag-gated; default skips dropped streams)
# ---------------------------------------------------------------------------


def test_reassign_data_deterministic_and_changes_stream():
    """With --reassign-data survivors adopt dropped replicas' streams via the
    pure (membership, t) assignment: two runs are bit-identical to each
    other, and diverge from the default skip-streams run after the drop."""
    plan = FaultPlan.build([{"kind": "drop", "round": 1, "replicas": [0, 7]}])
    kw = {**KW, "steps": 16, "eval_every": 0}
    a = run_elastic_training(TINY, plan, reassign_data=True, **kw)
    b = run_elastic_training(TINY, plan, reassign_data=True, **kw)
    c = run_elastic_training(TINY, plan, **kw)
    np.testing.assert_array_equal(np.asarray(a["losses"]), np.asarray(b["losses"]))
    # pre-drop (steps 0-4) identical to the default, divergent after
    np.testing.assert_array_equal(
        np.asarray(a["losses"][:5]), np.asarray(c["losses"][:5])
    )
    assert not np.array_equal(np.asarray(a["losses"][6:]), np.asarray(c["losses"][6:]))
    assert np.isfinite(a["losses"]).all()


def test_stream_assignment_contract():
    """The assignment itself: identity at full membership, disjoint picks,
    full coverage over a cycle, pure in (membership, t)."""
    from repro.core.elastic import stream_assignment

    full = Membership.full(8)
    np.testing.assert_array_equal(stream_assignment(full, 11), np.arange(8))
    mem = full.drop([2, 5, 6])
    seen = set()
    for t in range(8):
        tab = stream_assignment(mem, t)
        picks = [int(tab[a]) for a in mem.active_ids]
        assert len(picks) == len(set(picks))
        seen.update(picks)
        np.testing.assert_array_equal(tab, stream_assignment(mem, t))  # pure
    assert seen == set(range(8))


# ---------------------------------------------------------------------------
# Pipeline runtime consumes the same ElasticContext
# ---------------------------------------------------------------------------


def _pipeline_trainer(elastic=None, replicas=4):
    from repro.core.elastic import ElasticContext
    from repro.core.outer import OuterConfig
    from repro.optim import AdamWConfig
    from repro.pipeline import PipelineTrainer

    return PipelineTrainer(
        cfg=TINY, num_stages=2, replicas=replicas,
        inner=AdamWConfig(lr=3e-3, weight_decay=0.0),
        outer=OuterConfig(method="noloco", inner_steps=2),
        seed=0, elastic=elastic,
    )


def _pipeline_batches(n, replicas=4):
    from repro.data import LoaderConfig, shard_iterator

    it = shard_iterator(LoaderConfig(
        vocab_size=TINY.vocab_size, seq_len=16, per_replica_batch=2,
        replicas=replicas,
    ))
    return [
        {k: jnp.asarray(v) for k, v in next(it).items()} for _ in range(n)
    ]


def test_pipeline_elastic_full_membership_matches_legacy():
    """Attaching an ElasticContext at full membership changes NOTHING: the
    routed-pipeline trajectory is bit-identical to the fixed-world trainer."""
    from repro.core.elastic import ElasticContext

    batches = _pipeline_batches(6)
    t_legacy = _pipeline_trainer(None)
    t_elastic = _pipeline_trainer(ElasticContext(world=4))
    s1 = t_legacy.init(jax.random.PRNGKey(0))
    s2 = t_elastic.init(jax.random.PRNGKey(0))
    for b in batches:
        s1, l1 = t_legacy.train_step(s1, b)
        s2, l2 = t_elastic.train_step(s2, b)
        assert l1 == l2
        s1, _ = t_legacy.maybe_outer_step(s1)
        s2, _ = t_elastic.maybe_outer_step(s2)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_elastic_membership_freezes_and_excludes():
    """Dropping a stage-replica: its params/opt freeze across inner AND outer
    steps, routing never touches it, and every stage's gossip pairing
    self-loops it."""
    from repro.core.elastic import ElasticContext

    ctx = ElasticContext(world=4)
    tr = _pipeline_trainer(ctx)
    state = tr.init(jax.random.PRNGKey(0))
    batches = _pipeline_batches(8)
    for b in batches[:2]:
        state, _ = tr.train_step(state, b)
        state, _ = tr.maybe_outer_step(state)
    ctx.set_membership(ctx.membership.drop([2]))
    snap = [jax.tree.map(lambda x: np.asarray(x[2]).copy(), p)
            for p in state["params"]]
    synced = 0
    for b in batches[2:]:
        routes = tr.routes(state["step"])
        for r in routes:
            assert int(r[2]) == 2  # no traffic through the dropped replica
            others = [int(r[i]) for i in (0, 1, 3)]
            assert sorted(others) == [0, 1, 3]
        state, _ = tr.train_step(state, b)
        state, did = tr.maybe_outer_step(state)
        synced += did
    assert synced >= 2
    for snap_p, p in zip(snap, state["params"]):
        for a, b in zip(jax.tree.leaves(snap_p), jax.tree.leaves(p)):
            np.testing.assert_array_equal(a, np.asarray(b)[2])
    # survivors moved
    assert not np.array_equal(
        np.asarray(jax.tree.leaves(state["params"][0])[0][0]),
        np.asarray(jax.tree.leaves(snap[0])[0]),
    )
    # weight std / eval aggregate over actives only (no crash, finite)
    assert np.isfinite(tr.weight_std(state))
    assert np.isfinite(float(tr.eval_loss(state["params"], batches[0])))
