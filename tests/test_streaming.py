"""Streaming partial-sync outer steps (DESIGN.md §2).

Covers the stream partitioner (hypothesis property suite), the staggered
:class:`~repro.core.outer.StreamSchedule`, the bytes-model message schedule
(blocking vs overlapped splits pinned per codec × fusing × stream count), the
stacked runtime's parity / churn-fallback / mid-stream-resume behaviour, and —
in XLA-forced-device subprocesses — the shard_map runtime's streamed program
pool.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import CommConfig, bytes_model, make_spec, pack, stream_partition
from repro.comm.payload import unpack_onto
from repro.core import outer as outer_lib
from repro.core.outer import StreamSchedule
from repro.launch.train import run_training
from repro.models.config import ModelConfig

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

TINY = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=128, dtype="float32", remat=False)

KW = dict(method="noloco", replicas=4, per_replica_batch=2, seq_len=32,
          inner_lr=3e-3, inner_steps=4, eval_every=0, total_steps=12)


def _tree(sizes, dtypes=None):
    """Deterministic mixed-shape pytree from a list of leaf sizes."""
    dtypes = dtypes or ["float32"] * len(sizes)
    key = jax.random.PRNGKey(0)
    out = {}
    for i, (n, dt) in enumerate(zip(sizes, dtypes)):
        k = jax.random.fold_in(key, i)
        shape = (n,) if n else ()
        if jnp.issubdtype(jnp.dtype(dt), jnp.floating):
            out[f"l{i:02d}"] = jax.random.normal(k, shape).astype(dt)
        else:
            out[f"l{i:02d}"] = jnp.arange(max(n, 1), dtype=dt).reshape(shape)
    return out


# ---------------------------------------------------------------------------
# StreamSchedule
# ---------------------------------------------------------------------------


def test_schedule_stream0_is_legacy_wall():
    sched = StreamSchedule(10, 1)
    fires = [t for t in range(31) if sched.due(t) is not None]
    assert fires == [10, 20, 30]  # exactly today's t % m == 0, t >= m wall
    assert sched.sync_index(0, 20) == 1


# ---------------------------------------------------------------------------
# bytes model — the actual message schedule (satellite: blocking accounting)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fuse", [True, False])
@pytest.mark.parametrize("codec", ["none", "fp16", "int8"])
@pytest.mark.parametrize("streams", [1, 2, 4])
@pytest.mark.parametrize("overlap", [False, True])
def test_bytes_model_stream_schedule_invariants(fuse, codec, streams, overlap):
    tree = jax.eval_shape(lambda: _tree([64, 8, 256, 16, 32]))
    cfg = CommConfig(codec=codec, fuse=fuse, streams=streams, overlap=overlap)
    cost = bytes_model.outer_step_cost(tree, cfg, method="noloco", world=8)
    assert cost.stream_count == streams
    assert len(cost.per_stream) == streams
    # the per-stream schedule sums to the cycle totals
    assert sum(s.payload_bytes for s in cost.per_stream) == cost.payload_bytes
    assert sum(s.blocking_bytes for s in cost.per_stream) == cost.blocking_bytes
    assert cost.overlapped_bytes == cost.payload_bytes - cost.blocking_bytes
    for s in cost.per_stream:
        assert s.payload_bytes == s.blocking_bytes + s.overlapped_bytes
        if overlap:
            # φ′ pre-sent during inner compute: only Δ_k blocks
            assert s.blocking_bytes * 2 == s.payload_bytes
        else:
            assert s.blocking_bytes == s.payload_bytes
            assert s.overlapped_bytes == 0
    # whole-cycle payload doesn't depend on the slicing — EXCEPT int8, whose
    # wire rounds every buffer up to whole quantization chunks (more buffers
    # → more chunk padding + per-chunk scales), so there slicing can only
    # add bytes, never hide them
    base = bytes_model.outer_step_cost(
        tree, CommConfig(codec=codec, fuse=fuse), method="noloco", world=8
    )
    if codec == "int8":
        assert cost.payload_bytes >= base.payload_bytes
    else:
        assert cost.payload_bytes == base.payload_bytes


def test_bytes_model_pinned_values():
    """Exact byte splits for a known tree: 2 fp32 leaves of 4096 + 64 elems
    → (Δ, φ) pair payload 33280 B; overlap halves the blocking wall; 4
    streams slice the wall to the largest stream's Δ."""
    tree = {
        "a": jax.ShapeDtypeStruct((64, 64), jnp.float32),
        "b": jax.ShapeDtypeStruct((64,), jnp.float32),
    }
    legacy = bytes_model.outer_step_cost(tree, CommConfig(), method="noloco")
    assert legacy.payload_bytes == legacy.blocking_bytes == 33280
    assert legacy.stream_count == 1 and legacy.overlapped_bytes == 0

    ov = bytes_model.outer_step_cost(
        tree, CommConfig(overlap=True), method="noloco"
    )
    assert ov.payload_bytes == 33280
    assert ov.blocking_bytes == ov.overlapped_bytes == 16640

    s4 = bytes_model.outer_step_cost(
        tree, CommConfig(streams=4, overlap=True), method="noloco"
    )
    assert s4.payload_bytes == 33280 and s4.blocking_bytes == 16640
    # the per-SYNC wall: the biggest stream blocks on its Δ only
    assert max(s.blocking_bytes for s in s4.per_stream) == 16384
    # fp16 halves the wire, int8 quarters it (plus bitcast fp32 scales)
    fp16 = bytes_model.outer_step_cost(
        tree, CommConfig(codec="fp16", streams=4, overlap=True), method="noloco"
    )
    assert fp16.payload_bytes == 16640 and fp16.blocking_bytes == 8320


def test_bytes_model_streams_rejects_diloco_and_bad_config():
    tree = {"a": jax.ShapeDtypeStruct((8,), jnp.float32)}
    with pytest.raises(ValueError, match="noloco-only"):
        bytes_model.outer_step_cost(
            tree, CommConfig(streams=2), method="diloco", world=4
        )
    with pytest.raises(ValueError, match="streams"):
        CommConfig(streams=0).validate()


# ---------------------------------------------------------------------------
# stacked runtime: parity, telemetry, mid-stream resume, churn fallback
# ---------------------------------------------------------------------------


def test_stream1_overlap_bitwise_matches_legacy():
    """streams=1 + overlap is the legacy trajectory BIT FOR BIT — the update
    math is untouched; only when bytes move changes."""
    base = run_training(TINY, steps=12, **KW)
    ov = run_training(TINY, steps=12, streams=1, overlap=True, **KW)
    np.testing.assert_array_equal(
        np.asarray(base["losses"]), np.asarray(ov["losses"])
    )
    assert ov["stream_count"] == 1
    assert 0.0 < ov["blocking_fraction"] < 1.0  # prefetch consumed after sync 1


def test_streams4_staggers_syncs_and_cuts_blocking(tmp_path):
    path = str(tmp_path / "t.jsonl")
    res = run_training(TINY, steps=16, streams=4, overlap=True,
                       log_jsonl=path, **KW)
    assert res["stream_count"] == 4
    assert res["blocking_fraction"] < 1.0
    events = [json.loads(l) for l in open(path)]
    ss = [e for e in events if e["event"] == "stream_sync"]
    # m=4, S=4 → one stream due at EVERY inner step from t=m on
    assert [e["stream"] for e in ss[:4]] == [0, 1, 2, 3]
    assert [e["sync_index"] for e in ss] == list(range(len(ss)))
    for e in ss:
        assert e["payload_bytes"] == e["blocking_bytes"] + e["overlapped_bytes"]
        assert e["blocked"] == (e["blocking_bytes"] == e["payload_bytes"])
    # first sync of each stream has nothing prefetched → blocks; later ones
    # consume the φ′ pre-send and block on Δ only
    assert all(e["blocked"] for e in ss[:4])
    assert not any(e["blocked"] for e in ss[4:])
    assert not any(e.get("epoch_fallback") for e in ss)  # healthy run


def test_resume_mid_stream_matches_uninterrupted(tmp_path):
    """Interrupt BETWEEN two stream syncs of the same round (prefetched φ and
    stream offsets in flight) — the checkpoint must carry them so the resumed
    trajectory is exact."""
    kw = dict(KW, streams=4, overlap=True)
    full = run_training(TINY, steps=12, **kw)
    d = str(tmp_path / "ckpt")
    # step 6 with m=4, S=4: streams 0..1 of round 1 synced, 2..3 pending
    run_training(TINY, steps=6, ckpt_dir=d, **kw)
    cont = run_training(TINY, steps=12, ckpt_dir=d, resume=True, **kw)
    assert cont["start_step"] == 6
    np.testing.assert_array_equal(
        np.asarray(full["losses"][6:]), np.asarray(cont["losses"])
    )
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(full["state"].theta)[0]),
        np.asarray(jax.tree.leaves(cont["state"].theta)[0]),
    )


def test_streamed_churn_converges_with_per_stream_fallback(tmp_path):
    """Drop/rejoin under streams=4: only streams whose membership epoch
    advanced mid-flight fall back to blocking (once each), and the final
    loss stays within 5% of the healthy streamed run."""
    from repro.launch.train_elastic import run_elastic_training
    from repro.sim import FaultPlan

    events = [
        {"kind": "drop", "step": 9, "replicas": [3]},
        {"kind": "rejoin", "step": 17, "replicas": [3]},
    ]
    kw = dict(method="noloco", replicas=8, per_replica_batch=2, seq_len=32,
              steps=28, inner_steps=4, inner_lr=3e-3, eval_every=28,
              stream_count=4)
    path = str(tmp_path / "churn.jsonl")
    res = run_elastic_training(TINY, FaultPlan.build(events),
                               log_jsonl=path, **kw)
    healthy = run_elastic_training(TINY, FaultPlan(), **kw)
    assert np.isfinite(res["losses"]).all()
    assert res["blocking_fraction"] < 1.0
    assert abs(res["evals"][-1][1] - healthy["evals"][-1][1]) <= (
        0.05 * healthy["evals"][-1][1]
    )
    ss = [json.loads(l) for l in open(path)]
    ss = [e for e in ss if e["event"] == "stream_sync"]
    fallbacks = [e for e in ss if e.get("epoch_fallback")]
    # 2 membership changes × at most one fallback per stream each
    assert 0 < len(fallbacks) <= 2 * 4
    per_epoch: dict[int, list[int]] = {}
    for e in fallbacks:
        per_epoch.setdefault(e["step"] // 8, []).append(e["stream"])
    for streams in per_epoch.values():
        assert len(streams) == len(set(streams))  # once per stream at most


def test_legacy_sharded_overlapped_is_retired():
    with pytest.raises(NotImplementedError, match="streams=1, overlap=True"):
        outer_lib.outer_step_sharded_overlapped()


def test_streams_require_noloco():
    with pytest.raises(ValueError, match="noloco-only"):
        run_training(TINY, steps=4, method="diloco", replicas=4,
                     per_replica_batch=2, seq_len=32, inner_steps=4,
                     streams=2, overlap=True)


# ---------------------------------------------------------------------------
# shard_map runtime (subprocesses on 8 forced host devices)
# ---------------------------------------------------------------------------


def _run(code: str, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


PRELUDE = """
import json
import jax, jax.numpy as jnp, numpy as np
from repro.comm import CommConfig
from repro.core.elastic import ElasticContext
from repro.core.outer import OuterConfig
from repro.core.pairing import Membership
from repro.data import LoaderConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train_distributed import DistributedTrainer
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.parallel import plans as PL
from repro.sim import FaultPlan, SimCluster
from repro.train import DistributedProgram, LoopConfig, make_loop

CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=128, dtype="float32", remat=False)

def make_trainer(comm, elastic=None, inner_steps=4, seed=0):
    mesh = make_test_mesh(8, 1)
    plan = PL.make_plan("gossip_dp", mesh, shape_kind="train")
    return DistributedTrainer(
        cfg=CFG, mesh=mesh, plan=plan,
        outer_cfg=OuterConfig(method="noloco", inner_steps=inner_steps),
        inner_cfg=AdamWConfig(lr=3e-3, weight_decay=0.0),
        comm_cfg=comm, seed=seed, elastic=elastic,
    )

def make_run(trainer, plan_events, steps, ckpt_dir=None, resume=False,
             log_jsonl=None):
    program = DistributedProgram(trainer)
    sim = None
    if plan_events is not None:
        sim = SimCluster(program, FaultPlan.build(plan_events))
    loop = make_loop(
        sim or program,
        LoaderConfig(vocab_size=CFG.vocab_size, seq_len=32,
                     per_replica_batch=2, replicas=8, seed=0),
        LoopConfig(steps=steps, eval_every=0, seed=0, ckpt_dir=ckpt_dir,
                   resume=resume, log_jsonl=log_jsonl),
    )
    return loop, sim
"""


@pytest.mark.multidevice
def test_distributed_stream1_overlap_bitwise_and_streams4_converge():
    """shard_map runtime: streams=1+overlap reproduces the legacy compiled
    trajectory bitwise; streams=4 staggers and cuts the blocking fraction."""
    out = _run(PRELUDE + """
loop, _ = make_run(make_trainer(CommConfig()), None, 16)
base = loop.run()
loop, _ = make_run(make_trainer(CommConfig(overlap=True, streams=1)), None, 16)
ov1 = loop.run()
np.testing.assert_array_equal(np.asarray(base["losses"]),
                              np.asarray(ov1["losses"]))
t4 = make_trainer(CommConfig(overlap=True, streams=4))
loop, _ = make_run(t4, None, 16)
ov4 = loop.run()
assert np.isfinite(ov4["losses"]).all()
print(json.dumps({
    "bf1": ov1["blocking_fraction"], "bf4": ov4["blocking_fraction"],
    "syncs4": ov4["outer_syncs"], "stream_count": ov4["stream_count"],
}))
""")
    rec = json.loads(out.strip().splitlines()[-1])
    assert 0.0 < rec["bf1"] < 1.0
    assert rec["bf4"] < 1.0
    assert rec["stream_count"] == 4
    # m=4, S=4, 16 steps → streams fire at t=4..16: far more sync events
    # than the 3 whole-payload walls the legacy schedule would have hit
    assert rec["syncs4"] >= 12


@pytest.mark.multidevice
def test_distributed_streamed_churn_fallback_and_mid_stream_resume(tmp_path):
    """Elastic shard_map + streams=4: churn triggers at most one epoch
    fallback per stream per membership change, programs come from the pool
    (bounded misses), and a checkpoint taken BETWEEN stream syncs resumes the
    exact trajectory (stream offsets + prefetched φ round-trip)."""
    d = str(tmp_path / "ck")
    jl = str(tmp_path / "stream_churn.jsonl")  # TrainLoop appends — keep it per-test
    out = _run(PRELUDE + f"""
EVENTS = [dict(kind="drop", step=9, replicas=[3]),
          dict(kind="rejoin", step=21, replicas=[3])]
def elastic(): return ElasticContext(Membership.full(8))
t0 = make_trainer(CommConfig(overlap=True, streams=4), elastic=elastic())
loop, _ = make_run(t0, EVENTS, 32, log_jsonl={jl!r})
full = loop.run()
assert np.isfinite(full["losses"]).all()
evs = [json.loads(l) for l in open({jl!r})]
ss = [e for e in evs if e["event"] == "stream_sync"]
fb = [e for e in ss if e.get("epoch_fallback")]
assert 0 < len(fb) <= 8, fb  # 2 changes x <= 1 per stream
stats = t0.pool.stats()
assert stats["misses"] <= stats["max_programs_per_view"] * 3

# exact resume from a checkpoint taken mid-round (stream 1 of round 2 done,
# streams 2..3 pending, prefetches in flight)
t1 = make_trainer(CommConfig(overlap=True, streams=4), elastic=elastic())
loop, _ = make_run(t1, EVENTS, 10, ckpt_dir={d!r})
loop.run()
t2 = make_trainer(CommConfig(overlap=True, streams=4), elastic=elastic())
loop, _ = make_run(t2, EVENTS, 32, ckpt_dir={d!r}, resume=True)
cont = loop.run()
assert cont["start_step"] == 10
np.testing.assert_allclose(np.asarray(full["losses"][10:]),
                           np.asarray(cont["losses"]), rtol=0, atol=0)
print("OK", full["blocking_fraction"])
""")
    assert "OK" in out
