"""Fig. 5 reproduction: (A) tree-allreduce vs gossip pair-averaging expected
time ratio across world sizes and latency variances; (B) DiLoCo global-
blocking overhead vs NoLoCo pairwise blocking."""
import math
import time

from repro.core import latency
from benchmarks.common import emit


def main() -> None:
    # --- Fig 5A: speedup ratio, closed form + Monte-Carlo -------------------
    for n in (16, 64, 256, 1024):
        for sigma2 in (0.1, 0.5, 1.0):
            sigma = math.sqrt(sigma2)
            t0 = time.perf_counter()
            tree = latency.simulate_tree_allreduce(n, 0.0, sigma, rounds=400, seed=0)
            pair = latency.simulate_pair_average(0.0, sigma, rounds=4000, seed=0)
            us = (time.perf_counter() - t0) * 1e6
            cf = latency.speedup_closed_form(n, 0.0, sigma)
            emit(
                f"fig5a_n{n}_s{sigma2}", us,
                f"ratio_sim={tree / pair:.2f};ratio_closed_form={cf:.2f}",
            )

    # --- Fig 5B: blocking overhead ------------------------------------------
    for n in (64, 256, 1024):
        for inner in (50, 100):
            t0 = time.perf_counter()
            r = latency.simulate_blocking_overhead(
                n, outer_rounds=250, inner_steps=inner, mu=1.0, sigma2=0.5
            )
            us = (time.perf_counter() - t0) * 1e6
            emit(f"fig5b_n{n}_m{inner}", us, f"diloco_over_noloco={r['ratio']:.3f}")


if __name__ == "__main__":
    main()
