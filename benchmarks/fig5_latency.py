"""Fig. 5 reproduction: (A) tree-allreduce vs gossip pair-averaging expected
time ratio across world sizes and latency variances; (B) DiLoCo global-
blocking overhead vs NoLoCo pairwise blocking; (C) size-aware outer-round
times on paper_llama shapes, with the payload bytes taken from
repro.comm.bytes_model for each wire codec × overlap setting."""
import math
import time

from repro.comm import CommConfig, bytes_model
from repro.core import latency
from benchmarks.common import emit


def main() -> None:
    # --- Fig 5A: speedup ratio, closed form + Monte-Carlo -------------------
    for n in (16, 64, 256, 1024):
        for sigma2 in (0.1, 0.5, 1.0):
            sigma = math.sqrt(sigma2)
            t0 = time.perf_counter()
            tree = latency.simulate_tree_allreduce(n, 0.0, sigma, rounds=400, seed=0)
            pair = latency.simulate_pair_average(0.0, sigma, rounds=4000, seed=0)
            us = (time.perf_counter() - t0) * 1e6
            cf = latency.speedup_closed_form(n, 0.0, sigma)
            emit(
                f"fig5a_n{n}_s{sigma2}", us,
                f"ratio_sim={tree / pair:.2f};ratio_closed_form={cf:.2f}",
            )

    # --- Fig 5B: blocking overhead ------------------------------------------
    for n in (64, 256, 1024):
        for inner in (50, 100):
            t0 = time.perf_counter()
            r = latency.simulate_blocking_overhead(
                n, outer_rounds=250, inner_steps=inner, mu=1.0, sigma2=0.5
            )
            us = (time.perf_counter() - t0) * 1e6
            emit(f"fig5b_n{n}_m{inner}", us, f"diloco_over_noloco={r['ratio']:.3f}")

    # --- Fig 5C: codec-aware payload bytes & outer-round time ----------------
    # Exact per-outer-step byte counts from the comm layer (fp32 Δ/φ master
    # copies on paper_llama shapes), fed into the size-aware latency model.
    sigma = math.sqrt(0.5)
    params = bytes_model.abstract_params("paper-small-125m")
    base = bytes_model.outer_step_cost(params, CommConfig())
    for codec in ("none", "fp16", "int8"):
        for overlap in (False, True):
            t0 = time.perf_counter()
            cost = bytes_model.outer_step_cost(
                params, CommConfig(codec=codec, overlap=overlap)
            )
            t_pair = latency.pair_average_time_bytes(
                0.0, sigma, payload_bytes=cost.blocking_bytes
            )
            us = (time.perf_counter() - t0) * 1e6
            tag = f"fig5c_{codec}" + ("_overlap" if overlap else "")
            emit(
                tag, us,
                f"blocking_MB={cost.blocking_bytes / 1e6:.1f};"
                f"messages={cost.blocking_messages};"
                f"bytes_reduction_vs_none={base.payload_bytes / cost.payload_bytes:.2f};"
                f"pair_round_s={t_pair:.2f}",
            )
    # message-count cost of NOT fusing (one permute per leaf)
    unfused = bytes_model.outer_step_cost(params, CommConfig(fuse=False))
    emit("fig5c_unfused_messages", 0.0,
         f"messages={unfused.messages};fused_messages={base.messages}")


if __name__ == "__main__":
    main()
