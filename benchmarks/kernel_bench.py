"""Kernel bench: the DISPATCHED production path of every registered op (what
models/core/comm actually run — resolved per ``impl="auto"``, so the jnp
twins on this CPU box and the Pallas kernels on TPU), plus the naive oracles
for reference and derived TPU roofline estimates.  Interpret-mode Pallas
timing is meaningless on CPU, so no forced-pallas numbers are recorded.

Writes BENCH_kernels.json (registered in benchmarks/run.py; part of the CI
bench-smoke job) so the production-path perf trajectory is tracked per PR.
"""
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.comm import CommConfig, get_codec
from repro.kernels import ops, ref
from repro.kernels.dispatch import KernelConfig, default_config
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")

_RESULTS: dict[str, dict] = {}


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _record(name: str, us: float, derived: str) -> None:
    emit(name, us, derived)
    _RESULTS[name] = {"us_per_call": round(us, 3), "derived": derived}


def main() -> None:
    key = jax.random.PRNGKey(0)
    impl = default_config().resolved_impl()

    # -- flash attention: b=1 h=8 kv=2 s=1024 d=128 (GQA production path) ---
    b, s, h, kv, d = 1, 1024, 8, 2, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    fn = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, mode="causal"))
    us = _time(fn, q, k, v)
    flops = 4 * b * h * s * s * d  # qk + pv
    tpu_us = flops / PEAK_FLOPS * 1e6
    _record("kernel_flash_attn_s1024_gqa", us,
            f"impl={impl};flops={flops:.3g};tpu_roofline_us={tpu_us:.1f}")

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    fn_ref = jax.jit(lambda q: ref.reference_attention(q, q, q, mode="causal"))
    us_ref = _time(fn_ref, qf)
    _record("kernel_flash_attn_s1024_oracle", us_ref, "naive_full_softmax")

    # -- fused noloco update: n = 16M params -------------------------------
    n = 1 << 24
    xs = [jax.random.normal(jax.random.fold_in(key, i), (n,), jnp.bfloat16)
          for i in range(4)]
    fn2 = jax.jit(lambda *a: ops.noloco_update_pytree(
        {"w": a[0]}, {"w": a[1]}, {"w": a[2]}, {"w": a[3]},
        alpha=0.5, beta=0.7, gamma=1.0))
    us2 = _time(fn2, *xs)
    bytes_moved = n * 2 * 6  # 4 reads + 2 writes bf16
    tpu_us2 = bytes_moved / HBM_BW * 1e6
    _record("kernel_noloco_update_16M", us2,
            f"impl={impl};bytes={bytes_moved:.3g};tpu_roofline_us={tpu_us2:.1f}")

    # -- ssd: b=1 s=512 h=4 p=64 n=64, dispatched chunked path --------------
    x = jax.random.normal(key, (1, 512, 4, 64)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 9), (1, 512, 4))) * 0.1
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 8), (4,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(key, 7), (1, 512, 64)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 6), (1, 512, 64)) * 0.3
    fn3 = jax.jit(lambda *args: ops.ssd_chunk(*args, chunk=128)[0])
    us3 = _time(fn3, x, dt, a, bm, cm)
    _record("kernel_ssd_s512", us3, f"impl={impl};chunked_production_path")
    fn3r = jax.jit(lambda *args: ref.reference_ssd(*args)[0])
    us3r = _time(fn3r, x, dt, a, bm, cm)
    _record("kernel_ssd_s512_oracle", us3r, "token_recurrence")

    # -- rglru scan: b=1 s=2048 w=512 --------------------------------------
    ar = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 11), (1, 2048, 512))) * 0.5 + 0.45
    br = jax.random.normal(jax.random.fold_in(key, 12), (1, 2048, 512)) * 0.3
    fn4 = jax.jit(lambda a, b: ops.rglru_scan(a, b))
    us4 = _time(fn4, ar, br)
    _record("kernel_rglru_scan_s2048", us4, f"impl={impl};linear_recurrence")

    # -- serving hot-loop ops: R=8 slots, pool 256 pages x 16 tokens --------
    # These are the per-token ops of the ServeEngine decode step and the
    # per-chunk op of chunked prefill — the serving-side counterparts of the
    # training kernels above.
    r, np_, bs, kvh, hq, d = 8, 256, 16, 2, 8, 128
    mbk = 64
    kp = jax.random.normal(jax.random.fold_in(key, 20), (np_ + 1, bs, kvh, d)) * 0.3
    vp = jax.random.normal(jax.random.fold_in(key, 21), (np_ + 1, bs, kvh, d)) * 0.3
    tables = jax.random.randint(jax.random.fold_in(key, 22), (r, mbk), 0, np_)
    pos = jnp.full((r,), mbk * bs // 2, jnp.int32)
    qd = jax.random.normal(jax.random.fold_in(key, 23), (r, hq, d))
    fnp = jax.jit(lambda *a: ops.paged_attention(*a, mode="causal"))
    usp = _time(fnp, qd, kp, vp, tables, pos)
    read = r * (mbk * bs // 2) * kvh * d * 4 * 2  # K+V f32 up to position
    _record("kernel_paged_attn_decode_r8", usp,
            f"impl={impl};kv_bytes={read:.3g};tpu_roofline_us={read / HBM_BW * 1e6:.1f}")

    cch = 32
    qc = jax.random.normal(jax.random.fold_in(key, 24), (r, cch, hq, d))
    fnc = jax.jit(lambda *a: ops.paged_chunk_attention(*a, mode="causal"))
    usc = _time(fnc, qc, kp, vp, tables, pos)
    _record("kernel_paged_attn_chunk_r8_c32", usc,
            f"impl={impl};per_token_us={usc / (r * cch):.2f};"
            f"decode_equiv_us={usp * cch:.1f}")

    w = 2048
    hr = jax.random.normal(jax.random.fold_in(key, 25), (r, w))
    ag = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 26), (r, w)))
    bg = jax.random.normal(jax.random.fold_in(key, 27), (r, w)) * 0.3
    fnr = jax.jit(lambda *a: ops.rglru_decode(*a))
    usr = _time(fnr, hr, ag, bg)
    _record("kernel_rglru_decode_r8_w2048", usr, f"impl={impl};fused_state_update")

    hh, p, nn = 8, 64, 64
    st = jax.random.normal(jax.random.fold_in(key, 28), (r, hh, p, nn)) * 0.1
    dt1 = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 29), (r, hh))) * 0.1
    ad = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 30), (hh,)) * 0.3)
    b1 = jax.random.normal(jax.random.fold_in(key, 31), (r, nn)) * 0.3
    c1 = jax.random.normal(jax.random.fold_in(key, 32), (r, nn)) * 0.3
    x1 = jax.random.normal(jax.random.fold_in(key, 33), (r, hh, p)) * 0.3
    fns = jax.jit(lambda *a: ops.ssd_decode(*a)[1])
    uss = _time(fns, st, dt1, ad, b1, c1, x1)
    sbytes = r * hh * p * nn * 4 * 2  # state read + write dominates
    _record("kernel_ssd_decode_r8", uss,
            f"impl={impl};state_bytes={sbytes:.3g};"
            f"tpu_roofline_us={sbytes / HBM_BW * 1e6:.1f}")

    # -- comm codecs: encode+decode round trip of a 16M-element fp32 gossip
    # buffer through the production codec object (int8 runs the dispatched
    # quantize kernels), plus the exact wire-byte reduction.
    n = 1 << 24
    buf = jax.random.normal(jax.random.fold_in(key, 10), (n,), jnp.float32)
    for name in ("fp16", "int8"):
        cfg = CommConfig(codec=name)
        codec = get_codec(cfg)
        rt = jax.jit(lambda b: codec.decode(codec.encode(b), jnp.float32, n))
        us5 = _time(rt, buf)
        wire = codec.wire_bytes(n, jnp.float32)
        raw = n * 4
        tpu_us5 = (raw + wire) / HBM_BW * 1e6  # read raw + write wire
        _record(f"kernel_comm_codec_{name}_16M", us5,
                f"impl={impl};wire_bytes={wire:.3g};reduction={raw / wire:.2f}x;"
                f"tpu_roofline_us={tpu_us5:.1f}")

    with open(OUT, "w") as f:
        json.dump(
            {"impl": impl, "backend": jax.default_backend(), "kernels": _RESULTS},
            f, indent=2,
        )


if __name__ == "__main__":
    main()
