"""Kernel micro-bench: jnp reference wall time on CPU (interpret-mode Pallas
timing is meaningless) + derived TPU roofline estimates for the kernels."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.comm import CommConfig, bytes_model, get_codec
from repro.kernels import ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    key = jax.random.PRNGKey(0)
    # flash attention: b=1 h=8 s=1024 d=128
    b, s, h, d = 1, 1024, 8, 128
    q = jax.random.normal(key, (b * h, s, d), jnp.float32)
    fn = jax.jit(lambda q: ref.reference_attention(q, q, q, mode="causal"))
    us = _time(fn, q)
    flops = 4 * b * h * s * s * d  # qk + pv
    tpu_us = flops / PEAK_FLOPS * 1e6
    emit("kernel_flash_attn_s1024", us, f"flops={flops:.3g};tpu_roofline_us={tpu_us:.1f}")

    # noloco update: n = 16M params
    n = 1 << 24
    xs = [jax.random.normal(jax.random.fold_in(key, i), (n,), jnp.bfloat16) for i in range(5)]
    fn2 = jax.jit(lambda *a: ref.reference_noloco_update(*a, alpha=0.5, beta=0.7, gamma=1.0))
    us2 = _time(fn2, *xs)
    bytes_moved = n * 2 * 7  # 5 reads + 2 writes bf16
    tpu_us2 = bytes_moved / HBM_BW * 1e6
    emit("kernel_noloco_update_16M", us2, f"bytes={bytes_moved:.3g};tpu_roofline_us={tpu_us2:.1f}")

    # ssd: b=1 s=512 h=4 p=64 n=64
    x = jax.random.normal(key, (1, 512, 4, 64)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 9), (1, 512, 4))) * 0.1
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 8), (4,)) * 0.3)
    bm = jax.random.normal(jax.random.fold_in(key, 7), (1, 512, 64)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(key, 6), (1, 512, 64)) * 0.3
    fn3 = jax.jit(lambda *args: ref.reference_ssd(*args)[0])
    us3 = _time(fn3, x, dt, a, bm, cm)
    emit("kernel_ssd_s512", us3, "oracle_recurrence")

    # comm codecs: encode+decode round trip of a 16M-element fp32 gossip
    # buffer (the compute cost of compressing the outer payload), plus the
    # wire-byte reduction the codec buys (from the exact bytes model).
    n = 1 << 24
    buf = jax.random.normal(jax.random.fold_in(key, 10), (n,), jnp.float32)
    for name in ("fp16", "int8"):
        cfg = CommConfig(codec=name)
        codec = get_codec(cfg)
        rt = jax.jit(lambda b: codec.decode(codec.encode(b), jnp.float32, n))
        us4 = _time(rt, buf)
        wire = codec.wire_bytes(n, jnp.float32)
        raw = n * 4
        tpu_us4 = (raw + wire) / HBM_BW * 1e6  # read raw + write wire
        emit(f"kernel_comm_codec_{name}_16M", us4,
             f"wire_bytes={wire:.3g};reduction={raw / wire:.2f}x;"
             f"tpu_roofline_us={tpu_us4:.1f}")


if __name__ == "__main__":
    main()
