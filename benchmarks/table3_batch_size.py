"""Table 3 proxy: decentralized methods improve with larger global batch."""
import time

from benchmarks.common import emit
from repro.launch.train import run_training
from repro.models.config import ModelConfig

TINY = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=256, dtype="float32", remat=False)


def main() -> None:
    for method in ("diloco", "noloco"):
        evs = {}
        for pb in (2, 4):
            t0 = time.perf_counter()
            res = run_training(
                TINY, method=method, replicas=4, per_replica_batch=pb,
                seq_len=48, steps=80, inner_lr=2e-3, inner_steps=20,
                eval_every=80, eval_batches=2, seed=4,
            )
            us = (time.perf_counter() - t0) * 1e6 / 80
            evs[pb] = res["evals"][-1][1]
            emit(f"table3_{method}_b{pb}", us, f"val_loss={evs[pb]:.4f}")
        emit(f"table3_{method}_gain", 0.0, f"small_minus_large={evs[2]-evs[4]:+.4f}")


if __name__ == "__main__":
    main()
