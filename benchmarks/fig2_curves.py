"""Fig. 2 proxy: validation-loss trajectories for FSDP / DiLoCo / NoLoCo over
training (the paper's Fig. 2 shows NoLoCo tracking DiLoCo closely, both a few
percent above FSDP, with the gap narrowing)."""
import time

from benchmarks.common import emit
from repro.launch.train import run_training
from repro.models.config import ModelConfig

TINY = ModelConfig(num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
                   d_ff=192, vocab_size=256, dtype="float32", remat=False)


def main() -> None:
    steps = 120
    for method in ("fsdp", "diloco", "noloco"):
        t0 = time.perf_counter()
        res = run_training(
            TINY, method=method, replicas=4, per_replica_batch=2, seq_len=64,
            steps=steps, inner_lr=2e-3,
            inner_steps=20 if method == "noloco" else 40,
            eval_every=30, eval_batches=2, seed=6,
        )
        us = (time.perf_counter() - t0) * 1e6 / steps
        curve = ";".join(f"s{t}={v:.4f}" for t, v in res["evals"])
        emit(f"fig2_{method}", us, curve)


if __name__ == "__main__":
    main()
