"""Engine throughput benchmark: tokens/s, comm bytes per outer step and the
blocking fraction from the unified TrainLoop's own accounting, on
paper-small-125m (reduced), written to BENCH_engine.json so the perf
trajectory is tracked from PR 2 onward.

Since the streaming-outer-steps PR the benchmarked engine config is
``streams=STREAMS`` with the §3.2 φ-prefetch: each sync event exchanges one
payload stream and only its Δ half blocks, so ``blocking_bytes_per_outer_step``
is the event-averaged blocking bytes per STREAM SYNC (the new wall), while
``baseline_blocking_bytes_per_outer_step`` keeps the pre-streaming whole-payload
wall for the cut-factor trajectory.
"""
import json
import os
import time

from benchmarks.common import emit
from repro.configs import registry
from repro.launch.train import run_training

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
STEPS = 30
STREAMS = 4


def main() -> None:
    cfg = registry.get_config("paper-small-125m").reduced(
        vocab_size=512, dtype="float32", remat=False
    )
    t0 = time.perf_counter()
    res = run_training(
        cfg, method="noloco", replicas=4, per_replica_batch=2, seq_len=64,
        steps=STEPS, inner_lr=2e-3, inner_steps=10, eval_every=0, seed=0,
        streams=STREAMS, overlap=True,
    )
    us = (time.perf_counter() - t0) * 1e6 / STEPS
    comm = res["comm"] or {}
    # pre-streaming wall: the whole fused payload blocked at every sync
    baseline_blocking = comm.get("payload_bytes", 0)
    syncs = max(res["outer_syncs"], 1)
    blocking = round(res["blocking_bytes"] / syncs)
    overlapped = round((res["comm_bytes"] - res["blocking_bytes"]) / syncs)
    bench = {
        "arch": cfg.name,
        "steps": STEPS,
        "stream_count": res.get("stream_count", 1),
        "tokens_per_s": round(res["tokens_per_s"], 2),
        "wall_s": round(res["wall_s"], 3),
        "outer_syncs": res["outer_syncs"],
        "comm_bytes_per_outer_step": comm.get("payload_bytes", 0),
        "blocking_bytes_per_outer_step": blocking,
        "overlapped_bytes_per_outer_step": overlapped,
        "blocking_fraction": round(res["blocking_fraction"], 4),
        "baseline_blocking_bytes_per_outer_step": baseline_blocking,
        "blocking_cut_factor": round(baseline_blocking / max(blocking, 1), 2),
        "final_train_loss": round(res["losses"][-1], 4),
        "final_weight_std": res["final_weight_std"],
    }
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2)
    emit("engine_tokens_per_s", us, f"tok_s={bench['tokens_per_s']}")
    emit("engine_comm", 0.0,
         f"blocking_per_sync={bench['blocking_bytes_per_outer_step']};"
         f"cut={bench['blocking_cut_factor']}x;"
         f"blocking_frac={bench['blocking_fraction']}")


if __name__ == "__main__":
    main()
