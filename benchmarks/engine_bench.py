"""Engine throughput benchmark: tokens/s, comm bytes per outer step and the
blocking fraction from the unified TrainLoop's own accounting, on
paper-small-125m (reduced), written to BENCH_engine.json so the perf
trajectory is tracked from PR 2 onward.
"""
import json
import os
import time

from benchmarks.common import emit
from repro.configs import registry
from repro.launch.train import run_training

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
STEPS = 30


def main() -> None:
    cfg = registry.get_config("paper-small-125m").reduced(
        vocab_size=512, dtype="float32", remat=False
    )
    t0 = time.perf_counter()
    res = run_training(
        cfg, method="noloco", replicas=4, per_replica_batch=2, seq_len=64,
        steps=STEPS, inner_lr=2e-3, inner_steps=10, eval_every=0, seed=0,
    )
    us = (time.perf_counter() - t0) * 1e6 / STEPS
    comm = res["comm"] or {}
    bench = {
        "arch": cfg.name,
        "steps": STEPS,
        "tokens_per_s": round(res["tokens_per_s"], 2),
        "wall_s": round(res["wall_s"], 3),
        "outer_syncs": res["outer_syncs"],
        "comm_bytes_per_outer_step": comm.get("payload_bytes", 0),
        "blocking_bytes_per_outer_step": comm.get("blocking_bytes", 0),
        "blocking_fraction": round(res["blocking_fraction"], 4),
        "final_train_loss": round(res["losses"][-1], 4),
        "final_weight_std": res["final_weight_std"],
    }
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2)
    emit("engine_tokens_per_s", us, f"tok_s={bench['tokens_per_s']}")
    emit("engine_comm", 0.0,
         f"bytes_per_outer={bench['comm_bytes_per_outer_step']};"
         f"blocking_frac={bench['blocking_fraction']}")


if __name__ == "__main__":
    main()
