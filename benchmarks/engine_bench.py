"""Engine throughput benchmark: tokens/s, comm bytes per outer step and the
blocking fraction from the unified TrainLoop's own accounting, on
paper-small-125m (reduced), written to BENCH_engine.json so the perf
trajectory is tracked from PR 2 onward.

Since the streaming-outer-steps PR the benchmarked engine config is
``streams=STREAMS`` with the §3.2 φ-prefetch: each sync event exchanges one
payload stream and only its Δ half blocks, so ``blocking_bytes_per_outer_step``
is the event-averaged blocking bytes per STREAM SYNC (the new wall), while
``baseline_blocking_bytes_per_outer_step`` keeps the pre-streaming whole-payload
wall for the cut-factor trajectory.

Since the asynchronous-rounds PR the bench also runs the 2x-straggler
comparison (``async_straggler``): the same slow replica modeled
round-synchronously (straggle events — it sits out every other round and
forces a self-pair on the odd survivor) vs. on its own round clock (a rate
event — it syncs late with a stale Δ), reporting blocked syncs, idle rounds
and the max staleness the async run recorded.
"""
import json
import os
import time

from benchmarks.common import emit
from repro.configs import registry
from repro.launch.train import run_training
from repro.launch.train_elastic import run_elastic_training
from repro.sim import FaultEvent, FaultPlan

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")
STEPS = 30
STREAMS = 4
ASYNC_STEPS = 24
ASYNC_INNER = 4


def _async_straggler_comparison(cfg) -> dict:
    """2x straggler on 8 replicas, round-synchronous vs. per-replica clocks."""
    rounds = ASYNC_STEPS // ASYNC_INNER
    kw = dict(
        replicas=8, per_replica_batch=2, seq_len=64, steps=ASYNC_STEPS,
        inner_steps=ASYNC_INNER, inner_lr=2e-3, eval_every=0, seed=0,
    )
    # round-synchronous: a 2x-slow replica misses every other round
    sync_plan = FaultPlan([
        FaultEvent(kind="straggle", round=r, replicas=[1])
        for r in range(1, rounds, 2)
    ])
    t0 = time.perf_counter()
    sync = run_elastic_training(cfg, sync_plan, **kw)
    sync_wall = time.perf_counter() - t0
    # asynchronous: the same slowdown as a rate multiplier on its own clock
    async_plan = FaultPlan([
        FaultEvent(kind="rate", round=0, replicas=[1], rate=0.5)
    ])
    t0 = time.perf_counter()
    asyn = run_elastic_training(cfg, async_plan, **kw)
    async_wall = time.perf_counter() - t0

    def idle_rounds(res):
        return sum(len(r.get("absent", [])) for r in res["rounds"])

    return {
        "replicas": 8, "straggler_rate": 0.5, "steps": ASYNC_STEPS,
        "sync": {
            "blocked_syncs": sync["blocked_syncs"],
            "idle_replica_rounds": idle_rounds(sync),
            "blocking_fraction": round(sync["blocking_fraction"], 4),
            "outer_syncs": sync["outer_syncs"],
            "wall_s": round(sync_wall, 3),
        },
        "async": {
            "blocked_syncs": asyn["blocked_syncs"],
            "idle_replica_rounds": idle_rounds(asyn),
            "max_staleness": asyn["max_staleness"],
            "blocking_fraction": round(asyn["blocking_fraction"], 4),
            "outer_syncs": asyn["outer_syncs"],
            "wall_s": round(async_wall, 3),
        },
    }


def main() -> None:
    cfg = registry.get_config("paper-small-125m").reduced(
        vocab_size=512, dtype="float32", remat=False
    )
    t0 = time.perf_counter()
    res = run_training(
        cfg, method="noloco", replicas=4, per_replica_batch=2, seq_len=64,
        steps=STEPS, inner_lr=2e-3, inner_steps=10, eval_every=0, seed=0,
        streams=STREAMS, overlap=True,
    )
    us = (time.perf_counter() - t0) * 1e6 / STEPS
    comm = res["comm"] or {}
    # pre-streaming wall: the whole fused payload blocked at every sync
    baseline_blocking = comm.get("payload_bytes", 0)
    syncs = max(res["outer_syncs"], 1)
    blocking = round(res["blocking_bytes"] / syncs)
    overlapped = round((res["comm_bytes"] - res["blocking_bytes"]) / syncs)
    bench = {
        "arch": cfg.name,
        "steps": STEPS,
        "stream_count": res.get("stream_count", 1),
        "tokens_per_s": round(res["tokens_per_s"], 2),
        "wall_s": round(res["wall_s"], 3),
        "outer_syncs": res["outer_syncs"],
        "comm_bytes_per_outer_step": comm.get("payload_bytes", 0),
        "blocking_bytes_per_outer_step": blocking,
        "overlapped_bytes_per_outer_step": overlapped,
        "blocking_fraction": round(res["blocking_fraction"], 4),
        "baseline_blocking_bytes_per_outer_step": baseline_blocking,
        "blocking_cut_factor": round(baseline_blocking / max(blocking, 1), 2),
        "final_train_loss": round(res["losses"][-1], 4),
        "final_weight_std": res["final_weight_std"],
        "async_straggler": _async_straggler_comparison(cfg),
    }
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2)
    emit("engine_tokens_per_s", us, f"tok_s={bench['tokens_per_s']}")
    emit("engine_comm", 0.0,
         f"blocking_per_sync={bench['blocking_bytes_per_outer_step']};"
         f"cut={bench['blocking_cut_factor']}x;"
         f"blocking_frac={bench['blocking_fraction']}")
    a = bench["async_straggler"]
    emit("engine_async_straggler", 0.0,
         f"sync_blocked={a['sync']['blocked_syncs']};"
         f"async_blocked={a['async']['blocked_syncs']};"
         f"async_max_tau={a['async']['max_staleness']}")


if __name__ == "__main__":
    main()
