"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,table2]

Prints ``name,us_per_call,derived`` CSV rows (harness contract).
"""

import argparse
import sys
import traceback


MODULES = [
    ("fig5", "benchmarks.fig5_latency"),          # Fig 5A/5B latency + blocking
    ("table2", "benchmarks.table2_convergence"),  # Table 2 FSDP/DiLoCo/NoLoCo
    ("fig2", "benchmarks.fig2_curves"),           # Fig 2 loss trajectories
    ("fig3", "benchmarks.fig3_weight_variance"),  # Fig 3B std ~ LR (Thm 1)
    ("fig4", "benchmarks.fig4_routing"),          # Fig 4 routing ablation
    ("table3", "benchmarks.table3_batch_size"),   # Table 3 batch-size ablation
    ("kernels", "benchmarks.kernel_bench"),       # Pallas kernel roofline est.
    ("engine", "benchmarks.engine_bench"),        # TrainLoop throughput -> BENCH_engine.json
    ("serve", "benchmarks.serve_bench"),          # continuous vs static batching -> BENCH_serve.json
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset keys")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if only and key not in only:
            continue
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main()
        except Exception:
            failures += 1
            print(f"{key},0,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
