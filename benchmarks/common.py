"""Shared benchmark plumbing: CSV emission in the harness format."""
import sys


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()
