"""Fig. 3B proxy: cross-replica weight std tracks the inner LR schedule
(Theorem 1: V(phi) ~ omega^2). Reports the Pearson correlation between the
std and the LR over training — the paper finds 0.91-0.97."""
import time

import numpy as np

from benchmarks.common import emit
from repro.launch.train import run_training
from repro.models.config import ModelConfig
from repro.optim import warmup_cosine

TINY = ModelConfig(num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
                   d_ff=192, vocab_size=256, dtype="float32", remat=False)


def main() -> None:
    steps = 160
    t0 = time.perf_counter()
    res = run_training(
        TINY, method="noloco", replicas=4, per_replica_batch=2, seq_len=64,
        steps=steps, inner_lr=3e-3, inner_steps=10, eval_every=10,
        eval_batches=1, warmup=20, seed=2,
    )
    us = (time.perf_counter() - t0) * 1e6 / steps
    sched = warmup_cosine(3e-3, steps, warmup_steps=20)
    pts = res["weight_stds"]
    xs = np.asarray([float(sched(np.int32(t))) for t, _ in pts])
    ys = np.asarray([v for _, v in pts])
    # paper correlates AFTER the warmup peak
    keep = slice(2, None)
    corr = float(np.corrcoef(xs[keep], ys[keep])[0, 1])
    emit("fig3b_std_lr_pearson", us, f"corr={corr:.3f};n={len(pts)}")
    emit("fig3b_final_weight_std", 0.0, f"std={res['final_weight_std']:.6f}")


if __name__ == "__main__":
    main()
