"""Table 2 proxy at CPU scale: final validation loss for FSDP / DiLoCo /
NoLoCo on the synthetic LM, several (DP, model) settings.

Paper claims to check: both decentralized methods land a few percent above
FSDP; NoLoCo <= DiLoCo in most settings (paper: up to 4% faster convergence).
"""
import time

from benchmarks.common import emit
from repro.launch.train import run_training
from repro.models.config import ModelConfig

TINY = ModelConfig(num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
                   d_ff=192, vocab_size=256, dtype="float32", remat=False)
STEPS = 120


def main() -> None:
    for replicas in (4, 8):
        results = {}
        for method in ("fsdp", "diloco", "noloco"):
            t0 = time.perf_counter()
            res = run_training(
                TINY, method=method, replicas=replicas, per_replica_batch=2,
                seq_len=64, steps=STEPS, inner_lr=2e-3,
                inner_steps=20 if method == "noloco" else 40,
                eval_every=STEPS, eval_batches=2, seed=1,
            )
            us = (time.perf_counter() - t0) * 1e6 / STEPS
            ev = res["evals"][-1][1]
            results[method] = ev
            emit(f"table2_dp{replicas}_{method}", us, f"val_loss={ev:.4f}")
        rel = (results["diloco"] - results["noloco"]) / results["fsdp"]
        emit(f"table2_dp{replicas}_relppl", 0.0,
             f"diloco_minus_noloco_over_fsdp={rel:+.4f}")


if __name__ == "__main__":
    main()
