"""Render dryrun JSON artifacts into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m benchmarks.dryrun_report dryrun_single.json [...]
"""

import json
import sys


def fmt_bytes(x):
    if x is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def main() -> None:
    rows = []
    for path in sys.argv[1:]:
        rows.extend(json.load(open(path)))

    print("## Dry-run matrix")
    print()
    print("| arch | shape | step | mesh | plan | status | compile_s | peak_bytes/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("status") == "skip":
            print(f"| {r['arch']} | {r.get('shape','-')} | {r.get('step','-')} | "
                  f"{r.get('mesh','-')} | - | SKIP ({r['reason'][:40]}…) | - | - | - |")
            continue
        if r.get("status") != "ok":
            print(f"| {r.get('arch')} | {r.get('shape')} | {r.get('step','-')} | "
                  f"{r.get('mesh')} | - | **FAIL** | - | - | - |")
            continue
        mem = r.get("memory", {})
        colls = ",".join(f"{k.split('-')[-1][:4]}×{v}" for k, v in r["collectives"].items() if v)
        print(f"| {r['arch']} | {r['shape']} | {r['step']} | {r['mesh']} | {r['plan']} "
              f"| ok | {r['compile_s']} | {fmt_bytes(mem.get('peak_bytes'))} "
              f"| {colls or 'none'} |")

    print()
    print("## Roofline (per device, TPU v5e constants)")
    print()
    print("| arch | shape | step | compute_s | memory_s | collective_s | bottleneck "
          "| cross-replica B | model-axis B | useful |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {r['step']} | {rf['compute_s']:.4f} "
              f"| {rf['memory_s']:.4f} | {rf['collective_s']:.5f} | **{rf['bottleneck']}** "
              f"| {fmt_bytes(rf['cross_replica_bytes'])} | {fmt_bytes(rf['model_axis_bytes'])} "
              f"| {rf['useful_ratio']:.2f} |")


if __name__ == "__main__":
    main()
