"""Fig. 4 reproduction: random vs fixed pipeline routing with the outer
optimizer OFF. Reports std(random)/std(fixed) (paper: ~0.85-0.9) and the
validation-loss ratio."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.data import SyntheticLM
from repro.models.config import ModelConfig
from repro.pipeline import PipelineTrainer

CFG = ModelConfig(num_layers=4, d_model=96, num_heads=4, num_kv_heads=4,
                  d_ff=192, vocab_size=256, dtype="float32", remat=False)


def _run(routing: str, steps: int = 80, R: int = 4, B: int = 2, S: int = 48):
    lm = SyntheticLM(256, seed=5)
    tr = PipelineTrainer(CFG, num_stages=2, replicas=R, routing=routing, seed=3)
    st = tr.init(jax.random.PRNGKey(0))
    losses = []
    for t in range(steps):
        toks = np.stack([
            lm.sample_tokens(r * 7919 + t, B * (S + 1)).reshape(B, S + 1)
            for r in range(R)
        ])
        batch = {"tokens": jnp.asarray(toks[:, :, :-1]),
                 "labels": jnp.asarray(toks[:, :, 1:])}
        st, loss = tr.train_step(st, batch)
        losses.append(loss)
    return tr.weight_std(st), float(np.mean(losses[-10:]))


def main() -> None:
    t0 = time.perf_counter()
    std_r, loss_r = _run("random")
    std_f, loss_f = _run("fixed")
    us = (time.perf_counter() - t0) * 1e6 / 160
    emit("fig4a_std_ratio", us, f"random_over_fixed={std_r / std_f:.3f}")
    emit("fig4b_loss_ratio", 0.0, f"random_over_fixed={loss_r / loss_f:.3f}")


if __name__ == "__main__":
    main()
