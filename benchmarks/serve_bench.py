"""Serving benchmark: the fast-path matrix, written to BENCH_serve.json.

Four comparisons on one mixed prompt/generation workload:

  * continuous vs static admission (the PR-7 scheduling win, kept as the
    regression anchor: continuous must not lose its lead);
  * chunked vs single-shot prefill from COLD jit caches — the compile-zoo
    comparison: single-shot retraces per distinct prompt length, chunked
    compiles ONE fixed-width program (the run uses all-distinct lengths to
    make the zoo explicit);
  * a concurrency sweep (tok/s + TTFT p50/p99 vs slot count) — how the
    engine trades time-to-first-token against batch throughput;
  * speculative decode on vs off, with a depth-truncated draft and the
    measured acceptance rate.

Per-token decode latency comes from a separate synced pass
(``sync_each_step`` serializes the host loop, so it is never the timed one).
"""
import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import registry
from repro.models import model as M
from repro.models.common import values_of
from repro.serve import Request, ServeConfig, ServeEngine, SpecServeEngine, truncate_layers
from repro.serve import engine as engine_mod

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

SLOTS = 4
PAGES = 96
PAGE_SIZE = 8
# mixed lengths: the workload where slot churn matters.  All prompt lengths
# DISTINCT so single-shot prefill pays one retrace per request.
LOADS = [(4, 8), (12, 24), (8, 12), (20, 6), (6, 24), (10, 8), (16, 16), (3, 12)]


def _requests(vocab: int) -> list[Request]:
    rng = np.random.default_rng(0)
    return [
        Request(rid=i, prompt=rng.integers(0, vocab, size=(pl,)).tolist(), max_new=gl)
        for i, (pl, gl) in enumerate(LOADS)
    ]


def _scfg(policy="continuous", *, slots=SLOTS, chunk=16, budget=0, sync=False):
    return ServeConfig(
        max_slots=slots, num_pages=PAGES, page_size=PAGE_SIZE,
        max_new_cap=max(gl for _, gl in LOADS), policy=policy,
        sync_each_step=sync, prefill_chunk=chunk, prefill_budget=budget,
    )


def _summarize(engine, finished, wall):
    toks = sum(len(f.tokens) for f in finished)
    ttfts = sorted(f.ttft_s for f in finished)
    return {
        "requests": len(finished),
        "gen_tokens": toks,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(toks / max(wall, 1e-9), 2),
        "decode_steps": engine.decode_steps,
        "ttft_p50_s": round(float(np.percentile(ttfts, 50)), 4),
        "ttft_p99_s": round(float(np.percentile(ttfts, 99)), 4),
    }


def _run(params, cfg, scfg, *, draft=None, spec_k=4):
    if draft is not None:
        engine = SpecServeEngine(params, cfg, scfg, draft[0], draft[1], spec_k=spec_k)
    else:
        engine = ServeEngine(params, cfg, scfg)
    reqs = [dataclasses.replace(r) for r in _requests(cfg.vocab_size)]
    t0 = time.perf_counter()
    finished = engine.run(reqs)
    jax.block_until_ready(engine.state.out_len)
    wall = time.perf_counter() - t0
    out = _summarize(engine, finished, wall)
    out["policy"] = scfg.policy
    out["prefill_chunk"] = scfg.prefill_chunk
    if draft is not None:
        out["spec_k"] = spec_k
        out["spec_rounds"] = engine.spec_rounds
        out["accept_rate"] = round(engine.accept_rate, 4)
    return out, engine


def _cold() -> None:
    """Drop every compiled serving program so the next run pays compiles —
    how the chunked-vs-single-shot comparison isolates the compile zoo."""
    engine_mod._programs.cache_clear()
    engine_mod._chunk_program.cache_clear()
    jax.clear_caches()


def main() -> None:
    cfg = registry.get_config("paper-small-125m").reduced(
        vocab_size=512, dtype="float32", remat=False
    )
    params = values_of(M.init_params(jax.random.PRNGKey(0), cfg))

    # -- scheduling: continuous vs static (warm caches, like PR 7) ----------
    _run(params, cfg, _scfg("continuous"))  # warm pass
    cont, _ = _run(params, cfg, _scfg("continuous"))
    stat, _ = _run(params, cfg, _scfg("static"))
    _, synced = _run(params, cfg, _scfg("continuous", sync=True))
    st = np.asarray(synced.decode_step_times)

    # -- prefill: chunked vs single-shot, both from COLD jit caches ---------
    _cold()
    single, _ = _run(params, cfg, _scfg("continuous", chunk=0))
    _cold()
    chunked, _ = _run(params, cfg, _scfg("continuous", chunk=16))

    # -- concurrency sweep: tok/s and TTFT percentiles vs slot count --------
    sweep = []
    for slots in (1, 2, SLOTS):
        res, _ = _run(params, cfg, _scfg("continuous", slots=slots))
        res["slots"] = slots
        sweep.append(res)

    # -- speculative decode: depth-truncated draft of the same weights ------
    draft = truncate_layers(params, cfg, max(1, cfg.num_layers // 2))
    _run(params, cfg, _scfg("continuous"), draft=draft)  # warm spec program
    spec, _ = _run(params, cfg, _scfg("continuous"), draft=draft)

    bench = {
        "arch": cfg.name,
        "slots": SLOTS,
        "pages": PAGES,
        "page_size": PAGE_SIZE,
        "requests": len(LOADS),
        "continuous": cont,
        "static": stat,
        "speedup_tokens_per_s": round(cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9), 2),
        "decode_step_p50_s": round(float(np.percentile(st, 50)), 5),
        "decode_step_p99_s": round(float(np.percentile(st, 99)), 5),
        "prefill_single_shot": single,
        "prefill_chunked": chunked,
        "chunked_speedup": round(
            chunked["tokens_per_s"] / max(single["tokens_per_s"], 1e-9), 2
        ),
        "slot_sweep": sweep,
        "spec": spec,
        "spec_draft_layers": max(1, cfg.num_layers // 2),
    }
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2)
    emit("serve_continuous", 0.0,
         f"tok_s={cont['tokens_per_s']};ttft_p99={cont['ttft_p99_s']}")
    emit("serve_static", 0.0,
         f"tok_s={stat['tokens_per_s']};ttft_p99={stat['ttft_p99_s']}")
    emit("serve_speedup", 0.0,
         f"x{bench['speedup_tokens_per_s']};"
         f"steps={cont['decode_steps']}v{stat['decode_steps']}")
    emit("serve_chunked_prefill", 0.0,
         f"x{bench['chunked_speedup']};cold_tok_s="
         f"{chunked['tokens_per_s']}v{single['tokens_per_s']};"
         f"ttft_p99={chunked['ttft_p99_s']}v{single['ttft_p99_s']}")
    emit("serve_spec_decode", 0.0,
         f"tok_s={spec['tokens_per_s']};accept={spec['accept_rate']};"
         f"rounds={spec['spec_rounds']}")


if __name__ == "__main__":
    main()
