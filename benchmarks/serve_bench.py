"""Serving benchmark: continuous batching vs the static-batching baseline at
equal concurrency on a mixed prompt/generation workload, written to
BENCH_serve.json so the serving perf trajectory is tracked.

Both policies run the SAME engine, model, page pool, and request load — the
only difference is the admit rule (refill freed slots mid-flight vs drain the
whole batch first), so the speedup isolates the scheduling win.  Per-token
decode latency is measured on a separate synced pass (``sync_each_step``
serializes the host loop, so it is never timed for throughput).
"""
import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import registry
from repro.models import model as M
from repro.models.common import values_of
from repro.serve import Request, ServeConfig, ServeEngine

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

SLOTS = 4
PAGES = 96
PAGE_SIZE = 8
# mixed lengths: the workload where slot churn matters
LOADS = [(4, 8), (12, 24), (8, 12), (20, 6), (6, 24), (10, 8), (16, 16), (3, 12)]


def _requests(vocab: int) -> list[Request]:
    rng = np.random.default_rng(0)
    return [
        Request(rid=i, prompt=rng.integers(0, vocab, size=(pl,)).tolist(), max_new=gl)
        for i, (pl, gl) in enumerate(LOADS)
    ]


def _run(params, cfg, policy: str, *, sync: bool = False):
    scfg = ServeConfig(
        max_slots=SLOTS, num_pages=PAGES, page_size=PAGE_SIZE,
        max_new_cap=max(gl for _, gl in LOADS), policy=policy,
        sync_each_step=sync,
    )
    engine = ServeEngine(params, cfg, scfg)
    reqs = [dataclasses.replace(r) for r in _requests(cfg.vocab_size)]
    t0 = time.perf_counter()
    finished = engine.run(reqs)
    jax.block_until_ready(engine.state.out_len)
    wall = time.perf_counter() - t0
    toks = sum(len(f.tokens) for f in finished)
    ttfts = sorted(f.ttft_s for f in finished)
    return {
        "policy": policy,
        "requests": len(finished),
        "gen_tokens": toks,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(toks / max(wall, 1e-9), 2),
        "decode_steps": engine.decode_steps,
        "ttft_p50_s": round(float(np.percentile(ttfts, 50)), 4),
        "ttft_p99_s": round(float(np.percentile(ttfts, 99)), 4),
    }, engine


def main() -> None:
    cfg = registry.get_config("paper-small-125m").reduced(
        vocab_size=512, dtype="float32", remat=False
    )
    params = values_of(M.init_params(jax.random.PRNGKey(0), cfg))

    # warm pass compiles the decode program + the prefill-length buckets so
    # both timed policies start from the same jit caches
    _run(params, cfg, "continuous")

    cont, _ = _run(params, cfg, "continuous")
    stat, _ = _run(params, cfg, "static")
    # synced pass for per-token latency percentiles (never the timed one)
    _, synced = _run(params, cfg, "continuous", sync=True)
    st = np.asarray(synced.decode_step_times)

    bench = {
        "arch": cfg.name,
        "slots": SLOTS,
        "pages": PAGES,
        "page_size": PAGE_SIZE,
        "requests": len(LOADS),
        "continuous": cont,
        "static": stat,
        "speedup_tokens_per_s": round(cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9), 2),
        "decode_step_p50_s": round(float(np.percentile(st, 50)), 5),
        "decode_step_p99_s": round(float(np.percentile(st, 99)), 5),
    }
    with open(OUT, "w") as f:
        json.dump(bench, f, indent=2)
    emit("serve_continuous", 0.0,
         f"tok_s={cont['tokens_per_s']};ttft_p99={cont['ttft_p99_s']}")
    emit("serve_static", 0.0,
         f"tok_s={stat['tokens_per_s']};ttft_p99={stat['ttft_p99_s']}")
    emit("serve_speedup", 0.0,
         f"x{bench['speedup_tokens_per_s']};"
         f"steps={cont['decode_steps']}v{stat['decode_steps']}")


if __name__ == "__main__":
    main()
