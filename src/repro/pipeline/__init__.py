from repro.pipeline.runner import PipelineTrainer, split_stages

__all__ = ["PipelineTrainer", "split_stages"]
