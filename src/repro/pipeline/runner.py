"""Dynamic pipeline routing (paper §3.1) + the §5.2 ablation harness.

The paper splits the model into consecutive stages, replicates each stage
DP-wide, and at every step routes each microbatch from a RANDOM replica of
stage s to a random replica of stage s+1 (backward follows the same path).
This implicitly mixes the weights of different DP instances: §5.2 shows the
cross-replica weight std drops ~10–15% with NO outer synchronization at all.

Simulation realization (exact semantics, one process): stage-s params carry a
leading replica axis; routing between stages is a gather by a per-step random
permutation of the replica axis.  ``jax.grad`` transposes the gather, so
gradients automatically flow back along the forward route — precisely the
paper's backward rule.  On a (stage, replica) device mesh the same
permutation is a ``lax.ppermute`` at each stage boundary; the simulation and
the collective are the same linear operator.

``routing="random"`` vs ``routing="fixed"`` is the §5.2 ablation switch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.comm import CommConfig
from repro.core import metrics as metrics_lib
from repro.core import pairing
from repro.core.elastic import ElasticContext
from repro.core.outer import OuterConfig, OuterState, outer_step_stacked
from repro.kernels.dispatch import KernelConfig
from repro.models import model as model_api
from repro.models import transformer as tfm
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, cross_entropy_parts, embed_tokens, logits_sharded
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.sharding import ShardCtx

PyTree = Any


# ---------------------------------------------------------------------------
# Stage splitting of a ModelConfig transformer
# ---------------------------------------------------------------------------


def split_stages(cfg: ModelConfig, num_stages: int) -> list[ModelConfig]:
    """Split layers as evenly as possible into consecutive stage configs."""
    if cfg.num_layers % num_stages:
        raise ValueError("num_layers must divide evenly into stages")
    per = cfg.num_layers // num_stages
    return [dataclasses.replace(cfg, num_layers=per) for _ in range(num_stages)]


def init_stage_params(key, cfg: ModelConfig, stage: int, num_stages: int) -> PyTree:
    """Stage 0 owns the embedding; the last stage owns the final norm (+ the
    tied unembedding reads stage 0's table in the simulation — we give the
    last stage its OWN unembedding to keep stages self-contained)."""
    scfg = split_stages(cfg, num_stages)[stage]
    p: dict = {"stack": tfm.init_stack(key, scfg)}
    if stage == 0:
        from repro.models.layers import init_embedding

        p["embed"] = init_embedding(jax.random.fold_in(key, 1), cfg)
    if stage == num_stages - 1:
        from repro.models.layers import init_embedding, init_norm

        p["final_norm"] = init_norm(cfg, cfg.d_model)
        p["unembed"] = init_embedding(jax.random.fold_in(key, 2), cfg)
    return p


def apply_stage(
    params: PyTree, cfg: ModelConfig, stage: int, num_stages: int, x: jax.Array,
    ctx: ShardCtx,
) -> jax.Array:
    scfg = split_stages(cfg, num_stages)[stage]
    if stage == 0:
        x = embed_tokens(params["embed"], cfg, x, ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, _ = tfm.apply_stack(params["stack"], scfg, x, ctx, positions=positions)
    return x


def stage_loss(
    params: PyTree, cfg: ModelConfig, x: jax.Array, labels: jax.Array, ctx: ShardCtx
) -> jax.Array:
    h = apply_norm(params["final_norm"], x)
    logits = logits_sharded(params["unembed"], cfg, h, ctx)
    nll, cnt = cross_entropy_parts(logits, labels, cfg, ctx)
    return nll / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Routed pipeline trainer (stacked replicas)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PipelineTrainer:
    """DP×PP trainer with per-step random routing; inner AdamW per replica.

    ``routing``: "random" (paper §3.1) or "fixed" (classic pipelining — the
    §5.2 baseline where DP instances never exchange information when the
    outer optimizer is off).

    ``outer`` enables the paper's COMPLETE method (§3.1 routing + §3.2 gossip
    outer optimizer): every ``outer.inner_steps`` steps each stage runs one
    NoLoCo/DiLoCo outer step over its replica axis, reusing the exact
    :func:`repro.core.outer.outer_step_stacked` machinery (pairings from
    :mod:`repro.core.pairing`, wire codec from ``comm``).  ``outer=None``
    keeps the routing-only trainer (the §5.2 no-outer baseline).

    ``elastic`` attaches the shared :class:`~repro.core.elastic.
    ElasticContext` (DESIGN.md §7): routing permutations restrict to the
    ACTIVE replica set (:func:`~repro.core.pairing.elastic_route_permutation`
    — inactive stage-replicas carry no traffic and their params/opt freeze),
    every stage's gossip pairing is drawn over active members only via
    :func:`~repro.core.pairing.elastic_partner_table` (per-stage seed offset,
    partition-aware), and loss/eval/weight-std aggregate over active
    replicas.  ``elastic=None`` keeps the fixed-world trainer bit-for-bit."""

    cfg: ModelConfig
    num_stages: int
    replicas: int
    inner: AdamWConfig = dataclasses.field(default_factory=lambda: AdamWConfig(lr=1e-3, weight_decay=0.0))
    routing: str = "random"
    outer: OuterConfig | None = None
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)
    kernel_cfg: KernelConfig = dataclasses.field(default_factory=KernelConfig)
    seed: int = 0
    elastic: ElasticContext | None = None

    def __post_init__(self):
        if self.elastic is not None and self.elastic.world != self.replicas:
            raise ValueError(
                f"elastic world {self.elastic.world} != replicas {self.replicas}"
            )

    @property
    def outer_enabled(self) -> bool:
        return self.outer is not None and self.outer.method != "none"

    def init(self, key) -> dict:
        params = []
        for s in range(self.num_stages):
            stage_keys = jax.random.split(jax.random.fold_in(key, s), self.replicas)
            # IMPORTANT: same init across replicas (φ_{0,i} ≡ φ_0, paper §A)
            one = values_of(
                init_stage_params(stage_keys[0], self.cfg, s, self.num_stages)
            )
            params.append(jax.tree.map(
                lambda v: jnp.broadcast_to(v[None], (self.replicas,) + v.shape), one
            ))
        opt = [jax.vmap(adamw_init)(p) for p in params]
        state = {"params": params, "opt": opt, "step": 0}
        if self.outer_enabled:
            state["outer"] = {
                "phi": [jax.tree.map(jnp.copy, p) for p in params],
                "delta": [jax.tree.map(jnp.zeros_like, p) for p in params],
                "step": 0,
            }
        return state

    # -- routing --------------------------------------------------------

    def routes(self, step: int) -> list[jax.Array]:
        """One permutation per stage boundary (num_stages-1 of them).

        With an elastic context and a partial membership the permutations
        restrict to a bijection on the ACTIVE set (inactive replicas route to
        themselves and carry no traffic); at full membership the elastic draw
        is bit-identical to the static one, so the healthy path never
        changes."""
        if self.routing == "fixed":
            return [jnp.arange(self.replicas)] * (self.num_stages - 1)
        elastic_view = (
            self.elastic.membership
            if self.elastic is not None and not self.elastic.is_full
            else None
        )
        out = []
        for b in range(self.num_stages - 1):
            if elastic_view is not None:
                out.append(jnp.asarray(pairing.elastic_route_permutation(
                    step * 97 + b, elastic_view, seed=self.seed
                )))
            else:
                out.append(pairing.pairing_permutation(
                    step * 97 + b, self.replicas, seed=self.seed
                ))
        return out

    def _active_weights(self) -> jax.Array:
        """(R,) f32 participation weights for loss/eval aggregation."""
        if self.elastic is None or self.elastic.is_full:
            return jnp.ones((self.replicas,), jnp.float32)
        return jnp.asarray(self.elastic.membership.active_array()).astype(jnp.float32)

    # -- loss over routed paths ------------------------------------------

    def loss(
        self, params: list, batch: dict, routes: list[jax.Array],
        weights: jax.Array | None = None,
    ) -> jax.Array:
        """Active-weighted mean loss over replicas; x (R, B, S) follows the
        routed path.  ``weights=None`` (or all ones) is the plain mean."""
        ctx = ShardCtx.local()
        x = batch["tokens"]
        for s in range(self.num_stages):
            if s > 0:
                x = jnp.take(x, routes[s - 1], axis=0)
            x = jax.vmap(
                lambda p, xx: apply_stage(p, self.cfg, s, self.num_stages, xx, ctx)
            )(params[s], x)
        # labels must follow the full route of their microbatch
        lab = batch["labels"]
        for r in routes:
            lab = jnp.take(lab, r, axis=0)
        losses = jax.vmap(
            lambda p, xx, ll: stage_loss(p, self.cfg, xx, ll, ctx)
        )(params[-1], x, lab)
        if weights is None:
            return jnp.mean(losses)
        return jnp.sum(losses * weights) / jnp.maximum(jnp.sum(weights), 1.0)

    # -- one SGD step -------------------------------------------------------

    def _jitted_step(self):
        if not hasattr(self, "_step_cache"):
            def step(params, opt, batch, routes, weights):
                loss, grads = jax.value_and_grad(
                    lambda ps: self.loss(ps, batch, routes, weights)
                )(params)
                act = weights > 0

                def _sel(new, old):
                    return jnp.where(
                        act.reshape((-1,) + (1,) * (new.ndim - 1)), new, old
                    )

                new_params, new_opt = [], []
                for p, o, g in zip(params, opt, grads):
                    np_, no_, _ = jax.vmap(
                        lambda gg, oo, pp: adamw_update(gg, oo, pp, self.inner)
                    )(g, o, p)
                    # frozen (inactive) replicas keep params AND moments: the
                    # weighted loss already zeroes their grads, but AdamW's
                    # count/eps math would still drift them
                    np_ = jax.tree.map(_sel, np_, p)
                    no_ = jax.tree.map(_sel, no_, o)
                    new_params.append(np_)
                    new_opt.append(no_)
                return new_params, new_opt, loss

            object.__setattr__(self, "_step_cache", jax.jit(step))
        return self._step_cache

    def train_step(self, state: dict, batch: dict) -> tuple[dict, float]:
        routes = self.routes(state["step"])
        new_params, new_opt, loss = self._jitted_step()(
            state["params"], state["opt"], batch, routes, self._active_weights()
        )
        new_state = dict(
            state, params=new_params, opt=new_opt, step=state["step"] + 1
        )
        return new_state, float(loss)

    # -- outer optimizer (§3.2 gossip, per stage over the replica axis) -----

    def maybe_outer_step(self, state: dict) -> tuple[dict, bool]:
        """Run the NoLoCo/DiLoCo outer step on every stage when due.

        Each stage's replicas form their own gossip group: stage s draws its
        OWN random matching for outer round k (seed offset by stage), so the
        pairings across stages are independent — combined with the random
        routing this is the paper's full §3.1+§3.2 method.  Fast weights are
        reset to the new slow weights (look-ahead semantics); AdamW moments
        persist, matching :class:`~repro.core.GossipTrainer`."""
        if not self.outer_enabled:
            return state, False
        m = self.outer.inner_steps
        k = int(state["outer"]["step"])
        # outer round k fires once step reaches (k+1)*m — idempotent between
        # inner steps (calling twice at the same step is a no-op)
        if state["step"] < (k + 1) * m:
            return state, False
        round_plan = None
        active = None
        if self.elastic is not None:
            # one participation decision for the round, shared by all stages
            # (consumes the straggler view); each stage draws its OWN pairing
            # over those participants below
            round_plan = self.elastic.plan_round(None)
            active = None if round_plan.active is None else jnp.asarray(round_plan.active)
        new_params, new_phi, new_delta = [], [], []
        for s in range(self.num_stages):
            partner = None
            if self.outer.method == "noloco":
                stage_seed = self.seed + 1_000_003 * (s + 1)
                if round_plan is not None:
                    partner = jnp.asarray(pairing.elastic_partner_table(
                        k, round_plan.participants, seed=stage_seed,
                        groups=self.elastic.partition,
                    ))
                else:
                    partner = jnp.asarray(pairing.partner_table(
                        k, self.replicas, seed=stage_seed
                    ))
            ost = OuterState(
                phi=state["outer"]["phi"][s],
                delta=state["outer"]["delta"][s],
                step=jnp.asarray(k, jnp.int32),
            )
            new_ost, new_theta = outer_step_stacked(
                ost, state["params"][s], self.outer,
                partner=partner, active=active,
                comm_cfg=self.comm, kernel_cfg=self.kernel_cfg,
            )
            new_params.append(new_theta)
            new_phi.append(new_ost.phi)
            new_delta.append(new_ost.delta)
        new_state = dict(
            state,
            params=new_params,
            outer={"phi": new_phi, "delta": new_delta, "step": k + 1},
        )
        return new_state, True

    # -- grad-free eval --------------------------------------------------------

    def eval_loss(self, params: list, batch: dict) -> jax.Array:
        """Active-mean loss over replicas WITHOUT routing (identity routes):
        each replica is evaluated as a self-contained pipeline, no
        gradients."""
        if not hasattr(self, "_eval_cache"):
            fixed = [jnp.arange(self.replicas)] * (self.num_stages - 1)
            object.__setattr__(
                self, "_eval_cache",
                jax.jit(lambda ps, b, w: self.loss(ps, b, fixed, w)),
            )
        return self._eval_cache(params, batch, self._active_weights())

    # -- §5.2 metric -----------------------------------------------------------

    def weight_std(self, state: dict) -> float:
        """Mean across params of the std across ACTIVE replicas (all stages)
        — shared impl: :func:`repro.core.metrics.replica_weight_std`."""
        params = state["params"]
        if self.elastic is not None and not self.elastic.is_full:
            ids = jnp.asarray(self.elastic.active_ids())
            if len(ids) < 2:
                return 0.0
            params = [
                jax.tree.map(lambda x: jnp.take(x, ids, axis=0), p)
                for p in params
            ]
        return float(metrics_lib.replica_weight_std(params))
