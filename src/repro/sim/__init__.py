"""Deterministic fault-injecting cluster simulator (DESIGN.md §7).

``SimCluster`` wraps the real :class:`~repro.train.GossipProgram` as a
:class:`~repro.train.program.TrainProgram` decorator and replays a
:class:`FaultPlan` — node dropout, rejoin-with-warm-start, stragglers that
miss outer rounds, network partitions — against the production outer-step
math and telemetry, step for step reproducibly.
"""

from repro.sim.faults import FaultEvent, FaultPlan
from repro.sim.cluster import SimCluster

__all__ = ["FaultEvent", "FaultPlan", "SimCluster"]
