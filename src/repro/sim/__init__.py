"""Deterministic fault-injecting cluster simulator (DESIGN.md §7).

``SimCluster`` wraps a real elastic program — the stacked
:class:`~repro.train.GossipProgram` or the shard_map
:class:`~repro.train.DistributedProgram` — as a
:class:`~repro.train.program.TrainProgram` decorator and replays a
:class:`FaultPlan` — node dropout, rejoin-with-warm-start, stragglers that
miss outer rounds, network partitions — against the production outer-step
math and telemetry, step for step reproducibly; on the mesh that path is
the per-membership-view compiled ppermute program pool.
"""

from repro.sim.faults import FaultEvent, FaultPlan
from repro.sim.cluster import SimCluster

__all__ = ["FaultEvent", "FaultPlan", "SimCluster"]
