"""SimCluster: drive a REAL training program through cluster churn.

The simulator is a :class:`~repro.train.program.TrainProgram` decorator — the
unified :class:`~repro.train.loop.TrainLoop` drives it exactly like a healthy
program, and every inner/outer step below it is the production path.  It is
RUNTIME-AGNOSTIC: any program exposing the elastic surface (an attached
:class:`~repro.core.elastic.ElasticContext` plus the
``inner_step_index`` / ``outer_round_index`` / ``sync_due`` / ``warm_start``
hooks) can be decorated — the stacked :class:`~repro.train.GossipProgram`
(vmap gather gossip) and the shard_map
:class:`~repro.train.DistributedProgram` (compiled ppermute programs from the
per-membership-view pool) replay the SAME fault plans through their own
outer steps.  SimCluster only does four things:

  * replays the :class:`~repro.sim.faults.FaultPlan` at inner-step
    boundaries (membership drops/rejoins, straggler registration,
    partition views) — each event is applied once, keyed by the state's own
    step counter, so a resumed run never re-applies history;
  * delegates the rejoin warm start to the program (θ = φ = a live peer's φ,
    δ = 0, fresh AdamW moments — on the mesh that is a gather+scatter over
    the replica axis);
  * optionally redistributes dropped replicas' loader streams over survivors
    (``reassign_data``, the pure :func:`~repro.core.elastic.stream_assignment`
    of ``(membership, t)`` — deterministic, resume-safe);
  * keeps an auditable ``history`` of events and per-round participation
    (partner tables included) for tests and telemetry.

Asynchronous rounds (DESIGN.md §7, "Asynchronous rounds & staleness"): when
the plan carries ``rate`` events (or ``async_clock=True``), every replica
gets its OWN round clock — a :class:`ReplicaClock` grants inner steps by
rate credit, so a slow replica reaches sync index *i* late and exchanges a
stale Δ at the next MERGED sync tick instead of sitting the round out.  The
pairing at a merged tick is drawn over all round participants (an involution
— non-due replicas serve as passive, frozen sources), only due replicas
apply the update, and each contribution's staleness τ (merged ticks skipped
since that replica's previous sync) feeds the ``stale="momentum"`` discount
(:func:`repro.core.outer.stale_discount`).  A rate-1 world is bit-identical
to the synchronous path: every tick grants every member a step, every merged
tick's due set is the full active set, and the τ=0 exchange takes the legacy
compiled program.

What it does NOT model (see DESIGN.md §7): message loss inside a surviving
pair, Byzantine values — faults are participation/clock-rate changes.
"""

from __future__ import annotations

from typing import Any

import numpy as np

import jax.numpy as jnp

from repro.core import pairing as pairing_lib
from repro.core.elastic import stream_assignment
from repro.sim.faults import FaultEvent, FaultPlan

PyTree = Any

__all__ = ["ReplicaClock", "SimCluster"]


class ReplicaClock:
    """Per-replica round clocks: pure host-side state, fully checkpointable.

    Wall time is the TrainLoop's step counter (one tick per loop step).  Each
    replica earns inner steps at its ``rate`` (credits accumulate; a step is
    granted when credit reaches 1), so ``local_step`` counts the steps a
    replica ACTUALLY took.  Replica ``r`` is *due* for its next sync once
    ``local_step[r] >= (sync_count[r] + 1) * m`` — heterogeneous rates put
    replicas on different sync indices.  Whenever the due set is non-empty
    the cluster runs one MERGED sync tick (counter ``merged_tick``); a due
    replica's staleness τ is the number of merged ticks it skipped since its
    own previous sync — stationary at ``1/rate − 1`` for a constant-rate
    straggler, and exactly 0 everywhere in a rate-1 world.
    """

    def __init__(self, world: int, inner_steps: int):
        self.world = int(world)
        self.inner_steps = int(inner_steps)
        self.rate = np.ones((world,), dtype=np.float64)
        self.credit = np.zeros((world,), dtype=np.float64)
        self.local_step = np.zeros((world,), dtype=np.int64)
        self.sync_count = np.zeros((world,), dtype=np.int64)
        self.last_sync_tick = np.full((world,), -1, dtype=np.int64)
        self.merged_tick = 0

    def set_rate(self, replicas, rate: float) -> None:
        for r in replicas:
            self.rate[int(r)] = float(rate)

    def tick(self, member_mask: np.ndarray) -> np.ndarray:
        """Advance one wall tick; returns the bool step-grant mask.

        Non-members neither accrue credit nor step (their clock is paused —
        a rejoin resumes it without a backlog burst)."""
        member = np.asarray(member_mask, dtype=bool)
        self.credit = np.where(member, self.credit + self.rate, self.credit)
        # 1e-9 slack absorbs float accumulation drift for rates like 1/3
        grant = member & (self.credit >= 1.0 - 1e-9)
        self.credit = np.where(grant, self.credit - 1.0, self.credit)
        self.local_step = np.where(grant, self.local_step + 1, self.local_step)
        return grant

    def due_mask(self, member_mask: np.ndarray) -> np.ndarray:
        member = np.asarray(member_mask, dtype=bool)
        m = self.inner_steps
        return member & (self.local_step >= (self.sync_count + 1) * m)

    def staleness(self) -> np.ndarray:
        """τ per replica at the CURRENT merged tick: ticks skipped since the
        replica's own previous sync (0 for a replica that synced last tick,
        and 0 for everyone at the very first tick)."""
        return np.maximum(self.merged_tick - self.last_sync_tick - 1, 0)

    def advance_sync(self, due: np.ndarray) -> None:
        """Account one merged sync tick: ``due`` replicas' sync indices move."""
        due = np.asarray(due, dtype=bool)
        self.sync_count = np.where(due, self.sync_count + 1, self.sync_count)
        self.last_sync_tick = np.where(due, self.merged_tick, self.last_sync_tick)
        self.merged_tick += 1

    # -- checkpoint view ----------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "rate": self.rate.copy(),
            "credit": self.credit.copy(),
            "local_step": self.local_step.copy(),
            "sync_count": self.sync_count.copy(),
            "last_sync_tick": self.last_sync_tick.copy(),
            "merged_tick": np.int64(self.merged_tick),
        }

    def load_state_dict(self, tree: dict) -> None:
        self.rate = np.asarray(tree["rate"], dtype=np.float64).copy()
        self.credit = np.asarray(tree["credit"], dtype=np.float64).copy()
        self.local_step = np.asarray(tree["local_step"], dtype=np.int64).copy()
        self.sync_count = np.asarray(tree["sync_count"], dtype=np.int64).copy()
        self.last_sync_tick = np.asarray(
            tree["last_sync_tick"], dtype=np.int64
        ).copy()
        self.merged_tick = int(tree["merged_tick"])


class SimCluster:
    """Deterministic fault-injecting wrapper around an elastic program."""

    def __init__(
        self,
        program,
        plan: FaultPlan,
        *,
        reassign_data: bool = False,
        async_clock: bool | None = None,
    ):
        if getattr(program, "elastic", None) is None:
            raise ValueError(
                "SimCluster needs a program with an ElasticContext attached "
                "(GossipProgram, or DistributedProgram whose trainer was "
                "built with elastic=...)"
            )
        plan.validate(program.replicas)
        self.program = program
        self.plan = plan
        self.replicas = program.replicas
        self.reassign_data = reassign_data
        self._straggle: dict[int, int] = {}  # replica -> rounds left to miss
        self.history: list[dict] = []
        self._async_events: list[dict] = []  # per-sync records the loop drains
        self.blocked_syncs = 0     # forced self-pairs while peers existed
        self.max_staleness = 0     # max τ any exchange contributed
        # asynchronous per-replica clock: auto-enabled by rate events in the
        # plan, forced on/off by async_clock (off + rate events is an error)
        has_rates = bool(plan.rate_events())
        if async_clock is None:
            async_clock = has_rates
        if has_rates and not async_clock:
            raise ValueError(
                "the fault plan has rate events but async_clock=False: rate "
                "multipliers only act through the asynchronous replica clock"
            )
        self.clock: ReplicaClock | None = None
        if async_clock:
            if not hasattr(program, "outer_step_async"):
                raise ValueError(
                    "asynchronous clock needs a program exposing "
                    "outer_step_async (GossipProgram / DistributedProgram)"
                )
            ccfg = self._comm_cfg()
            if ccfg is not None and (ccfg.streams > 1 or ccfg.overlap):
                raise ValueError(
                    "the asynchronous replica clock does not compose with "
                    "streaming outer steps / φ-prefetch yet — run with "
                    "streams=1, overlap=False"
                )
            self.clock = ReplicaClock(self.replicas, self._inner_steps())

    # -- membership passthrough (loop telemetry reads these) ----------------

    @property
    def membership(self) -> pairing_lib.Membership:
        return self.program.membership

    @property
    def membership_epoch(self) -> int:
        return self.program.membership_epoch

    # -- fault application --------------------------------------------------

    def _inner_steps(self) -> int:
        # both runtimes expose the cadence through their outer config
        prog = self.program
        if hasattr(prog, "tcfg"):
            return prog.tcfg.outer.inner_steps
        return prog.trainer.outer_cfg.inner_steps

    def _comm_cfg(self):
        prog = self.program
        if hasattr(prog, "tcfg"):
            return prog.tcfg.comm
        return getattr(prog.trainer, "comm_cfg", None)

    def _apply_events(self, state, t: int):
        for ev in self.plan.events_at(t, self._inner_steps()):
            state = self._apply(state, ev, t)
        return state

    def _apply(self, state, ev: FaultEvent, t: int):
        mem = self.program.membership
        rec: dict[str, Any] = {"event": ev.kind, "step": t}
        if ev.kind == "drop":
            self.program.set_membership(mem.drop(ev.replicas))
            rec["replicas"] = sorted(ev.replicas)
        elif ev.kind == "rejoin":
            source = ev.source
            if source is None:
                candidates = [r for r in mem.active_ids if r not in ev.replicas]
                if not candidates:
                    raise ValueError("rejoin needs at least one live peer to warm-start from")
                source = candidates[0]
            if source in ev.replicas or not mem.mask[source]:
                raise ValueError(f"rejoin source {source} is not a live peer")
            for r in ev.replicas:
                if mem.mask[r]:
                    raise ValueError(f"replica {r} is already active; cannot rejoin")
                state = self.program.warm_start(state, r, source)
            self.program.set_membership(mem.add(ev.replicas))
            rec["replicas"] = sorted(ev.replicas)
            rec["source"] = source
        elif ev.kind == "straggle":
            for r in ev.replicas:
                if not mem.mask[r]:
                    raise ValueError(f"straggler {r} is not an active replica")
                self._straggle[r] = max(self._straggle.get(r, 0), ev.rounds)
            rec["replicas"] = sorted(ev.replicas)
            rec["rounds"] = ev.rounds
        elif ev.kind == "rate":
            # validated at init: rate events imply the async clock exists
            self.clock.set_rate(ev.replicas, ev.rate)
            rec["replicas"] = sorted(ev.replicas)
            rec["rate"] = ev.rate
        elif ev.kind == "partition":
            self.program.set_partition(ev.groups)
            rec["groups"] = [sorted(g) for g in ev.groups]
        elif ev.kind == "heal":
            self.program.set_partition(None)
        self.history.append(rec)
        return state

    # -- TrainProgram surface ----------------------------------------------

    def init_state(self, example_batch: dict):
        return self.program.init_state(example_batch)

    def inner_step(self, state, batch: dict, rng):
        t = self.program.inner_step_index(state)
        state = self._apply_events(state, t)
        if self.clock is not None:
            # grant this tick's inner steps by rate credit; replicas whose
            # clock did not fire are frozen through the usual active mask
            grant = self.clock.tick(np.asarray(self.program.membership.mask))
            self.program.elastic.tick_active = grant
        if self.reassign_data and not self.program.membership.is_full:
            # survivors adopt dropped replicas' streams (time-multiplexed);
            # a pure function of (membership, t), so resume replays it exactly
            table = jnp.asarray(stream_assignment(self.program.membership, t))
            batch = {k: jnp.take(v, table, axis=0) for k, v in batch.items()}
        # the program itself aggregates loss over active replicas
        return self.program.inner_step(state, batch, rng)

    def _blocked_count(self, partner, participants: set[int]) -> int:
        """Forced self-pairs: participants the table left alone while other
        participants existed — the blocking a synchronous round charges to a
        straggler (its partner has nobody) and the async clock eliminates."""
        if partner is None or len(participants) <= 1:
            return 0
        return sum(1 for r in participants if int(partner[r]) == r)

    def maybe_outer_step(self, state):
        if self.clock is not None:
            return self._maybe_outer_step_async(state)
        if not self.program.sync_due(state):
            return state, False
        round_idx = self.program.outer_round_index(state)
        absent = frozenset(
            r for r, k in self._straggle.items()
            if k > 0 and self.program.membership.mask[r]
        )
        self.program.round_absent = absent
        state, synced = self.program.maybe_outer_step(state)
        self._straggle = {
            r: k - 1 for r, k in self._straggle.items() if k > 1
        }
        partner = self.program.last_partner  # the table the round REALLY used
        participants = set(self.program.membership.active_ids) - absent
        blocked = self._blocked_count(partner, participants)
        self.blocked_syncs += blocked
        self.history.append({
            "event": "round",
            "round": round_idx,
            "active": list(self.program.membership.active_ids),
            "absent": sorted(absent),
            "partner": None if partner is None else [int(p) for p in partner],
            "blocked": blocked,
            "partition": (
                None if self.program.partition is None
                else [sorted(g) for g in self.program.partition]
            ),
        })
        if synced:
            self._async_events.append({
                "mode": "sync",
                "sync_index": round_idx,
                "due": sorted(participants),
                "staleness": [0] * self.replicas,
                "max_staleness": 0,
                "blocked": blocked,
            })
        return state, synced

    def _maybe_outer_step_async(self, state):
        """One merged sync tick of the asynchronous clock (if any replica is
        due): pairing over all round participants, update applied by the due
        set, staleness-stamped contributions."""
        mem_mask = np.asarray(self.program.membership.mask, dtype=bool)
        due = self.clock.due_mask(mem_mask)
        absent = frozenset(
            r for r, k in self._straggle.items() if k > 0 and mem_mask[r]
        )
        if absent:
            due = due.copy()
            due[list(absent)] = False
        if not due.any():
            return state, False
        tick = self.clock.merged_tick
        staleness = self.clock.staleness()
        self.program.round_absent = absent
        state, synced = self.program.outer_step_async(
            state, sync_index=tick, due=due, staleness=staleness
        )
        self.clock.advance_sync(due)
        self._straggle = {r: k - 1 for r, k in self._straggle.items() if k > 1}
        partner = self.program.last_partner
        participants = set(self.program.membership.active_ids) - absent
        due_ids = [int(r) for r in np.nonzero(due)[0]]
        blocked = self._blocked_count(partner, participants)
        self.blocked_syncs += blocked
        tau_due = [int(staleness[r]) for r in due_ids]
        max_tau = max(tau_due, default=0)
        self.max_staleness = max(self.max_staleness, max_tau)
        self.history.append({
            "event": "round",
            "round": tick,
            "active": list(self.program.membership.active_ids),
            "absent": sorted(absent),
            "due": due_ids,
            "staleness": [int(s) for s in staleness],
            "partner": None if partner is None else [int(p) for p in partner],
            "blocked": blocked,
            "partition": (
                None if self.program.partition is None
                else [sorted(g) for g in self.program.partition]
            ),
        })
        if synced:
            self._async_events.append({
                "mode": "async",
                "sync_index": tick,
                "due": due_ids,
                "staleness": [int(s) for s in staleness],
                "max_staleness": max_tau,
                "blocked": blocked,
            })
        return state, synced

    def eval_step(self, state, batch: dict, rng) -> float:
        return self.program.eval_step(state, batch, rng)

    def weight_std(self, state) -> float:
        return self.program.weight_std(state)

    def state_pytree(self, state) -> dict:
        tree = self.program.state_pytree(state)
        # in-flight straggler debts must survive a restart, or a resumed run
        # would let a mid-straggle replica back into rounds it missed in the
        # uninterrupted trajectory — even (especially) when the debt outlives
        # this run's --steps horizon and only the resumed run spends it
        straggle = np.zeros((self.replicas,), dtype=np.int64)
        for r, k in self._straggle.items():
            straggle[r] = k
        tree["sim"] = {"straggle": straggle}
        if self.clock is not None:
            # the per-replica round clocks (rates, credits, local steps, sync
            # indices, merged-tick counter) are exactly as resume-critical
            tree["sim"]["clock"] = self.clock.state_dict()
        return tree

    def load_state_pytree(self, state, tree: dict):
        state = self.program.load_state_pytree(state, tree)
        if "sim" in tree:
            straggle = np.asarray(tree["sim"]["straggle"])
            self._straggle = {
                int(r): int(k) for r, k in enumerate(straggle) if k > 0
            }
            if "clock" in tree["sim"]:
                if self.clock is None:
                    self.clock = ReplicaClock(self.replicas, self._inner_steps())
                self.clock.load_state_dict(tree["sim"]["clock"])
        return state

    def comm_cost(self):
        return self.program.comm_cost()

    # -- program passthrough (telemetry) ------------------------------------

    def drain_recompile_events(self) -> list[dict]:
        drain = getattr(self.program, "drain_recompile_events", None)
        return [] if drain is None else drain()

    def drain_stream_events(self) -> list[dict]:
        # NB: with streaming the program syncs ONE stream per due step, so
        # straggler debts (decremented above per sync) are spent per STREAM
        # sync, not per full outer cycle — a 1-round straggle misses one
        # stream's exchange (see DESIGN.md, streaming outer steps)
        drain = getattr(self.program, "drain_stream_events", None)
        return [] if drain is None else drain()

    def pool_stats(self) -> dict | None:
        stats = getattr(self.program, "pool_stats", None)
        return None if stats is None else stats()

    def drain_async_events(self) -> list[dict]:
        """Per-sync participation/staleness records since the last drain —
        the TrainLoop turns these into ``outer_async`` telemetry events and
        the ``max_staleness`` / ``blocked_syncs`` summary fields (emitted for
        BOTH clock modes, so a synchronous baseline's blocked rounds are
        directly comparable to the async run's)."""
        events, self._async_events = self._async_events, []
        return events

    # -- diagnostics --------------------------------------------------------

    def rounds(self) -> list[dict]:
        """The per-round participation records (subset of ``history``)."""
        return [h for h in self.history if h["event"] == "round"]
