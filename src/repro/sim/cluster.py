"""SimCluster: drive a REAL training program through cluster churn.

The simulator is a :class:`~repro.train.program.TrainProgram` decorator — the
unified :class:`~repro.train.loop.TrainLoop` drives it exactly like a healthy
program, and every inner/outer step below it is the production path.  It is
RUNTIME-AGNOSTIC: any program exposing the elastic surface (an attached
:class:`~repro.core.elastic.ElasticContext` plus the
``inner_step_index`` / ``outer_round_index`` / ``sync_due`` / ``warm_start``
hooks) can be decorated — the stacked :class:`~repro.train.GossipProgram`
(vmap gather gossip) and the shard_map
:class:`~repro.train.DistributedProgram` (compiled ppermute programs from the
per-membership-view pool) replay the SAME fault plans through their own
outer steps.  SimCluster only does four things:

  * replays the :class:`~repro.sim.faults.FaultPlan` at inner-step
    boundaries (membership drops/rejoins, straggler registration,
    partition views) — each event is applied once, keyed by the state's own
    step counter, so a resumed run never re-applies history;
  * delegates the rejoin warm start to the program (θ = φ = a live peer's φ,
    δ = 0, fresh AdamW moments — on the mesh that is a gather+scatter over
    the replica axis);
  * optionally redistributes dropped replicas' loader streams over survivors
    (``reassign_data``, the pure :func:`~repro.core.elastic.stream_assignment`
    of ``(membership, t)`` — deterministic, resume-safe);
  * keeps an auditable ``history`` of events and per-round participation
    (partner tables included) for tests and telemetry.

What it does NOT model (see DESIGN.md §7): wall-clock skew, message loss
inside a surviving pair, Byzantine values, or asynchronous outer rounds —
every fault is a round-granular participation change.
"""

from __future__ import annotations

from typing import Any

import numpy as np

import jax.numpy as jnp

from repro.core import pairing as pairing_lib
from repro.core.elastic import stream_assignment
from repro.sim.faults import FaultEvent, FaultPlan

PyTree = Any

__all__ = ["SimCluster"]


class SimCluster:
    """Deterministic fault-injecting wrapper around an elastic program."""

    def __init__(self, program, plan: FaultPlan, *, reassign_data: bool = False):
        if getattr(program, "elastic", None) is None:
            raise ValueError(
                "SimCluster needs a program with an ElasticContext attached "
                "(GossipProgram, or DistributedProgram whose trainer was "
                "built with elastic=...)"
            )
        plan.validate(program.replicas)
        self.program = program
        self.plan = plan
        self.replicas = program.replicas
        self.reassign_data = reassign_data
        self._straggle: dict[int, int] = {}  # replica -> rounds left to miss
        self.history: list[dict] = []

    # -- membership passthrough (loop telemetry reads these) ----------------

    @property
    def membership(self) -> pairing_lib.Membership:
        return self.program.membership

    @property
    def membership_epoch(self) -> int:
        return self.program.membership_epoch

    # -- fault application --------------------------------------------------

    def _inner_steps(self) -> int:
        # both runtimes expose the cadence through their outer config
        prog = self.program
        if hasattr(prog, "tcfg"):
            return prog.tcfg.outer.inner_steps
        return prog.trainer.outer_cfg.inner_steps

    def _apply_events(self, state, t: int):
        for ev in self.plan.events_at(t, self._inner_steps()):
            state = self._apply(state, ev, t)
        return state

    def _apply(self, state, ev: FaultEvent, t: int):
        mem = self.program.membership
        rec: dict[str, Any] = {"event": ev.kind, "step": t}
        if ev.kind == "drop":
            self.program.set_membership(mem.drop(ev.replicas))
            rec["replicas"] = sorted(ev.replicas)
        elif ev.kind == "rejoin":
            source = ev.source
            if source is None:
                candidates = [r for r in mem.active_ids if r not in ev.replicas]
                if not candidates:
                    raise ValueError("rejoin needs at least one live peer to warm-start from")
                source = candidates[0]
            if source in ev.replicas or not mem.mask[source]:
                raise ValueError(f"rejoin source {source} is not a live peer")
            for r in ev.replicas:
                if mem.mask[r]:
                    raise ValueError(f"replica {r} is already active; cannot rejoin")
                state = self.program.warm_start(state, r, source)
            self.program.set_membership(mem.add(ev.replicas))
            rec["replicas"] = sorted(ev.replicas)
            rec["source"] = source
        elif ev.kind == "straggle":
            for r in ev.replicas:
                if not mem.mask[r]:
                    raise ValueError(f"straggler {r} is not an active replica")
                self._straggle[r] = max(self._straggle.get(r, 0), ev.rounds)
            rec["replicas"] = sorted(ev.replicas)
            rec["rounds"] = ev.rounds
        elif ev.kind == "partition":
            self.program.set_partition(ev.groups)
            rec["groups"] = [sorted(g) for g in ev.groups]
        elif ev.kind == "heal":
            self.program.set_partition(None)
        self.history.append(rec)
        return state

    # -- TrainProgram surface ----------------------------------------------

    def init_state(self, example_batch: dict):
        return self.program.init_state(example_batch)

    def inner_step(self, state, batch: dict, rng):
        t = self.program.inner_step_index(state)
        state = self._apply_events(state, t)
        if self.reassign_data and not self.program.membership.is_full:
            # survivors adopt dropped replicas' streams (time-multiplexed);
            # a pure function of (membership, t), so resume replays it exactly
            table = jnp.asarray(stream_assignment(self.program.membership, t))
            batch = {k: jnp.take(v, table, axis=0) for k, v in batch.items()}
        # the program itself aggregates loss over active replicas
        return self.program.inner_step(state, batch, rng)

    def maybe_outer_step(self, state):
        if not self.program.sync_due(state):
            return state, False
        round_idx = self.program.outer_round_index(state)
        absent = frozenset(
            r for r, k in self._straggle.items()
            if k > 0 and self.program.membership.mask[r]
        )
        self.program.round_absent = absent
        state, synced = self.program.maybe_outer_step(state)
        self._straggle = {
            r: k - 1 for r, k in self._straggle.items() if k > 1
        }
        partner = self.program.last_partner  # the table the round REALLY used
        self.history.append({
            "event": "round",
            "round": round_idx,
            "active": list(self.program.membership.active_ids),
            "absent": sorted(absent),
            "partner": None if partner is None else [int(p) for p in partner],
            "partition": (
                None if self.program.partition is None
                else [sorted(g) for g in self.program.partition]
            ),
        })
        return state, synced

    def eval_step(self, state, batch: dict, rng) -> float:
        return self.program.eval_step(state, batch, rng)

    def weight_std(self, state) -> float:
        return self.program.weight_std(state)

    def state_pytree(self, state) -> dict:
        tree = self.program.state_pytree(state)
        # in-flight straggler debts must survive a restart, or a resumed run
        # would let a mid-straggle replica back into rounds it missed in the
        # uninterrupted trajectory
        straggle = np.zeros((self.replicas,), dtype=np.int64)
        for r, k in self._straggle.items():
            straggle[r] = k
        tree["sim"] = {"straggle": straggle}
        return tree

    def load_state_pytree(self, state, tree: dict):
        state = self.program.load_state_pytree(state, tree)
        if "sim" in tree:
            straggle = np.asarray(tree["sim"]["straggle"])
            self._straggle = {
                int(r): int(k) for r, k in enumerate(straggle) if k > 0
            }
        return state

    def comm_cost(self):
        return self.program.comm_cost()

    # -- program passthrough (telemetry) ------------------------------------

    def drain_recompile_events(self) -> list[dict]:
        drain = getattr(self.program, "drain_recompile_events", None)
        return [] if drain is None else drain()

    def drain_stream_events(self) -> list[dict]:
        # NB: with streaming the program syncs ONE stream per due step, so
        # straggler debts (decremented above per sync) are spent per STREAM
        # sync, not per full outer cycle — a 1-round straggle misses one
        # stream's exchange (see DESIGN.md, streaming outer steps)
        drain = getattr(self.program, "drain_stream_events", None)
        return [] if drain is None else drain()

    def pool_stats(self) -> dict | None:
        stats = getattr(self.program, "pool_stats", None)
        return None if stats is None else stats()

    # -- diagnostics --------------------------------------------------------

    def rounds(self) -> list[dict]:
        """The per-round participation records (subset of ``history``)."""
        return [h for h in self.history if h["event"] == "round"]
