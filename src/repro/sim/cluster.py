"""SimCluster: drive the REAL training program through cluster churn.

The simulator is a :class:`~repro.train.program.TrainProgram` decorator — the
unified :class:`~repro.train.loop.TrainLoop` drives it exactly like a healthy
program, and every inner/outer step below it is the production path
(:class:`~repro.train.GossipProgram` → :class:`~repro.core.GossipTrainer` →
``outer_step_stacked`` over the :class:`~repro.comm.StackedGather`
communicator).  SimCluster only does three things:

  * replays the :class:`~repro.sim.faults.FaultPlan` at inner-step
    boundaries (membership drops/rejoins, straggler registration,
    partition views) — each event is applied once, keyed by the state's own
    step counter, so a resumed run never re-applies history;
  * performs the rejoin warm start (θ = φ = a live peer's φ, δ = 0, fresh
    AdamW moments) — the only state surgery elasticity needs;
  * aggregates loop-facing metrics (loss, eval, weight std) over the ACTIVE
    replica set and keeps an auditable ``history`` of events and per-round
    participation (partner tables included) for tests and telemetry.

What it does NOT model (see DESIGN.md §7): wall-clock skew, message loss
inside a surviving pair, Byzantine values, or asynchronous outer rounds —
every fault is a round-granular participation change.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import pairing as pairing_lib
from repro.core.noloco import TrainState
from repro.optim import AdamWState
from repro.sim.faults import FaultEvent, FaultPlan
from repro.train.adapters import GossipProgram

PyTree = Any

__all__ = ["SimCluster"]


class SimCluster:
    """Deterministic fault-injecting wrapper around a :class:`GossipProgram`."""

    def __init__(self, program: GossipProgram, plan: FaultPlan):
        plan.validate(program.replicas)
        self.program = program
        self.plan = plan
        self.replicas = program.replicas
        self._straggle: dict[int, int] = {}  # replica -> rounds left to miss
        self.history: list[dict] = []

    # -- membership passthrough (loop telemetry reads these) ----------------

    @property
    def membership(self) -> pairing_lib.Membership:
        return self.program.membership

    @property
    def membership_epoch(self) -> int:
        return self.program.membership_epoch

    @property
    def inner_steps(self) -> int:
        return self.program.tcfg.outer.inner_steps

    # -- fault application --------------------------------------------------

    def _apply_events(self, state: TrainState, t: int) -> TrainState:
        for ev in self.plan.events_at(t, self.inner_steps):
            state = self._apply(state, ev, t)
        return state

    def _apply(self, state: TrainState, ev: FaultEvent, t: int) -> TrainState:
        mem = self.program.membership
        rec: dict[str, Any] = {"event": ev.kind, "step": t}
        if ev.kind == "drop":
            self.program.set_membership(mem.drop(ev.replicas))
            rec["replicas"] = sorted(ev.replicas)
        elif ev.kind == "rejoin":
            source = ev.source
            if source is None:
                candidates = [r for r in mem.active_ids if r not in ev.replicas]
                if not candidates:
                    raise ValueError("rejoin needs at least one live peer to warm-start from")
                source = candidates[0]
            if source in ev.replicas or not mem.mask[source]:
                raise ValueError(f"rejoin source {source} is not a live peer")
            for r in ev.replicas:
                state = self._warm_start(state, r, source)
            self.program.set_membership(mem.add(ev.replicas))
            rec["replicas"] = sorted(ev.replicas)
            rec["source"] = source
        elif ev.kind == "straggle":
            for r in ev.replicas:
                if not mem.mask[r]:
                    raise ValueError(f"straggler {r} is not an active replica")
                self._straggle[r] = max(self._straggle.get(r, 0), ev.rounds)
            rec["replicas"] = sorted(ev.replicas)
            rec["rounds"] = ev.rounds
        elif ev.kind == "partition":
            self.program.set_partition(ev.groups)
            rec["groups"] = [sorted(g) for g in ev.groups]
        elif ev.kind == "heal":
            self.program.set_partition(None)
        self.history.append(rec)
        return state

    def _warm_start(self, state: TrainState, replica: int, source: int) -> TrainState:
        """Rejoin surgery: the comeback replica adopts a live peer's slow
        weights as BOTH its φ and θ (fresh look-ahead), zero outer momentum,
        zero inner-optimizer moments — exactly what a node that fetched φ
        from one peer and restarted would hold."""
        if self.program.membership.mask[replica]:
            raise ValueError(f"replica {replica} is already active; cannot rejoin")

        def adopt(x):
            return x.at[replica].set(x[source])

        def zero_row(x):
            return x.at[replica].set(jnp.zeros_like(x[replica]))

        return TrainState(
            theta=jax.tree.map(
                lambda th, p: th.at[replica].set(p[source]), state.theta, state.outer.phi
            ),
            opt=AdamWState(
                mu=jax.tree.map(zero_row, state.opt.mu),
                nu=jax.tree.map(zero_row, state.opt.nu),
                count=state.opt.count.at[replica].set(0),
            ),
            outer=dataclasses.replace(
                state.outer,
                phi=jax.tree.map(adopt, state.outer.phi),
                delta=jax.tree.map(zero_row, state.outer.delta),
            ),
            inner_step=state.inner_step,
        )

    # -- TrainProgram surface ----------------------------------------------

    def init_state(self, example_batch: dict) -> TrainState:
        return self.program.init_state(example_batch)

    def inner_step(self, state: TrainState, batch: dict, rng):
        state = self._apply_events(state, int(state.inner_step))
        # the program itself aggregates loss over active replicas
        return self.program.inner_step(state, batch, rng)

    def maybe_outer_step(self, state: TrainState):
        if not self.program.trainer.should_sync(state):
            return state, False
        round_idx = int(state.outer.step)
        absent = frozenset(
            r for r, k in self._straggle.items()
            if k > 0 and self.program.membership.mask[r]
        )
        self.program.round_absent = absent
        state, synced = self.program.maybe_outer_step(state)
        self._straggle = {
            r: k - 1 for r, k in self._straggle.items() if k > 1
        }
        partner = self.program.last_partner  # the table the round REALLY used
        self.history.append({
            "event": "round",
            "round": round_idx,
            "active": list(self.program.membership.active_ids),
            "absent": sorted(absent),
            "partner": None if partner is None else [int(p) for p in partner],
            "partition": (
                None if self.program.partition is None
                else [sorted(g) for g in self.program.partition]
            ),
        })
        return state, synced

    def eval_step(self, state: TrainState, batch: dict, rng) -> float:
        return self.program.eval_step(state, batch, rng)

    def weight_std(self, state: TrainState) -> float:
        return self.program.weight_std(state)

    def state_pytree(self, state: TrainState) -> dict:
        tree = self.program.state_pytree(state)
        # in-flight straggler debts must survive a restart, or a resumed run
        # would let a mid-straggle replica back into rounds it missed in the
        # uninterrupted trajectory
        straggle = np.zeros((self.replicas,), dtype=np.int64)
        for r, k in self._straggle.items():
            straggle[r] = k
        tree["sim"] = {"straggle": straggle}
        return tree

    def load_state_pytree(self, state: TrainState, tree: dict) -> TrainState:
        state = self.program.load_state_pytree(state, tree)
        if "sim" in tree:
            straggle = np.asarray(tree["sim"]["straggle"])
            self._straggle = {
                int(r): int(k) for r, k in enumerate(straggle) if k > 0
            }
        return state

    def comm_cost(self):
        return self.program.comm_cost()

    # -- diagnostics --------------------------------------------------------

    def rounds(self) -> list[dict]:
        """The per-round participation records (subset of ``history``)."""
        return [h for h in self.history if h["event"] == "round"]
