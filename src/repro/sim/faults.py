"""Fault plans: the declarative schedule of cluster events a SimCluster
replays against a real training program.

A plan is a list of :class:`FaultEvent`\\ s, each anchored either to an inner
``step`` or to an outer ``round`` (``round: r`` resolves to the first inner
step of round *r*'s inner phase, ``r * m`` — the event is in force for that
round's exchange).  Plans are plain JSON on the wire::

    {"events": [
        {"kind": "drop",    "round": 2, "replicas": [3, 5]},
        {"kind": "rejoin",  "round": 5, "replicas": [3, 5]},
        {"kind": "straggle","round": 3, "replicas": [1], "rounds": 1},
        {"kind": "rate",    "step": 0, "replicas": [1], "rate": 0.5},
        {"kind": "partition","round": 4, "groups": [[0, 1, 2, 3], [4, 5, 6, 7]]},
        {"kind": "heal",    "round": 6}
    ]}

Event kinds:

``drop``
    Replicas leave the cluster: frozen in inner AND outer steps, excluded
    from every pairing draw (membership epoch bumps).
``rejoin``
    Replicas come back, warm-started from a live peer's slow weights φ
    (``source``, default: lowest-id active replica): θ = φ = φ_source,
    δ = 0, fresh inner-optimizer moments.  Membership epoch bumps.
``straggle``
    Replicas miss the next ``rounds`` outer rounds (participation, not
    membership): their partners self-pair, their own (φ, δ, θ-reset) are
    skipped, inner training continues — the next round they join sees a
    Δ spanning the missed rounds' inner steps.
``rate``
    Replicas change wall-clock speed: from the anchor step on, the replica
    earns inner steps at ``rate`` times the full tick rate (``rate: 1.0``
    restores full speed).  Unlike ``straggle`` — a one-shot participation
    debt measured in whole rounds — a rate multiplier puts the replica on
    its OWN round clock: it reaches each sync index late and exchanges a
    stale Δ instead of sitting the round out (SimCluster's asynchronous
    clock, DESIGN.md §7).  Rates persist until changed by a later event.
``partition``
    The pairing graph splits into ``groups``: pairs never cross a component
    until a ``heal`` event (gossip keeps running inside each island).
``heal``
    Remove the partition.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable, Sequence

__all__ = ["FaultEvent", "FaultPlan", "KINDS"]

KINDS = ("drop", "rejoin", "straggle", "rate", "partition", "heal")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str
    replicas: tuple[int, ...] = ()
    step: int | None = None     # inner step the event applies before
    round: int | None = None    # outer round whose inner phase it opens
    rounds: int = 1             # straggle: consecutive outer rounds missed
    rate: float = 1.0           # rate: step-rate multiplier (0 < rate <= 1)
    source: int | None = None   # rejoin: peer whose φ seeds the warm start
    groups: tuple[tuple[int, ...], ...] = ()  # partition components

    def __post_init__(self):
        object.__setattr__(self, "replicas", tuple(int(r) for r in self.replicas))
        object.__setattr__(
            self, "groups", tuple(tuple(int(r) for r in g) for g in self.groups)
        )

    def resolved_step(self, inner_steps: int) -> int:
        """The inner step this event applies BEFORE."""
        if self.step is not None:
            return int(self.step)
        return int(self.round) * int(inner_steps)

    def effect_end_step(self, inner_steps: int) -> int:
        """The last inner step this event still has an effect at.

        For most kinds that is the anchor step itself, but a ``straggle``
        debt stays in force for ``rounds`` further outer rounds — a run whose
        horizon truncates the debt must checkpoint it and resume exactly
        (the SimCluster persists in-flight debts in its state pytree).  A
        ``rate`` multiplier persists until a later rate event, so its effect
        is open-ended and launchers should not warn about it."""
        anchor = self.resolved_step(inner_steps)
        if self.kind == "straggle":
            return anchor + int(self.rounds) * int(inner_steps)
        return anchor

    def validate(self, world: int) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind: {self.kind!r} (one of {KINDS})")
        if (self.step is None) == (self.round is None):
            raise ValueError(
                f"{self.kind} event needs exactly one of step/round "
                f"(got step={self.step}, round={self.round})"
            )
        anchor = self.step if self.step is not None else self.round
        if anchor < 0:
            raise ValueError(f"{self.kind} event anchored at negative {anchor}")
        if self.kind in ("drop", "rejoin", "straggle", "rate") and not self.replicas:
            raise ValueError(f"{self.kind} event needs replicas")
        for r in self.replicas:
            if not 0 <= r < world:
                raise ValueError(f"replica id {r} outside world {world}")
        if self.kind == "straggle" and self.rounds < 1:
            raise ValueError("straggle needs rounds >= 1")
        if self.kind == "rate" and not 0.0 < self.rate <= 1.0:
            raise ValueError(
                f"rate event needs 0 < rate <= 1 (rates are relative to the "
                f"fastest replica's tick rate; got {self.rate})"
            )
        if self.kind == "partition":
            if not self.groups:
                raise ValueError("partition event needs groups")
            flat = [r for g in self.groups for r in g]
            if len(flat) != len(set(flat)):
                raise ValueError("partition groups must be disjoint")
            for r in flat:
                if not 0 <= r < world:
                    raise ValueError(f"partition replica id {r} outside world {world}")
        if self.source is not None and not 0 <= self.source < world:
            raise ValueError(f"source id {self.source} outside world {world}")

    def as_dict(self) -> dict[str, Any]:
        out: dict[str, Any] = {"kind": self.kind}
        if self.step is not None:
            out["step"] = self.step
        if self.round is not None:
            out["round"] = self.round
        if self.replicas:
            out["replicas"] = list(self.replicas)
        if self.kind == "straggle":
            out["rounds"] = self.rounds
        if self.kind == "rate":
            out["rate"] = self.rate
        if self.source is not None:
            out["source"] = self.source
        if self.groups:
            out["groups"] = [list(g) for g in self.groups]
        return out

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "FaultEvent":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown fault event fields: {sorted(extra)}")
        d = dict(d)
        return cls(
            kind=d.pop("kind"),
            replicas=tuple(d.pop("replicas", ())),
            groups=tuple(tuple(g) for g in d.pop("groups", ())),
            **d,
        )


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An ordered schedule of fault events (order breaks same-step ties)."""

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def validate(self, world: int) -> None:
        for ev in self.events:
            ev.validate(world)

    def events_at(self, step: int, inner_steps: int) -> list[FaultEvent]:
        return [
            ev for ev in self.events if ev.resolved_step(inner_steps) == step
        ]

    def max_anchor_step(self, inner_steps: int) -> int:
        """The last inner step any event applies before (-1 for an empty
        plan).  Launchers compare this against the run horizon: an event
        anchored past ``--steps`` silently never fires, which is almost
        always a misconfigured plan worth warning about."""
        if not self.events:
            return -1
        return max(ev.resolved_step(inner_steps) for ev in self.events)

    def max_effect_step(self, inner_steps: int) -> int:
        """The last inner step any event still has an effect at (-1 for an
        empty plan).  Straggle debts extend ``rounds`` outer rounds past
        their anchor, so this can exceed :meth:`max_anchor_step` — launchers
        warn against THIS when a plan's effects outlive ``--steps`` (the
        in-flight part checkpoints and resumes exactly; the warning is for
        the case where the run is never resumed).  Open-ended ``rate``
        events are excluded: a persistent rate is not a truncation."""
        if not self.events:
            return -1
        return max(
            ev.effect_end_step(inner_steps)
            for ev in self.events
        )

    def rate_events(self) -> list[FaultEvent]:
        """The rate events in the plan (SimCluster auto-enables its
        asynchronous per-replica clock when any are present)."""
        return [ev for ev in self.events if ev.kind == "rate"]

    def to_json(self) -> str:
        return json.dumps({"events": [ev.as_dict() for ev in self.events]}, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        data = json.loads(text)
        events = data["events"] if isinstance(data, dict) else data
        return cls(events=tuple(FaultEvent.from_dict(d) for d in events))

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def build(cls, events: Iterable[FaultEvent | dict]) -> "FaultPlan":
        return cls(events=tuple(
            ev if isinstance(ev, FaultEvent) else FaultEvent.from_dict(ev)
            for ev in events
        ))
