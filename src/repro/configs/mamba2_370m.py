"""mamba2-370m [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060] 48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=1,             # attention-free; SSD heads derive from d_inner
    num_kv_heads=1,
    d_ff=0,                  # no MLP: block = norm + SSD mixer
    vocab_size=50_280,
    attn_pattern=("ssd",),
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    use_rope=False,
)
PLAN = "gossip_dp"
