"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 2:1 pattern.
[arXiv:2402.19427] 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,           # 12 full (rglru,rglru,local) periods + 2 remainder
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,          # MQA on the local-attention layers
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    mlp_variant="geglu",
    attn_pattern=("rglru", "rglru", "local"),
    sliding_window=2048,
    lru_width=4096,
    embed_scale=True,
)
PLAN = "gossip_dp"
