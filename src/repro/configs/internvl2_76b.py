"""internvl2-76b [vlm]: InternViT (STUB) + InternLM2/llama3-style decoder.
[arXiv:2404.16821] 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    mlp_variant="swiglu",
    frontend="vision",
    frontend_dim=3200,       # InternViT-6B hidden size (stub patch embeds)
    frontend_tokens=256,
    tie_embeddings=False,
)
PLAN = "fsdp_hybrid"
