"""granite-moe-1b-a400m [moe]: 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,                # per-expert hidden dim
    moe_d_ff=512,
    num_experts=32,
    num_experts_per_token=8,
    vocab_size=49_155,
    mlp_variant="swiglu",
)
PLAN = "gossip_dp"
