"""gemma-2b [dense]: GeGLU, head_dim=256, MQA. [arXiv:2403.08295]
18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000.

long_500k runs via the sliding-window VARIANT (window 4096) — see
registry.variant_for_shape; the base config attends globally."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    mlp_variant="geglu",
    embed_scale=True,
)
PLAN = "gossip_dp"
