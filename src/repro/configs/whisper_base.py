"""whisper-base [audio]: enc-dec transformer backbone, conv/mel frontend STUB.
[arXiv:2212.04356] 6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="encdec",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    mlp_variant="gelu",
    norm_type="layernorm",
    use_rope=False,          # whisper: absolute sinusoidal positions
    is_encoder_decoder=True,
    num_encoder_layers=6,
    encoder_seq=1500,        # 30 s of mel frames after the (stubbed) conv stack
    frontend="audio",
    frontend_dim=512,        # stub provides post-conv frame embeddings
    frontend_tokens=1500,
)
PLAN = "gossip_dp"
