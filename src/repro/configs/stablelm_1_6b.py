"""stablelm-1.6b [dense]: full MHA. [hf:stabilityai/stablelm-2-1_6b]
24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    norm_type="layernorm",
    mlp_variant="swiglu",
    tie_embeddings=False,
)
PLAN = "gossip_dp"
