"""minitron-8b [dense]: pruned nemotron. [arXiv:2407.14679]
32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=256_000,
    mlp_variant="relu2",     # nemotron squared-ReLU MLP
    norm_type="layernorm",
    tie_embeddings=False,
)
PLAN = "gossip_dp"
