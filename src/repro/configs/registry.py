"""Architecture registry: ``--arch <id>`` resolution, per-arch parallelism
plan, and per-shape config variants."""

from __future__ import annotations

import dataclasses

from repro.configs import (
    gemma_2b,
    granite_moe_1b,
    internvl2_76b,
    mamba2_370m,
    minitron_8b,
    paper_llama,
    qwen3_0_6b,
    qwen3_moe_235b,
    recurrentgemma_9b,
    stablelm_1_6b,
    whisper_base,
)
from repro.configs.shapes import SHAPES, InputShape, input_specs, shape_skips
from repro.models.config import ModelConfig

__all__ = [
    "ARCHS",
    "PLANS",
    "get_config",
    "get_plan",
    "variant_for_shape",
    "SHAPES",
    "input_specs",
    "shape_skips",
]

_MODULES = {
    "whisper-base": whisper_base,
    "qwen3-0.6b": qwen3_0_6b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "gemma-2b": gemma_2b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b,
    "stablelm-1.6b": stablelm_1_6b,
    "minitron-8b": minitron_8b,
    "internvl2-76b": internvl2_76b,
    "mamba2-370m": mamba2_370m,
    "paper-small-125m": paper_llama,
    "paper-medium-1.3b": paper_llama,
    "paper-large-6.8b": paper_llama,
}

ARCHS: dict[str, ModelConfig] = {
    **{name: mod.CONFIG for name, mod in _MODULES.items() if not name.startswith("paper")},
    "paper-small-125m": paper_llama.SMALL,
    "paper-medium-1.3b": paper_llama.MEDIUM,
    "paper-large-6.8b": paper_llama.LARGE,
}

PLANS: dict[str, str] = {name: mod.PLAN for name, mod in _MODULES.items()}

ASSIGNED = [
    "whisper-base",
    "qwen3-0.6b",
    "granite-moe-1b-a400m",
    "recurrentgemma-9b",
    "gemma-2b",
    "qwen3-moe-235b-a22b",
    "stablelm-1.6b",
    "minitron-8b",
    "internvl2-76b",
    "mamba2-370m",
]


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_plan(arch: str) -> str:
    return PLANS[arch]


def variant_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-shape config adjustments:
    * gemma-2b @ long_500k -> sliding-window variant (window 4096), the dense
      arch we run at 500k per the assignment's sliding-window carve-out."""
    if shape.name == "long_500k" and cfg.name == "gemma-2b":
        return dataclasses.replace(
            cfg, attn_pattern=("local",), sliding_window=4096, name="gemma-2b"
        )
    return cfg
