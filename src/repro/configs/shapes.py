"""Assigned input shapes and per-(arch, shape) ShapeDtypeStruct input specs.

  train_4k     seq 4 096,   global batch 256   -> train_step
  prefill_32k  seq 32 768,  global batch 32    -> prefill_step
  decode_32k   seq 32 768 cache, global batch 128, ONE new token -> serve_step
  long_500k    seq 524 288 cache, global batch 1 (sub-quadratic archs only)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["InputShape", "SHAPES", "input_specs", "shape_skips"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# archs that run long_500k (sub-quadratic); see DESIGN.md §Arch-applicability
LONG_OK = {"recurrentgemma-9b", "mamba2-370m", "gemma-2b"}  # gemma via sliding-window variant


def shape_skips(cfg: ModelConfig, shape: InputShape) -> str | None:
    """Return a skip reason or None if the (arch, shape) combo runs."""
    if shape.name == "long_500k":
        if cfg.name in LONG_OK:
            return None
        return "full-attention arch: 524k dense KV decode is quadratic — skipped per assignment"
    return None


def _frontend_entries(cfg: ModelConfig, batch: int) -> dict:
    out = {}
    if cfg.frontend == "audio":
        out["encoder_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.frontend_dim or cfg.d_model), jnp.bfloat16
        )
    elif cfg.frontend == "vision":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16
        )
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this step kind
    (weak-type-correct, shardable, no device allocation)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        text = s - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
        spec = {
            "tokens": jax.ShapeDtypeStruct((b, text), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, text), jnp.int32),
        }
        spec.update(_frontend_entries(cfg, b))
        return spec
    if shape.kind == "prefill":
        text = s - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
        spec = {"tokens": jax.ShapeDtypeStruct((b, text), jnp.int32)}
        spec.update(_frontend_entries(cfg, b))
        return spec
    # decode: ONE new token; the KV/state cache of size s is a separate input
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
