"""The paper's own Llama-style models (Table 1): small 125M / medium 1.3B /
large 6.8B, vocab 128k, seq 1024, trained with AdamW inner + NoLoCo/DiLoCo
outer (OPT hyper-parameters)."""

from repro.models.config import ModelConfig


def _paper(name, hidden, layers, inter, heads):
    return ModelConfig(
        name=name,
        arch_type="dense",
        num_layers=layers,
        d_model=hidden,
        num_heads=heads,
        num_kv_heads=heads,
        d_ff=inter,
        vocab_size=128_000,
        mlp_variant="gelu",       # OPT/llama-era baseline MLP
        norm_type="layernorm",
        tie_embeddings=True,
    )


SMALL = _paper("paper-small-125m", 768, 12, 3072, 16)
MEDIUM = _paper("paper-medium-1.3b", 2048, 24, 8192, 32)
LARGE = _paper("paper-large-6.8b", 4096, 32, 16384, 32)
CONFIG = SMALL
PLAN = "gossip_dp"
