"""qwen3-0.6b [dense]: qk_norm, GQA. [hf:Qwen/Qwen3-8B]
28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,            # qwen3 signature: head_dim 128 > d_model/heads
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_variant="swiglu",
)
PLAN = "gossip_dp"
