"""qwen3-moe-235b-a22b [moe]: 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]
94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    num_layers=94,           # 94 = 1-layer period scanned 94x
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,               # per-expert hidden dim
    moe_d_ff=1536,
    num_experts=128,
    num_experts_per_token=8,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp_variant="swiglu",
)
PLAN = "fsdp_hybrid"
