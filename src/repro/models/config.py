"""Unified architecture configuration covering all assigned architectures and
the paper's own Llama-style models.

One ``ModelConfig`` describes: dense decoders (llama/qwen/gemma/stablelm/
minitron), MoE decoders (granite/qwen3-moe), hybrid recurrent (recurrentgemma
RG-LRU + local attention), pure SSM (mamba2 SSD), encoder-decoder audio
(whisper) and VLM decoders with a stubbed vision frontend (internvl2).
"""

from __future__ import annotations

import dataclasses

from repro.kernels.dispatch import KernelConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int | None = None  # default d_model // num_heads (gemma: 256)

    # -- block features -------------------------------------------------
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    qk_norm: bool = False        # qwen3-style per-head RMS norm on q,k
    rope_theta: float = 10_000.0
    use_rope: bool = True        # whisper uses sinusoidal absolute positions
    tie_embeddings: bool = True
    logit_softcap: float | None = None  # gemma-style tanh soft-capping
    embed_scale: bool = False           # multiply embeddings by sqrt(d_model) (gemma)

    # -- attention pattern ------------------------------------------------
    # cycled over layers; entries: "global" | "local" | "rglru" | "ssd"
    attn_pattern: tuple[str, ...] = ("global",)
    sliding_window: int | None = None  # window for "local" layers

    # -- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_d_ff: int | None = None          # per-expert hidden dim
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01        # load-balance loss coefficient

    # -- SSM (mamba2 SSD) ----------------------------------------------------
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv_width: int = 4

    # -- RG-LRU (recurrentgemma) ----------------------------------------------
    lru_width: int | None = None  # default d_model

    # -- encoder-decoder --------------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0  # whisper: 1500 mel frames after the (stubbed) conv

    # -- modality frontend (STUB per assignment carve-out) ----------------------
    frontend: str | None = None  # "audio" | "vision"
    frontend_dim: int = 0        # raw embedding dim produced by the stub
    frontend_tokens: int = 0     # patches / frames consumed by the decoder

    # -- kernel dispatch --------------------------------------------------------
    # Which implementation backs the compute hot-spots (attention, SSD,
    # RG-LRU): Pallas kernels or their jnp twins.  impl="auto" resolves to
    # Pallas on TPU and jnp elsewhere; see repro.kernels.dispatch.
    kernels: KernelConfig = dataclasses.field(default_factory=KernelConfig)

    # -- numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    # Fully unroll every lax.scan (layers, kv blocks, loss chunks). Used by the
    # dry-run's depth-1/2 cost variants: XLA cost_analysis counts while-loop
    # bodies ONCE, so trip-count-correct FLOPs/bytes need unrolled modules.
    unroll_scans: bool = False

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def layer_types(self) -> tuple[str, ...]:
        pat = self.attn_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def is_subquadratic(self) -> bool:
        """True iff no layer attends globally — required for long_500k."""
        return all(t != "global" for t in self.layer_types)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decoder (whisper is enc-dec)

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0 or self.arch_type == "ssm"
        if "local" in self.attn_pattern:
            assert self.sliding_window, "local attention needs sliding_window"
        if self.arch_type == "moe":
            assert self.num_experts > 0 and self.num_experts_per_token > 0
        if self.arch_type == "ssm":
            assert self.ssm_state_dim > 0
        if self.is_encoder_decoder:
            assert self.num_encoder_layers > 0 and self.encoder_seq > 0

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts, same family."""
        small: dict = dict(
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads > 1 else 1,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.head_dim else None,
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            num_encoder_layers=min(self.num_encoder_layers, 2)
            if self.num_encoder_layers
            else 0,
            frontend_dim=min(self.frontend_dim, 128) if self.frontend_dim else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            ssm_state_dim=min(self.ssm_state_dim, 32) if self.ssm_state_dim else 0,
            ssm_chunk=16 if self.ssm_state_dim else self.ssm_chunk,
            lru_width=min(self.lru_width, 256) if self.lru_width else None,
        )
        if self.num_experts:
            small.update(
                num_experts=min(self.num_experts, 4),
                num_experts_per_token=min(self.num_experts_per_token, 2),
                moe_d_ff=min(self.moe_d_ff or self.d_ff, 128),
            )
        # keep the pattern representative at 2 layers: first + last type
        # (e.g. recurrentgemma ("rglru","rglru","local") -> ("rglru","local"))
        if len(self.attn_pattern) > 1:
            small["attn_pattern"] = (self.attn_pattern[0], self.attn_pattern[-1])
        small.update(overrides)
        return dataclasses.replace(self, **small)
