"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm (the paper's Listing 1, adapted to JAX):
sequence is split into chunks of Q tokens; within a chunk the output is the
"attention-like" quadratic form with the decay kernel L; across chunks a
linear state recurrence (scanned) passes (H, P, N) states.  Both pieces are
O(S·Q) compute and O(S) memory — mamba2 therefore runs the long_500k shape.

TP: heads are sharded over the model axis (state recurrence is head-local);
B/C projections (ngroups=1, shared across heads) are replicated; the only
collective is the row-parallel out-proj psum.

The intra-chunk quadratic form is the compute hot-spot; it runs through the
kernel-dispatch layer (:func:`repro.kernels.ops.ssd_chunk` — the Pallas
kernel in repro/kernels/ssd_scan.py or its jnp twin per ``cfg.kernels``,
differentiable via custom_vjp).  This module owns the projections, conv,
gating and cache plumbing around it.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.kernels.dispatch import KernelConfig
from repro.models.common import param, truncated_normal
from repro.parallel.sharding import ShardCtx


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def num_heads_ssm(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def init_ssd(key, cfg) -> dict:
    d = cfg.d_model
    di = d_inner(cfg)
    n = cfg.ssm_state_dim
    h = num_heads_ssm(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    std = 1.0 / math.sqrt(d)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[0], (h,), jnp.float32, math.log(1e-3), math.log(1e-1))
    dt_bias = jnp.exp(u)
    dt_bias = dt_bias + jnp.log(-jnp.expm1(-dt_bias))  # inverse softplus
    a_init = jax.random.uniform(ks[1], (h,), jnp.float32, 1.0, 16.0)
    return {
        "w_z": param(truncated_normal(ks[2], (d, di), std, dt), "fsdp", "tp"),
        "w_x": param(truncated_normal(ks[3], (d, di), std, dt), "fsdp", "tp"),
        "w_b": param(truncated_normal(ks[4], (d, n), std, dt), "fsdp", None),
        "w_c": param(truncated_normal(ks[5], (d, n), std, dt), "fsdp", None),
        "w_dt": param(truncated_normal(ks[6], (d, h), std, dt), "fsdp", "tp"),
        "dt_bias": param(dt_bias, "tp"),
        "a_log": param(jnp.log(a_init), "tp"),
        "d_skip": param(jnp.ones((h,), jnp.float32), "tp"),
        "conv": param(jnp.zeros((cfg.ssm_conv_width, di), dt).at[-1].set(1.0), None, "tp"),
        "norm_scale": param(jnp.ones((di,), jnp.float32), "tp"),
        "w_out": param(truncated_normal(jax.random.fold_in(key, 9), (di, d), 1.0 / math.sqrt(di), dt), "tp", "fsdp"),
    }


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSDCache:
    """Decode state: conv tail (B, K−1, di_local) + SSM state (B,H_l,P,N)."""

    conv: jax.Array
    state: jax.Array

    @staticmethod
    def init(cfg, batch: int, di_local: int, h_local: int, dtype) -> "SSDCache":
        return SSDCache(
            conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, di_local), dtype),
            state=jnp.zeros((batch, h_local, cfg.ssm_head_dim, cfg.ssm_state_dim), jnp.float32),
        )


def _causal_conv(u, kernel, tail):
    k = kernel.shape[0]
    if tail is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = tail.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i : i + u.shape[1], :] * kernel[i][None, None, :] for i in range(k))
    return out, full[:, -(k - 1) :, :]


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P)   already dt-scaled NOT applied; raw x
    dt: jax.Array,     # (B, S, H)      positive step sizes
    a: jax.Array,      # (H,)           negative decay rates (−exp(a_log))
    b_mat: jax.Array,  # (B, S, N)
    c_mat: jax.Array,  # (B, S, N)
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
    unroll: bool = False,
    config: KernelConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD: returns (y (B,S,H,P), final_state (B,H,P,N)).

    Thin wrapper over the dispatched :func:`repro.kernels.ops.ssd_chunk`
    (Pallas intra-chunk kernel or jnp twin + shared inter-chunk scan).  No
    dtype casts here: both implementations upcast to f32 per-tile, so model-
    dtype inputs stream at native width (apply_ssd already feeds f32)."""
    return kernel_ops.ssd_chunk(
        x, dt, a, b_mat, c_mat,
        chunk=chunk, initial_state=initial_state, unroll=unroll, config=config,
    )


def apply_ssd(
    p: dict,
    cfg,
    x: jax.Array,  # (B, S, d)
    ctx: ShardCtx,
    *,
    cache: SSDCache | None = None,
    chunk_lengths: jax.Array | None = None,  # (B,) valid tokens per chunk row
    chunk_exact: bool = False,               # per-token decode-bitwise states
) -> tuple[jax.Array, SSDCache | None]:
    w_z = ctx.gather_param(p["w_z"], axis=0)
    w_x = ctx.gather_param(p["w_x"], axis=0)
    w_b = ctx.gather_param(p["w_b"], axis=0)
    w_c = ctx.gather_param(p["w_c"], axis=0)
    w_dt = ctx.gather_param(p["w_dt"], axis=0)
    w_out = ctx.gather_param(p["w_out"], axis=1)

    bsz, s, _ = x.shape
    hd = cfg.ssm_head_dim

    z = x @ w_z                                          # (B,S,di_local)
    u_in = x @ w_x
    u, new_conv = _causal_conv(u_in, p["conv"], cache.conv if cache is not None else None)
    u = jax.nn.silu(u.astype(jnp.float32))
    b_mat = (x @ w_b).astype(jnp.float32)
    c_mat = (x @ w_c).astype(jnp.float32)
    dt = jax.nn.softplus((x @ w_dt).astype(jnp.float32) + p["dt_bias"])  # (B,S,H_l)
    a = -jnp.exp(p["a_log"])                             # (H_l,)

    h_local = u.shape[-1] // hd
    u_heads = u.reshape(bsz, s, h_local, hd)

    chunked = cache is not None and chunk_lengths is not None
    if chunked:
        # CHUNK-RESUMABLE serving prefill/verify: row c of slot r is real iff
        # c < chunk_lengths[r].  Masking dt to EXACTLY 0.0 on the garbage
        # tail makes each pad token a bitwise no-op on the recurrence
        # (decay exp(0·a) = 1, input dt·x = 0), so the carried state equals
        # the state at the last valid token with no selection needed; the
        # conv tail is still selected positionally.
        k1 = p["conv"].shape[0] - 1
        ext = jnp.concatenate([cache.conv.astype(u_in.dtype), u_in], axis=1)
        lengths = chunk_lengths.astype(jnp.int32)
        tok_valid = jnp.arange(s, dtype=jnp.int32)[None, :] < lengths[:, None]
        if chunk_exact:
            # spec-decode verify: sequential dispatched single-step updates so
            # token c's state is BITWISE the decode step after token c; the
            # cache carries the per-token trajectory (B, S, ...) for the
            # engine to select the accepted prefix from.
            def step(st, inp):
                dt1, b1, c1, u1 = inp
                st2, y1 = kernel_ops.ssd_decode(
                    st, dt1, a, b1, c1, u1, config=cfg.kernels
                )
                return st2, (st2, y1)

            _, (states, ys) = jax.lax.scan(
                step,
                cache.state,
                (
                    dt.transpose(1, 0, 2),
                    b_mat.transpose(1, 0, 2),
                    c_mat.transpose(1, 0, 2),
                    u_heads.transpose(1, 0, 2, 3),
                ),
            )
            y = ys.transpose(1, 0, 2, 3)                    # (B,S,H_l,P)
            win = jnp.arange(s)[:, None] + 1 + jnp.arange(k1)[None, :]
            new_cache = SSDCache(conv=ext[:, win], state=states.transpose(1, 0, 2, 3, 4))
        else:
            dtm = jnp.where(tok_valid[..., None], dt, 0.0)
            y, final_state = ssd_chunked(
                u_heads, dtm, a, b_mat, c_mat, cfg.ssm_chunk,
                initial_state=cache.state,
                unroll=cfg.unroll_scans, config=cfg.kernels,
            )
            tidx = lengths[:, None] + jnp.arange(k1)[None, :]
            tail = jnp.take_along_axis(
                ext, jnp.broadcast_to(tidx[:, :, None], (ext.shape[0], k1, ext.shape[2])), axis=1
            )
            new_cache = SSDCache(conv=tail, state=final_state)
        y = y + p["d_skip"][None, None, :, None] * u_heads
        y = y.reshape(bsz, s, h_local * hd)
        g = y * jax.nn.silu(z.astype(jnp.float32))
        ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
        if ctx.ff_tp(d_inner(cfg)) > 1:
            ms = ctx.psum_model(ms) / ctx.tp
        g = g * jax.lax.rsqrt(ms + 1e-6) * p["norm_scale"]
        out = g.astype(x.dtype) @ w_out
        if ctx.ff_tp(d_inner(cfg)) > 1:
            out = ctx.scatter_seq_sum(out, axis=1)
        return out, new_cache

    decode = cache is not None and s == 1
    if not decode:
        y, final_state = ssd_chunked(
            u_heads, dt, a, b_mat, c_mat, cfg.ssm_chunk,
            initial_state=cache.state if cache is not None else None,
            unroll=cfg.unroll_scans, config=cfg.kernels,
        )
        new_cache = (
            SSDCache(conv=new_conv, state=final_state) if cache is not None else None
        )
    else:
        # single-token recurrence h' = exp(dt·a)·h + dt·(B ⊗ x) — dispatched
        # fused update (serving hot loop)
        state, y1 = kernel_ops.ssd_decode(
            cache.state, dt[:, 0], a, b_mat[:, 0], c_mat[:, 0], u_heads[:, 0],
            config=cfg.kernels,
        )
        y = y1[:, None]                                  # (B,1,H_l,P)
        new_cache = SSDCache(conv=new_conv, state=state)
        final_state = state

    y = y + p["d_skip"][None, None, :, None] * u_heads
    y = y.reshape(bsz, s, h_local * hd)

    # gated RMSNorm (mamba2): norm(y * silu(z)) — per-channel, head-local
    g = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    if ctx.ff_tp(d_inner(cfg)) > 1:
        # mean over the FULL di dim needs a psum of local sums
        ms = ctx.psum_model(ms) / ctx.tp
    g = g * jax.lax.rsqrt(ms + 1e-6) * p["norm_scale"]

    out = g.astype(x.dtype) @ w_out
    if ctx.ff_tp(d_inner(cfg)) > 1:
        out = ctx.scatter_seq_sum(out, axis=1)
    return out, new_cache
