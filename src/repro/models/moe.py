"""Mixture-of-Experts block: top-k routing, capacity-bounded scatter dispatch,
expert parallelism over the model axis with an explicit all-to-all.

Design (production pattern, DeepSeek/GShard style, adapted for TPU):

  * tokens enter SEQUENCE-SHARDED over the model axis (T_local = T / tp) so
    the dispatch buffers stay small;
  * router + top-k run locally; each (token, k) assignment is scattered into a
    per-expert capacity buffer ``(E, C, d)`` — no (T, E, C) one-hot tensor is
    ever materialized;
  * one ``all_to_all`` over the model axis regroups buffers so each shard
    holds the tokens of its E/tp local experts;
  * local experts run as a dense batched ffn (E_local, tp*C, d);
  * the inverse all-to-all + combine-weighted scatter-add returns outputs.

Tokens above capacity are dropped (standard; capacity_factor controls it) —
the router aux loss keeps load roughly balanced so drops are rare.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import param, truncated_normal
from repro.parallel.sharding import ShardCtx

__all__ = ["init_moe", "apply_moe"]


def init_moe(key, cfg) -> dict:
    d = cfg.d_model
    e = cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(f)
    p = {
        # router stays replicated (tiny) and in f32 for routing stability
        "router": param(truncated_normal(ks[0], (d, e), std_in, jnp.float32), None, None),
        "w_in": param(truncated_normal(ks[1], (e, d, f), std_in, dt), "expert", "fsdp", None),
        "w_out": param(truncated_normal(ks[2], (e, f, d), std_out, dt), "expert", None, "fsdp"),
    }
    if cfg.mlp_variant in ("swiglu", "geglu"):
        p["w_gate"] = param(
            truncated_normal(ks[3], (e, d, f), std_in, dt), "expert", "fsdp", None
        )
    return p


def _act(cfg, gate_h, h):
    if cfg.mlp_variant == "swiglu":
        return jax.nn.silu(gate_h) * h
    if cfg.mlp_variant == "geglu":
        return jax.nn.gelu(gate_h, approximate=True) * h
    return jax.nn.gelu(h, approximate=True)


def apply_moe(
    p: dict, cfg, x: jax.Array, ctx: ShardCtx
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S_local, d) — sequence-sharded over the model axis when tp > 1
    (the transformer block handles the scatter/gather around this call).

    Returns (y, aux_loss) with y in the same layout as x.
    """
    b, s, d = x.shape
    e = cfg.num_experts
    k = cfg.num_experts_per_token
    ep = ctx.experts_tp(e)
    e_local = e // ep

    xt = x.reshape(b * s, d)
    t = b * s

    # ---- routing (f32) -----------------------------------------------------
    logits = xt.astype(jnp.float32) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize over top-k

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef

    # ---- capacity assignment ------------------------------------------------
    cap = max(1, int(math.ceil(t * k / e * cfg.moe_capacity_factor)))
    flat_e = top_e.reshape(-1)  # (T*k,)
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)

    # position of each assignment within its expert, via sorted segment ranks
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    rank_sorted = jnp.arange(t * k) - seg_start[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < cap

    # ---- scatter into per-expert capacity buffers ----------------------------
    buf = jnp.zeros((e, cap, d), x.dtype)
    safe_e = jnp.where(keep, flat_e, 0)
    safe_r = jnp.where(keep, rank, 0)
    vals = jnp.where(keep[:, None], xt[flat_tok], 0)
    buf = buf.at[safe_e, safe_r].add(vals.astype(x.dtype))

    # ---- expert parallelism: all-to-all over the model axis -------------------
    if ep > 1:
        # (E, C, d) -> (ep, E_local, C, d) -> a2a -> (E_local, ep*C, d)
        buf = buf.reshape(ep, e_local, cap, d)
        buf = ctx.all_to_all_model(buf, split_axis=0, concat_axis=2)  # (1*,E_l,ep*C,d)
        buf = buf.reshape(e_local, ep * cap, d)
    # else: buf stays (E, C, d) == (E_local, C, d)

    # ---- local expert FFN ------------------------------------------------------
    w_in = ctx.gather_param(p["w_in"], axis=1)   # (E_l, d, f): ZeRO-3 dim = d
    w_out = ctx.gather_param(p["w_out"], axis=2)  # (E_l, f, d): ZeRO-3 dim = d
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    if "w_gate" in p:
        w_gate = ctx.gather_param(p["w_gate"], axis=1)
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        h = _act(cfg, g, h)
    else:
        h = _act(cfg, None, h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_out)

    # ---- inverse all-to-all ------------------------------------------------------
    if ep > 1:
        out_buf = out_buf.reshape(e_local, ep, cap, d)
        out_buf = ctx.all_to_all_model(out_buf, split_axis=1, concat_axis=0)
        out_buf = out_buf.reshape(e, cap, d)

    # ---- combine -------------------------------------------------------------------
    gathered = out_buf[safe_e, safe_r]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((t, d), jnp.float32)
    y = y.at[flat_tok].add(gathered.astype(jnp.float32) * flat_w[:, None])
    return y.reshape(b, s, d).astype(x.dtype), aux
