"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block layout (Griffin "recurrent block"):

    x ── W_x ──► conv1d(w=4) ──► RG-LRU ──┐
    x ── W_gate ──────────► GeLU ──────── ⊙ ──► W_out ──► y

RG-LRU recurrence (per channel):
    r_t = σ(x_t @ W_r)                      (recurrence gate)
    i_t = σ(x_t @ W_i)                      (input gate)
    a_t = a ** (c · r_t),  a = σ(Λ)         (c = 8)
    h_t = a_t · h_{t−1} + sqrt(1 − a_t²) · (i_t ⊙ u_t)

Training/prefill runs the linear recurrence through the kernel-dispatch
layer (:func:`repro.kernels.ops.rglru_scan` — the Pallas doubling-scan
kernel in repro/kernels/rglru_scan.py or its ``associative_scan`` jnp twin
per ``cfg.kernels``, differentiable via custom_vjp); decode is a single-step
state update — both O(S) compute and O(1) memory per token, which is why
recurrentgemma runs the ``long_500k`` shape.

TP: the LRU width is sharded over the model axis; the recurrence is
channelwise so it needs NO collectives — only the final row-parallel W_out
psum.  (Deviation from Griffin: we use full d→w linear gates instead of
block-diagonal ones; semantics preserved, parameter count slightly higher.)
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.models.common import param, truncated_normal
from repro.parallel.sharding import ShardCtx

C_EXP = 8.0


def init_rglru(key, cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    std = 1.0 / math.sqrt(d)
    # Λ init so that a = σ(Λ) ∈ [0.9, 0.999]
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u / (1.0 - u))
    return {
        "w_x": param(truncated_normal(ks[1], (d, w), std, dt), "fsdp", "tp"),
        "w_gate": param(truncated_normal(ks[2], (d, w), std, dt), "fsdp", "tp"),
        "w_r": param(truncated_normal(ks[3], (d, w), std, dt), "fsdp", "tp"),
        "w_i": param(truncated_normal(ks[4], (d, w), std, dt), "fsdp", "tp"),
        "conv": param(jnp.zeros((4, w), dt).at[-1].set(1.0), None, "tp"),
        "lam": param(lam, "tp"),
        "w_out": param(truncated_normal(ks[5], (w, d), 1.0 / math.sqrt(w), dt), "tp", "fsdp"),
    }


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RGLRUCache:
    """Decode state: conv tail (B, K-1, w_local) + LRU hidden (B, w_local)."""

    conv: jax.Array
    h: jax.Array

    @staticmethod
    def init(cfg, batch: int, w_local: int, dtype) -> "RGLRUCache":
        return RGLRUCache(
            conv=jnp.zeros((batch, 3, w_local), dtype),
            h=jnp.zeros((batch, w_local), jnp.float32),
        )


def _causal_conv(u: jax.Array, kernel: jax.Array, tail: jax.Array | None):
    """Depthwise causal conv, width K: u (B,S,w), kernel (K,w)."""
    k = kernel.shape[0]
    if tail is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = tail.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)  # (B, S+K-1, w)
    out = sum(
        full[:, i : i + u.shape[1], :] * kernel[i][None, None, :] for i in range(k)
    )
    new_tail = full[:, -(k - 1) :, :]
    return out, new_tail


def apply_rglru(
    p: dict,
    cfg,
    x: jax.Array,  # (B, S, d)
    ctx: ShardCtx,
    *,
    cache: RGLRUCache | None = None,
    chunk_lengths: jax.Array | None = None,  # (B,) valid tokens per chunk row
    chunk_exact: bool = False,               # per-token decode-bitwise states
) -> tuple[jax.Array, RGLRUCache | None]:
    w_x = ctx.gather_param(p["w_x"], axis=0)
    w_gate = ctx.gather_param(p["w_gate"], axis=0)
    w_r = ctx.gather_param(p["w_r"], axis=0)
    w_i = ctx.gather_param(p["w_i"], axis=0)
    w_out = ctx.gather_param(p["w_out"], axis=1)

    u_in = x @ w_x                               # (B,S,w_local)
    gate = jax.nn.gelu((x @ w_gate).astype(jnp.float32), approximate=True)
    u, new_conv = _causal_conv(u_in, p["conv"], cache.conv if cache is not None else None)

    r = jax.nn.sigmoid((x @ w_r).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ w_i).astype(jnp.float32))
    # a_t = a^(c·r_t) with a = σ(Λ)  ⇒  log a_t = c·r_t·log σ(Λ) = −c·r_t·softplus(−Λ)
    log_a = C_EXP * r * (-jax.nn.softplus(-p["lam"]))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))

    chunked = cache is not None and chunk_lengths is not None
    if chunked:
        # CHUNK-RESUMABLE serving prefill/verify: row c of slot r is real iff
        # c < chunk_lengths[r].  The recurrence is causal, so per-token states
        # for valid tokens are untouched by the ragged garbage tail — only
        # the carried state/tail must be SELECTED at the last valid token.
        s, k1 = x.shape[1], p["conv"].shape[0] - 1
        ext = jnp.concatenate([cache.conv.astype(u_in.dtype), u_in], axis=1)
        lengths = chunk_lengths.astype(jnp.int32)
        if chunk_exact:
            # spec-decode verify: sequential dispatched single-step updates so
            # token c's state is BITWISE the decode step after token c; the
            # cache carries the full per-token trajectory (B, S, ...) for the
            # engine to select the accepted prefix from.
            def step(hprev, ab):
                at, bt = ab
                hn = kernel_ops.rglru_decode(hprev, at, bt, config=cfg.kernels)
                return hn, hn

            _, hs = jax.lax.scan(
                step, cache.h, (a.transpose(1, 0, 2), b.transpose(1, 0, 2))
            )
            h = hs.transpose(1, 0, 2)                       # (B,S,w)
            win = jnp.arange(s)[:, None] + 1 + jnp.arange(k1)[None, :]
            tails = ext[:, win]                             # (B,S,K-1,w)
            new_cache = RGLRUCache(conv=tails, h=h)
        else:
            b = b.at[:, 0].add(a[:, 0] * cache.h)
            h = kernel_ops.rglru_scan(a, b, config=cfg.kernels)
            sel = jnp.clip(lengths - 1, 0, s - 1)[:, None, None]
            h_last = jnp.take_along_axis(h, jnp.broadcast_to(sel, (h.shape[0], 1, h.shape[2])), axis=1)[:, 0]
            h_last = jnp.where(lengths[:, None] > 0, h_last, cache.h)
            tidx = lengths[:, None] + jnp.arange(k1)[None, :]
            tail = jnp.take_along_axis(
                ext, jnp.broadcast_to(tidx[:, :, None], (ext.shape[0], k1, ext.shape[2])), axis=1
            )
            new_cache = RGLRUCache(conv=tail, h=h_last)
        y = (h * gate).astype(x.dtype) @ w_out
        if ctx.ff_tp(cfg.lru_width or cfg.d_model) > 1:
            y = ctx.scatter_seq_sum(y, axis=1)
        return y, new_cache

    decode = cache is not None and x.shape[1] == 1
    if not decode:
        # h_t = a_t h_{t-1} + b_t — dispatched linear-recurrence kernel
        if cache is not None:  # prefill continuing from an existing state
            b = b.at[:, 0].add(a[:, 0] * cache.h)
        h = kernel_ops.rglru_scan(a, b, config=cfg.kernels)
        new_cache = (
            RGLRUCache(conv=new_conv, h=h[:, -1]) if cache is not None else None
        )
    else:
        # dispatched single-step update (serving hot loop)
        h_last = kernel_ops.rglru_decode(cache.h, a[:, 0], b[:, 0], config=cfg.kernels)
        h = h_last[:, None, :]
        new_cache = RGLRUCache(conv=new_conv, h=h_last)

    y = (h * gate).astype(x.dtype) @ w_out
    if ctx.ff_tp(cfg.lru_width or cfg.d_model) > 1:
        y = ctx.scatter_seq_sum(y, axis=1)
    return y, new_cache
