from repro.models.config import ModelConfig
from repro.models.common import Param, param, unzip, values_of, specs_of
from repro.models import model as model_api

__all__ = ["ModelConfig", "Param", "param", "unzip", "values_of", "specs_of", "model_api"]
