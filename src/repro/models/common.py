"""Parameter machinery shared by all models.

Every weight is created as a :class:`Param` — the array plus *logical* axis
names describing how each dim shards:

    None      replicated
    "tp"      tensor-parallel       -> mesh "model" axis
    "expert"  expert-parallel       -> mesh "model" axis
    "fsdp"    ZeRO-3 weight shard   -> mesh "data" axis (fsdp_hybrid plan only)

``unzip`` splits a Param tree into (values, logical_specs); the launcher maps
logical specs to mesh PartitionSpecs according to the arch's parallelism plan
(repro/parallel/plans.py).  Model *apply* code only ever sees plain arrays —
at whatever local shapes shard_map hands it.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["Param", "param", "unzip", "values_of", "specs_of", "truncated_normal"]


@dataclasses.dataclass
class Param:
    value: jax.Array
    logical: tuple[str | None, ...]

    def __post_init__(self):
        if hasattr(self.value, "ndim") and len(self.logical) != self.value.ndim:
            raise ValueError(
                f"logical spec {self.logical} does not match shape {self.value.shape}"
            )


# Registered as a pytree node (logical spec as static aux data) so that
# jax.eval_shape can trace init functions abstractly — the dry-run builds
# 235B-param trees as ShapeDtypeStructs without allocating anything.
jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.logical),
    lambda aux, ch: Param(value=ch[0], logical=aux),
)


def param(value: jax.Array, *logical: str | None) -> Param:
    return Param(value=value, logical=tuple(logical))


def _is_param(x) -> bool:
    return isinstance(x, Param)


def unzip(tree: PyTree) -> tuple[PyTree, PyTree]:
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_param)
    specs = jax.tree.map(lambda p: p.logical, tree, is_leaf=_is_param)
    return values, specs


def values_of(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda p: p.value, tree, is_leaf=_is_param)


def specs_of(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda p: p.logical, tree, is_leaf=_is_param)


def truncated_normal(key, shape, stddev, dtype) -> jax.Array:
    # fan-in scaled init; truncation at 2σ like flax.linen default
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev
    return x.astype(dtype)
