"""Transformer assembly: heterogeneous layer patterns, scan-over-periods with
remat, KV/recurrent caches, encoder-decoder support.

Layer layout: ``cfg.attn_pattern`` is cycled over ``num_layers``.  Layers are
grouped into PERIODS (one full cycle); all full periods are stacked and run
under one ``jax.lax.scan`` (compile time stays O(period), crucial for the
94-layer MoE dry-runs at 512 devices); the remainder (num_layers % period)
is unrolled.

Per-layer caches are pytrees stacked along the scan dim and threaded through
the scan as xs/ys.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssd as ssd_lib
from repro.models.attention import AttnCache
from repro.models.common import Param
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.parallel.sharding import ShardCtx

PyTree = Any


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------


def init_block(key, cfg, kind: str, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    p: dict = {"ln1": init_norm(cfg, cfg.d_model)}
    if kind in ("global", "local", "encoder"):
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
    elif kind == "rglru":
        p["mixer"] = rglru_lib.init_rglru(ks[0], cfg)
    elif kind == "ssd":
        p["mixer"] = ssd_lib.init_ssd(ks[0], cfg)
    else:  # pragma: no cover
        raise ValueError(kind)
    if cross:
        p["ln_cross"] = init_norm(cfg, cfg.d_model)
        p["cross_attn"] = attn_lib.init_attention(ks[1], cfg, cross=True)
    if cfg.d_ff > 0 or cfg.arch_type == "moe":
        p["ln2"] = init_norm(cfg, cfg.d_model)
        if cfg.arch_type == "moe":
            p["moe"] = moe_lib.init_moe(ks[2], cfg)
        else:
            p["mlp"] = init_mlp(ks[2], cfg)
    return p


def _split_seq(x: jax.Array, ctx: ShardCtx) -> tuple[jax.Array, bool]:
    """Slice the local sequence chunk out of a model-axis-replicated tensor
    (free — no collective) so MoE dispatch buffers stay small."""
    s = x.shape[1]
    if ctx.model_axis is None or ctx.tp == 1 or s % ctx.tp != 0 or s < ctx.tp:
        return x, False
    loc = s // ctx.tp
    start = ctx.model_index() * loc
    return jax.lax.dynamic_slice_in_dim(x, start, loc, 1), True


def apply_block(
    p: dict,
    cfg,
    x: jax.Array,
    ctx: ShardCtx,
    kind: str,
    *,
    positions: jax.Array | None = None,
    cache: PyTree | None = None,
    cross_cache: AttnCache | None = None,
    enc_out: jax.Array | None = None,
    decode: bool = False,
    paged: attn_lib.PagedView | None = None,
    chunk_lengths: jax.Array | None = None,
    chunk_exact: bool = False,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    """Pre-norm block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["ln1"], x)

    if kind in ("global", "local"):
        mode = "local" if kind == "local" else "causal"
        y, new_cache = attn_lib.apply_attention(
            p["attn"], cfg, h, ctx, mode=mode, positions=positions, cache=cache,
            paged=paged, decode=decode,
            chunk_lengths=chunk_lengths, chunk_exact=chunk_exact,
        )
    elif kind == "encoder":  # bidirectional self-attention (whisper encoder)
        y, new_cache = attn_lib.apply_attention(
            p["attn"], cfg, h, ctx, mode="full", positions=positions, cache=None
        )
    elif kind == "rglru":
        y, new_cache = rglru_lib.apply_rglru(
            p["mixer"], cfg, h, ctx, cache=cache,
            chunk_lengths=chunk_lengths, chunk_exact=chunk_exact,
        )
    elif kind == "ssd":
        y, new_cache = ssd_lib.apply_ssd(
            p["mixer"], cfg, h, ctx, cache=cache,
            chunk_lengths=chunk_lengths, chunk_exact=chunk_exact,
        )
    else:  # pragma: no cover
        raise ValueError(kind)
    x = x + y

    if "cross_attn" in p:
        h = apply_norm(p["ln_cross"], x)
        if enc_out is not None and cross_cache is not None:
            # PREFILL with a cache: build the encoder K/V cache now; decode
            # steps (enc_out=None) then reuse it read-only.
            cross_cache = attn_lib.build_cross_cache(p["cross_attn"], cfg, enc_out, ctx)
        y, cross_cache = attn_lib.apply_attention(
            p["cross_attn"], cfg, h, ctx, mode="full",
            positions=positions, kv_source=enc_out, cache=cross_cache,
        )
        x = x + y

    if "moe" in p:
        h = apply_norm(p["ln2"], x)
        h_loc, did_split = _split_seq(h, ctx)
        y, aux = moe_lib.apply_moe(p["moe"], cfg, h_loc, ctx)
        if did_split:
            y = ctx.all_gather_model(y, axis=1)
        x = x + y
    elif "mlp" in p:
        h = apply_norm(p["ln2"], x)
        x = x + apply_mlp(p["mlp"], cfg, h, ctx)

    return x, (new_cache, cross_cache), aux


# ---------------------------------------------------------------------------
# Stacked layers: scan over periods + unrolled remainder
# ---------------------------------------------------------------------------


def _stack_trees(trees: list[PyTree]) -> PyTree:
    def stack(*leaves):
        if isinstance(leaves[0], Param):
            return Param(
                value=jnp.stack([l.value for l in leaves]),
                logical=(None,) + leaves[0].logical,
            )
        return jnp.stack(list(leaves))

    return jax.tree.map(stack, *trees, is_leaf=lambda x: isinstance(x, Param))


def layer_plan(cfg) -> tuple[tuple[str, ...], int, int]:
    """(period pattern, n_full periods, n remainder layers)."""
    period = cfg.attn_pattern
    n = len(period)
    return period, cfg.num_layers // n, cfg.num_layers % n


def init_stack(key, cfg, *, cross: bool = False) -> dict:
    period, n_full, rem = layer_plan(cfg)
    params: dict = {"scan": [], "rem": []}
    for pos, kind in enumerate(period):
        layers = [
            init_block(jax.random.fold_in(key, pos * 1000 + i), cfg, kind, cross=cross)
            for i in range(n_full)
        ]
        params["scan"].append(_stack_trees(layers) if n_full else None)
    for j in range(rem):
        kind = period[j]
        params["rem"].append(
            init_block(jax.random.fold_in(key, 999_000 + j), cfg, kind, cross=cross)
        )
    return params


def apply_stack(
    params: dict,
    cfg,
    x: jax.Array,
    ctx: ShardCtx,
    *,
    positions: jax.Array | None = None,
    caches: dict | None = None,
    enc_out: jax.Array | None = None,
    decode: bool = False,
    kinds: tuple[str, ...] | None = None,
    paged: attn_lib.PagedView | None = None,
    chunk_lengths: jax.Array | None = None,
    chunk_exact: bool = False,
) -> tuple[jax.Array, dict | None, jax.Array]:
    """Run all layers. ``caches`` mirrors the params structure:
    {"scan": [stacked cache per position], "rem": [cache per layer]}."""
    period, n_full, rem = layer_plan(cfg)
    if kinds is not None:
        period = kinds  # e.g. ("encoder",) for the whisper encoder
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: dict | None = {"scan": [], "rem": []} if caches is not None else None

    if n_full:
        def scan_body(h, slices):
            param_slices, cache_slices = slices
            new_slices = []
            aux_sum = jnp.zeros((), jnp.float32)
            for pos, kind in enumerate(period):
                c = cache_slices[pos] if cache_slices is not None else None
                cc = c[1] if c is not None else None
                c0 = c[0] if c is not None else None
                h, nc, aux = apply_block(
                    param_slices[pos], cfg, h, ctx, kind,
                    positions=positions,
                    cache=c0,
                    cross_cache=cc,
                    enc_out=enc_out,
                    decode=decode,
                    paged=paged,  # scan closure constant (shared by layers)
                    chunk_lengths=chunk_lengths,
                    chunk_exact=chunk_exact,
                )
                aux_sum = aux_sum + aux
                new_slices.append(nc)
            return h, (tuple(new_slices), aux_sum)

        body = scan_body
        if cfg.remat and caches is None:
            body = jax.checkpoint(scan_body, prevent_cse=False)

        param_stacks = tuple(params["scan"][pos] for pos in range(len(period)))
        cache_stacks = (
            tuple(caches["scan"][pos] for pos in range(len(period)))
            if caches is not None
            else None
        )
        xs = (param_stacks, cache_stacks)
        x, (cache_out, auxs) = jax.lax.scan(body, x, xs, unroll=cfg.unroll_scans)
        aux_total = aux_total + jnp.sum(auxs)
        if new_caches is not None:
            new_caches["scan"] = list(cache_out)

    for j in range(rem):
        kind = period[j % len(period)]
        c = caches["rem"][j] if caches is not None else None
        cc = c[1] if c is not None else None
        c0 = c[0] if c is not None else None
        x, nc, aux = apply_block(
            params["rem"][j], cfg, x, ctx, kind,
            positions=positions, cache=c0, cross_cache=cc,
            enc_out=enc_out, decode=decode, paged=paged,
            chunk_lengths=chunk_lengths, chunk_exact=chunk_exact,
        )
        aux_total = aux_total + aux
        if new_caches is not None:
            new_caches["rem"].append(nc)

    return x, new_caches, aux_total
