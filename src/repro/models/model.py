"""Top-level model API: init / loss / prefill / decode for every assigned
architecture, driven entirely by :class:`ModelConfig`.

Batches are plain dicts:
    tokens          (B, S)   int32
    labels          (B, S)   int32            (training)
    loss_mask       (B, S)   bool, optional
    encoder_embeds  (B, enc_seq, frontend_dim)  — whisper STUB frontend
    image_embeds    (B, n_patches, frontend_dim) — internvl2 STUB frontend

Frontends are STUBS per the assignment carve-out: ``input_specs`` provides
precomputed frame/patch embeddings; this module only owns the projector that
maps them into d_model and the decoder that consumes them.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.attention import AttnCache, PagedAttnCache, PagedView
from repro.models.common import Param, param, truncated_normal, unzip, values_of
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_norm,
    cross_entropy_parts,
    embed_tokens,
    init_embedding,
    init_norm,
    logits_sharded,
    sinusoidal_positions,
)
from repro.models.rglru import RGLRUCache
from repro.models.ssd import SSDCache, d_inner, num_heads_ssm
from repro.parallel.sharding import ShardCtx

PyTree = Any

LOSS_CHUNK = 2048  # seq chunk for the memory-bounded LM loss


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(
        cfg,
        num_layers=cfg.num_encoder_layers,
        attn_pattern=("encoder",),
        arch_type="dense",
        use_rope=False,
    )


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    """GLOBAL-shape Param tree (use common.unzip to split values/specs)."""
    cfg.validate()
    ks = jax.random.split(key, 5)
    p: dict = {
        "embed": init_embedding(ks[0], cfg),
        "stack": tfm.init_stack(ks[1], cfg, cross=cfg.is_encoder_decoder),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if cfg.is_encoder_decoder:
        ecfg = encoder_cfg(cfg)
        p["encoder"] = tfm.init_stack(ks[2], ecfg)
        p["enc_norm"] = init_norm(cfg, cfg.d_model)
        if cfg.frontend_dim and cfg.frontend_dim != cfg.d_model:
            p["enc_proj"] = param(
                truncated_normal(
                    ks[3], (cfg.frontend_dim, cfg.d_model),
                    1.0 / math.sqrt(cfg.frontend_dim), jnp.dtype(cfg.dtype),
                ),
                "fsdp", None,
            )
    if cfg.frontend == "vision":
        p["projector"] = param(
            truncated_normal(
                ks[4], (cfg.frontend_dim, cfg.d_model),
                1.0 / math.sqrt(cfg.frontend_dim), jnp.dtype(cfg.dtype),
            ),
            "fsdp", None,
        )
    return p


# ---------------------------------------------------------------------------
# Forward pieces
# ---------------------------------------------------------------------------


def encode(p: PyTree, cfg: ModelConfig, encoder_embeds: jax.Array, ctx: ShardCtx) -> jax.Array:
    """Whisper encoder over STUB frame embeddings."""
    x = encoder_embeds
    if "enc_proj" in p:
        x = x @ ctx.gather_param(p["enc_proj"], axis=0)
    x = x.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    ecfg = encoder_cfg(cfg)
    x, _, _ = tfm.apply_stack(p["encoder"], ecfg, x, ctx, kinds=("encoder",))
    return apply_norm(p["enc_norm"], x)


def embed_input(
    p: PyTree, cfg: ModelConfig, batch: dict, ctx: ShardCtx
) -> tuple[jax.Array, jax.Array | None]:
    """Token (+frontend) embedding. Returns (x, loss_mask_extra)."""
    tokens = batch["tokens"]
    x = embed_tokens(p["embed"], cfg, tokens, ctx)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    mask_extra = None
    if cfg.frontend == "vision" and "image_embeds" in batch:
        img = batch["image_embeds"] @ ctx.gather_param(p["projector"], axis=0)
        img = img.astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
        b = tokens.shape[0]
        mask_extra = jnp.concatenate(
            [
                jnp.zeros((b, img.shape[1]), bool),
                jnp.ones((b, tokens.shape[1]), bool),
            ],
            axis=1,
        )
    if not cfg.use_rope:  # absolute sinusoidal positions (whisper decoder)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    return x, mask_extra


def _lm_loss(
    p: PyTree, cfg: ModelConfig, x: jax.Array, labels: jax.Array,
    mask: jax.Array | None, ctx: ShardCtx,
) -> jax.Array:
    """Chunked-over-sequence LM loss: never materializes (B, S, V) logits."""
    b, s, d = x.shape
    if s <= LOSS_CHUNK or s % LOSS_CHUNK:
        logits = logits_sharded(p["embed"], cfg, x, ctx)
        nll, cnt = cross_entropy_parts(logits, labels, cfg, ctx, mask)
        return nll / jnp.maximum(cnt, 1.0)
    nc = s // LOSS_CHUNK
    xc = x.reshape(b, nc, LOSS_CHUNK, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, LOSS_CHUNK).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, LOSS_CHUNK).transpose(1, 0, 2) if mask is not None else None

    def body(carry, inp):
        if mc is None:
            xi, li = inp
            mi = None
        else:
            xi, li, mi = inp
        logits = logits_sharded(p["embed"], cfg, xi, ctx)
        nll, cnt = cross_entropy_parts(logits, li, cfg, ctx, mi)
        # rank-1 carry: old-jax shard_map's transpose rejects rank-0 avals
        # crossing a scan inside the body (parallel/compat.py notes)
        return carry + jnp.stack([nll, cnt]), None

    xs = (xc, lc) if mc is None else (xc, lc, mc)
    sums, _ = jax.lax.scan(
        body, jnp.zeros((2,)), xs, unroll=cfg.unroll_scans
    )
    return sums[0] / jnp.maximum(sums[1], 1.0)


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------


def loss_fn(
    params: PyTree, cfg: ModelConfig, batch: dict, ctx: ShardCtx, rng: jax.Array | None = None
) -> tuple[jax.Array, dict]:
    """Next-token LM loss (+ MoE aux).  ``params`` is a VALUE tree."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["encoder_embeds"], ctx)

    x, mask_extra = embed_input(params, cfg, batch, ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, aux = tfm.apply_stack(
        params["stack"], cfg, x, ctx, positions=positions, enc_out=enc_out
    )
    x = apply_norm(params["final_norm"], x)

    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask_extra is not None:
        # frontend tokens predict nothing; align labels with text positions
        pad = jnp.zeros((labels.shape[0], mask_extra.shape[1] - labels.shape[1]), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        mask = mask_extra if mask is None else jnp.concatenate([pad.astype(bool), mask], axis=1)

    loss = _lm_loss(params, cfg, x, labels, mask, ctx)
    total = loss + aux
    return total, {"lm_loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Caches / serving
# ---------------------------------------------------------------------------


def _mixer_cache(cfg: ModelConfig, kind: str, batch: int, length: int):
    """Param-annotated cache for one layer (GLOBAL shapes; logical specs:
    "dp" batch dim, "seq_kv" sequence dim, "tp" width/head dims)."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    if kind in ("global", "local"):
        size = min(length, cfg.sliding_window) if kind == "local" else length
        seq_logical = "seq_kv" if kind == "global" else None
        return AttnCache(
            k=param(jnp.zeros((batch, size, kv, hd), dt), "dp", seq_logical, None, None),
            v=param(jnp.zeros((batch, size, kv, hd), dt), "dp", seq_logical, None, None),
            index=param(jnp.zeros((), jnp.int32)),
        )
    if kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return RGLRUCache(
            conv=param(jnp.zeros((batch, 3, w), dt), "dp", None, "tp"),
            h=param(jnp.zeros((batch, w), jnp.float32), "dp", "tp"),
        )
    if kind == "ssd":
        h = num_heads_ssm(cfg)
        return SSDCache(
            conv=param(jnp.zeros((batch, cfg.ssm_conv_width - 1, d_inner(cfg)), dt), "dp", None, "tp"),
            state=param(
                jnp.zeros((batch, h, cfg.ssm_head_dim, cfg.ssm_state_dim), jnp.float32),
                "dp", "tp", None, None,
            ),
        )
    raise ValueError(kind)  # pragma: no cover


def init_cache_tree(cfg: ModelConfig, batch: int, length: int) -> dict:
    """Param-annotated cache tree mirroring the stack structure."""
    period, n_full, rem = tfm.layer_plan(cfg)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)

    def one(kind):
        mixer = _mixer_cache(cfg, kind, batch, length)
        cross = None
        if cfg.is_encoder_decoder:
            cross = AttnCache(
                k=param(jnp.zeros((batch, cfg.encoder_seq, kv, hd), dt), "dp", None, None, None),
                v=param(jnp.zeros((batch, cfg.encoder_seq, kv, hd), dt), "dp", None, None, None),
                index=param(jnp.zeros((), jnp.int32)),
            )
        return (mixer, cross)

    caches: dict = {"scan": [], "rem": []}
    for pos, kind in enumerate(period):
        layers = [one(kind) for _ in range(n_full)]
        caches["scan"].append(tfm._stack_trees(layers) if n_full else None)
    for j in range(rem):
        caches["rem"].append(one(period[j]))
    return caches


def prefill(
    params: PyTree, cfg: ModelConfig, batch: dict, caches: PyTree, ctx: ShardCtx
) -> tuple[jax.Array, PyTree]:
    """Fill caches from a full prompt; returns (last-position hidden, caches)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch["encoder_embeds"], ctx)
    x, _ = embed_input(params, cfg, batch, ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, new_caches, _ = tfm.apply_stack(
        params["stack"], cfg, x, ctx, positions=positions,
        caches=caches, enc_out=enc_out,
    )
    x = apply_norm(params["final_norm"], x)
    return x[:, -1:], new_caches


def init_paged_cache_tree(
    cfg: ModelConfig, num_slots: int, num_pages: int, page_size: int
) -> dict:
    """Serving cache tree: paged K/V pools for attention layers (shared
    across request slots, + trash page), per-slot recurrent state for
    RG-LRU/SSD layers.  Plain arrays (single-host serving — no shard specs).

    Encoder-decoder and vision-frontend archs are not servable through the
    paged engine (their prompts are not plain token streams)."""
    if cfg.is_encoder_decoder or cfg.frontend == "vision":
        raise ValueError(
            "paged serving supports decoder-only token models; "
            f"got frontend={cfg.frontend!r} enc-dec={cfg.is_encoder_decoder}"
        )
    period, n_full, rem = tfm.layer_plan(cfg)
    dt = jnp.dtype(cfg.dtype)

    def one(kind):
        if kind in ("global", "local"):
            mixer = PagedAttnCache.init(cfg, num_pages, page_size)
        elif kind == "rglru":
            mixer = RGLRUCache.init(cfg, num_slots, cfg.lru_width or cfg.d_model, dt)
        elif kind == "ssd":
            mixer = SSDCache.init(cfg, num_slots, d_inner(cfg), num_heads_ssm(cfg), dt)
        else:  # pragma: no cover
            raise ValueError(kind)
        return (mixer, None)

    caches: dict = {"scan": [], "rem": []}
    for pos, kind in enumerate(period):
        layers = [one(kind) for _ in range(n_full)]
        caches["scan"].append(tfm._stack_trees(layers) if n_full else None)
    for j in range(rem):
        caches["rem"].append(one(period[j]))
    return caches


def paged_prefill(
    params: PyTree, cfg: ModelConfig, tokens: jax.Array, caches: PyTree,
    view: PagedView, ctx: ShardCtx,
) -> tuple[jax.Array, PyTree]:
    """Prefill ONE request (tokens (1, S)) into the paged caches.

    ``view.block_tables`` is the single (1, MB) row of the slot being filled;
    attention scatters every prompt token's K/V into those pages while the
    attention itself runs over the fresh K/V (dispatched flash kernel,
    canonical positions).  Recurrent caches in ``caches`` must be batch-1
    scratch (the engine merges the final states into the slot afterwards).
    Returns (vocab-LOCAL logits of the last prompt position (1, 1, V/tp),
    new caches)."""
    x = embed_tokens(params["embed"], cfg, tokens, ctx)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if not cfg.use_rope:
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, new_caches, _ = tfm.apply_stack(
        params["stack"], cfg, x, ctx, positions=positions,
        caches=caches, paged=view,
    )
    x = apply_norm(params["final_norm"], x)
    return logits_sharded(params["embed"], cfg, x[:, -1:], ctx), new_caches


def paged_prefill_chunk(
    params: PyTree, cfg: ModelConfig, tokens: jax.Array, caches: PyTree,
    view: PagedView, ctx: ShardCtx, *, lengths: jax.Array, collect: bool = False,
) -> tuple[jax.Array, PyTree]:
    """One CHUNK of prefill for all R slots at once: tokens (R, C), with slot
    r's chunk starting at absolute position ``view.positions[r]`` and only its
    first ``lengths[r]`` tokens real (ragged tails scatter to the trash page
    and compute discarded garbage).  Recurrent caches must carry the states
    as of position ``view.positions[r]`` — chunk boundaries resume exactly.

    One fixed-C program serves every prompt-length mix; the engine walks long
    prompts through repeated calls, bumping ``view.positions`` by ``lengths``.

    ``collect=False`` (prefill): returns (vocab-LOCAL logits of each slot's
    LAST VALID position (R, 1, V/tp), new caches with carried final states).
    ``collect=True`` (speculative verify): attention + recurrences run
    per-token BITWISE-identical to decode steps, and returns (logits for all
    C positions (R, C, V/tp), caches whose recurrent leaves carry the full
    per-token state trajectory (B, C, ...) for accept-prefix selection)."""
    x = embed_tokens(params["embed"], cfg, tokens, ctx)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    positions = view.positions[:, None] + jnp.arange(tokens.shape[1], dtype=jnp.int32)[None]
    if not cfg.use_rope:
        table = sinusoidal_positions(2**15, cfg.d_model).astype(x.dtype)
        x = x + jnp.take(table, jnp.clip(positions, 0, 2**15 - 1), axis=0)
    x, new_caches, _ = tfm.apply_stack(
        params["stack"], cfg, x, ctx, positions=positions,
        caches=caches, paged=view, chunk_lengths=lengths, chunk_exact=collect,
    )
    x = apply_norm(params["final_norm"], x)
    if collect:
        return logits_sharded(params["embed"], cfg, x, ctx), new_caches
    sel = jnp.clip(lengths - 1, 0, x.shape[1] - 1)[:, None, None]
    x_last = jnp.take_along_axis(
        x, jnp.broadcast_to(sel, (x.shape[0], 1, x.shape[2])), axis=1
    )
    return logits_sharded(params["embed"], cfg, x_last, ctx), new_caches


def paged_decode_step(
    params: PyTree, cfg: ModelConfig, tokens: jax.Array, caches: PyTree,
    view: PagedView, ctx: ShardCtx,
) -> tuple[jax.Array, PyTree]:
    """One decode step for ALL request slots at once: tokens (R, 1), per-slot
    positions/activity in ``view``.  Inactive slots compute garbage that goes
    to the trash page / gets overwritten at admission — no conditionals in
    the hot path.  Returns (vocab-LOCAL logits (R, 1, V/tp), new caches)."""
    x = embed_tokens(params["embed"], cfg, tokens, ctx)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if not cfg.use_rope:
        table = sinusoidal_positions(2**15, cfg.d_model).astype(x.dtype)
        rows = jnp.take(table, jnp.clip(view.positions, 0, 2**15 - 1), axis=0)
        x = x + rows[:, None]
    x, new_caches, _ = tfm.apply_stack(
        params["stack"], cfg, x, ctx, positions=view.positions[:, None],
        caches=caches, decode=True, paged=view,
    )
    x = apply_norm(params["final_norm"], x)
    return logits_sharded(params["embed"], cfg, x, ctx), new_caches


def decode_step(
    params: PyTree, cfg: ModelConfig, tokens: jax.Array, index: jax.Array,
    caches: PyTree, ctx: ShardCtx,
) -> tuple[jax.Array, PyTree]:
    """One-token decode: tokens (B, 1), index = #tokens already in cache.
    Returns (vocab-LOCAL logits (B, 1, V/tp), new caches)."""
    x = embed_tokens(params["embed"], cfg, tokens, ctx)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if not cfg.use_rope:
        table = sinusoidal_positions(2**15, cfg.d_model).astype(x.dtype)
        row = jax.lax.dynamic_slice_in_dim(table, jnp.clip(index, 0, 2**15 - 1), 1, 0)
        x = x + row[None]
    positions = index[None] if index.ndim == 0 else index
    x, new_caches, _ = tfm.apply_stack(
        params["stack"], cfg, x, ctx, positions=positions,
        caches=caches, decode=True,
    )
    x = apply_norm(params["final_norm"], x)
    return logits_sharded(params["embed"], cfg, x, ctx), new_caches
