"""Norms, positional encodings, MLPs and (vocab-sharded) embeddings.

Init functions build GLOBAL-shape :class:`Param` trees with logical sharding
annotations; apply functions operate on whatever LOCAL shards ``shard_map``
hands them, using :class:`ShardCtx` for the collectives.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import Param, param, truncated_normal
from repro.parallel.sharding import ShardCtx

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg, dim: int) -> dict:
    p = {"scale": param(jnp.ones((dim,), jnp.float32), None)}
    if cfg.norm_type == "layernorm":
        p["bias"] = param(jnp.zeros((dim,), jnp.float32), None)
    return p


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-math.log(10_000.0) * jnp.arange(dim // 2, dtype=jnp.float32) / max(dim // 2 - 1, 1))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_mlp(key, cfg, d_model: int | None = None, d_ff: int | None = None) -> dict:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.mlp_variant in ("swiglu", "geglu")
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(f)
    p = {
        "w_in": param(truncated_normal(k1, (d, f), std_in, _dtype(cfg)), "fsdp", "tp"),
        "w_out": param(truncated_normal(k2, (f, d), std_out, _dtype(cfg)), "tp", "fsdp"),
    }
    if gated:
        p["w_gate"] = param(truncated_normal(k3, (d, f), std_in, _dtype(cfg)), "fsdp", "tp")
    return p


def apply_mlp(p: dict, cfg, x: jax.Array, ctx: ShardCtx) -> jax.Array:
    """Column-parallel in, row-parallel out; psum (or reduce-scatter under
    sequence parallelism) at the end."""
    w_in = ctx.gather_param(p["w_in"], axis=0)
    w_out = ctx.gather_param(p["w_out"], axis=1)
    h = x @ w_in
    if cfg.mlp_variant == "swiglu":
        w_gate = ctx.gather_param(p["w_gate"], axis=0)
        h = jax.nn.silu(x @ w_gate) * h
    elif cfg.mlp_variant == "geglu":
        w_gate = ctx.gather_param(p["w_gate"], axis=0)
        h = jax.nn.gelu(x @ w_gate, approximate=True) * h
    elif cfg.mlp_variant == "relu2":  # nemotron/minitron squared ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h, approximate=True)
    y = h @ w_out  # partial sum over tp shards of f
    return ctx.scatter_seq_sum(y, axis=x.ndim - 2)


# ---------------------------------------------------------------------------
# Embedding (vocab-sharded) and logits
# ---------------------------------------------------------------------------


def init_embedding(key, cfg) -> dict:
    std = 1.0 / math.sqrt(cfg.d_model)
    emb = truncated_normal(key, (cfg.vocab_size, cfg.d_model), std, jnp.float32)
    p = {"table": param(emb.astype(_dtype(cfg)), "tp", "fsdp")}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        w = truncated_normal(k2, (cfg.d_model, cfg.vocab_size), std, _dtype(cfg))
        p["unembed"] = param(w, "fsdp", "tp")
    return p


def embed_tokens(p: dict, cfg, tokens: jax.Array, ctx: ShardCtx) -> jax.Array:
    """Vocab-sharded lookup: each model shard owns a contiguous vocab slice;
    out-of-range tokens contribute zero and a psum combines the slices."""
    table = ctx.gather_param(p["table"], axis=1)  # ZeRO-3 gathers d, not vocab
    vt = ctx.vocab_tp(cfg.vocab_size)
    if vt == 1:
        return jnp.take(table, tokens, axis=0)
    shard = ctx.model_index()
    vloc = cfg.vocab_size // vt
    start = shard * vloc
    local = tokens - start
    in_range = (local >= 0) & (local < vloc)
    local = jnp.clip(local, 0, vloc - 1)
    out = jnp.take(table, local, axis=0)
    out = jnp.where(in_range[..., None], out, 0)
    return ctx.psum_model(out)


def logits_sharded(p: dict, cfg, x: jax.Array, ctx: ShardCtx) -> jax.Array:
    """Returns vocab-LOCAL logits (..., V/tp). Softmax/loss must psum."""
    if cfg.tie_embeddings:
        table = ctx.gather_param(p["table"], axis=1)
        w = table.T  # (d, V_local)
    else:
        w = ctx.gather_param(p["unembed"], axis=0)
    logits = (x @ w).astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def cross_entropy_parts(
    logits_local: jax.Array, labels: jax.Array, cfg, ctx: ShardCtx, mask: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """(sum of token NLL, token count) from vocab-sharded logits.

    Stable log-softmax with cross-shard max (pmax) and sum (psum); label hit
    is looked up in the local vocab slice and psum'd."""
    vt = ctx.vocab_tp(cfg.vocab_size)
    # stability max: constant w.r.t. differentiation (log-sum-exp grads are
    # exact with a stop_gradient'ed max; pmax has no transpose rule anyway)
    m = jax.lax.stop_gradient(jnp.max(logits_local, axis=-1, keepdims=True))
    m = ctx.pmax_model(m)
    ex = jnp.exp(logits_local - m)
    denom = ctx.psum_model(jnp.sum(ex, axis=-1))  # (...,)

    if vt == 1:
        hit = jnp.take_along_axis(logits_local, labels[..., None], axis=-1)[..., 0]
    else:
        shard = ctx.model_index()
        vloc = logits_local.shape[-1]
        local = labels - shard * vloc
        in_range = (local >= 0) & (local < vloc)
        local = jnp.clip(local, 0, vloc - 1)
        hit = jnp.take_along_axis(logits_local, local[..., None], axis=-1)[..., 0]
        hit = ctx.psum_model(jnp.where(in_range, hit, 0.0))

    nll = jnp.log(denom) + m[..., 0] - hit
    if mask is None:
        return jnp.sum(nll), jnp.asarray(nll.size, jnp.float32)
    w = mask.astype(jnp.float32)
    return jnp.sum(nll * w), jnp.sum(w)


def cross_entropy_sharded(
    logits_local: jax.Array, labels: jax.Array, cfg, ctx: ShardCtx, mask: jax.Array | None = None
) -> jax.Array:
    """Mean token NLL from vocab-sharded logits."""
    s, n = cross_entropy_parts(logits_local, labels, cfg, ctx, mask)
    return s / jnp.maximum(n, 1.0)
