"""Attention: GQA/MQA/MHA with qk-norm, RoPE, causal / sliding-window / cross
modes, dispatched kernels for training/prefill, and KV caches for decode.

Kernel routing (see DESIGN.md §6): the training / encoder / prefill paths —
canonical ``arange`` positions, no cache reads — go through
``repro.kernels.ops.flash_attention`` (Pallas flash kernel or its jnp
online-softmax twin per ``cfg.kernels``, differentiable via ``custom_vjp``).
The cache-dependent paths (decode over ring buffers / sequence-sharded
caches, flash-decode stats combine) keep the positions-aware
:func:`blockwise_attention` below.

Tensor parallelism: q heads are sharded over the model axis (when divisible —
see ``ShardCtx.heads_tp``); K/V projections are small (num_kv_heads × head_dim)
and are REPLICATED across model shards, which is the standard GQA-under-TP
choice: attention itself then needs no collective, only the output projection
psum (row-parallel).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.models.common import param, truncated_normal
from repro.models.layers import apply_rope
from repro.parallel.sharding import ShardCtx

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg, *, cross: bool = False) -> dict:
    d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "w_q": param(truncated_normal(ks[0], (d, h, hd), std, dt), "fsdp", "tp", None),
        "w_k": param(truncated_normal(ks[1], (d, kv, hd), std, dt), "fsdp", None, None),
        "w_v": param(truncated_normal(ks[2], (d, kv, hd), std, dt), "fsdp", None, None),
        "w_o": param(
            truncated_normal(ks[3], (h, hd, d), 1.0 / math.sqrt(h * hd), dt),
            "tp",
            None,
            "fsdp",
        ),
    }
    if cfg.qk_norm:
        p["q_norm"] = param(jnp.ones((hd,), jnp.float32), None)
        p["k_norm"] = param(jnp.ones((hd,), jnp.float32), None)
    return p


def _rms(x, scale, eps=1e-6):
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention in pure jnp — O(S) memory
# ---------------------------------------------------------------------------


def _mask_block(mode, q_pos, kv_pos, window):
    """(Bq, Bk) additive mask block from absolute positions.

    Negative kv positions mark padding / not-yet-written cache slots and are
    NEVER valid (a plain ``kp <= qp`` would let −1e9 sentinels through as
    zero-logit keys and pollute the softmax denominator)."""
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    alive = kp >= 0
    if mode == "full":
        valid = alive & jnp.ones(qp.shape[:1] + kp.shape[1:], bool)
    elif mode == "causal":
        valid = alive & (kp <= qp)
    elif mode == "local":
        valid = alive & (kp <= qp) & (kp > qp - window)
    else:  # pragma: no cover
        raise ValueError(mode)
    return jnp.where(valid, 0.0, NEG_INF)


@partial(jax.jit, static_argnames=("mode", "window", "block_kv", "return_stats", "unroll"))
def blockwise_attention(
    q: jax.Array,        # (B, Sq, H, D)
    k: jax.Array,        # (B, Sk, H, D)  — kv heads already expanded to H
    v: jax.Array,        # (B, Sk, H, D)
    q_positions: jax.Array,   # (Sq,) absolute positions
    kv_positions: jax.Array,  # (Sk,)
    *,
    mode: str = "causal",
    window: int = 0,
    block_kv: int = 1024,
    return_stats: bool = False,
    unroll: bool = False,
) -> jax.Array | tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax attention scanned over KV blocks — the positions-aware
    variant used by the decode/cache paths.  kernels/ref.jnp_flash_attention
    is the grouped canonical-positions twin of this same m/l/acc recurrence;
    a fix to the numerics here (sentinels, l==0 guard, corr rescale) must be
    mirrored there.

    With ``return_stats`` the UNNORMALIZED accumulator and the (m, l) softmax
    stats are returned — used by the sequence-sharded ("flash-decode") cache
    path to combine partial attention across model shards with a psum."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    q32 = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,Sq,D)

    nblk = max(1, math.ceil(sk / block_kv))
    pad = nblk * block_kv - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-(10**9))
    kb = k.reshape(b, nblk, block_kv, h, d).transpose(1, 0, 3, 2, 4)  # (n,B,H,Bk,D)
    vb = v.reshape(b, nblk, block_kv, h, d).transpose(1, 0, 3, 2, 4)
    pb = kv_positions.reshape(nblk, block_kv)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, kpos = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kblk.astype(jnp.float32))
        s = s + _mask_block(mode, q_positions, kpos, window)[None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, h, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        jnp.zeros((b, h, sq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, pb), unroll=unroll)
    if return_stats:
        return acc, m, l  # (B,H,Sq,D), (B,H,Sq), (B,H,Sq)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,D)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AttnCache:
    """Decode cache. For "global" layers ``k/v`` hold the full context
    (B, S_max, KV, D); for "local" layers they are a ring buffer of size
    (B, window, KV, D) written at ``index % window``."""

    k: jax.Array
    v: jax.Array
    index: jax.Array  # scalar int32: number of tokens already cached

    @staticmethod
    def init(cfg, batch: int, length: int, mode: str) -> "AttnCache":
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        size = min(length, cfg.sliding_window) if mode == "local" else length
        dt = jnp.dtype(cfg.dtype)
        return AttnCache(
            k=jnp.zeros((batch, size, kv, hd), dt),
            v=jnp.zeros((batch, size, kv, hd), dt),
            index=jnp.zeros((), jnp.int32),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedAttnCache:
    """Serving KV cache: a pool of fixed-size pages shared by all request
    slots, addressed through the per-slot block tables in :class:`PagedView`.

    ``k_pages``/``v_pages`` are (num_pages + 1, page_size, KV, D); the LAST
    page is the TRASH page — decode steps of inactive slots redirect their
    masked writes there, so one fully-batched scatter serves every slot
    without conditionals and without corrupting live pages.  Trash contents
    are never read: the positional mask (key pos <= slot pos) rejects any
    page entry past a request's context, and inactive slots' outputs are
    discarded by the engine."""

    k_pages: jax.Array
    v_pages: jax.Array

    @staticmethod
    def init(cfg, num_pages: int, page_size: int) -> "PagedAttnCache":
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        return PagedAttnCache(
            k_pages=jnp.zeros((num_pages + 1, page_size, kv, hd), dt),
            v_pages=jnp.zeros((num_pages + 1, page_size, kv, hd), dt),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedView:
    """Per-step view of the paged cache, shared by every attention layer
    (block tables are layer-independent: all layers of one request use the
    same logical→physical page mapping, each layer owning its own pools).

    ``block_tables`` (R, MB) int32 — physical page id of each slot's logical
    block (rows beyond a request's allocation may hold stale ids; positional
    masking makes them unreachable).  ``positions`` (R,) int32 — index of the
    token being processed this step.  ``active`` (R,) bool — slots currently
    owning a request; inactive slots write to the trash page."""

    block_tables: jax.Array
    positions: jax.Array
    active: jax.Array


def _expand_kv(x: jax.Array, head_map: jax.Array) -> jax.Array:
    """Gather the kv head per (local) q head: (B,S,KV,D) -> (B,S,Hl,D)."""
    return jnp.take(x, head_map, axis=2)


def _dispatched_attention(
    q: jax.Array,   # (B, Sq, H_local, D)
    k: jax.Array,   # (B, Sk, KV, D) — FULL (replicated) kv heads
    v: jax.Array,   # (B, Sk, KV, D)
    cfg,
    ctx: ShardCtx,
    tp_h: int,
    *,
    mode: str,
    window: int,
) -> jax.Array:
    """Training / encoder / prefill attention through the kernel-dispatch
    layer (:func:`repro.kernels.ops.flash_attention` — Pallas or jnp twin per
    ``cfg.kernels``, canonical arange positions).

    When whole GQA groups are shard-local, the kv heads serving this shard's
    query heads are sliced out so K/V stay at kv-head width all the way into
    the kernel; with partial groups per shard (rare) K/V are gathered to
    local-head width first.
    """
    h, kv = cfg.num_heads, cfg.num_kv_heads
    h_local = q.shape[2]
    g = h // kv if kv and h % kv == 0 else 0
    if g and h_local % g == 0:
        if tp_h > 1:
            kv_local = h_local // g
            start = ctx.model_index() * kv_local
            k = jax.lax.dynamic_slice_in_dim(k, start, kv_local, 2)
            v = jax.lax.dynamic_slice_in_dim(v, start, kv_local, 2)
    else:
        shard = ctx.model_index() if tp_h > 1 else jnp.zeros((), jnp.int32)
        global_heads = shard * h_local + jnp.arange(h_local)
        head_map = (global_heads * kv) // h
        k = _expand_kv(k, head_map)
        v = _expand_kv(v, head_map)
    return kernel_ops.flash_attention(
        q, k, v, mode=mode, window=window, unroll=cfg.unroll_scans,
        config=cfg.kernels,
    )


def build_cross_cache(p: dict, cfg, encoder_out: jax.Array, ctx: ShardCtx) -> AttnCache:
    """Precompute encoder K/V once for cross-attention decode (whisper)."""
    w_k = ctx.gather_param(p["w_k"], axis=0)
    w_v = ctx.gather_param(p["w_v"], axis=0)
    k = jnp.einsum("bsd,dhk->bshk", encoder_out, w_k)
    v = jnp.einsum("bsd,dhk->bshk", encoder_out, w_v)
    if cfg.qk_norm:
        k = _rms(k, p["k_norm"])
    return AttnCache(k=k, v=v, index=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Full attention block (projections + attention + out-proj)
# ---------------------------------------------------------------------------


def apply_attention(
    p: dict,
    cfg,
    x: jax.Array,             # (B, S, d)
    ctx: ShardCtx,
    *,
    mode: str = "causal",     # causal | local | full (cross / encoder self)
    positions: jax.Array | None = None,  # (S,) absolute positions of x
    kv_source: jax.Array | None = None,  # cross-attention encoder states
    cache: AttnCache | None = None,      # prefill (S>1) or decode (S==1)
    paged: PagedView | None = None,      # serving view (with PagedAttnCache)
    decode: bool = False,                # paged phase selector
    chunk_lengths: jax.Array | None = None,  # (R,) valid tokens per chunk row
    chunk_exact: bool = False,           # per-token decode-bitwise attention
) -> tuple[jax.Array, AttnCache | None]:
    """Attention block: projections + (cached) attention + output projection.

    Positions contract: the NO-CACHE and PREFILL paths assume CANONICAL
    positions (``positions[i] == i``) — they route through the dispatched
    kernel, whose causal/sliding masks are derived from row indices, while
    ``positions`` still drives RoPE.  Every current caller satisfies this
    (training, encoder, prefill all pass ``arange``); a future caller with
    offset/packed positions must use :func:`blockwise_attention` (which
    honors arbitrary position vectors) like the cache paths below do.

    Cache semantics:
      * ``cache is None``          — training / encoder forward.
      * ``cache`` and S > 1        — PREFILL: attention over the fresh K/V,
                                     then K/V written into the cache
                                     (sequence-sharded when ctx.kv_shard_seq).
      * ``cache`` and S == 1       — DECODE: append one token, attend over
                                     cache (flash-decode psum combine when the
                                     cache is sequence-sharded).
    """
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    tp_h = ctx.heads_tp(h)
    h_local = h // tp_h

    w_q = ctx.gather_param(p["w_q"], axis=0)
    w_o = ctx.gather_param(p["w_o"], axis=2)

    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)

    q = jnp.einsum("bsd,dhk->bshk", x, w_q)  # h is LOCAL when sharded
    if cfg.qk_norm:
        q = _rms(q, p["q_norm"])
    if cfg.use_rope and mode != "full":
        q = apply_rope(q, positions, cfg.rope_theta)

    # K/V of the *new* tokens.  For cross-attention with a cache the encoder
    # K/V were precomputed by build_cross_cache — skip the projections.
    reuse_cross = mode == "full" and cache is not None
    if not reuse_cross:
        w_k = ctx.gather_param(p["w_k"], axis=0)
        w_v = ctx.gather_param(p["w_v"], axis=0)
        kv_in = kv_source if kv_source is not None else x
        k = jnp.einsum("bsd,dhk->bshk", kv_in, w_k)  # kv heads replicated
        v = jnp.einsum("bsd,dhk->bshk", kv_in, w_v)
        if cfg.qk_norm:
            k = _rms(k, p["k_norm"])
        if cfg.use_rope and mode != "full":
            k = apply_rope(k, positions, cfg.rope_theta)

    shard = ctx.model_index() if tp_h > 1 else jnp.zeros((), jnp.int32)
    global_heads = shard * h_local + jnp.arange(h_local)
    head_map = (global_heads * kv) // h

    # =====================================================================
    # PAGED serving cache: page-pool scatter + block-table attention
    # =====================================================================
    if isinstance(cache, PagedAttnCache):
        if paged is None:
            raise ValueError("PagedAttnCache requires a PagedView")
        if tp_h > 1:
            raise NotImplementedError(
                "paged serving assumes unsharded attention heads (tp=1)"
            )
        window = cfg.sliding_window or 0
        trash = cache.k_pages.shape[0] - 1
        page_size = cache.k_pages.shape[1]
        mb = paged.block_tables.shape[1]
        if not decode and chunk_lengths is not None:
            # CHUNKED PREFILL / SPEC VERIFY: R slots × C tokens.  Token
            # (r, c) sits at absolute position paged.positions[r] + c and is
            # real iff c < chunk_lengths[r] on an active slot — ragged tails
            # and idle slots scatter to the trash page, and their output rows
            # are garbage the engine discards.
            base = paged.positions
            c_idx = jnp.arange(s, dtype=jnp.int32)[None, :]
            tok_pos = base[:, None] + c_idx                        # (R, C)
            valid = (c_idx < chunk_lengths[:, None]) & paged.active[:, None]
            blk = jnp.clip(tok_pos // page_size, 0, mb - 1)
            pages_idx = jnp.take_along_axis(paged.block_tables, blk, axis=1)
            pages_idx = jnp.where(valid, pages_idx, trash)         # (R, C)
            offs = tok_pos % page_size
            kp = cache.k_pages.at[pages_idx, offs].set(k)
            vp = cache.v_pages.at[pages_idx, offs].set(v)
            if chunk_exact:
                # Speculative verify: scan single-token paged attention over
                # the chunk so row c is BITWISE the decode step at base + c —
                # this is what makes accepted proposals exactly the tokens
                # non-speculative decode would have produced.
                def step(_, qc_pos):
                    qc, posc = qc_pos
                    out_c = kernel_ops.paged_attention(
                        qc, kp, vp, paged.block_tables, posc,
                        mode=mode, window=window, config=cfg.kernels,
                    )
                    return None, out_c

                _, out = jax.lax.scan(
                    step, None, (q.transpose(1, 0, 2, 3), tok_pos.T)
                )
                out = out.transpose(1, 0, 2, 3)
            else:
                out = kernel_ops.paged_chunk_attention(
                    q, kp, vp, paged.block_tables, base,
                    mode=mode, window=window, config=cfg.kernels,
                )
            return _out_proj(out, w_o, ctx, tp_h), PagedAttnCache(kp, vp)
        if not decode:
            # PREFILL (B == 1, canonical positions): attention over the fresh
            # K/V exactly like the dense prefill, then every prompt token's
            # K/V scattered into the slot's pages.
            out = _dispatched_attention(
                q, k, v, cfg, ctx, tp_h, mode=mode, window=window,
            )
            tok = jnp.arange(s, dtype=jnp.int32)
            pages_idx = paged.block_tables[0, tok // page_size]
            offs = tok % page_size
            kp = cache.k_pages.at[pages_idx, offs].set(k[0])
            vp = cache.v_pages.at[pages_idx, offs].set(v[0])
            return _out_proj(out, w_o, ctx, tp_h), PagedAttnCache(kp, vp)
        # DECODE: one token per slot — masked page scatter (inactive slots
        # redirect to the trash page) + the dispatched paged-attention kernel.
        pos = paged.positions
        blk = jnp.clip(pos // page_size, 0, mb - 1)
        pages_idx = jnp.take_along_axis(paged.block_tables, blk[:, None], axis=1)[:, 0]
        pages_idx = jnp.where(paged.active, pages_idx, trash)
        offs = pos % page_size
        kp = cache.k_pages.at[pages_idx, offs].set(k[:, 0])
        vp = cache.v_pages.at[pages_idx, offs].set(v[:, 0])
        out = kernel_ops.paged_attention(
            q[:, 0], kp, vp, paged.block_tables, pos,
            mode=mode, window=window, config=cfg.kernels,
        )[:, None]
        return _out_proj(out, w_o, ctx, tp_h), PagedAttnCache(kp, vp)

    # =====================================================================
    # No cache: plain (training / encoder) attention — dispatched kernels
    # =====================================================================
    if cache is None:
        out = _dispatched_attention(
            q, k, v, cfg, ctx, tp_h, mode=mode, window=cfg.sliding_window or 0,
        )
        return _out_proj(out, w_o, ctx, tp_h), None

    # =====================================================================
    # Cross-attention decode: read-only precomputed encoder K/V
    # =====================================================================
    if reuse_cross:
        ck, cv = cache.k, cache.v
        kv_positions = jnp.arange(ck.shape[1], dtype=jnp.int32)
        out = blockwise_attention(
            q, _expand_kv(ck, head_map), _expand_kv(cv, head_map),
            positions, kv_positions, mode="full", unroll=cfg.unroll_scans,
        )
        return _out_proj(out, w_o, ctx, tp_h), cache

    # =====================================================================
    # PREFILL: attend over fresh K/V (dispatched kernels), then fill the cache
    # =====================================================================
    if s > 1:
        out = _dispatched_attention(
            q, k, v, cfg, ctx, tp_h, mode=mode, window=cfg.sliding_window or 0,
        )
        size_local = cache.k.shape[1]
        if ctx.kv_shard_seq and ctx.tp > 1 and mode == "causal":
            start = ctx.model_index() * size_local
            ck = jax.lax.dynamic_slice(k, (0, start, 0, 0), (b, size_local, kv, hd))
            cv = jax.lax.dynamic_slice(v, (0, start, 0, 0), (b, size_local, kv, hd))
        elif mode == "local" and s >= size_local:
            # keep the LAST `window` tokens in ring order (slot = pos % size)
            take = s - size_local
            ck_lin = jax.lax.dynamic_slice_in_dim(k, take, size_local, 1)
            cv_lin = jax.lax.dynamic_slice_in_dim(v, take, size_local, 1)
            # positions of these tokens are [s-size_local, s); slot = pos % size
            roll = -(take % size_local)
            ck = jnp.roll(ck_lin, roll, axis=1)
            cv = jnp.roll(cv_lin, roll, axis=1)
        else:
            ck = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0))
        new_cache = AttnCache(k=ck, v=cv, index=jnp.full((), s, jnp.int32))
        return _out_proj(out, w_o, ctx, tp_h), new_cache

    # =====================================================================
    # DECODE (S == 1)
    # =====================================================================
    size_local = cache.k.shape[1]

    if ctx.kv_shard_seq and ctx.tp > 1 and mode == "causal":
        # sequence-sharded cache: masked owner write + psum softmax combine
        start = ctx.model_index() * size_local
        local_idx = cache.index - start
        in_range = (local_idx >= 0) & (local_idx < size_local)
        safe = jnp.clip(local_idx, 0, size_local - 1)
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, safe, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, safe, 0, 0))
        ck = jnp.where(in_range, ck, cache.k)
        cv = jnp.where(in_range, cv, cache.v)
        new_cache = AttnCache(k=ck, v=cv, index=cache.index + 1)
        kv_positions = start + jnp.arange(size_local, dtype=jnp.int32)
        kv_positions = jnp.where(kv_positions <= cache.index, kv_positions, -(10**9))
        acc, m, l = blockwise_attention(
            q, _expand_kv(ck, head_map), _expand_kv(cv, head_map),
            positions, kv_positions, mode="causal", return_stats=True,
            unroll=cfg.unroll_scans,
        )
        gm = ctx.pmax_model(m)
        corr = jnp.exp(m - gm)
        l = ctx.psum_model(l * corr)
        acc = ctx.psum_model(acc * corr[..., None])
        out = (acc / jnp.maximum(l[..., None], 1e-30)).transpose(0, 2, 1, 3).astype(q.dtype)
        return jnp.einsum("bshk,hkd->bsd", out, w_o), new_cache  # complete, replicated

    if mode == "local":
        slot = cache.index % size_local
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
        slots = jnp.arange(size_local, dtype=jnp.int32)
        age = (slot - slots) % size_local
        kv_positions = cache.index - age
        valid = kv_positions >= jnp.maximum(cache.index - size_local + 1, 0)
        kv_positions = jnp.where(valid, kv_positions, -(10**9))
    else:  # causal, unsharded cache
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, cache.index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, cache.index, 0, 0))
        kv_positions = jnp.arange(size_local, dtype=jnp.int32)
        kv_positions = jnp.where(kv_positions <= cache.index, kv_positions, -(10**9))
    new_cache = AttnCache(k=ck, v=cv, index=cache.index + 1)
    out = blockwise_attention(
        q, _expand_kv(ck, head_map), _expand_kv(cv, head_map),
        positions, kv_positions,
        mode=mode, window=cfg.sliding_window or 0,
        unroll=cfg.unroll_scans,
    )
    return _out_proj(out, w_o, ctx, tp_h), new_cache


def _out_proj(out: jax.Array, w_o: jax.Array, ctx: ShardCtx, tp_h: int) -> jax.Array:
    """Row-parallel output projection; psum (or reduce-scatter) when q heads
    are sharded, plain matmul when attention is replicated."""
    y = jnp.einsum("bshk,hkd->bsd", out, w_o)
    if tp_h > 1:
        y = ctx.scatter_seq_sum(y, axis=1)
    return y
