"""Ensemble speculative decoding: a second NoLoCo replica drafts, the
promoted target verifies.

NoLoCo's partial averaging (paper Eq. 2-3) never collapses the ensemble: a
checkpoint holds R slightly-diverse replicas, so a SECOND replica — or a
depth-truncated slice of the first (:func:`repro.serve.promote.
truncate_layers`) — is a free draft model that agrees with the target on
most easy tokens.  The engine here exploits that without changing what is
served:

  * DRAFT — ``spec_k`` scanned decode steps of the draft model propose a
    token run.  The scan body is literally :func:`repro.serve.engine.
    _decode_core` with the draft's params/caches, so proposals (and the
    draft's sampling noise) are bitwise what the draft would decode solo.
  * VERIFY — ONE chunked forward of the target
    (:func:`repro.models.model.paged_prefill_chunk` with ``collect=True``)
    scores all ``spec_k`` fed tokens at once.  The collect path runs
    attention and the recurrent mixers as sequential per-token updates,
    BITWISE identical to the target's own decode steps — which is the whole
    exactness argument: the accepted prefix plus the first corrected token
    are, token for token, what the target would have produced alone (greedy
    or sampled — noise is keyed by (request id, output index), independent
    of who proposed the token).  ``--verify`` in launch/serve.py checks this
    end-to-end against a non-speculative engine.
  * COMMIT / ROLLBACK — per slot, ``commit = accepted + 1`` tokens land in
    the output buffer; positions advance by ``commit``.  KV for rejected
    tokens needs NO explicit rollback: the positional mask (``kv_pos <=
    q_pos``) hides pages past the new position, and the stale entries are
    overwritten in place when decoding reaches them again.  Recurrent states
    DO roll back: the verify pass returns per-token state trajectories and
    the engine selects index ``commit - 1``; the draft restores the matching
    snapshot emitted by its proposal scan.

The draft shares the target's block tables and page allocator (same page
ids index its own, separately-shaped pools), so admission control and leak
accounting stay single-sourced.  Host sync cost: one small device_get of
the per-slot commit vector per ROUND (amortized over up to ``spec_k``
tokens), versus none for plain decode — the acceptance telemetry rides it.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.attention import PagedAttnCache, PagedView
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardCtx
from repro.serve.engine import (
    _SAMPLE_KEY,
    EngineState,
    ServeConfig,
    ServeEngine,
    _chunk_program,
    _decode_core,
)

__all__ = ["SpecServeEngine"]


# ---------------------------------------------------------------------------
# Cache-tree walkers.  Engine caches are {"scan": [entry|None], "rem":
# [entry]} with entry = (mixer, cross); "scan" mixers carry a leading layer
# axis (depth-stacked), "rem" mixers do not.  Attention mixers are
# PagedAttnCache (shared pools, no per-token state to roll back); everything
# else is per-slot recurrent state.
# ---------------------------------------------------------------------------


def _rec_snapshot(caches):
    """Recurrent mixers only, every leaf transposed to put the SLOT axis
    first — the draft scan stacks these per step, and a slot-leading layout
    makes the later per-slot trajectory select one take_along_axis."""
    def pick(e, stacked):
        if e is None:
            return None
        mixer, _ = e
        if isinstance(mixer, PagedAttnCache):
            return None
        return jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0) if stacked else x, mixer)

    return {
        "scan": [pick(e, True) for e in caches["scan"]],
        "rem": [pick(e, False) for e in caches["rem"]],
    }


def _where_keep(keep, new, old, stacked):
    k = (
        keep.reshape((1, -1) + (1,) * (new.ndim - 2))
        if stacked
        else keep.reshape((-1,) + (1,) * (new.ndim - 1))
    )
    return jnp.where(k, new, old)


def _restore_draft(old, final, snaps, sel, keep):
    """Draft caches after a round: written page pools from the scan's final
    state, recurrent mixers rolled back to snapshot ``sel[r]`` per slot."""
    def one(o, f, s, stacked):
        if o is None:
            return None
        mixer_o, cross = o
        if isinstance(mixer_o, PagedAttnCache):
            return (f[0], cross)

        def leaf(ol, sl):
            # sl: (k, R, ...) scan-stacked snapshots, slot axis already first
            idx = sel.reshape((1, -1) + (1,) * (sl.ndim - 2))
            picked = jnp.take_along_axis(sl, idx, axis=0)[0]  # (R, ...)
            if stacked:
                picked = jnp.moveaxis(picked, 0, 1)           # (L, R, ...)
            return _where_keep(keep, picked, ol, stacked)

        return (jax.tree.map(leaf, mixer_o, s), cross)

    return {
        "scan": [one(o, f, s, True) for o, f, s in zip(old["scan"], final["scan"], snaps["scan"])],
        "rem": [one(o, f, s, False) for o, f, s in zip(old["rem"], final["rem"], snaps["rem"])],
    }


def _accept_target(old, new, sel, keep):
    """Target caches after a round: written pools from the verify pass,
    recurrent mixers taken from its per-token trajectory at index ``sel[r]``
    (trajectory axis sits right after the slot axis: (L?, R, C, ...))."""
    def one(o, n, stacked):
        if o is None:
            return None
        mixer_o, cross = o
        mixer_n, _ = n
        if isinstance(mixer_o, PagedAttnCache):
            return (mixer_n, cross)
        t_ax = 2 if stacked else 1

        def leaf(ol, nl):
            idx = (
                sel.reshape((1, -1, 1) + (1,) * (nl.ndim - 3))
                if stacked
                else sel.reshape((-1, 1) + (1,) * (nl.ndim - 2))
            )
            picked = jnp.squeeze(jnp.take_along_axis(nl, idx, axis=t_ax), axis=t_ax)
            return _where_keep(keep, picked, ol, stacked)

        return (jax.tree.map(leaf, mixer_o, mixer_n), cross)

    return {
        "scan": [one(o, n, True) for o, n in zip(old["scan"], new["scan"])],
        "rem": [one(o, n, False) for o, n in zip(old["rem"], new["rem"])],
    }


@functools.lru_cache(maxsize=None)
def _spec_program(cfg: ModelConfig, dcfg: ModelConfig, k: int):
    """ONE jitted speculative round per (target, draft, spec_k): draft scan →
    target verify → accept/rollback.  Returns (new target EngineState, new
    draft caches, per-slot commit counts)."""
    ctx = ShardCtx.local()

    def spec_impl(params, draft_params, state, draft_caches):
        # -- draft proposes k tokens (its own decode steps, bitwise) --------
        def dstep(dstate, _):
            ns = _decode_core(dcfg, ctx, draft_params, dstate)
            return ns, (ns.tokens, _rec_snapshot(ns.caches))

        dstate0 = dataclasses.replace(
            state, caches=draft_caches, out_buf=jnp.zeros_like(state.out_buf)
        )
        dfinal, (props, snaps) = jax.lax.scan(dstep, dstate0, None, length=k)
        props_t = props.T                                   # (R, k); col j = p_{j+1}

        # -- target verifies all k feeds in one chunked forward -------------
        # feed = [current token, p_1, ..., p_{k-1}]; o_{j+1} is sampled from
        # the logits after feed j with the SAME (rid, output index) noise a
        # plain decode step would use.
        feed = jnp.concatenate([state.tokens[:, None], props_t[:, : k - 1]], axis=1)
        remaining = jnp.clip(state.budgets - state.out_len, 0, k)
        lengths = jnp.where(state.active, remaining, 0)
        view = PagedView(state.block_tables, state.positions, state.active)
        logits, traj = M.paged_prefill_chunk(
            params, cfg, feed, state.caches, view, ctx,
            lengths=lengths, collect=True,
        )                                                   # (R, k, V)
        idx = state.out_len[:, None] + jnp.arange(k, dtype=jnp.int32)[None]
        keys = jax.vmap(jax.vmap(
            lambda rid, i: jax.random.fold_in(jax.random.fold_in(_SAMPLE_KEY, rid), i)
        ))(jnp.broadcast_to(state.rids[:, None], idx.shape), idx)
        g = jax.vmap(jax.vmap(
            lambda key: jax.random.gumbel(key, logits.shape[-1:], jnp.float32)
        ))(keys)
        o = jnp.argmax(
            logits + state.temps[:, None, None] * g, axis=-1
        ).astype(jnp.int32)                                 # (R, k); col j = o_{j+1}

        # -- accept prefix + first correction -------------------------------
        eq = (props_t[:, : k - 1] == o[:, : k - 1]).astype(jnp.int32)
        accepted = jnp.sum(jnp.cumprod(eq, axis=1), axis=1)             # (R,)
        commit = jnp.minimum(accepted + 1, remaining)
        commit = jnp.where(state.active, commit, 0)
        keep = state.active & (commit > 0)
        sel = jnp.clip(commit - 1, 0, k - 1)

        # committed tokens land at output indices out_len .. out_len+commit-1;
        # rejected columns scatter out of range and are dropped
        cols = jnp.arange(k, dtype=jnp.int32)[None, :]
        cap = state.out_buf.shape[1]
        wi = jnp.where(cols < commit[:, None], idx, cap)
        rows = jnp.broadcast_to(
            jnp.arange(state.out_buf.shape[0], dtype=jnp.int32)[:, None], wi.shape
        )
        out_buf = state.out_buf.at[rows, wi].set(o, mode="drop")

        t_next = jnp.take_along_axis(o, sel[:, None], axis=1)[:, 0]
        new_state = EngineState(
            caches=_accept_target(state.caches, traj, sel, keep),
            block_tables=state.block_tables,
            tokens=jnp.where(keep, t_next, state.tokens),
            positions=state.positions + commit,
            active=state.active,
            temps=state.temps,
            rids=state.rids,
            out_buf=out_buf,
            out_len=state.out_len + commit,
            budgets=state.budgets,
        )
        new_draft = _restore_draft(draft_caches, dfinal.caches, snaps, sel, keep)
        return new_state, new_draft, commit, accepted

    return jax.jit(spec_impl, donate_argnums=(2, 3))


class SpecServeEngine(ServeEngine):
    """ServeEngine whose decode step is a speculative round.

    ``spec_k`` is the round width: the draft runs ``spec_k`` decode steps
    and the target verifies ``spec_k`` fed tokens, committing between 1 and
    ``spec_k`` tokens per round (the classic bonus token is forgone so the
    draft never has to catch up — its snapshots already cover every commit).
    ``spec_k=1`` degenerates to plain decode plus wasted draft work.

    Output is EXACTLY the target engine's, so the draft only affects speed:
    a good draft (second NoLoCo replica, truncated slice) commits close to
    ``spec_k`` tokens per round; a terrible one still serves correct tokens
    at roughly plain-decode speed.
    """

    def __init__(
        self,
        params: Any,
        cfg: ModelConfig,
        scfg: ServeConfig,
        draft_params: Any,
        draft_cfg: ModelConfig | None = None,
        *,
        spec_k: int = 4,
    ):
        if not scfg.prefill_chunk:
            raise ValueError("speculative decode requires chunked prefill "
                             "(prefill_chunk > 0)")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        super().__init__(params, cfg, scfg)
        self.dcfg = draft_cfg or cfg
        if self.dcfg.vocab_size != cfg.vocab_size:
            raise ValueError("draft and target must share a vocabulary")
        self.draft_params = draft_params
        self.spec_k = spec_k
        self.draft_caches = M.init_paged_cache_tree(
            self.dcfg, scfg.max_slots, scfg.num_pages, scfg.page_size
        )
        self._spec_fn = _spec_program(cfg, self.dcfg, spec_k)
        self._draft_chunk_fn = _chunk_program(self.dcfg, scfg.prefill_chunk)
        self.spec_rounds = 0
        self.spec_commit_total = 0
        self.spec_accept_total = 0
        self.spec_prop_total = 0

    @property
    def accept_rate(self) -> float:
        """Accepted / USABLE draft proposals.  A slot-round with ``rem``
        budget tokens left can accept at most min(spec_k−1, rem−1) proposals
        (commit is capped at rem), so that is what each participation adds to
        the denominator — a perfect draft scores exactly 1.0 even on the
        budget-tail rounds.

        A zero denominator (every round so far had rem == 1 for every slot,
        or no spec round ran at all) is vacuously perfect: not one usable
        proposal was rejected, so the rate is 1.0 — NOT 0.0, which would
        falsely read as "the draft never matched", and NOT NaN."""
        if not self.spec_prop_total:
            return 1.0
        return self.spec_accept_total / self.spec_prop_total

    # -- prefill: the draft walks the same chunks through its own caches ----

    def _prefill_chunk_step(self, slot: int) -> None:
        occ = self._slots[slot]
        req = occ["req"]
        cur = occ["cursor"]
        c = self.scfg.prefill_chunk
        n = min(c, len(req.prompt) - cur)
        toks = req.prompt[cur: cur + n] + [0] * (c - n)
        scratch = self._prefill_caches(self.draft_caches, occ.get("rec_d"))
        key = jax.random.fold_in(jax.random.fold_in(_SAMPLE_KEY, req.rid), 0)
        _tok0, new_d = self._draft_chunk_fn(
            self.draft_params,
            jnp.asarray(toks, jnp.int32),
            jnp.int32(n),
            scratch,
            occ["row"],
            jnp.int32(cur),
            jnp.float32(0.0),
            key,
        )
        if cur + n < len(req.prompt):
            self.draft_caches = self._merge_pools(self.draft_caches, new_d)
            occ["rec_d"] = self._extract_rec(new_d)
        else:
            # the draft's sampled first token is DISCARDED — token 0 comes
            # from the target's chunk step below (exactness)
            self.draft_caches = self._merge_caches(self.draft_caches, new_d, slot)
            occ["rec_d"] = None
        super()._prefill_chunk_step(slot)

    # -- decode: one speculative round per tick -----------------------------

    def step(self):
        done = self._evict_finished()
        self._admit()
        self._advance_prefills()
        if any(
            s is not None and s["phase"] == "decode"
            and s["steps"] < s["req"].max_new
            for s in self._slots
        ):
            t0 = time.perf_counter()
            new_state, new_draft, commit, accepted = self._spec_fn(
                self.params, self.draft_params, self.state, self.draft_caches
            )
            self.state = new_state
            self.draft_caches = new_draft
            # the round's one host sync: k tokens' worth of scheduling state
            commits = np.asarray(jax.device_get(commit))
            accepts = np.asarray(jax.device_get(accepted))
            now = time.perf_counter()
            if self.scfg.sync_each_step:
                self.decode_step_times.append(now - t0)
            self.decode_steps += 1
            self.spec_rounds += 1
            for slot, occ in enumerate(self._slots):
                if occ is None or occ["phase"] != "decode":
                    continue
                n = int(commits[slot])
                if n <= 0:
                    continue
                rem = occ["req"].max_new - occ["steps"]
                usable = max(min(self.spec_k - 1, rem - 1), 0)
                acc = min(int(accepts[slot]), usable)
                occ["spec_rounds"] = occ.get("spec_rounds", 0) + 1
                occ["spec_commit"] = occ.get("spec_commit", 0) + n
                occ["spec_accept"] = occ.get("spec_accept", 0) + acc
                occ["spec_prop"] = occ.get("spec_prop", 0) + usable
                self.spec_commit_total += n
                self.spec_accept_total += acc
                self.spec_prop_total += usable
                for _ in range(n):
                    if occ["steps"] < occ["req"].max_new:
                        occ["t_toks"].append(now)
                    occ["steps"] += 1
        return done

    def _finish_stats(self, occ: dict) -> dict:
        prop = occ.get("spec_prop", 0)
        acc = occ.get("spec_accept", 0)
        return {
            "spec_rounds": occ.get("spec_rounds", 0),
            "spec_tokens": occ.get("spec_commit", 0),
            # 0 usable proposals (e.g. max_new == 1: every round has rem == 1)
            # is vacuously perfect — same convention as ``accept_rate``
            "accept_rate": acc / prop if prop else 1.0,
        }
