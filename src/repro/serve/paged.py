"""Host-side page bookkeeping for the paged KV cache.

The device side (models/attention.py: PagedAttnCache / PagedView, the
dispatched paged-attention kernel) only ever sees page POOLS and block
TABLES; which physical page backs which request block is decided here, on
the host, by a free-list allocator.  Pages are identical fixed-size units,
so allocation is O(1) pops with zero fragmentation — the whole point of
paging the cache (vLLM, arXiv:2309.06180) versus reserving max-length dense
rings per slot.

Page id ``num_pages`` (one past the pool) is the TRASH page: never
allocated, it absorbs the masked writes of inactive slots in the batched
decode step.  Unused block-table entries also point at it, keeping every
table entry a valid pool index.
"""

from __future__ import annotations


class BlockAllocator:
    """Free-list allocator over ``num_pages`` fixed-size KV pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(f"need >=1 pages of >=1 tokens, got {num_pages}x{page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: recently-freed pages are reused first (their cache
        # lines / HBM pages are hottest)
        self._free = list(range(num_pages))

    @property
    def trash_page(self) -> int:
        return self.num_pages

    @property
    def free_count(self) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV entries."""
        return -(-max(n_tokens, 1) // self.page_size)

    def can_alloc(self, n_blocks: int) -> bool:
        return n_blocks <= len(self._free)

    def alloc(self, n_blocks: int) -> list[int]:
        if not self.can_alloc(n_blocks):
            raise MemoryError(
                f"paged KV OOM: need {n_blocks} pages, {len(self._free)} free"
            )
        taken = self._free[-n_blocks:]
        del self._free[-n_blocks:]
        return taken

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if not 0 <= b < self.num_pages:
                raise ValueError(f"freeing invalid page id {b}")
            if b in self._free:
                raise ValueError(f"double free of page {b}")
        self._free.extend(blocks)
