"""Host-side page bookkeeping for the paged KV cache.

The device side (models/attention.py: PagedAttnCache / PagedView, the
dispatched paged-attention kernel) only ever sees page POOLS and block
TABLES; which physical page backs which request block is decided here, on
the host, by a free-list allocator.  Pages are identical fixed-size units,
so allocation is O(1) pops with zero fragmentation — the whole point of
paging the cache (vLLM, arXiv:2309.06180) versus reserving max-length dense
rings per slot.

Page id ``num_pages`` (one past the pool) is the TRASH page: never
allocated, it absorbs the masked writes of inactive slots in the batched
decode step.  Unused block-table entries also point at it, keeping every
table entry a valid pool index.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Lease:
    """Pages reserved but not yet committed to a running request.

    Chunked prefill spans many scheduler ticks, and speculative decode
    writes K/V for tokens that may be rejected — in both cases pages leave
    the free list BEFORE the request is guaranteed to keep them.  A lease
    makes that window explicit: ``commit`` transfers ownership to the
    request (pages are later returned via :meth:`BlockAllocator.free`),
    ``rollback`` returns them immediately.  Either way the page is never in
    two places at once, which is what the leak tests assert."""

    blocks: list[int]
    state: str = "reserved"   # reserved | committed | rolled_back


class BlockAllocator:
    """Free-list allocator over ``num_pages`` fixed-size KV pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(f"need >=1 pages of >=1 tokens, got {num_pages}x{page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list: recently-freed pages are reused first (their cache
        # lines / HBM pages are hottest)
        self._free = list(range(num_pages))
        self._reserved: list[Lease] = []

    @property
    def trash_page(self) -> int:
        return self.num_pages

    @property
    def free_count(self) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` KV entries."""
        return -(-max(n_tokens, 1) // self.page_size)

    def can_alloc(self, n_blocks: int) -> bool:
        return n_blocks <= len(self._free)

    def alloc(self, n_blocks: int) -> list[int]:
        if not self.can_alloc(n_blocks):
            raise MemoryError(
                f"paged KV OOM: need {n_blocks} pages, {len(self._free)} free"
            )
        taken = self._free[-n_blocks:]
        del self._free[-n_blocks:]
        return taken

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if not 0 <= b < self.num_pages:
                raise ValueError(f"freeing invalid page id {b}")
            if b in self._free:
                raise ValueError(f"double free of page {b}")
        self._free.extend(blocks)

    # -- lease API: reserve → (commit | rollback) ---------------------------

    def reserve(self, n_blocks: int) -> Lease:
        """Take pages off the free list under a revocable lease (chunked
        prefill in flight, speculative tokens not yet verified)."""
        lease = Lease(blocks=self.alloc(n_blocks))
        self._reserved.append(lease)
        return lease

    def commit(self, lease: Lease) -> list[int]:
        """The request keeps the pages; caller now owns them and must
        eventually :meth:`free` them.  Returns the block list."""
        if lease.state != "reserved":
            raise ValueError(f"commit of {lease.state} lease")
        lease.state = "committed"
        self._reserved.remove(lease)
        return lease.blocks

    def rollback(self, lease: Lease) -> None:
        """Abandon the lease (cancelled admission / rejected speculation):
        pages go straight back to the free list."""
        if lease.state != "reserved":
            raise ValueError(f"rollback of {lease.state} lease")
        lease.state = "rolled_back"
        self._reserved.remove(lease)
        self.free(lease.blocks)

    @property
    def reserved_count(self) -> int:
        return sum(len(l.blocks) for l in self._reserved)

    def check_leaks(self, owned: int = 0) -> None:
        """Invariant: free + reserved + caller-owned pages == pool size, and
        the trash page was never handed out."""
        total = self.free_count + self.reserved_count + owned
        if total != self.num_pages:
            raise AssertionError(
                f"page leak: free={self.free_count} reserved={self.reserved_count} "
                f"owned={owned} != pool={self.num_pages}"
            )
        for lease in self._reserved:
            if self.trash_page in lease.blocks:
                raise AssertionError("trash page leaked into a lease")
        if self.trash_page in self._free:
            raise AssertionError("trash page leaked into the free list")
