"""Serving subsystem: continuous batching over a paged KV cache.

The counterpart of :mod:`repro.train` for the inference side of the north
star — promote one replica of a NoLoCo checkpoint (:func:`promote`) and
serve it through a request-driven engine (:class:`ServeEngine`) whose decode
hot loop runs the dispatched Pallas/jnp serving kernels (paged attention,
RG-LRU/SSD single-token updates) registered in :mod:`repro.kernels.dispatch`.
"""

from repro.serve.engine import (
    EngineState,
    FinishedRequest,
    Request,
    ServeConfig,
    ServeEngine,
)
from repro.serve.paged import BlockAllocator, Lease
from repro.serve.promote import promote, resolve_replica, truncate_layers
from repro.serve.router import ReplicaRouter
from repro.serve.spec import SpecServeEngine

__all__ = [
    "BlockAllocator",
    "EngineState",
    "FinishedRequest",
    "Lease",
    "ReplicaRouter",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "SpecServeEngine",
    "promote",
    "resolve_replica",
    "truncate_layers",
]
