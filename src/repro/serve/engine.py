"""Continuous-batching inference engine over the paged KV cache.

Scheduler state machine (one host loop around one jitted decode program):

    QUEUED ──admit──► PREFILL ──chunks──► DECODING ──evict──► FINISHED
                 ▲    (interleaved         │
                 │     with decode)        │
                 └──────── pages freed ◄───┘

Each :meth:`ServeEngine.step`:
  1. EVICT — slots whose request hit its token budget are read out (the ONE
     host sync a request ever costs), their pages returned to the allocator.
  2. ADMIT — while a slot and enough pages are free, the next queued request
     claims the slot and RESERVES pages for prompt+max_new up front (lease —
     committed when prefill completes), so a running request can never OOM
     mid-decode.  ``policy="static"`` instead admits only into an all-idle
     engine — classic static batching, kept as the measured baseline.
  3. PREFILL (chunked) — admitted prompts advance ``prefill_chunk`` tokens
     per call through ONE fixed-shape jitted chunk program (ragged last
     chunk masked positionally; RG-LRU/SSD states carried exactly across
     chunk boundaries), at most ``prefill_budget`` tokens per tick so long
     prompts INTERLEAVE with decode instead of stalling the batch.  With
     ``prefill_chunk=0`` the PR-7 single-shot path (batch-1, exact prompt
     length, retraces per distinct length) is kept as the measured baseline.
  4. DECODE — one fused, donated, jitted step advances ALL active slots:
     per-slot positions drive RoPE + the paged-attention mask, per-slot
     temperatures drive gumbel sampling, sampled tokens land in an on-device
     output buffer.  Nothing crosses the host boundary per token; streaming
     consumers get tokens from the eviction-wave device_get plus an optional
     periodic drain (see :meth:`ServeEngine.drain`).

Inactive slots ride along (their writes hit the trash page, their recurrent
states are overwritten at admission) — the decode program never retraces as
requests come and go.

Exactness: with attention/recurrent mixers every slot's row is computed
independently, and sampling noise is keyed by (request id, output index)
rather than engine step, so a request decoded in a churning batch produces
bitwise the tokens of a solo run — greedy or sampled (tested end-to-end).
Chunked prefill preserves this: the chunk decomposition of a prompt depends
only on the prompt length, never on batch occupancy.  MoE blocks break it
(capacity is batch-global); they serve fine but without the guarantee.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.attention import PagedAttnCache, PagedView
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardCtx
from repro.serve.paged import BlockAllocator

__all__ = ["Request", "FinishedRequest", "ServeConfig", "EngineState", "ServeEngine"]

# Root of every sampling stream; token i of request rid draws its gumbel
# noise from fold_in(fold_in(_SAMPLE_KEY, rid), i).
_SAMPLE_KEY = jax.random.PRNGKey(17)


def _sample_keys(rids: jax.Array, indices: jax.Array) -> jax.Array:
    """Per-slot sampling keys: token ``indices[r]`` of request ``rids[r]``."""
    return jax.vmap(
        lambda rid, i: jax.random.fold_in(jax.random.fold_in(_SAMPLE_KEY, rid), i)
    )(rids, indices)


def _decode_core(cfg: ModelConfig, ctx: ShardCtx, params, state: "EngineState") -> "EngineState":
    """One batched decode step as a pure function — jitted by
    :func:`_programs`, and scanned by serve/spec.py as the draft proposer
    (which is what keeps draft proposals bitwise-identical to the draft
    engine decoding on its own)."""
    view = PagedView(state.block_tables, state.positions, state.active)
    logits, caches = M.paged_decode_step(
        params, cfg, state.tokens[:, None], state.caches, view, ctx
    )
    logits = logits[:, 0]                                   # (R, V)
    # temperature-t categorical == argmax(logits + t·gumbel); t=0 greedy.
    # Noise is keyed by (request id, output index), NOT engine step — a
    # request draws the same sample stream wherever the scheduler puts it,
    # which is what makes batched sampling match a solo run exactly.
    keys = _sample_keys(state.rids, state.out_len)
    g = jax.vmap(lambda k: jax.random.gumbel(k, logits.shape[-1:], jnp.float32))(keys)
    nxt = jnp.argmax(logits + state.temps[:, None] * g, axis=-1).astype(jnp.int32)
    row = jnp.arange(state.out_buf.shape[0])
    idx = jnp.clip(state.out_len, 0, state.out_buf.shape[1] - 1)
    keep = state.out_buf[row, idx]
    out_buf = state.out_buf.at[row, idx].set(jnp.where(state.active, nxt, keep))
    act = state.active.astype(jnp.int32)
    return EngineState(
        caches=caches,
        block_tables=state.block_tables,
        tokens=jnp.where(state.active, nxt, state.tokens),
        positions=state.positions + act,
        active=state.active,
        temps=state.temps,
        rids=state.rids,
        out_buf=out_buf,
        out_len=state.out_len + act,
        budgets=state.budgets,
    )


@functools.lru_cache(maxsize=None)
def _programs(cfg: ModelConfig):
    """Jitted decode/prefill programs for one model config, shared by every
    engine serving it (ModelConfig is frozen/hashable) — a fresh engine, e.g.
    a solo-verification run or a router replica, reuses the already-compiled
    programs."""
    ctx = ShardCtx.local()
    decode = jax.jit(functools.partial(_decode_core, cfg, ctx), donate_argnums=(1,))

    def prefill_impl(params, tokens, caches, table_row, temp, key):
        view = PagedView(
            table_row[None],
            jnp.zeros((1,), jnp.int32),
            jnp.ones((1,), bool),
        )
        logits, new_caches = M.paged_prefill(params, cfg, tokens[None], caches, view, ctx)
        g = jax.random.gumbel(key, logits[0, 0].shape, jnp.float32)
        tok0 = jnp.argmax(logits[0, 0] + temp * g).astype(jnp.int32)
        return tok0, new_caches

    # one jitted callable; retraces per distinct prompt LENGTH only (exact
    # lengths — lengths are few under bucketed real workloads)
    prefill = jax.jit(prefill_impl, donate_argnums=(2,))
    return decode, prefill


@functools.lru_cache(maxsize=None)
def _chunk_program(cfg: ModelConfig, chunk: int):
    """ONE jitted chunk-prefill program per (model, chunk size) — this is
    what replaces the per-prompt-length compile zoo.  Batch-1: the engine
    walks one slot's prompt through it chunk by chunk, carrying recurrent
    states in the caches and bumping ``base``; the ragged last chunk rides
    the positional mask.  The sampled ``tok0`` is only meaningful on the
    final chunk (logits are taken at the last VALID position)."""
    ctx = ShardCtx.local()

    def chunk_impl(params, tokens, length, caches, table_row, base, temp, key):
        view = PagedView(table_row[None], base[None], jnp.ones((1,), bool))
        logits, new_caches = M.paged_prefill_chunk(
            params, cfg, tokens[None], caches, view, ctx, lengths=length[None]
        )
        g = jax.random.gumbel(key, logits[0, 0].shape, jnp.float32)
        tok0 = jnp.argmax(logits[0, 0] + temp * g).astype(jnp.int32)
        return tok0, new_caches

    return jax.jit(chunk_impl, donate_argnums=(3,))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    temperature: float = 0.0
    submit_t: float = 0.0


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    prompt: list[int]
    tokens: list[int]
    submit_t: float
    admit_t: float       # prefill completed = first token exists
    finish_t: float
    stats: dict = dataclasses.field(default_factory=dict)  # e.g. spec accept rate

    @property
    def ttft_s(self) -> float:
        return self.admit_t - self.submit_t


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4          # R: concurrent requests in the decode batch
    num_pages: int = 128        # KV page pool size (per layer), excl. trash
    page_size: int = 16         # tokens per page
    max_new_cap: int = 128      # on-device output buffer width
    policy: str = "continuous"  # "continuous" | "static" (baseline)
    sync_each_step: bool = False  # block per decode step (per-token timing)
    prefill_chunk: int = 32     # chunked-prefill width; 0 = single-shot (PR-7)
    prefill_budget: int = 0     # max prefill tokens per tick; 0 = unlimited

    def validate(self) -> None:
        if self.policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.max_slots < 1:
            raise ValueError("need at least one slot")
        if self.prefill_chunk < 0 or self.prefill_budget < 0:
            raise ValueError("prefill_chunk/prefill_budget must be >= 0")
        if self.prefill_budget and not self.prefill_chunk:
            raise ValueError("prefill_budget requires chunked prefill")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    """Everything the jitted decode step touches — donated through it."""

    caches: Any               # paged attn pools + per-slot recurrent states
    block_tables: jax.Array   # (R, MB) int32
    tokens: jax.Array         # (R,) int32 — token being fed this step
    positions: jax.Array      # (R,) int32 — its position
    active: jax.Array         # (R,) bool
    temps: jax.Array          # (R,) f32 — 0 = greedy
    rids: jax.Array           # (R,) int32 — request id (seeds its gumbel noise)
    out_buf: jax.Array        # (R, CAP) int32 — generated tokens, on device
    out_len: jax.Array        # (R,) int32
    budgets: jax.Array        # (R,) int32 — max_new per slot (spec clamps on it)


class ServeEngine:
    """Request-driven serving engine for one decoder-only model."""

    def __init__(self, params: Any, cfg: ModelConfig, scfg: ServeConfig):
        scfg.validate()
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.ctx = ShardCtx.local()
        self.alloc = BlockAllocator(scfg.num_pages, scfg.page_size)
        r, mb = scfg.max_slots, scfg.num_pages
        self._mb = mb
        caches = M.init_paged_cache_tree(cfg, r, scfg.num_pages, scfg.page_size)
        self.state = EngineState(
            caches=caches,
            block_tables=jnp.full((r, mb), self.alloc.trash_page, jnp.int32),
            tokens=jnp.zeros((r,), jnp.int32),
            positions=jnp.zeros((r,), jnp.int32),
            active=jnp.zeros((r,), bool),
            temps=jnp.zeros((r,), jnp.float32),
            rids=jnp.zeros((r,), jnp.int32),
            out_buf=jnp.zeros((r, scfg.max_new_cap), jnp.int32),
            out_len=jnp.zeros((r,), jnp.int32),
            budgets=jnp.zeros((r,), jnp.int32),
        )
        self.queue: list[Request] = []
        # host mirror of per-slot occupancy: request, lease/blocks, phase
        # ("prefill" | "decode"), prefill cursor + carried recurrent scratch,
        # admit_t, steps, per-token dispatch times, streamed-token watermark
        self._slots: list[dict | None] = [None] * r
        self._decode_fn, self._prefill_fn = _programs(cfg)
        self._chunk_fn = (
            _chunk_program(cfg, scfg.prefill_chunk) if scfg.prefill_chunk else None
        )
        self._token_cb = None
        self.decode_steps = 0
        self.decode_step_times: list[float] = []

    # -- prefill cache surgery ---------------------------------------------

    def _entry_scratch(self, entry, stacked: bool, prev=None):
        """Prefill view of one layer-group cache entry: shared page pools
        pass through, per-slot recurrent state becomes batch-1 zeros — or the
        batch-1 state CARRIED from the previous chunk of the same prompt."""
        mixer, cross = entry
        if isinstance(mixer, PagedAttnCache):
            return (mixer, cross)
        if prev is not None:
            return prev
        ax = 1 if stacked else 0
        scratch = jax.tree.map(
            lambda x: jnp.zeros(x.shape[:ax] + (1,) + x.shape[ax + 1:], x.dtype),
            mixer,
        )
        return (scratch, cross)

    def _entry_merge(self, old, new, stacked: bool, slot: int):
        mixer_o, _ = old
        mixer_n, cross = new
        if isinstance(mixer_o, PagedAttnCache):
            return (mixer_n, cross)  # pages were written in place
        if stacked:
            merged = jax.tree.map(
                lambda o, n: o.at[:, slot].set(n[:, 0]), mixer_o, mixer_n
            )
        else:
            merged = jax.tree.map(lambda o, n: o.at[slot].set(n[0]), mixer_o, mixer_n)
        return (merged, cross)

    def _prefill_caches(self, caches, rec=None):
        def at(d, kind, i):
            return None if d is None else d[kind][i]

        return {
            "scan": [
                self._entry_scratch(e, True, at(rec, "scan", i))
                if e is not None else None
                for i, e in enumerate(caches["scan"])
            ],
            "rem": [
                self._entry_scratch(e, False, at(rec, "rem", i))
                for i, e in enumerate(caches["rem"])
            ],
        }

    def _extract_rec(self, new):
        """Batch-1 recurrent entries of a chunk's output caches, to be carried
        into the next chunk of the same prompt (page-pool entries drop to
        None — the written pools live in engine state, not per-slot)."""
        def pick(e):
            if e is None:
                return None
            mixer, cross = e
            return None if isinstance(mixer, PagedAttnCache) else (mixer, cross)

        return {
            "scan": [pick(e) for e in new["scan"]],
            "rem": [pick(e) for e in new["rem"]],
        }

    def _merge_pools(self, old, new):
        """Mid-prompt chunk merge: adopt the chunk program's page pools (the
        originals were DONATED into it, so engine state must take the written
        buffers), keep every slot's full-batch recurrent states untouched."""
        def pool(o, n):
            if o is None:
                return None
            mixer_o, cross = o
            mixer_n, _ = n
            return (mixer_n, cross) if isinstance(mixer_o, PagedAttnCache) else o

        return {
            "scan": [pool(o, n) for o, n in zip(old["scan"], new["scan"])],
            "rem": [pool(o, n) for o, n in zip(old["rem"], new["rem"])],
        }

    def _merge_caches(self, old, new, slot: int):
        return {
            "scan": [
                self._entry_merge(o, n, True, slot) if o is not None else None
                for o, n in zip(old["scan"], new["scan"])
            ],
            "rem": [
                self._entry_merge(o, n, False, slot)
                for o, n in zip(old["rem"], new["rem"])
            ],
        }

    # -- scheduler ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new > self.scfg.max_new_cap:
            raise ValueError(
                f"request {req.rid}: max_new {req.max_new} exceeds engine cap "
                f"{self.scfg.max_new_cap}"
            )
        need = self.alloc.blocks_for(len(req.prompt) + req.max_new)
        if need > self.alloc.num_pages or need > self._mb:
            raise ValueError(
                f"request {req.rid} needs {need} pages; pool holds "
                f"{self.alloc.num_pages}"
            )
        if not req.submit_t:
            req.submit_t = time.perf_counter()
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _finish_stats(self, occ: dict) -> dict:
        """Per-request stats attached at eviction; spec engines override."""
        return {}

    def _emit_tokens(self, slot: int, occ: dict, out_buf, upto: int) -> None:
        """Stream tokens [emitted, upto) of a slot to the token callback,
        stamped with their decode DISPATCH times (host times; exact when
        sync_each_step, otherwise early by the device queue depth)."""
        if self._token_cb is None:
            return
        req: Request = occ["req"]
        upto = min(upto, req.max_new)
        for i in range(occ["emitted"], upto):
            t = occ["t_toks"][i] if i < len(occ["t_toks"]) else time.perf_counter()
            self._token_cb(req.rid, i, int(out_buf[slot, i]), t)
        occ["emitted"] = max(occ["emitted"], upto)

    def drain(self) -> None:
        """Flush generated-but-unstreamed tokens to the token callback with
        ONE device_get for the whole batch — the periodic streaming path (the
        free path being the eviction-wave read in :meth:`_evict_finished`).
        Never called per token: decode stays sync-free."""
        if self._token_cb is None:
            return
        pending = [
            (slot, occ) for slot, occ in enumerate(self._slots)
            if occ is not None and occ["phase"] == "decode"
            and occ["emitted"] < min(occ["steps"], occ["req"].max_new)
        ]
        if not pending:
            return
        out_buf = np.asarray(jax.device_get(self.state.out_buf))
        for slot, occ in pending:
            self._emit_tokens(slot, occ, out_buf, min(occ["steps"], occ["req"].max_new))

    def _evict_finished(self) -> list[FinishedRequest]:
        done: list[FinishedRequest] = []
        out_buf = None
        for slot, occ in enumerate(self._slots):
            if (
                occ is None or occ["phase"] != "decode"
                or occ["steps"] < occ["req"].max_new
            ):
                continue
            if out_buf is None:  # one device_get serves every eviction this step
                out_buf = np.asarray(jax.device_get(self.state.out_buf))
            req: Request = occ["req"]
            toks = out_buf[slot, : req.max_new].tolist()
            self._emit_tokens(slot, occ, out_buf, req.max_new)
            done.append(
                FinishedRequest(
                    rid=req.rid, prompt=req.prompt, tokens=toks,
                    submit_t=req.submit_t, admit_t=occ["admit_t"],
                    finish_t=time.perf_counter(),
                    stats=self._finish_stats(occ),
                )
            )
            self.alloc.free(occ["blocks"])
            self._slots[slot] = None
            st = self.state
            self.state = dataclasses.replace(
                st,
                active=st.active.at[slot].set(False),
                positions=st.positions.at[slot].set(0),
                tokens=st.tokens.at[slot].set(0),
                out_len=st.out_len.at[slot].set(0),
            )
        return done

    def _admit(self) -> None:
        if self.scfg.policy == "static" and any(s is not None for s in self._slots):
            return  # static baseline: wait for the whole batch to drain
        free = self._free_slots()
        while self.queue and free:
            req = self.queue[0]
            need = self.alloc.blocks_for(len(req.prompt) + req.max_new)
            if not self.alloc.can_alloc(need):
                break  # head-of-line blocks until pages free up (no preempt)
            self.queue.pop(0)
            slot = free.pop(0)
            if self._chunk_fn is not None:
                # chunked path: pages leave the free list under a lease
                # (committed when the last chunk lands), the slot parks in
                # "prefill" phase and _advance_prefills walks it forward
                lease = self.alloc.reserve(need)
                row = np.full((self._mb,), self.alloc.trash_page, np.int32)
                row[: len(lease.blocks)] = lease.blocks
                row_dev = jnp.asarray(row)
                st = self.state
                self.state = dataclasses.replace(
                    st, block_tables=st.block_tables.at[slot].set(row_dev)
                )
                self._slots[slot] = {
                    "req": req, "lease": lease, "row": row_dev,
                    "phase": "prefill", "cursor": 0, "rec": None,
                    "admit_t": 0.0, "steps": 0, "t_toks": [], "emitted": 0,
                }
                continue
            blocks = self.alloc.alloc(need)
            row = np.full((self._mb,), self.alloc.trash_page, np.int32)
            row[: len(blocks)] = blocks
            row_dev = jnp.asarray(row)

            st = self.state
            # scratch shares the page-pool buffers with st.caches; prefill
            # donates them and _merge keeps the returned (written) pools
            scratch = self._prefill_caches(st.caches)
            key = jax.random.fold_in(jax.random.fold_in(_SAMPLE_KEY, req.rid), 0)
            tok0, new_caches = self._prefill_fn(
                self.params,
                jnp.asarray(req.prompt, jnp.int32),
                scratch,
                row_dev,
                jnp.float32(req.temperature),
                key,
            )
            merged = self._merge_caches(st.caches, new_caches, slot)
            self.state = dataclasses.replace(
                st,
                caches=merged,
                block_tables=st.block_tables.at[slot].set(row_dev),
                tokens=st.tokens.at[slot].set(tok0),
                positions=st.positions.at[slot].set(len(req.prompt)),
                active=st.active.at[slot].set(True),
                temps=st.temps.at[slot].set(req.temperature),
                rids=st.rids.at[slot].set(req.rid),
                out_buf=st.out_buf.at[slot, 0].set(tok0),
                out_len=st.out_len.at[slot].set(1),
                budgets=st.budgets.at[slot].set(req.max_new),
            )
            now = time.perf_counter()
            self._slots[slot] = {
                "req": req, "blocks": blocks, "phase": "decode",
                "admit_t": now, "steps": 1, "t_toks": [now], "emitted": 0,
            }

    def _prefill_chunk_step(self, slot: int) -> None:
        """Advance one prefill-phase slot by one fixed-width chunk through the
        shared jitted chunk program; on the last chunk, commit the lease and
        flip the slot into the decode batch."""
        occ = self._slots[slot]
        req: Request = occ["req"]
        c = self.scfg.prefill_chunk
        cur = occ["cursor"]
        n = min(c, len(req.prompt) - cur)
        toks = req.prompt[cur: cur + n] + [0] * (c - n)
        st = self.state
        # scratch aliases the engine's page pools (donated by the chunk
        # program) and carries the slot's batch-1 recurrent states
        scratch = self._prefill_caches(st.caches, occ["rec"])
        key = jax.random.fold_in(jax.random.fold_in(_SAMPLE_KEY, req.rid), 0)
        tok0, new_caches = self._chunk_fn(
            self.params,
            jnp.asarray(toks, jnp.int32),
            jnp.int32(n),
            scratch,
            occ["row"],
            jnp.int32(cur),
            jnp.float32(req.temperature),
            key,
        )
        occ["cursor"] = cur + n
        if occ["cursor"] < len(req.prompt):
            self.state = dataclasses.replace(
                st, caches=self._merge_pools(st.caches, new_caches)
            )
            occ["rec"] = self._extract_rec(new_caches)
            return
        blocks = self.alloc.commit(occ.pop("lease"))
        merged = self._merge_caches(st.caches, new_caches, slot)
        now = time.perf_counter()
        self.state = dataclasses.replace(
            st,
            caches=merged,
            tokens=st.tokens.at[slot].set(tok0),
            positions=st.positions.at[slot].set(len(req.prompt)),
            active=st.active.at[slot].set(True),
            temps=st.temps.at[slot].set(req.temperature),
            rids=st.rids.at[slot].set(req.rid),
            out_buf=st.out_buf.at[slot, 0].set(tok0),
            out_len=st.out_len.at[slot].set(1),
            budgets=st.budgets.at[slot].set(req.max_new),
        )
        occ.update(
            {"blocks": blocks, "phase": "decode", "rec": None,
             "admit_t": now, "steps": 1}
        )
        occ["t_toks"].append(now)

    def _advance_prefills(self) -> None:
        """Spend up to ``prefill_budget`` prompt tokens (0 = all pending) on
        chunk steps, round-robin over prefill-phase slots, so long prompts
        interleave with decode instead of stalling the running batch."""
        if self._chunk_fn is None:
            return
        budget = self.scfg.prefill_budget or (1 << 30)
        while budget > 0:
            pending = [
                s for s, occ in enumerate(self._slots)
                if occ is not None and occ["phase"] == "prefill"
            ]
            if not pending:
                return
            for slot in pending:
                if budget <= 0:
                    return
                self._prefill_chunk_step(slot)
                budget -= self.scfg.prefill_chunk

    def step(self) -> list[FinishedRequest]:
        """One scheduler tick: evict → admit → prefill chunks → fused decode."""
        done = self._evict_finished()
        self._admit()
        self._advance_prefills()
        if any(
            s is not None and s["phase"] == "decode"
            and s["steps"] < s["req"].max_new
            for s in self._slots
        ):
            t0 = time.perf_counter()
            self.state = self._decode_fn(self.params, self.state)
            if self.scfg.sync_each_step:
                jax.block_until_ready(self.state.out_len)
            now = time.perf_counter()
            if self.scfg.sync_each_step:
                self.decode_step_times.append(now - t0)
            self.decode_steps += 1
            for occ in self._slots:
                if occ is not None and occ["phase"] == "decode":
                    if occ["steps"] < occ["req"].max_new:
                        occ["t_toks"].append(now)
                    occ["steps"] += 1
        return done

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self._slots)

    def run(
        self,
        requests: list[Request],
        token_cb=None,
        drain_every: int = 0,
    ) -> list[FinishedRequest]:
        """Serve a batch of requests to completion (submit-all load).

        ``token_cb(rid, index, token, dispatch_t)`` streams tokens as they
        reach the host: on each eviction wave (free — rides the existing
        device_get) and, if ``drain_every`` > 0, every that-many ticks via
        :meth:`drain`."""
        self._token_cb = token_cb
        for r in requests:
            self.submit(r)
        finished: list[FinishedRequest] = []
        guard = 0
        limit = (
            10_000
            + sum(r.max_new for r in requests) * 4
            + sum(len(r.prompt) for r in requests)
        )
        while not self.idle:
            finished.extend(self.step())
            guard += 1
            if drain_every and guard % drain_every == 0:
                self.drain()
            if guard > limit:  # pragma: no cover
                raise RuntimeError("serve loop failed to converge")
        finished.extend(self._evict_finished())
        return finished
