"""Train→serve checkpoint promotion: pull ONE replica's weights out of a
NoLoCo training checkpoint and hand them to the inference engine.

NoLoCo never fully synchronizes its replicas (paper §1) — a checkpoint holds
an ENSEMBLE of R distinct weight sets, stacked along a leading replica axis,
plus each replica's outer anchor φ.  Promotion therefore has to choose:

  * ``replica`` — which ensemble member;
  * ``source`` — ``"theta"`` (the fast inner weights: freshest, carries the
    last partial inner loop) or ``"phi"`` (the outer anchor: the smoothed
    Eq. 2–3 state, what the paper evaluates after averaging).

Elastic runs can checkpoint with replicas dropped from the gossip.  A frozen
replica's θ stopped moving at its last active round, so promoting it silently
would serve stale weights — the saved membership mask is validated and a
frozen/out-of-range choice warns and falls back to the first ACTIVE replica.

Supported layouts (see train/adapters.py state_pytree):
  * gossip / elastic: {"theta", "outer": {"phi", ...}, "membership", ...}
  * distributed (shard_map): {"theta", "phi", "delta", ...}
  * pipeline: {"params": [per-stage], ...} — stage-partitioned, NOT
    promotable to a single serving model; raises with a pointer.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib

__all__ = ["promote", "resolve_replica", "truncate_layers"]


def resolve_replica(membership: dict | None, replica: int, world: int) -> int:
    """Validate ``replica`` against the checkpoint's membership; warn and
    fall back to the first active replica when it is frozen or out of range."""
    mask = None
    if membership is not None:
        mask = np.asarray(membership["mask"], dtype=bool)
        world = int(mask.shape[0])
    if 0 <= replica < world and (mask is None or mask[replica]):
        return replica
    if mask is not None and mask.any():
        fallback = int(np.flatnonzero(mask)[0])
        reason = (
            f"out of range (world={world})"
            if not 0 <= replica < world
            else "frozen in the saved membership (dropped from the gossip)"
        )
        warnings.warn(
            f"replica {replica} is {reason}; promoting first active replica "
            f"{fallback} instead",
            stacklevel=2,
        )
        return fallback
    if 0 <= replica < world:
        return replica
    fallback = 0
    warnings.warn(
        f"replica {replica} out of range (world={world}); promoting replica 0",
        stacklevel=2,
    )
    return fallback


def promote(
    ckpt_dir: str,
    *,
    step: int | None = None,
    replica: int = 0,
    source: str = "theta",
) -> tuple[Any, dict]:
    """Load a training checkpoint and extract one replica's serving params.

    Returns ``(params, info)``: a plain value tree matching
    ``models.model.init_params`` structure, and an info dict with the
    resolved ``{"step", "replica", "source", "world"}``."""
    if source not in ("theta", "phi"):
        raise ValueError(f"source must be 'theta' or 'phi', got {source!r}")
    if step is None:
        step = ckpt_lib.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    tree = ckpt_lib.restore(ckpt_dir, step)
    prog = tree.get("program", tree)

    if "params" in prog and "theta" not in prog:
        raise ValueError(
            "pipeline checkpoints hold stage-partitioned params and cannot "
            "be promoted to a single serving model; re-train with the gossip "
            "or distributed runtime, or stitch stages offline"
        )
    if "theta" not in prog:
        raise ValueError(
            f"unrecognized checkpoint layout: keys {sorted(prog)} — expected "
            "a gossip/distributed training checkpoint"
        )

    if source == "theta":
        stacked = prog["theta"]
    elif "outer" in prog:           # gossip layout
        stacked = prog["outer"]["phi"]
    elif "phi" in prog:             # distributed layout
        stacked = prog["phi"]
    else:
        raise ValueError("checkpoint has no outer state; use source='theta'")

    leaves = jax.tree.leaves(stacked)
    if not leaves:
        raise ValueError("checkpoint weight tree is empty")
    world = int(np.asarray(leaves[0]).shape[0])
    replica = resolve_replica(prog.get("membership"), replica, world)

    params = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[replica]), stacked)
    info = {"step": int(step), "replica": int(replica), "source": source, "world": world}
    return params, info


def truncate_layers(params: Any, cfg: Any, num_layers: int) -> tuple[Any, Any]:
    """Depth-truncated draft model: keep the FIRST ``num_layers`` blocks of a
    promoted (plain-value) param tree, sharing embed / final norm / unembed.

    A truncated slice of the SAME replica is the cheapest speculative-decode
    draft when only one NoLoCo replica is promoted: early layers dominate
    next-token agreement, so the slice proposes well while costing a fraction
    of a full second replica.  Truncation must respect the layer-cycle
    structure (``cfg.attn_pattern`` periods scanned as stacks + an unrolled
    remainder): full periods slice the stacks' depth axis, the leftover
    layers of the first partial period are pulled out of the stacks into the
    remainder list.  Returns ``(draft_params, draft_cfg)`` ready for
    ``SpecServeEngine``."""
    from repro.models import transformer as tfm

    if not 1 <= num_layers <= cfg.num_layers:
        raise ValueError(
            f"num_layers must be in [1, {cfg.num_layers}], got {num_layers}"
        )
    period, n_full, _rem = tfm.layer_plan(cfg)
    p = len(period)
    n_full2, rem2 = num_layers // p, num_layers % p
    stack = params["stack"]
    scan2 = [
        (jax.tree.map(lambda x: x[:n_full2], s) if n_full2 and s is not None else None)
        for s in stack["scan"]
    ]
    rem_list = []
    for j in range(rem2):
        if n_full2 < n_full:
            # layer n_full2·p + j lives at depth n_full2 of scan stack j
            rem_list.append(jax.tree.map(lambda x: x[n_full2], stack["scan"][j]))
        else:
            rem_list.append(stack["rem"][j])
    draft_params = dict(params)
    draft_params["stack"] = {"scan": scan2, "rem": rem_list}
    return draft_params, dataclasses.replace(cfg, num_layers=num_layers)
