"""Multi-replica request routing.

A NoLoCo checkpoint promotes to SEVERAL serving models (one per ensemble
replica), and nothing forces them behind one engine: each replica gets its
own :class:`~repro.serve.engine.ServeEngine` (own page pool, own slots) and
the router spreads requests across them.  Because every engine serving the
same ``ModelConfig`` resolves its decode/prefill/chunk programs through the
module-level ``functools.lru_cache`` factories in :mod:`repro.serve.engine`,
N replicas compile ONCE — the router adds replicas, not programs.

Policies:
  * ``round_robin`` — requests cycle through replicas in submission order;
    deterministic, good when replicas and requests are uniform.
  * ``least_loaded`` — each request goes to the replica with the fewest
    queued + in-flight tokens of pending work; absorbs skewed request sizes.

Routing is exactness-preserving by construction: engines never share
mutable state, and a request's tokens depend only on (params, request id,
prompt) — not on which replica decodes it when replicas serve the same
promoted weights.  With DIFFERENT replicas the ensemble's outputs differ
per replica, which is the point of serving them all.
"""

from __future__ import annotations

from typing import Sequence

from repro.serve.engine import FinishedRequest, Request, ServeEngine

__all__ = ["ReplicaRouter"]


class ReplicaRouter:
    """Fan requests out over a pool of promoted ServeEngines."""

    def __init__(self, engines: Sequence[ServeEngine], policy: str = "least_loaded"):
        if not engines:
            raise ValueError("router needs at least one engine")
        if policy not in ("round_robin", "least_loaded"):
            raise ValueError(f"unknown routing policy {policy!r}")
        self.engines = list(engines)
        self.policy = policy
        self._rr = 0
        self.routed: list[int] = [0] * len(self.engines)

    def _load(self, eng: ServeEngine) -> int:
        """Pending work in tokens: queued prompts+budgets plus the remaining
        budget of every occupied slot."""
        load = sum(len(r.prompt) + r.max_new for r in eng.queue)
        for occ in eng._slots:
            if occ is None:
                continue
            req = occ["req"]
            left = len(req.prompt) - occ.get("cursor", len(req.prompt))
            load += left + max(req.max_new - occ["steps"], 0)
        return load

    def pick(self) -> int:
        if self.policy == "round_robin":
            i = self._rr % len(self.engines)
            self._rr += 1
            return i
        loads = [self._load(e) for e in self.engines]
        return loads.index(min(loads))

    def submit(self, req: Request) -> int:
        """Route one request; returns the replica index it landed on."""
        i = self.pick()
        self.engines[i].submit(req)
        self.routed[i] += 1
        return i

    @property
    def idle(self) -> bool:
        return all(e.idle for e in self.engines)

    def step(self) -> list[tuple[int, FinishedRequest]]:
        """One tick of every non-idle engine; returns (replica, finished)."""
        done: list[tuple[int, FinishedRequest]] = []
        for i, eng in enumerate(self.engines):
            if not eng.idle:
                done.extend((i, f) for f in eng.step())
        return done

    def run(self, requests: Sequence[Request]) -> list[tuple[int, FinishedRequest]]:
        """Route and serve a request batch to completion."""
        for r in requests:
            self.submit(r)
        finished: list[tuple[int, FinishedRequest]] = []
        guard = 0
        limit = 10_000 + sum(len(r.prompt) + r.max_new for r in requests) * 4
        while not self.idle:
            finished.extend(self.step())
            guard += 1
            if guard > limit:  # pragma: no cover
                raise RuntimeError("router loop failed to converge")
        for i, eng in enumerate(self.engines):
            finished.extend((i, f) for f in eng._evict_finished())
        return finished
