"""Adapters wrapping the three runtimes as :class:`TrainProgram`\\ s.

  * :class:`GossipProgram`      — stacked simulation (:class:`repro.core.
    GossipTrainer`): replicas on a leading vmap axis, CPU-friendly.
  * :class:`DistributedProgram` — shard_map runtime (:class:`repro.launch.
    train_distributed.DistributedTrainer`): per-replica shards on a device
    mesh, ppermute gossip from a precompiled pairing pool.
  * :class:`PipelineProgram`    — routed pipeline (:class:`repro.pipeline.
    PipelineTrainer`): §3.1 random routing + per-stage §3.2 gossip.

Each adapter owns exactly three concerns: batch-layout conversion, the
checkpoint pytree round trip (``state_pytree`` / ``load_state_pytree``), and
the static :class:`~repro.comm.bytes_model.CommCost` of one outer step.  All
training math stays in the wrapped runtime.
"""

from __future__ import annotations

from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm import CommConfig, bytes_model
from repro.core import metrics as metrics_lib
from repro.core import pairing as pairing_lib
from repro.core.noloco import GossipTrainer, TrainState, TrainerConfig
from repro.core.outer import OuterState
from repro.core.pairing import Membership
from repro.models import model as model_api
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.optim import AdamWState
from repro.parallel.sharding import ShardCtx
from repro.pipeline import PipelineTrainer
from repro.pipeline.runner import init_stage_params

PyTree = Any

__all__ = ["GossipProgram", "DistributedProgram", "PipelineProgram"]


def _one_replica(tree: PyTree) -> PyTree:
    """abstract single-replica view of a stacked tree (for byte costing)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree
    )


def _cost(tree_one: PyTree, comm: CommConfig, method: str, world: int):
    if method in ("none", "fsdp"):
        return None
    return bytes_model.outer_step_cost(
        tree_one, comm, method=method, world=world
    )


# ---------------------------------------------------------------------------
# Stacked simulation
# ---------------------------------------------------------------------------


class GossipProgram:
    """Stacked-simulation runtime: :class:`GossipTrainer` under one jit.

    Elastic membership (DESIGN.md §7): the program carries an epoch-stamped
    :class:`~repro.core.pairing.Membership` over its replica slots plus an
    optional network-partition view, and draws every round's pairing with
    :func:`~repro.core.pairing.elastic_partner_table` — inactive replicas are
    frozen in both inner and outer steps, a replica whose partner misses the
    round self-pairs (pure self-momentum, the odd-world sit-out path), and
    eval/weight-std aggregate over ACTIVE replicas only.  ``round_absent``
    names stragglers for the NEXT outer round only (participation, not
    membership — it clears once consumed).  Membership and partition ride in
    the checkpoint pytree, so a resumed run reproduces the elastic trajectory.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        *,
        replicas: int,
        seed: int = 0,
        membership: Membership | None = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.replicas = replicas
        self.seed = seed
        self.membership = membership or Membership.full(replicas)
        if self.membership.world != replicas:
            raise ValueError(
                f"membership world {self.membership.world} != replicas {replicas}"
            )
        self.partition: tuple[tuple[int, ...], ...] | None = None
        self.round_absent: frozenset[int] = frozenset()
        # the pairing the LAST outer round actually used ((world,) ndarray,
        # None for diloco's all-reduce) — the audit source for SimCluster
        # history / telemetry, never recomputed downstream
        self.last_partner: np.ndarray | None = None
        ctx = ShardCtx.local()

        def loss_fn(params, batch, rng):
            return model_api.loss_fn(params, cfg, batch, ctx)[0]

        self.trainer = GossipTrainer(tcfg, loss_fn)
        self._inner_jit = jax.jit(self.trainer.inner_step)
        self._eval_jit = jax.jit(self.trainer.eval_loss)

    # -- membership ---------------------------------------------------------

    @property
    def membership_epoch(self) -> int:
        return self.membership.epoch

    def set_membership(self, membership: Membership) -> None:
        if membership.world != self.replicas:
            raise ValueError(
                f"membership world {membership.world} != replicas {self.replicas}"
            )
        self.membership = membership

    def set_partition(self, groups) -> None:
        """Restrict pairings to partition components (None heals)."""
        self.partition = (
            None if groups is None else tuple(tuple(int(r) for r in g) for g in groups)
        )

    def _active_arr(self) -> jnp.ndarray | None:
        """(world,) bool mask for the inner step, or None when everyone is in
        (keeps the healthy path's compiled signature untouched)."""
        if self.membership.is_full:
            return None
        return jnp.asarray(self.membership.active_array())

    # -- TrainProgram -------------------------------------------------------

    def init_state(self, example_batch: dict) -> TrainState:
        one = values_of(model_api.init_params(jax.random.PRNGKey(self.seed), self.cfg))
        stacked = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (self.replicas,) + v.shape), one
        )
        return self.trainer.init(stacked)

    def inner_step(self, state, batch, rng):
        active = self._active_arr()
        if active is None:
            return self._inner_jit(state, batch, rng)
        state, metrics = self._inner_jit(state, batch, rng, active)
        # frozen replicas' stale-weight losses are not training signal: the
        # loop's mean (and telemetry) sees active replicas only, consistent
        # with eval_step/weight_std
        ids = jnp.asarray(self.membership.active_ids)
        metrics = dict(metrics, loss=jnp.take(metrics["loss"], ids))
        return state, metrics

    def maybe_outer_step(self, state):
        if not self.trainer.should_sync(state):
            return state, False
        absent, self.round_absent = self.round_absent, frozenset()
        absent = absent & set(self.membership.active_ids)
        if absent == set(self.membership.active_ids):
            # every live replica timed out this round: nobody exchanges, the
            # round still happens (the outer counter must advance so the
            # schedule stays aligned across the cluster)
            self.last_partner = np.arange(self.replicas)
            active = jnp.zeros((self.replicas,), bool)
            return self.trainer.outer_step(
                state, partner=jnp.asarray(self.last_partner), active=active
            ), True
        participants = self.membership.without(absent)
        partner = None
        self.last_partner = None
        if self.tcfg.outer.method == "noloco":
            self.last_partner = pairing_lib.elastic_partner_table(
                int(state.outer.step), participants,
                seed=self.tcfg.outer.seed, groups=self.partition,
            )
            partner = jnp.asarray(self.last_partner)
        active = None
        if not participants.is_full:
            active = jnp.asarray(participants.active_array())
        return self.trainer.outer_step(state, partner=partner, active=active), True

    def eval_step(self, state, batch, rng) -> float:
        losses = self._eval_jit(state.theta, batch, rng)
        return float(jnp.mean(losses[jnp.asarray(self.membership.active_ids)]))

    def weight_std(self, state) -> float:
        """Cross-replica weight std over ACTIVE replicas (a dropped replica's
        stale weights are not part of the ensemble)."""
        if self.membership.num_active < 2:
            return 0.0
        ids = jnp.asarray(self.membership.active_ids)
        theta = jax.tree.map(lambda x: jnp.take(x, ids, axis=0), state.theta)
        return float(metrics_lib.replica_weight_std(theta))

    def state_pytree(self, state: TrainState) -> dict:
        part = np.full((self.replicas,), -1, dtype=np.int64)
        if self.partition is not None:
            for gid, group in enumerate(self.partition):
                for r in group:
                    part[r] = gid
        return {
            "theta": state.theta,
            "opt": {"mu": state.opt.mu, "nu": state.opt.nu, "count": state.opt.count},
            "outer": {
                "phi": state.outer.phi,
                "delta": state.outer.delta,
                "step": state.outer.step,
            },
            "inner_step": state.inner_step,
            "membership": {
                "mask": np.asarray(self.membership.mask, dtype=bool),
                "epoch": np.int64(self.membership.epoch),
                "partition": part,
            },
        }

    def load_state_pytree(self, state: TrainState, tree: dict) -> TrainState:
        if "membership" in tree:
            mem = tree["membership"]
            self.membership = Membership(
                world=self.replicas,
                mask=tuple(bool(b) for b in np.asarray(mem["mask"])),
                epoch=int(mem["epoch"]),
            )
            part = np.asarray(mem["partition"])
            if (part >= 0).any():
                groups = [
                    tuple(int(i) for i in np.nonzero(part == g)[0])
                    for g in sorted(set(int(p) for p in part if p >= 0))
                ]
                self.partition = tuple(groups)
            else:
                self.partition = None
        return TrainState(
            theta=tree["theta"],
            opt=AdamWState(
                mu=tree["opt"]["mu"], nu=tree["opt"]["nu"],
                count=jnp.asarray(tree["opt"]["count"]),
            ),
            outer=OuterState(
                phi=tree["outer"]["phi"], delta=tree["outer"]["delta"],
                step=jnp.asarray(tree["outer"]["step"]),
            ),
            inner_step=jnp.asarray(tree["inner_step"]),
        )

    def comm_cost(self):
        one = jax.eval_shape(
            lambda: values_of(
                model_api.init_params(jax.random.PRNGKey(0), self.cfg)
            )
        )
        return _cost(one, self.tcfg.comm, self.tcfg.outer.method, self.replicas)


# ---------------------------------------------------------------------------
# shard_map runtime
# ---------------------------------------------------------------------------


class DistributedProgram:
    """Mesh runtime: wraps a configured ``DistributedTrainer``.

    Stacked ``(R, B, S)`` loader batches are flattened to the global
    replica-major ``(R*B, S)`` rows the shard_map step consumes."""

    def __init__(self, trainer):
        self.trainer = trainer
        self.replicas = trainer.plan.replicas

    @staticmethod
    def _to_global(batch: dict) -> dict:
        return {
            k: jnp.asarray(np.asarray(v).reshape(-1, np.asarray(v).shape[-1]))
            for k, v in batch.items()
        }

    def init_state(self, example_batch: dict):
        return self.trainer.init_state(self._to_global(example_batch))

    def inner_step(self, state, batch, rng):
        return self.trainer.inner_step(state, self._to_global(batch))

    def maybe_outer_step(self, state):
        return self.trainer.maybe_outer_step(state)

    def eval_step(self, state, batch, rng) -> float:
        losses = self.trainer.eval_loss(state, self._to_global(batch))
        return float(jnp.mean(losses))

    def weight_std(self, state) -> float:
        return float(metrics_lib.replica_weight_std(state["theta"]))

    def state_pytree(self, state) -> dict:
        tree = {
            "theta": state["theta"],
            "opt": {
                "mu": state["opt"].mu, "nu": state["opt"].nu,
                "count": state["opt"].count,
            },
            "phi": state["phi"],
            "delta": state["delta"],
            "outer_step": state["outer_step"],
            "inner_step": np.int64(state["inner_step"]),
        }
        if "phi_pre" in state:
            tree["phi_pre"] = state["phi_pre"]
        return tree

    def load_state_pytree(self, state, tree) -> dict:
        b = self.trainer.bundle
        put = jax.device_put
        new = dict(
            state,
            theta=put(tree["theta"], b.theta_shardings),
            opt=AdamWState(
                mu=put(tree["opt"]["mu"], b.opt_shardings.mu),
                nu=put(tree["opt"]["nu"], b.opt_shardings.nu),
                count=put(jnp.asarray(tree["opt"]["count"]), b.opt_shardings.count),
            ),
            phi=put(tree["phi"], b.theta_shardings),
            delta=put(tree["delta"], b.theta_shardings),
            outer_step=put(
                jnp.asarray(tree["outer_step"]), state["outer_step"].sharding
            ),
            inner_step=int(tree["inner_step"]),
        )
        if "phi_pre" in tree:
            new["phi_pre"] = put(tree["phi_pre"], b.theta_shardings)
        elif "phi_pre" in state:
            # resuming WITH --overlap from a checkpoint written without it:
            # the partner's φ was never pre-sent, so bootstrap from our own
            # restored φ (self-copy), NOT the random-init φ_0 sitting in the
            # freshly-initialized state — that would drag mean_phi halfway
            # back to init on the first outer step.
            new["phi_pre"] = jax.tree.map(jnp.copy, new["phi"])
        return new

    def comm_cost(self):
        one = _one_replica(self.trainer.theta_struct())
        return _cost(
            one, self.trainer.comm_cfg, self.trainer.outer_cfg.method, self.replicas
        )


# ---------------------------------------------------------------------------
# Routed pipeline
# ---------------------------------------------------------------------------


class PipelineProgram:
    """Routed-pipeline runtime: §3.1 routing + per-stage §3.2 gossip."""

    def __init__(self, trainer: PipelineTrainer):
        self.trainer = trainer
        self.replicas = trainer.replicas

    def init_state(self, example_batch: dict) -> dict:
        return self.trainer.init(jax.random.PRNGKey(self.trainer.seed))

    def inner_step(self, state, batch, rng):
        state, loss = self.trainer.train_step(state, batch)
        return state, {"loss": jnp.asarray(loss)}

    def maybe_outer_step(self, state):
        return self.trainer.maybe_outer_step(state)

    def eval_step(self, state, batch, rng) -> float:
        return float(self.trainer.eval_loss(state["params"], batch))

    def weight_std(self, state) -> float:
        return self.trainer.weight_std(state)

    def state_pytree(self, state) -> dict:
        tree = {
            "params": state["params"],
            "opt": [
                {"mu": o.mu, "nu": o.nu, "count": o.count} for o in state["opt"]
            ],
            "step": np.int64(state["step"]),
        }
        if "outer" in state:
            tree["outer"] = {
                "phi": state["outer"]["phi"],
                "delta": state["outer"]["delta"],
                "step": np.int64(state["outer"]["step"]),
            }
        return tree

    def load_state_pytree(self, state, tree) -> dict:
        new = {
            "params": list(tree["params"]),
            "opt": [
                AdamWState(mu=o["mu"], nu=o["nu"], count=jnp.asarray(o["count"]))
                for o in tree["opt"]
            ],
            "step": int(tree["step"]),
        }
        if "outer" in tree:
            new["outer"] = {
                "phi": list(tree["outer"]["phi"]),
                "delta": list(tree["outer"]["delta"]),
                "step": int(tree["outer"]["step"]),
            }
        elif "outer" in state:
            # warm-starting gossip from a method=none checkpoint: slow
            # weights start AT the restored fast weights (fresh look-ahead),
            # zero momentum, outer counter aligned so the next sync fires at
            # the next m-step boundary
            m = self.trainer.outer.inner_steps
            new["outer"] = {
                "phi": [jax.tree.map(jnp.copy, p) for p in new["params"]],
                "delta": [jax.tree.map(jnp.zeros_like, p) for p in new["params"]],
                "step": new["step"] // m,
            }
        return new

    def comm_cost(self):
        tr = self.trainer
        if not tr.outer_enabled:
            return None
        # one replica's payload = all of its per-stage parameters; the stage
        # trees from init_stage_params are already single-replica
        one = {
            f"stage{s}": jax.eval_shape(
                lambda s=s: values_of(init_stage_params(
                    jax.random.PRNGKey(0), tr.cfg, s, tr.num_stages
                ))
            )
            for s in range(tr.num_stages)
        }
        return _cost(one, tr.comm, tr.outer.method, tr.replicas)
