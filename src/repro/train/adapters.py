"""Adapters wrapping the three runtimes as :class:`TrainProgram`\\ s.

  * :class:`GossipProgram`      — stacked simulation (:class:`repro.core.
    GossipTrainer`): replicas on a leading vmap axis, CPU-friendly.
  * :class:`DistributedProgram` — shard_map runtime (:class:`repro.launch.
    train_distributed.DistributedTrainer`): per-replica shards on a device
    mesh, ppermute gossip from the per-membership-view
    :class:`~repro.parallel.steps.OuterProgramPool`.
  * :class:`PipelineProgram`    — routed pipeline (:class:`repro.pipeline.
    PipelineTrainer`): §3.1 random routing + per-stage §3.2 gossip.

Each adapter owns exactly three concerns: batch-layout conversion, the
checkpoint pytree round trip (``state_pytree`` / ``load_state_pytree``), and
the static :class:`~repro.comm.bytes_model.CommCost` of one outer step.  All
training math stays in the wrapped runtime.

Elasticity is owned by ONE object across all three runtimes: a
:class:`~repro.core.elastic.ElasticContext` (membership epoch + active mask +
partner source, DESIGN.md §7).  The shared :class:`_ElasticSurface` mixin
exposes the context uniformly (``membership`` / ``membership_epoch`` /
``set_membership`` / ``set_partition`` / ``round_absent`` / ``last_partner``)
so :class:`~repro.sim.SimCluster` and the loop's membership telemetry drive
any adapter without knowing which runtime is underneath; membership rides in
every adapter's checkpoint pytree via the context's ``state_dict``.
"""

from __future__ import annotations

from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm import CommConfig, bytes_model
from repro.comm import payload as payload_lib
from repro.core import metrics as metrics_lib
from repro.core import pairing as pairing_lib
from repro.core.elastic import ElasticContext
from repro.core.noloco import GossipTrainer, TrainState, TrainerConfig
from repro.core.outer import OuterState, StreamSchedule
from repro.core.pairing import Membership
from repro.models import model as model_api
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.optim import AdamWState
from repro.parallel.sharding import ShardCtx
from repro.pipeline import PipelineTrainer
from repro.pipeline.runner import init_stage_params

PyTree = Any

__all__ = ["GossipProgram", "DistributedProgram", "PipelineProgram"]


def _one_replica(tree: PyTree) -> PyTree:
    """abstract single-replica view of a stacked tree (for byte costing)."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree
    )


def _cost(tree_one: PyTree, comm: CommConfig, method: str, world: int):
    if method in ("none", "fsdp"):
        return None
    return bytes_model.outer_step_cost(
        tree_one, comm, method=method, world=world
    )


class _ElasticSurface:
    """The uniform elastic surface over ``self.elastic`` (an
    :class:`~repro.core.elastic.ElasticContext` or None for a fixed world).

    ``membership_epoch`` is None for a fixed-world program — the loop's
    telemetry duck-types on that and stays silent."""

    elastic: ElasticContext | None

    @property
    def membership(self) -> Membership | None:
        return None if self.elastic is None else self.elastic.membership

    @property
    def membership_epoch(self) -> int | None:
        return None if self.elastic is None else self.elastic.epoch

    @property
    def partition(self):
        return None if self.elastic is None else self.elastic.partition

    @property
    def round_absent(self) -> frozenset[int]:
        return frozenset() if self.elastic is None else self.elastic.round_absent

    @round_absent.setter
    def round_absent(self, value) -> None:
        self._require_elastic().round_absent = frozenset(value)

    @property
    def last_partner(self) -> np.ndarray | None:
        return None if self.elastic is None else self.elastic.last_partner

    def set_membership(self, membership: Membership) -> None:
        self._require_elastic().set_membership(membership)

    def set_partition(self, groups) -> None:
        """Restrict pairings to partition components (None heals)."""
        self._require_elastic().set_partition(groups)

    def _require_elastic(self) -> ElasticContext:
        if self.elastic is None:
            raise ValueError(
                f"{type(self).__name__} has no ElasticContext attached; "
                "construct it with one to drive membership changes"
            )
        return self.elastic


# ---------------------------------------------------------------------------
# Stacked simulation
# ---------------------------------------------------------------------------


class GossipProgram(_ElasticSurface):
    """Stacked-simulation runtime: :class:`GossipTrainer` under one jit.

    Elastic membership (DESIGN.md §7): the program's
    :class:`~repro.core.elastic.ElasticContext` carries the epoch-stamped
    :class:`~repro.core.pairing.Membership` over its replica slots plus the
    partition view and per-round straggler set; every round's pairing comes
    from :func:`~repro.core.pairing.elastic_partner_table` via
    ``ElasticContext.plan_round`` — inactive replicas are frozen in both
    inner and outer steps, a replica whose partner misses the round
    self-pairs (pure self-momentum, the odd-world sit-out path), and
    eval/weight-std aggregate over ACTIVE replicas only.  Membership and
    partition ride in the checkpoint pytree, so a resumed run reproduces the
    elastic trajectory.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        *,
        replicas: int,
        seed: int = 0,
        membership: Membership | None = None,
        elastic: ElasticContext | None = None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.replicas = replicas
        self.seed = seed
        if elastic is None:
            elastic = ElasticContext(membership or Membership.full(replicas))
        elif membership is not None:
            raise ValueError("pass membership OR elastic, not both")
        if elastic.world != replicas:
            raise ValueError(
                f"elastic world {elastic.world} != replicas {replicas}"
            )
        self.elastic = elastic
        ctx = ShardCtx.local()

        def loss_fn(params, batch, rng):
            return model_api.loss_fn(params, cfg, batch, ctx)[0]

        self.trainer = GossipTrainer(tcfg, loss_fn)
        self._inner_jit = jax.jit(self.trainer.inner_step)
        self._eval_jit = jax.jit(self.trainer.eval_loss)

        # streaming outer steps (DESIGN.md §2): staggered per-stream syncs,
        # engaged for streams > 1 OR the φ-prefetch overlap (streams=1 +
        # overlap is the legacy §3.2 pre-send expressed as one stream)
        tcfg.comm.validate()
        self._streaming = tcfg.outer.method == "noloco" and (
            tcfg.comm.streams > 1 or tcfg.comm.overlap
        )
        if tcfg.comm.streams > 1 and tcfg.outer.method != "noloco":
            raise ValueError("streams > 1 is a noloco-only feature (gossip pairing)")
        self._schedule = None
        self._partition = None
        self._stream_events: list[dict] = []
        self._phi_pre = None
        self._pre_partner = None
        self._pre_epoch = None
        self._stream_cost = None
        if self._streaming:
            s = tcfg.comm.streams
            self._schedule = StreamSchedule(tcfg.outer.inner_steps, s)
            one = jax.eval_shape(
                lambda: values_of(
                    model_api.init_params(jax.random.PRNGKey(seed), cfg)
                )
            )
            stacked = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct((replicas,) + x.shape, x.dtype),
                one,
            )
            self._partition = payload_lib.stream_partition(
                stacked, s, fuse=tcfg.comm.fuse
            )
            self._pre_partner = np.full((s, replicas), -1, dtype=np.int64)
            self._pre_epoch = np.full((s,), -1, dtype=np.int64)

    # -- elastic runtime hooks (SimCluster drives these) ---------------------

    def inner_step_index(self, state: TrainState) -> int:
        return int(state.inner_step)

    def outer_round_index(self, state: TrainState) -> int:
        return int(state.outer.step)

    def sync_due(self, state: TrainState) -> bool:
        if self._streaming:
            return self._schedule.due(int(state.inner_step)) is not None
        return self.trainer.should_sync(state)

    def warm_start(self, state: TrainState, replica: int, source: int) -> TrainState:
        """Rejoin surgery: the comeback replica adopts a live peer's slow
        weights as BOTH its φ and θ (fresh look-ahead), zero outer momentum,
        zero inner-optimizer moments — exactly what a node that fetched φ
        from one peer and restarted would hold."""
        import dataclasses

        def adopt(x):
            return x.at[replica].set(x[source])

        def zero_row(x):
            return x.at[replica].set(jnp.zeros_like(x[replica]))

        return TrainState(
            theta=jax.tree.map(
                lambda th, p: th.at[replica].set(p[source]),
                state.theta, state.outer.phi,
            ),
            opt=AdamWState(
                mu=jax.tree.map(zero_row, state.opt.mu),
                nu=jax.tree.map(zero_row, state.opt.nu),
                count=state.opt.count.at[replica].set(0),
            ),
            outer=dataclasses.replace(
                state.outer,
                phi=jax.tree.map(adopt, state.outer.phi),
                delta=jax.tree.map(zero_row, state.outer.delta),
            ),
            inner_step=state.inner_step,
        )

    def _active_arr(self) -> jnp.ndarray | None:
        """(world,) bool mask for the inner step, or None when everyone is in
        (keeps the healthy path's compiled signature untouched)."""
        arr = self.elastic.active_array()
        return None if arr is None else jnp.asarray(arr)

    # -- TrainProgram -------------------------------------------------------

    def init_state(self, example_batch: dict) -> TrainState:
        one = values_of(model_api.init_params(jax.random.PRNGKey(self.seed), self.cfg))
        stacked = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (self.replicas,) + v.shape), one
        )
        return self.trainer.init(stacked)

    def inner_step(self, state, batch, rng):
        active = self._active_arr()
        if active is None:
            return self._inner_jit(state, batch, rng)
        state, metrics = self._inner_jit(state, batch, rng, active)
        # frozen replicas' stale-weight losses are not training signal: the
        # loop's mean (and telemetry) sees active replicas only, consistent
        # with eval_step/weight_std
        ids = jnp.asarray(self.elastic.active_ids())
        metrics = dict(metrics, loss=jnp.take(metrics["loss"], ids))
        return state, metrics

    def maybe_outer_step(self, state):
        if self._streaming:
            return self._maybe_stream_sync(state)
        if not self.trainer.should_sync(state):
            return state, False
        partner_fn = None
        if self.tcfg.outer.method == "noloco":
            step = int(state.outer.step)

            def partner_fn(parts):
                return pairing_lib.elastic_partner_table(
                    step, parts, seed=self.tcfg.outer.seed,
                    groups=self.elastic.partition,
                )

        plan = self.elastic.plan_round(partner_fn)
        partner = None if plan.partner is None else jnp.asarray(plan.partner)
        active = None if plan.active is None else jnp.asarray(plan.active)
        return self.trainer.outer_step(state, partner=partner, active=active), True

    def outer_step_async(self, state, *, sync_index: int, due, staleness):
        """One merged sync tick of the asynchronous clock (DESIGN.md §7).

        The pairing is drawn over ALL round participants at key
        ``sync_index`` (the merged-tick counter) — an involution, so non-due
        participants serve as passive sources whose in-progress (Δ, φ) the
        gather reads — but only ``due`` replicas apply the update (the
        active mask freezes everyone else).  Under ``stale="momentum"`` each
        contribution is discounted by its staleness τ before the exchange.
        A rate-1 world takes the full-participation/τ=0 fast path: the exact
        legacy synchronous call, bit for bit."""
        if self.tcfg.outer.method != "noloco":
            raise ValueError("asynchronous merged-tick sync is NoLoCo-only")
        seed = self.tcfg.outer.seed

        def partner_fn(parts):
            return pairing_lib.elastic_partner_table(
                sync_index, parts, seed=seed, groups=self.elastic.partition,
            )

        plan = self.elastic.plan_round(partner_fn)
        if plan.all_absent:
            # every member is in straggle debt: frozen no-exchange round
            return self.trainer.outer_step(
                state, partner=jnp.asarray(plan.partner),
                active=jnp.asarray(plan.active),
            ), True
        due = np.asarray(due, dtype=bool)
        tau = np.asarray(staleness)
        update = due.copy()
        if plan.active is not None:
            update &= np.asarray(plan.active, dtype=bool)
        partner = jnp.asarray(plan.partner)
        if update.all() and not tau.any():
            # everyone due, nobody late: the legacy synchronous exchange
            return self.trainer.outer_step(state, partner=partner, active=None), True
        stale_arr = None
        if self.tcfg.outer.stale == "momentum" and tau.any():
            stale_arr = jnp.asarray(tau, jnp.float32)
        return self.trainer.outer_step(
            state, partner=partner, active=jnp.asarray(update),
            staleness=stale_arr,
        ), True

    def _maybe_stream_sync(self, state):
        """One stream's staggered sync (DESIGN.md §2, streaming outer steps).

        The global sync index ``i`` — the count of stream syncs so far, which
        ``OuterState.step`` tracks — is the gossip pairing key; stream ``k``'s
        next sync is ``i + streams``, the key its φ′ pre-send travels on.  A
        prefetched φ is consumed only when the pairing it was sent along still
        holds (same membership epoch AND the recorded partner table equals
        this round's actual table); otherwise that stream alone falls back to
        the blocking (Δ, φ) exchange — churn never blocks the other streams.
        """
        t = int(state.inner_step)
        k = self._schedule.due(t)
        if k is None:
            return state, False
        i = self._schedule.sync_index(k, t)
        streams = self._schedule.stream_count
        seed = self.tcfg.outer.seed
        overlap = self.tcfg.comm.overlap

        def partner_fn(parts):
            return pairing_lib.elastic_partner_table(
                i, parts, seed=seed, groups=self.elastic.partition
            )

        plan = self.elastic.plan_round(partner_fn)
        partner = jnp.asarray(plan.partner)
        active = None if plan.active is None else jnp.asarray(plan.active)

        had_prefetch = self._pre_epoch[k] >= 0
        consume = bool(
            overlap
            and self._phi_pre is not None
            and self._pre_epoch[k] == self.elastic.epoch
            and np.array_equal(self._pre_partner[k], np.asarray(plan.partner))
        )
        partner_next = None
        next_table = None
        if overlap:
            next_table = pairing_lib.elastic_partner_table(
                i + streams, self.elastic.membership, seed=seed,
                groups=self.elastic.partition,
            )
            partner_next = jnp.asarray(next_table)

        state, phi_pre_out = self.trainer.outer_step_stream(
            state, stream=k, partition=self._partition, partner=partner,
            active=active, phi_pre=self._phi_pre, consume_prefetch=consume,
            partner_next=partner_next,
        )
        if phi_pre_out is not None:
            self._phi_pre = phi_pre_out
            self._pre_partner[k] = np.asarray(next_table)
            self._pre_epoch[k] = self.elastic.epoch

        cost = self._cost_for_streams()
        sc = cost.per_stream[k] if cost else None
        payload = sc.payload_bytes if sc else 0
        blocking = sc.blocking_bytes if (sc and consume) else payload
        self._stream_events.append({
            "stream": k,
            "offset": self._schedule.offsets[k],
            "sync_index": i,
            "payload_bytes": payload,
            "blocking_bytes": blocking,
            "overlapped_bytes": payload - blocking,
            "blocked": not consume,
            "epoch_fallback": bool(overlap and not consume and had_prefetch),
        })
        return state, True

    def _cost_for_streams(self):
        if self._stream_cost is None:
            self._stream_cost = self.comm_cost()
        return self._stream_cost

    def drain_stream_events(self) -> list[dict]:
        events, self._stream_events = self._stream_events, []
        return events

    def eval_step(self, state, batch, rng) -> float:
        losses = self._eval_jit(state.theta, batch, rng)
        return float(jnp.mean(losses[jnp.asarray(self.elastic.active_ids())]))

    def weight_std(self, state) -> float:
        """Cross-replica weight std over ACTIVE replicas (a dropped replica's
        stale weights are not part of the ensemble)."""
        if self.elastic.membership.num_active < 2:
            return 0.0
        ids = jnp.asarray(self.elastic.active_ids())
        theta = jax.tree.map(lambda x: jnp.take(x, ids, axis=0), state.theta)
        return float(metrics_lib.replica_weight_std(theta))

    def state_pytree(self, state: TrainState) -> dict:
        tree = {
            "theta": state.theta,
            "opt": {"mu": state.opt.mu, "nu": state.opt.nu, "count": state.opt.count},
            "outer": {
                "phi": state.outer.phi,
                "delta": state.outer.delta,
                "step": state.outer.step,
            },
            "inner_step": state.inner_step,
            "membership": self.elastic.state_dict(),
        }
        if self._streaming:
            # in-flight stream state: the prefetched φ buffer plus the
            # (pairing, epoch) it was pre-sent along, so a resumed run makes
            # the same consume-vs-fallback decision at every stream sync
            stream = {
                "pre_partner": np.asarray(self._pre_partner),
                "pre_epoch": np.asarray(self._pre_epoch),
            }
            if self._phi_pre is not None:
                stream["phi_pre"] = self._phi_pre
            tree["stream"] = stream
        return tree

    def load_state_pytree(self, state: TrainState, tree: dict) -> TrainState:
        if "membership" in tree:
            self.elastic.load_state_dict(tree["membership"])
        if self._streaming:
            if "stream" in tree:
                st = tree["stream"]
                self._pre_partner = np.asarray(st["pre_partner"]).astype(np.int64)
                self._pre_epoch = np.asarray(st["pre_epoch"]).astype(np.int64)
                self._phi_pre = st.get("phi_pre")
            else:
                # checkpoint written without streaming: nothing was pre-sent,
                # so every stream's first sync after resume is a blocking one
                self._pre_partner = np.full_like(self._pre_partner, -1)
                self._pre_epoch = np.full_like(self._pre_epoch, -1)
                self._phi_pre = None
        return TrainState(
            theta=tree["theta"],
            opt=AdamWState(
                mu=tree["opt"]["mu"], nu=tree["opt"]["nu"],
                count=jnp.asarray(tree["opt"]["count"]),
            ),
            outer=OuterState(
                phi=tree["outer"]["phi"], delta=tree["outer"]["delta"],
                step=jnp.asarray(tree["outer"]["step"]),
            ),
            inner_step=jnp.asarray(tree["inner_step"]),
        )

    def comm_cost(self):
        one = jax.eval_shape(
            lambda: values_of(
                model_api.init_params(jax.random.PRNGKey(0), self.cfg)
            )
        )
        return _cost(one, self.tcfg.comm, self.tcfg.outer.method, self.replicas)


# ---------------------------------------------------------------------------
# shard_map runtime
# ---------------------------------------------------------------------------


class DistributedProgram(_ElasticSurface):
    """Mesh runtime: wraps a configured ``DistributedTrainer``.

    Stacked ``(R, B, S)`` loader batches are flattened to the global
    replica-major ``(R*B, S)`` rows the shard_map step consumes.

    Elasticity: the trainer's :class:`~repro.core.elastic.ElasticContext`
    (when attached) is surfaced here exactly like the stacked program's —
    SimCluster replays fault plans against the REAL compiled path, the outer
    step comes from the per-membership-view program pool, eval/weight-std
    aggregate over active replicas, and the membership epoch rides in the
    checkpoint so resume-after-churn reproduces the trajectory exactly."""

    def __init__(self, trainer):
        self.trainer = trainer
        self.replicas = trainer.plan.replicas
        self.elastic = trainer.elastic

    @staticmethod
    def _to_global(batch: dict) -> dict:
        return {
            k: jnp.asarray(np.asarray(v).reshape(-1, np.asarray(v).shape[-1]))
            for k, v in batch.items()
        }

    # -- elastic runtime hooks ----------------------------------------------

    def inner_step_index(self, state) -> int:
        return int(state["inner_step"])

    def outer_round_index(self, state) -> int:
        if self.trainer._streaming:
            # streaming: the global sync index of the stream due at this
            # step (the pairing key the round will use)
            t = int(state["inner_step"])
            k = self.trainer._schedule.due(t)
            if k is not None:
                return self.trainer._schedule.sync_index(k, t)
        # the stacked runtime reads the outer counter BEFORE the exchange
        # (round labels are 0-indexed); mirror that from the inner counter
        return int(state["inner_step"]) // self.trainer.outer_cfg.inner_steps - 1

    def sync_due(self, state) -> bool:
        if self.trainer._streaming:
            return self.trainer._schedule.due(int(state["inner_step"])) is not None
        m = self.trainer.outer_cfg.inner_steps
        return state["inner_step"] > 0 and state["inner_step"] % m == 0

    def warm_start(self, state, replica: int, source: int):
        """Rejoin over the mesh: the peer's φ row moves across replica shards
        (a gather+scatter on the replica axis — the only cross-replica traffic
        a rejoin costs)."""
        return self.trainer.warm_start(state, replica, source)

    def drain_recompile_events(self) -> list[dict]:
        events, self.trainer.recompile_events = self.trainer.recompile_events, []
        return events

    def drain_stream_events(self) -> list[dict]:
        events, self.trainer.stream_events = self.trainer.stream_events, []
        return events

    def pool_stats(self) -> dict:
        return self.trainer.pool.stats()

    def _active_ids(self) -> jnp.ndarray | None:
        if self.elastic is None or self.elastic.is_full:
            return None
        return jnp.asarray(self.elastic.active_ids())

    # -- TrainProgram -------------------------------------------------------

    def init_state(self, example_batch: dict):
        return self.trainer.init_state(self._to_global(example_batch))

    def inner_step(self, state, batch, rng):
        state, metrics = self.trainer.inner_step(state, self._to_global(batch))
        ids = self._active_ids()
        if ids is not None:
            metrics = dict(metrics, loss=jnp.take(metrics["loss"], ids))
        return state, metrics

    def maybe_outer_step(self, state):
        return self.trainer.maybe_outer_step(state)

    def outer_step_async(self, state, *, sync_index: int, due, staleness):
        return self.trainer.outer_step_async(
            state, sync_index=sync_index, due=due, staleness=staleness
        )

    def eval_step(self, state, batch, rng) -> float:
        losses = self.trainer.eval_loss(state, self._to_global(batch))
        ids = self._active_ids()
        if ids is not None:
            losses = jnp.take(losses, ids)
        return float(jnp.mean(losses))

    def weight_std(self, state) -> float:
        ids = self._active_ids()
        theta = state["theta"]
        if ids is not None:
            if len(ids) < 2:
                return 0.0
            theta = jax.tree.map(lambda x: jnp.take(x, ids, axis=0), theta)
        return float(metrics_lib.replica_weight_std(theta))

    def state_pytree(self, state) -> dict:
        tree = {
            "theta": state["theta"],
            "opt": {
                "mu": state["opt"].mu, "nu": state["opt"].nu,
                "count": state["opt"].count,
            },
            "phi": state["phi"],
            "delta": state["delta"],
            "outer_step": state["outer_step"],
            "inner_step": np.int64(state["inner_step"]),
        }
        if "phi_pre" in state:
            tree["phi_pre"] = state["phi_pre"]
        if self.trainer._streaming:
            # in-flight stream state: the (pairing, epoch) each stream's φ′
            # was pre-sent along, so a resumed run makes the same
            # consume-vs-fallback decision at every stream sync (phi_pre
            # itself rides above as device state)
            tree["stream"] = {
                "pre_partner": np.asarray(self.trainer._pre_partner),
                "pre_epoch": np.asarray(self.trainer._pre_epoch),
            }
        if self.elastic is not None:
            tree["membership"] = self.elastic.state_dict()
        return tree

    def load_state_pytree(self, state, tree) -> dict:
        if "membership" in tree and self.elastic is not None:
            self.elastic.load_state_dict(tree["membership"])
        b = self.trainer.bundle
        put = jax.device_put
        new = dict(
            state,
            theta=put(tree["theta"], b.theta_shardings),
            opt=AdamWState(
                mu=put(tree["opt"]["mu"], b.opt_shardings.mu),
                nu=put(tree["opt"]["nu"], b.opt_shardings.nu),
                count=put(jnp.asarray(tree["opt"]["count"]), b.opt_shardings.count),
            ),
            phi=put(tree["phi"], b.theta_shardings),
            delta=put(tree["delta"], b.theta_shardings),
            outer_step=put(
                jnp.asarray(tree["outer_step"]), state["outer_step"].sharding
            ),
            inner_step=int(tree["inner_step"]),
        )
        if self.trainer._streaming:
            if "stream" in tree:
                st = tree["stream"]
                self.trainer._pre_partner = np.asarray(
                    st["pre_partner"]).astype(np.int64)
                self.trainer._pre_epoch = np.asarray(
                    st["pre_epoch"]).astype(np.int64)
            else:
                # checkpoint written without streaming: nothing was pre-sent,
                # so every stream's first sync after resume blocks once
                self.trainer._pre_partner = np.full_like(
                    self.trainer._pre_partner, -1)
                self.trainer._pre_epoch = np.full_like(
                    self.trainer._pre_epoch, -1)
        if "phi_pre" in tree:
            new["phi_pre"] = put(tree["phi_pre"], b.theta_shardings)
        elif "phi_pre" in state:
            # resuming WITH --overlap from a checkpoint written without it:
            # the partner's φ was never pre-sent, so bootstrap from our own
            # restored φ (self-copy), NOT the random-init φ_0 sitting in the
            # freshly-initialized state — that would drag mean_phi halfway
            # back to init on the first outer step.
            new["phi_pre"] = jax.tree.map(jnp.copy, new["phi"])
        return new

    def comm_cost(self):
        one = _one_replica(self.trainer.theta_struct())
        return _cost(
            one, self.trainer.comm_cfg, self.trainer.outer_cfg.method, self.replicas
        )


# ---------------------------------------------------------------------------
# Routed pipeline
# ---------------------------------------------------------------------------


class PipelineProgram(_ElasticSurface):
    """Routed-pipeline runtime: §3.1 routing + per-stage §3.2 gossip.

    Elasticity: the trainer's :class:`~repro.core.elastic.ElasticContext`
    restricts routing permutations to the active set and draws every stage's
    gossip pairing over the active members only (inactive stage-replicas are
    frozen, carry no routed traffic, and never appear in a pairing)."""

    def __init__(self, trainer: PipelineTrainer):
        self.trainer = trainer
        self.replicas = trainer.replicas
        self.elastic = trainer.elastic

    def init_state(self, example_batch: dict) -> dict:
        return self.trainer.init(jax.random.PRNGKey(self.trainer.seed))

    def inner_step(self, state, batch, rng):
        state, loss = self.trainer.train_step(state, batch)
        return state, {"loss": jnp.asarray(loss)}

    def maybe_outer_step(self, state):
        return self.trainer.maybe_outer_step(state)

    def eval_step(self, state, batch, rng) -> float:
        return float(self.trainer.eval_loss(state["params"], batch))

    def weight_std(self, state) -> float:
        return self.trainer.weight_std(state)

    def state_pytree(self, state) -> dict:
        tree = {
            "params": state["params"],
            "opt": [
                {"mu": o.mu, "nu": o.nu, "count": o.count} for o in state["opt"]
            ],
            "step": np.int64(state["step"]),
        }
        if "outer" in state:
            tree["outer"] = {
                "phi": state["outer"]["phi"],
                "delta": state["outer"]["delta"],
                "step": np.int64(state["outer"]["step"]),
            }
        if self.elastic is not None:
            tree["membership"] = self.elastic.state_dict()
        return tree

    def load_state_pytree(self, state, tree) -> dict:
        if "membership" in tree and self.elastic is not None:
            self.elastic.load_state_dict(tree["membership"])
        new = {
            "params": list(tree["params"]),
            "opt": [
                AdamWState(mu=o["mu"], nu=o["nu"], count=jnp.asarray(o["count"]))
                for o in tree["opt"]
            ],
            "step": int(tree["step"]),
        }
        if "outer" in tree:
            new["outer"] = {
                "phi": list(tree["outer"]["phi"]),
                "delta": list(tree["outer"]["delta"]),
                "step": int(tree["outer"]["step"]),
            }
        elif "outer" in state:
            # warm-starting gossip from a method=none checkpoint: slow
            # weights start AT the restored fast weights (fresh look-ahead),
            # zero momentum, outer counter aligned so the next sync fires at
            # the next m-step boundary
            m = self.trainer.outer.inner_steps
            new["outer"] = {
                "phi": [jax.tree.map(jnp.copy, p) for p in new["params"]],
                "delta": [jax.tree.map(jnp.zeros_like, p) for p in new["params"]],
                "step": new["step"] // m,
            }
        return new

    def comm_cost(self):
        tr = self.trainer
        if not tr.outer_enabled:
            return None
        # one replica's payload = all of its per-stage parameters; the stage
        # trees from init_stage_params are already single-replica
        one = {
            f"stage{s}": jax.eval_shape(
                lambda s=s: values_of(init_stage_params(
                    jax.random.PRNGKey(0), tr.cfg, s, tr.num_stages
                ))
            )
            for s in range(tr.num_stages)
        }
        return _cost(one, tr.comm, tr.outer.method, tr.replicas)
