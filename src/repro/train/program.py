"""The :class:`TrainProgram` protocol — the contract between a runtime and
the unified :class:`~repro.train.loop.TrainLoop`.

A *program* owns the compiled step functions and the runtime-specific state
layout (stacked simulation, shard_map mesh, routed pipeline); the *loop* owns
everything runtime-agnostic: the step loop, eval cadence, wall-clock and
tokens/s accounting, comm-bytes accounting, the JSONL telemetry stream and
checkpoint/resume.  Batches always arrive stacked — ``{tokens, labels}`` of
shape ``(replicas, per_replica_batch, seq)`` from :func:`repro.data.
shard_iterator`; a program that wants a different layout (the shard_map
runtime consumes global ``(R*B, S)`` rows) converts internally.
"""

from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax

from repro.comm.bytes_model import CommCost

PyTree = Any

__all__ = ["TrainProgram"]


@runtime_checkable
class TrainProgram(Protocol):
    """What a runtime must provide to be driven by :class:`TrainLoop`.

    Elastic programs (adapters with a :class:`~repro.core.elastic.
    ElasticContext` attached, and the :class:`~repro.sim.SimCluster`
    decorator over them) additionally expose ``membership`` (an epoch-stamped
    :class:`~repro.core.pairing.Membership`) and ``membership_epoch``; the
    loop duck-types on their presence to emit ``membership`` telemetry events
    when the view changes and otherwise ignores them — a fixed-world program
    needs neither.  Programs with a compiled-program pool may also expose
    ``drain_recompile_events()`` / ``pool_stats()``; the loop surfaces those
    as ``recompile`` events and the ``run_end`` pool summary.

    To be DRIVEN BY SimCluster a program must further provide the elastic
    runtime hooks: ``inner_step_index(state)``, ``outer_round_index(state)``,
    ``sync_due(state)`` and ``warm_start(state, replica, source)`` (see
    :class:`repro.train.adapters._ElasticSurface` and the two elastic
    adapters for the contract).
    """

    #: number of gossip replicas (the leading axis of stacked batches)
    replicas: int

    def init_state(self, example_batch: dict) -> Any:
        """Build (and compile against) the initial training state.

        ``example_batch`` is a stacked batch used only for shapes — the loop
        draws it from a throwaway iterator so training consumes the exact
        deterministic stream from ``start_step`` onward."""
        ...

    def inner_step(self, state: Any, batch: dict, rng: jax.Array) -> tuple[Any, dict]:
        """One local optimizer step on every replica; returns (state, metrics)
        where ``metrics["loss"]`` holds per-replica losses."""
        ...

    def maybe_outer_step(self, state: Any) -> tuple[Any, bool]:
        """Run the outer (gossip/all-reduce) step iff due; returns
        (state, synced)."""
        ...

    def eval_step(self, state: Any, batch: dict, rng: jax.Array) -> float:
        """Grad-free mean eval loss across replicas for one stacked batch."""
        ...

    def weight_std(self, state: Any) -> float:
        """Cross-replica weight std (paper Fig. 3B / Fig. 4A diagnostic)."""
        ...

    def state_pytree(self, state: Any) -> Any:
        """Checkpoint view: a plain pytree (dicts/lists/arrays only) holding
        EVERYTHING needed to resume — θ, φ, δ, inner-opt moments, step
        counters.  Must round-trip through :mod:`repro.checkpoint`."""
        ...

    def load_state_pytree(self, state: Any, tree: Any) -> Any:
        """Rebuild runtime state from a restored checkpoint pytree.

        ``state`` is a freshly-initialized state (``init_state`` has already
        run) so programs can reuse its structure/shardings/compiled fns."""
        ...

    def comm_cost(self) -> CommCost | None:
        """Static per-replica cost of ONE outer step (bytes/messages/blocking
        split) under the configured codec, or None when the runtime never
        communicates (method="none")."""
        ...
