"""Unified training engine: one loop, three runtimes, resumable end-to-end.

See DESIGN.md §2.  :class:`TrainLoop` drives any :class:`TrainProgram`
(stacked simulation, shard_map mesh, routed pipeline) with shared eval
cadence, throughput/comm accounting, JSONL telemetry and checkpoint/resume.
"""

from repro.train.adapters import DistributedProgram, GossipProgram, PipelineProgram
from repro.train.loop import LoopConfig, TrainLoop, make_loop
from repro.train.program import TrainProgram

__all__ = [
    "DistributedProgram",
    "GossipProgram",
    "LoopConfig",
    "PipelineProgram",
    "TrainLoop",
    "TrainProgram",
    "make_loop",
]
