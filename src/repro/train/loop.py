"""The one training loop driving every runtime (see DESIGN.md §2).

Owns the runtime-agnostic half of training:

  * the step loop with warmup/eval cadence,
  * wall-clock + tokens/s throughput accounting,
  * comm-bytes accounting from :mod:`repro.comm.bytes_model` (per outer
    sync: payload bytes, blocking bytes, messages),
  * a JSONL telemetry event stream (``run_start`` / ``step`` / ``outer`` /
    ``stream_sync`` / ``eval`` / ``ckpt`` / ``run_end`` events, one JSON
    object per line; ``stream_sync`` records each staggered stream exchange —
    stream id, round offset, bytes, blocked vs overlapped),
  * periodic checkpointing with FULL resume: program state (θ/φ/δ/opt/step
    counters via ``TrainProgram.state_pytree``) plus the loop's own PRNG keys
    and step cursor; the data loader is fast-forwarded deterministically
    (``make_loader(start_step)``), so a resumed run reproduces the
    uninterrupted loss trajectory exactly (tested).

Per-step PRNG keys are ``fold_in(base, t)`` rather than a split chain, so the
stream at step t is independent of eval cadence and survives resume without
replaying t splits.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Iterator

import numpy as np

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.train.program import TrainProgram

__all__ = ["LoopConfig", "TrainLoop", "make_loop"]


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    """Runtime-agnostic knobs of the training loop."""

    steps: int
    eval_every: int = 0         # 0: never evaluate mid-run
    seed: int = 0               # base of the per-step PRNG fold-in streams
    ckpt_dir: str | None = None
    ckpt_every: int = 0         # 0: only the final save (when ckpt_dir set)
    ckpt_keep: int = 3          # retained periodic checkpoints
    resume: bool = False        # restore from latest ckpt under ckpt_dir
    log_jsonl: str | None = None  # telemetry stream path (appended on resume)
    log: bool = False           # human-readable progress prints
    run_name: str = "train"     # tag in telemetry events


class TrainLoop:
    """Drive a :class:`~repro.train.program.TrainProgram` end to end.

    ``make_loader(start_step)`` must return the deterministic stacked-batch
    stream beginning at ``start_step`` (see :func:`repro.data.shard_iterator`);
    ``eval_set`` is a fixed list of stacked batches (may be empty).
    """

    def __init__(
        self,
        program: TrainProgram,
        make_loader: Callable[[int], Iterator[dict]],
        cfg: LoopConfig,
        *,
        eval_set: list[dict] | None = None,
    ):
        self.program = program
        self.make_loader = make_loader
        self.cfg = cfg
        self.eval_set = eval_set or []
        self._jsonl = None

    # -- telemetry -----------------------------------------------------------

    def _emit(self, event: str, **fields) -> None:
        if self._jsonl is None:
            return
        rec = {"event": event, "run": self.cfg.run_name, **fields}
        self._jsonl.write(json.dumps(rec) + "\n")
        self._jsonl.flush()

    # -- checkpointing -------------------------------------------------------

    def _save(self, step: int, state, rngs: dict) -> str:
        tree = {
            "program": self.program.state_pytree(state),
            "loop": {"step": np.int64(step), **rngs},
        }
        path = ckpt_lib.save(
            self.cfg.ckpt_dir, step, tree, keep=self.cfg.ckpt_keep
        )
        self._emit("ckpt", step=step, path=path)
        return path

    def _try_resume(self, state):
        """Returns (state, start_step, rngs) — restored when possible."""
        cfg = self.cfg
        base = {
            "train_key": jax.random.PRNGKey(cfg.seed + 1),
            "eval_key": jax.random.PRNGKey(cfg.seed + 777),
        }
        if not (cfg.resume and cfg.ckpt_dir):
            return state, 0, base
        step = ckpt_lib.latest_step(cfg.ckpt_dir)
        if step is None:
            return state, 0, base
        tree = ckpt_lib.restore(cfg.ckpt_dir, step)
        state = self.program.load_state_pytree(state, tree["program"])
        rngs = {
            "train_key": jnp.asarray(tree["loop"]["train_key"]),
            "eval_key": jnp.asarray(tree["loop"]["eval_key"]),
        }
        return state, int(tree["loop"]["step"]), rngs

    # -- the loop ------------------------------------------------------------

    def run(self) -> dict[str, Any]:
        cfg = self.cfg
        if cfg.log_jsonl:
            self._jsonl = open(cfg.log_jsonl, "a")

        # init against an example batch from a THROWAWAY iterator so training
        # itself consumes the exact stream from start_step on
        state = self.program.init_state(next(self.make_loader(0)))
        state, start_step, rngs = self._try_resume(state)
        loader = self.make_loader(start_step)

        cost = self.program.comm_cost()
        self._emit(
            "run_start",
            program=type(self.program).__name__,
            replicas=self.program.replicas,
            steps=cfg.steps,
            start_step=start_step,
            resumed=start_step > 0,
            comm=cost.as_dict() if cost else None,
        )

        losses: list[float] = []
        evals: list[tuple[int, float]] = []
        weight_stds: list[tuple[int, float]] = []
        outer_syncs = 0
        comm_bytes = 0
        blocking_bytes = 0
        total_tokens = 0
        recompiles = 0
        max_staleness = 0
        blocked_syncs = 0
        # elastic programs expose an epoch-stamped Membership; emit a
        # telemetry event whenever the view changes (drop / rejoin)
        last_epoch = getattr(self.program, "membership_epoch", None)
        t0 = time.time()

        for t in range(start_step, cfg.steps):
            batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
            step_t0 = time.time()
            state, metrics = self.program.inner_step(
                state, batch, jax.random.fold_in(rngs["train_key"], t)
            )
            loss = float(jnp.mean(metrics["loss"]))
            losses.append(loss)
            total_tokens += int(np.prod(batch["tokens"].shape))
            state, synced = self.program.maybe_outer_step(state)
            # elastic shard_map programs recompile at membership-view
            # boundaries (OuterProgramPool): surface every compile as its own
            # telemetry event so churn-induced stalls are visible in
            # BENCH_engine-style runs (epoch, pool slot, build + first-call
            # wall-clock, pool size)
            drain = getattr(self.program, "drain_recompile_events", None)
            if drain is not None:
                for ev in drain():
                    recompiles += 1
                    self._emit("recompile", step=t + 1, **ev)
            # async merged-tick rounds (SimCluster per-replica clocks): one
            # event per sync carrying the due set, per-replica staleness τ and
            # the blocked-participant count; the synchronous baseline emits
            # the same shape (τ≡0) so blocked/idle comparisons line up
            adrain = getattr(self.program, "drain_async_events", None)
            if adrain is not None:
                for ev in adrain():
                    max_staleness = max(max_staleness, int(ev.get("max_staleness", 0)))
                    blocked_syncs += int(ev.get("blocked", 0))
                    self._emit("outer_async", step=t + 1, **ev)
            epoch = getattr(self.program, "membership_epoch", None)
            if epoch != last_epoch:
                last_epoch = epoch
                mem = self.program.membership
                self._emit(
                    "membership", step=t + 1, epoch=epoch,
                    num_active=mem.num_active, active=list(mem.active_ids),
                )
            dt = time.time() - step_t0
            self._emit(
                "step", step=t + 1, loss=loss, dt_s=round(dt, 6),
                tokens_per_s=round(total_tokens / max(time.time() - t0, 1e-9), 1),
            )
            if synced:
                outer_syncs += 1
                # streaming programs report the ACTUAL per-stream schedule
                # (which stream synced, whether its prefetch was consumed or
                # it fell back to blocking); byte accounting then follows the
                # events instead of the static whole-payload cost
                sdrain = getattr(self.program, "drain_stream_events", None)
                sevents = sdrain() if sdrain is not None else []
                if sevents:
                    payload = sum(ev["payload_bytes"] for ev in sevents)
                    blocking = sum(ev["blocking_bytes"] for ev in sevents)
                    comm_bytes += payload
                    blocking_bytes += blocking
                    for ev in sevents:
                        self._emit("stream_sync", step=t + 1, **ev)
                    self._emit(
                        "outer", step=t + 1, sync_index=outer_syncs,
                        payload_bytes=payload, blocking_bytes=blocking,
                    )
                else:
                    if cost is not None:
                        comm_bytes += cost.payload_bytes
                        blocking_bytes += cost.blocking_bytes
                    self._emit(
                        "outer", step=t + 1, sync_index=outer_syncs,
                        payload_bytes=cost.payload_bytes if cost else 0,
                        blocking_bytes=cost.blocking_bytes if cost else 0,
                    )
            if cfg.eval_every and (t + 1) % cfg.eval_every == 0 and self.eval_set:
                ev = float(np.mean([
                    self.program.eval_step(
                        state, b, jax.random.fold_in(rngs["eval_key"], t)
                    )
                    for b in self.eval_set
                ]))
                wstd = float(self.program.weight_std(state))
                evals.append((t + 1, ev))
                weight_stds.append((t + 1, wstd))
                self._emit("eval", step=t + 1, eval_loss=ev, weight_std=wstd)
                if cfg.log:
                    print(
                        f"step {t+1}: train={loss:.4f} eval={ev:.4f} "
                        f"wstd={wstd:.6f} ({time.time()-t0:.0f}s)", flush=True
                    )
            if cfg.ckpt_dir and cfg.ckpt_every and (t + 1) % cfg.ckpt_every == 0:
                self._save(t + 1, state, rngs)

        wall = time.time() - t0
        already_saved = (
            cfg.ckpt_every and cfg.steps % cfg.ckpt_every == 0
        )
        if cfg.ckpt_dir and cfg.steps > start_step and not already_saved:
            self._save(cfg.steps, state, rngs)
        final_std = float(self.program.weight_std(state))
        tokens_per_s = total_tokens / max(wall, 1e-9)
        summary = {
            "steps_run": cfg.steps - start_step,
            "start_step": start_step,
            "wall_s": wall,
            "tokens_per_s": tokens_per_s,
            "outer_syncs": outer_syncs,
            "comm_bytes": comm_bytes,
            "blocking_bytes": blocking_bytes,
            "blocking_fraction": (
                blocking_bytes / comm_bytes if comm_bytes else 0.0
            ),
            "final_weight_std": final_std,
            "membership_epoch": last_epoch,
            "recompiles": recompiles,
            "stream_count": getattr(cost, "stream_count", 1) if cost else 1,
        }
        if getattr(self.program, "drain_async_events", None) is not None:
            summary["max_staleness"] = max_staleness
            summary["blocked_syncs"] = blocked_syncs
        stats_fn = getattr(self.program, "pool_stats", None)
        pool_stats = stats_fn() if stats_fn is not None else None
        if pool_stats is not None:
            summary["pool"] = pool_stats
        self._emit("run_end", **summary)
        if self._jsonl is not None:
            self._jsonl.close()
            self._jsonl = None
        return {
            "losses": losses,
            "evals": evals,
            "weight_stds": weight_stds,
            "state": state,
            "comm": cost.as_dict() if cost else None,
            **summary,
        }


def make_loop(
    program: TrainProgram, loader_cfg, cfg: LoopConfig, *, n_eval: int = 2
) -> TrainLoop:
    """Standard loop assembly shared by the launcher CLIs: train stream from
    ``loader_cfg`` (a :class:`repro.data.LoaderConfig`, fast-forwardable via
    ``start_step``), eval stream from the ``seed + 777`` convention."""
    from repro.data import eval_batches, shard_iterator

    eval_cfg = dataclasses.replace(loader_cfg, seed=loader_cfg.seed + 777)
    return TrainLoop(
        program,
        lambda start: shard_iterator(loader_cfg, start_step=start),
        cfg,
        eval_set=eval_batches(eval_cfg, n_eval) if cfg.eval_every else [],
    )
