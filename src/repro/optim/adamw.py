"""Pure-JAX AdamW (the paper's inner optimizer) — no optax on this box.

State and update follow Loshchilov & Hutter decoupled weight decay with bias
correction, matching torch.optim.AdamW semantics used by the paper's
reference implementation.  First/second moments are kept in float32 regardless
of parameter dtype (bf16-safe), matching standard mixed-precision practice.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # Paper §4: "gradient clipping for gradients larger than unity".
    clip_norm: float | None = 1.0

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: PyTree       # first moment  (f32)
    nu: PyTree       # second moment (f32)
    count: jax.Array  # int32 step counter


def adamw_init(params: PyTree) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree.map(zeros32, params),
        nu=jax.tree.map(zeros32, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads: PyTree, state: AdamWState, params: PyTree, cfg: AdamWConfig
) -> tuple[PyTree, AdamWState, jax.Array]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    count = state.count + 1
    lr = cfg.lr_at(count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def _moment(m, g):
        return cfg.b1 * m + (1.0 - cfg.b1) * g.astype(jnp.float32)

    def _second(v, g):
        g32 = g.astype(jnp.float32)
        return cfg.b2 * v + (1.0 - cfg.b2) * g32 * g32

    mu = jax.tree.map(_moment, state.mu, grads)
    nu = jax.tree.map(_second, state.nu, grads)

    def _param(p, m, v):
        update = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (update + cfg.weight_decay * p32)
        return p32.astype(p.dtype)

    new_params = jax.tree.map(_param, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count), gnorm
