from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedules import Schedule, constant, linear_warmup, warmup_cosine

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "Schedule",
    "constant",
    "linear_warmup",
    "warmup_cosine",
]
