"""Learning-rate schedules (paper §4: 1000-step linear warm-up, then cosine
decay to 10% of peak over the remaining steps)."""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["constant", "linear_warmup", "warmup_cosine", "Schedule"]

Schedule = Callable[[jax.Array], jax.Array]


def constant(value: float) -> Schedule:
    return lambda step: jnp.full((), value, jnp.float32)


def linear_warmup(peak: float, warmup_steps: int) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        frac = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        return peak * frac

    return fn


def warmup_cosine(
    peak: float,
    total_steps: int,
    warmup_steps: int = 1000,
    final_ratio: float = 0.1,
) -> Schedule:
    """Linear warm-up to ``peak`` over ``warmup_steps``; cosine decay to
    ``final_ratio * peak`` at ``total_steps`` (paper: decay by one magnitude)."""

    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        progress = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        floor = final_ratio * peak
        cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(math.pi * progress))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
