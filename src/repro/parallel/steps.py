"""Distributed step builders: train / prefill / decode / outer (gossip &
all-reduce), all built from the same per-replica model code via shard_map.

Pattern (see DESIGN.md): the per-replica LOSS runs inside ``shard_map`` with
manual collectives (ShardCtx); ``jax.value_and_grad`` is taken OUTSIDE the
shard_map, so JAX's shard_map transposition inserts the correct gradient
collectives (replicated-over-model params automatically get their cotangents
psum'd over the model axis — no hand-written f/g operators to get wrong).
The AdamW update is a vmap over the leading replica dim under plain GSPMD
(elementwise, partitions trivially).

The NoLoCo outer step is a shard_map whose ONLY cross-replica communication
is one ``lax.ppermute`` (collective-permute); the DiLoCo baseline outer step
uses ``lax.pmean`` (all-reduce).  Roofline reads these straight from the HLO.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm import CommConfig
from repro.core import outer as outer_lib
from repro.core import pairing as pairing_lib
from repro.core.outer import OuterConfig, OuterState
from repro.core.pairing import Membership
from repro.kernels.dispatch import KernelConfig
from repro.models import model as model_api
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.parallel import compat
from repro.parallel import plans as plans_lib
from repro.parallel.plans import Plan

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter stacking (leading replica dim)
# ---------------------------------------------------------------------------


def stack_replicas(params: PyTree, replicas: int) -> PyTree:
    """Add the leading replica dim to every Param leaf (logical "replica").

    For simulation each replica starts from the SAME weights (the paper
    initializes all instances identically: φ_{0,i} ≡ φ_0)."""
    from repro.models.common import Param, param as mk

    def stk(p: Param) -> Param:
        v = jnp.broadcast_to(p.value[None], (replicas,) + p.value.shape)
        return mk(v, "replica", *p.logical)

    return jax.tree.map(stk, params, is_leaf=lambda x: isinstance(x, Param))


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------


def batch_pspecs(plan: Plan, batch: dict) -> dict:
    """tokens/labels (B, S): batch dim over all data axes; embeds likewise.

    A batch that does not divide the data axes (e.g. long_500k's batch of 1)
    is REPLICATED — every replica decodes the same stream (ensemble decode,
    noted in DESIGN.md)."""
    dp = plan.data_axes
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    # product of data-axis sizes: replicas × fsdp covers (pod, data)
    dp_total = plan.replicas * plan.fsdp
    out = {}
    for k, v in batch.items():
        nd = v.ndim if hasattr(v, "ndim") else len(v.shape)
        b = v.shape[0]
        entry = dp_entry if (dp and b % max(dp_total, 1) == 0) else None
        out[k] = P(entry, *([None] * (nd - 1)))
    return out


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainStepBundle:
    step_fn: Callable              # (theta, opt, batch) -> (theta, opt, metrics)
    theta_shardings: PyTree
    opt_shardings: PyTree
    pspecs: PyTree                 # theta PartitionSpecs (for checkpoint/outer)
    eval_fn: Callable | None = None  # (theta, batch) -> (R,) losses, grad-free


def _squeeze_replica(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: x[0], tree)


def _unsqueeze_replica(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: x[None], tree)


def build_loss_shard(
    cfg: ModelConfig, plan: Plan, mesh: Mesh, param_specs: PyTree, batch_specs: dict
):
    """shard_map'd per-replica loss: (stacked theta, batch) -> (R,) losses."""
    ctx = plan.ctx()
    rep_entry = plan.replica_entry

    def body(theta_local, batch_local):
        theta = _squeeze_replica(theta_local)  # drop leading local replica dim
        loss, metrics = model_api.loss_fn(theta, cfg, batch_local, ctx)
        # fsdp plan: tokens are sharded over `data` WITHIN the replica — the
        # per-replica loss is the mean over data shards of the local means
        # (equal token counts per shard).
        if plan.fsdp_axis is not None and plan.fsdp > 1:
            loss = jax.lax.pmean(loss, plan.fsdp_axis)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, plan.fsdp_axis), metrics)
        out = jnp.reshape(loss, (1,))
        mets = jax.tree.map(lambda m: jnp.reshape(m, (1,)), metrics)
        return out, mets

    in_specs = (param_specs, batch_specs)
    out_specs = (P(rep_entry), {"lm_loss": P(rep_entry), "aux_loss": P(rep_entry)})
    return compat.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )


def build_train_step(
    cfg: ModelConfig,
    plan: Plan,
    mesh: Mesh,
    params: PyTree,          # Param tree WITH leading replica dim (stack_replicas)
    batch_example: dict,     # arrays or ShapeDtypeStructs
    inner: AdamWConfig,
    *,
    data_sync: bool = False,  # DDP/FSDP baseline: all-reduce grads over replicas
) -> TrainStepBundle:
    pspecs = plans_lib.param_pspecs(plan, mesh, params)
    bspecs = batch_pspecs(plan, batch_example)
    loss_shard = build_loss_shard(cfg, plan, mesh, pspecs, bspecs)
    replicas = plan.replicas

    def total_loss(theta, batch):
        losses, metrics = loss_shard(theta, batch)
        return jnp.sum(losses) / replicas, (losses, metrics)

    def step(theta, opt, batch):
        (_, (losses, metrics)), grads = jax.value_and_grad(total_loss, has_aux=True)(
            theta, batch
        )
        if data_sync and replicas > 1:
            # traditional data-parallel baseline: gradient all-reduce across
            # the replica axes EVERY step (what NoLoCo removes entirely)
            grads = jax.tree.map(
                lambda g: jnp.broadcast_to(
                    jnp.mean(g, axis=0, keepdims=True), g.shape
                ),
                grads,
            )
        new_theta, new_opt, gnorm = jax.vmap(
            lambda g, o, p: adamw_update(g, o, p, inner)
        )(grads, opt, theta)
        metrics = dict(metrics)
        metrics["loss"] = losses
        metrics["grad_norm"] = gnorm
        return new_theta, new_opt, metrics

    theta_sh = plans_lib.shardings(mesh, pspecs)
    # AdamW moments mirror param specs (f32); count is per-replica (R,)
    rep_entry = plan.replica_entry
    opt_pspecs = AdamWState(
        mu=pspecs, nu=jax.tree.map(lambda s: s, pspecs), count=P(rep_entry)
    )
    opt_sh = plans_lib.shardings(mesh, opt_pspecs)
    bsh = plans_lib.shardings(mesh, bspecs)

    jitted = jax.jit(
        step,
        in_shardings=(theta_sh, opt_sh, bsh),
        donate_argnums=(0, 1),
    )
    # grad-free eval: the same shard_map'd loss, no value_and_grad, nothing
    # donated (eval must not consume the training state)
    eval_jit = jax.jit(
        lambda theta, batch: loss_shard(theta, batch)[0],
        in_shardings=(theta_sh, bsh),
    )
    return TrainStepBundle(
        step_fn=jitted, theta_shardings=theta_sh, opt_shardings=opt_sh,
        pspecs=pspecs, eval_fn=eval_jit,
    )


def init_opt_state(params_stacked_values: PyTree, replicas: int) -> AdamWState:
    """Per-replica AdamW state over stacked params (vmapped init)."""
    return jax.vmap(adamw_init)(params_stacked_values)


# ---------------------------------------------------------------------------
# Outer step (gossip / all-reduce)
# ---------------------------------------------------------------------------


def _local_replica_index(plan: Plan, mesh: Mesh) -> jax.Array:
    """This shard's LINEARIZED replica id (pod-major), inside shard_map."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    idx = jnp.zeros((), jnp.int32)
    for a in plan.replica_axes:
        idx = idx * sizes[a] + jax.lax.axis_index(a)
    return idx


def build_outer_step(
    plan: Plan,
    mesh: Mesh,
    param_specs: PyTree,     # stacked-theta PartitionSpecs
    outer_cfg: OuterConfig,
    perm: list[tuple[int, int]] | None,
    *,
    fuse_payload: bool = False,
    comm_cfg: CommConfig | None = None,
    kernel_cfg: KernelConfig | None = None,
    active: Any | None = None,
    staleness: Any | None = None,
    stream: int | None = None,
    partition: Any | None = None,
    consume_prefetch: bool = False,
    perm_presend: list[tuple[int, int]] | None = None,
):
    """One outer step over (theta, phi, delta) -> (theta', phi', delta').

    NoLoCo: ``perm`` is the static partner permutation over the LINEARIZED
    replica axes (pod-major), realized as one collective-permute.  The
    launcher precompiles a rotating set of random matchings (pairings are
    data-independent, so a small cycling pool preserves the paper's random-
    matching statistics without per-step recompilation).

    ``comm_cfg`` selects the wire codec / payload fusing (``fuse_payload`` is
    the legacy switch for ``comm_cfg.fuse``).

    STREAMING (DESIGN.md §2, streaming outer steps): with ``stream`` set, the
    program syncs ONE stream of ``partition`` (a
    :class:`~repro.comm.StreamPartition`) via
    :func:`~repro.core.outer.outer_step_sharded_stream` — only that stream's
    leaves are exchanged over ``perm``; everything else passes through
    bit-untouched.  ``consume_prefetch`` compiles the §3.2 φ-prefetch read
    (block on the Δ permute only) and ``perm_presend`` the φ′ pre-send for
    the stream's NEXT sync; either one switches the program to the
    (theta, phi, delta, phi_pre, step)-in-and-out signature, otherwise the
    legacy (theta, phi, delta, step) signature is kept.  The legacy
    whole-payload overlap spelling (``perm_next``) was removed: a single
    stream with ``consume_prefetch`` + ``perm_presend`` is exactly that
    program, and it now composes with elastic membership (the host falls
    back per stream when the pre-send pairing's epoch is stale).

    ``active`` (optional host-side (world,) bool array) bakes this round's
    PARTICIPANT set into the program (elastic runs; the pairing ``perm``
    already self-loops non-participants): a non-participant's (θ, φ, δ) pass
    through untouched — a dropped replica is frozen, a straggler keeps inner-
    training toward a multi-m Δ — and elastic DiLoCo means over participants
    only.  ``active=None`` (the healthy path) compiles the EXACT program it
    always did, so full membership stays bit-identical to the static
    schedule.  Programs are keyed per (membership view, pairing slot, stream
    variant) by :class:`OuterProgramPool`; this builder never decides who
    participates.

    ``staleness`` (optional host-side (world,) τ vector, ASYNC merged-tick
    rounds only) bakes each shard's staleness into the program the same way
    ``active`` is baked: the per-shard τ scalar feeds
    :func:`~repro.core.outer.outer_step_sharded`'s ``staleness`` hook, which
    applies the ``stale="momentum"`` 1/(1+τ) discount to that replica's OWN
    Δ before the ppermute — the partner receives the discounted
    contribution.  Incompatible with streamed programs (async rounds do not
    compose with streaming)."""
    rep = plan.replica_axes
    rep_entry = plan.replica_entry
    if comm_cfg is None:
        comm_cfg = CommConfig(fuse=fuse_payload)
    streamed = stream is not None
    if streamed and outer_cfg.method != "noloco":
        raise ValueError("streamed outer programs are NoLoCo-only")
    if (consume_prefetch or perm_presend is not None) and not streamed:
        raise ValueError(
            "consume_prefetch/perm_presend require a streamed program: the "
            "legacy whole-payload perm_next overlap was removed — build with "
            "stream=0 and a single-stream partition instead"
        )
    prefetching = streamed and (consume_prefetch or perm_presend is not None)
    if streamed and staleness is not None:
        raise ValueError("staleness (async rounds) does not compose with streaming")
    active_host = None if active is None else np.asarray(active, dtype=bool)
    stale_host = None if staleness is None else np.asarray(staleness, dtype=np.float32)

    def body(theta_l, phi_l, delta_l, *rest):
        theta = _squeeze_replica(theta_l)
        phi = _squeeze_replica(phi_l)
        delta = _squeeze_replica(delta_l)
        flag = None
        if active_host is not None:
            flag = jnp.asarray(active_host)[_local_replica_index(plan, mesh)]
        if streamed:
            if prefetching:
                phi_pre_l, step_l = rest
                phi_pre = _squeeze_replica(phi_pre_l)
            else:
                (step_l,) = rest
                phi_pre = None
            state = OuterState(phi=phi, delta=delta, step=step_l.reshape(()))
            new_state, new_theta, phi_pre_out = outer_lib.outer_step_sharded_stream(
                state, theta, outer_cfg, stream=stream, partition=partition,
                axis_names=rep, perm=perm, phi_pre=phi_pre,
                consume_prefetch=consume_prefetch, perm_next=perm_presend,
                comm_cfg=comm_cfg, kernel_cfg=kernel_cfg, active_flag=flag,
            )
            out = (
                _unsqueeze_replica(new_theta),
                _unsqueeze_replica(new_state.phi),
                _unsqueeze_replica(new_state.delta),
            )
            if prefetching:
                # no pre-send requested but prefetch consumed: the buffer
                # passes through so the program signature stays fixed
                pre = phi_pre_out if phi_pre_out is not None else phi_pre
                out = out + (_unsqueeze_replica(pre),)
            return out + (new_state.step.reshape((1,)),)
        (step_l,) = rest
        stale = None
        if stale_host is not None:
            stale = jnp.asarray(stale_host)[_local_replica_index(plan, mesh)]
        state = OuterState(phi=phi, delta=delta, step=step_l.reshape(()))
        new_state, new_theta = outer_lib.outer_step_sharded(
            state, theta, outer_cfg, axis_names=rep, perm=perm, comm_cfg=comm_cfg,
            kernel_cfg=kernel_cfg, active_flag=flag, staleness=stale,
        )
        if flag is not None:
            # freeze non-participants: keep pre-round (θ, φ, δ); the outer
            # counter still advances so the schedule stays aligned
            _sel = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(flag, a, b), new, old
            )
            new_theta = _sel(new_theta, theta)
            new_state = OuterState(
                phi=_sel(new_state.phi, phi),
                delta=_sel(new_state.delta, delta),
                step=new_state.step,
            )
        return (
            _unsqueeze_replica(new_theta),
            _unsqueeze_replica(new_state.phi),
            _unsqueeze_replica(new_state.delta),
            new_state.step.reshape((1,)),
        )

    n_params = 4 if prefetching else 3
    in_specs = (param_specs,) * n_params + (P(rep_entry),)
    out_specs = (param_specs,) * n_params + (P(rep_entry),)
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    sh = plans_lib.shardings(mesh, param_specs)
    step_sh = NamedSharding(mesh, P(rep_entry))
    return jax.jit(
        fn,
        in_shardings=(sh,) * n_params + (step_sh,),
        donate_argnums=tuple(range(n_params)),
    )


# ---------------------------------------------------------------------------
# Per-membership-view compiled program pool
# ---------------------------------------------------------------------------


class OuterProgramPool:
    """Compiled outer-step programs keyed by (membership view, pairing slot).

    ``lax.ppermute`` needs a STATIC permutation, so the shard_map runtime
    cannot draw a fresh random matching per round without recompiling.  The
    pool bounds compilation two ways (DESIGN.md §3):

      * ``schedule="random"`` — ``pairing_pool`` cycling matchings: round k
        uses the matching of pairing slot ``k % pairing_pool``, preserving
        the paper's random-matching statistics with at most ``pairing_pool``
        programs per membership view.
      * ``schedule="hypercube"`` — partner = id XOR 2^j with j =
        :func:`~repro.core.pairing.hypercube_dim`: at most log2(world)
        programs per membership view and still optimal mixing.

    Programs are keyed by the PARTICIPANT VIEW (mask + partition), not the
    membership epoch: two epochs with identical masks schedule identically
    (a node that left and came right back recompiles nothing), and the
    healthy view compiles the exact static-schedule programs (``active=None``
    path of :func:`build_outer_step`) — full membership stays bit-identical.
    Recompiles therefore happen ONLY at membership-view boundaries, at most
    ``max_programs_per_view`` per view, and each one is recorded for the
    engine's ``recompile`` telemetry (:mod:`repro.train.loop`).

    STREAMED pools (constructed with a ``partition``) additionally key each
    program by (stream, consume-vs-blocking, pre-send pairing): one stream's
    leaves sync per program call on its staggered round offset, and the
    elastic epoch-fallback from a consuming program to the blocking variant
    of the SAME pairing is a pool lookup, not a recompile of an existing
    entry.
    """

    def __init__(
        self,
        plan: Plan,
        mesh: Mesh,
        param_specs: PyTree,
        outer_cfg: OuterConfig,
        *,
        comm_cfg: CommConfig | None = None,
        kernel_cfg: KernelConfig | None = None,
        schedule: str = "random",
        pairing_pool: int = 16,
        seed: int = 0,
        partition: Any | None = None,  # StreamPartition for streamed programs
    ):
        if schedule not in ("random", "hypercube"):
            raise ValueError(f"unknown pairing schedule: {schedule!r}")
        self.plan = plan
        self.mesh = mesh
        self.param_specs = param_specs
        self.outer_cfg = outer_cfg
        self.comm_cfg = comm_cfg or CommConfig()
        self.kernel_cfg = kernel_cfg
        self.schedule = schedule
        self.pairing_pool = pairing_pool
        self.seed = seed
        self.partition = partition
        self._programs: dict[Any, Any] = {}
        self.hits = 0
        self.misses = 0
        self.events: list[dict] = []  # one record per compile (drained by the loop)

    # -- pure key/pairing derivation (no compilation; property-tested) -------

    @property
    def max_programs_per_view(self) -> int:
        """Upper bound on compiled programs per membership view.

        With the §3.2 overlap each program is keyed by the (slot, pre-send
        slot) PAIR: the random schedule's cycling slots still yield
        ``pairing_pool`` distinct pairs, but the hypercube schedule redraws
        its dimension order every log2(world) rounds, so pairs range over
        dims².  Streamed pools additionally key per stream and per
        consume-vs-blocking variant (a stream's first sync has no prefetch
        to consume), scaling the bound by ``streams`` and — under overlap —
        by 2."""
        world = self.plan.replicas
        noloco = self.outer_cfg.method == "noloco"
        overlap = self.comm_cfg.overlap and noloco
        streams = self.comm_cfg.streams if noloco else 1
        if self.schedule == "hypercube":
            dims = max(int(np.log2(world)), 1)
            base = dims * dims if overlap else dims
        else:
            base = self.pairing_pool
        return base * streams * (2 if overlap else 1)

    def pool_slot(self, outer_index: int) -> int:
        """The pairing slot of outer round ``outer_index`` — the bounded part
        of the program key."""
        if self.schedule == "hypercube":
            return pairing_lib.hypercube_dim(
                outer_index, self.plan.replicas, seed=self.seed
            )
        return outer_index % max(self.pairing_pool, 1)

    def pairs_for(
        self,
        outer_index: int,
        membership: Membership | None = None,
        groups: Any | None = None,
    ) -> tuple[int, list[tuple[int, int]]]:
        """(pool slot, static ppermute pairs) for one outer round.

        A pure function of ``(seed, slot, membership view)``: every node that
        agrees on the membership view derives the same pairs with zero
        control-plane messages — the coordinator-free property, preserved on
        the compiled path."""
        world = self.plan.replicas
        slot = self.pool_slot(outer_index)
        full = membership is None or (membership.is_full and groups is None)
        if self.schedule == "hypercube":
            if full:
                return slot, pairing_lib.hypercube_ppermute_pairs(
                    outer_index, world, seed=self.seed
                )
            return slot, pairing_lib.elastic_hypercube_ppermute_pairs(
                outer_index, membership, seed=self.seed, groups=groups
            )
        if full:
            return slot, pairing_lib.ppermute_pairs(slot, world, seed=self.seed)
        return slot, pairing_lib.elastic_ppermute_pairs(
            slot, membership, seed=self.seed, groups=groups
        )

    def view_key(
        self, membership: Membership | None, groups: Any | None = None
    ) -> Any:
        """Hashable participant-view part of the program key (None = the
        healthy full-membership view, shared by epochs with equal masks)."""
        if membership is None or (membership.is_full and groups is None):
            return None
        gk = None if groups is None else tuple(tuple(int(r) for r in g) for g in groups)
        return (tuple(membership.mask), gk)

    # -- compiled program lookup --------------------------------------------

    def program(
        self,
        outer_index: int,
        membership: Membership | None = None,
        groups: Any | None = None,
        *,
        stream: int | None = None,
        consume: bool = False,
        presend_index: int | None = None,
        presend_membership: Membership | None = None,
        update_mask: Any | None = None,
        staleness: Any | None = None,
    ) -> tuple[Any, dict]:
        """Compiled program for round ``outer_index`` under the given view.

        ``stream`` selects the STREAMED program variant (one stream of the
        pool's :class:`~repro.comm.StreamPartition` synced per call;
        ``outer_index`` is then the global stream-sync index).  ``consume``
        compiles the φ-prefetch read; ``presend_index`` adds the φ′ pre-send
        along the pairing of that FUTURE sync index (drawn against
        ``presend_membership`` — the full current membership, which may
        differ from this round's participant view when stragglers sit out).
        Both signature variants are part of the program key, so the elastic
        epoch-fallback (consume → blocking for one stream) is a pool lookup,
        never a rebuild of an existing entry.

        ASYNC merged-tick rounds (per-replica round clocks, DESIGN.md §7):
        ``update_mask`` is the host-side DUE set — only due replicas apply
        the outer update this tick; everyone else passes through frozen but
        still serves its in-progress (Δ, φ) over the ppermute as a passive
        source.  ``staleness`` is the per-replica τ vector baked into the
        program (``stale="momentum"`` discount; pass None for
        ``stale="naive"``, where τ is telemetry-only).  Both become part of
        the program key alongside the membership view, so the all-due τ=0
        tick takes the ``(view, slot)`` entry — bit-identical to the
        synchronous schedule.

        Returns ``(fn, info)`` with ``info = {key, slot, view, compiled,
        build_s, pool_size}`` — ``compiled`` marks a pool miss (the caller
        times the first invocation for the ``recompile`` telemetry event's
        wall-clock; XLA compiles lazily)."""
        slot, perm = self.pairs_for(outer_index, membership, groups)
        view = self.view_key(membership, groups)
        key: Any = (view, slot)
        perm_presend = None
        presend_key = None
        if stream is None and (consume or presend_index is not None):
            raise ValueError(
                "consume/presend are stream-program options; pass stream="
            )
        if presend_index is not None:
            slot_p, perm_presend = self.pairs_for(
                presend_index, presend_membership, groups
            )
            presend_key = (slot_p, self.view_key(presend_membership, groups))
        if stream is not None:
            if self.partition is None:
                raise ValueError(
                    "streamed programs need the pool constructed with a "
                    "StreamPartition (partition=...)"
                )
            key = (view, slot, "stream", stream, bool(consume), presend_key)
        active = None
        if view is not None:
            # the PARTICIPANT mask is the membership mask alone: an active
            # replica outside every partition component stays a participant
            # (its pairs self-loop, so it runs the self-momentum path) —
            # matching the stacked runtime's semantics exactly
            active = np.asarray(membership.mask, dtype=bool)
        stale_vec = None
        if update_mask is not None or staleness is not None:
            if stream is not None:
                raise ValueError(
                    "async update_mask/staleness do not compose with streamed "
                    "programs (SimCluster forbids the pairing at init)"
                )
            um_key = None
            if update_mask is not None:
                due = np.asarray(update_mask, dtype=bool)
                # the update set is the due replicas; non-due participants
                # freeze (passive sources over the ppermute)
                active = due if active is None else (active & due)
                um_key = tuple(bool(x) for x in due)
            st_key = None
            if staleness is not None:
                stale_vec = np.asarray(staleness, dtype=np.float32)
                st_key = tuple(float(x) for x in stale_vec)
            key = (view, slot, "async", um_key, st_key)
        compiled = key not in self._programs
        build_s = 0.0
        if compiled:
            self.misses += 1
            t0 = time.time()
            with compat.set_mesh(self.mesh):
                self._programs[key] = build_outer_step(
                    self.plan, self.mesh, self.param_specs, self.outer_cfg, perm,
                    comm_cfg=self.comm_cfg, kernel_cfg=self.kernel_cfg,
                    active=active, staleness=stale_vec, stream=stream,
                    partition=self.partition,
                    consume_prefetch=consume, perm_presend=perm_presend,
                )
            build_s = time.time() - t0
            self.events.append({
                "slot": str(slot), "view": "full" if view is None else "elastic",
                "epoch": None if membership is None else membership.epoch,
                "stream": stream,
                "async": update_mask is not None or staleness is not None,
                "build_s": round(build_s, 4), "pool_size": len(self._programs),
            })
        else:
            self.hits += 1
        info = {
            "key": key, "slot": slot, "view": view, "compiled": compiled,
            "build_s": build_s, "pool_size": len(self._programs),
        }
        return self._programs[key], info

    def drain_events(self) -> list[dict]:
        events, self.events = self.events, []
        return events

    def stats(self) -> dict:
        return {
            "pool_size": len(self._programs),
            "hits": self.hits,
            "misses": self.misses,
            "schedule": self.schedule,
            "max_programs_per_view": self.max_programs_per_view,
        }


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def build_decode_step(
    cfg: ModelConfig,
    plan: Plan,
    mesh: Mesh,
    params: PyTree,      # stacked Param tree
    caches: PyTree,      # Param-annotated cache tree (global shapes)
    batch_specs: dict,
):
    pspecs = plans_lib.param_pspecs(plan, mesh, params)
    pspecs = plans_lib.adjust_attn_specs_for_decode(plan, pspecs, params)
    cspecs = plans_lib.param_pspecs(plan, mesh, caches)
    ctx = plan.ctx()
    rep = plan.replica_axes
    dp = plan.data_axes
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def body(theta_l, caches_local, tokens, index):
        theta = _squeeze_replica(theta_l)
        logits, new_caches = model_api.decode_step(
            theta, cfg, tokens, index.reshape(()), caches_local, ctx
        )
        return logits, new_caches

    in_specs = (pspecs, cspecs, batch_specs["tokens"], P())
    vocab_entry = (
        plan.model_axis if cfg.vocab_size % plan.tp == 0 and plan.tp > 1 else None
    )
    out_specs = (P(dp_entry, None, vocab_entry), cspecs)
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    logits_sh = NamedSharding(mesh, out_specs[0])
    return jax.jit(
        fn,
        in_shardings=(
            plans_lib.shardings(mesh, pspecs),
            plans_lib.shardings(mesh, cspecs),
            NamedSharding(mesh, batch_specs["tokens"]),
            NamedSharding(mesh, P()),
        ),
        # cache outputs must carry the SAME shardings as the inputs so the
        # serve loop can feed them straight back in (donated)
        out_shardings=(logits_sh, plans_lib.shardings(mesh, cspecs)),
        donate_argnums=(1,),
    ), (pspecs, cspecs)


def build_prefill_step(
    cfg: ModelConfig,
    plan: Plan,
    mesh: Mesh,
    params: PyTree,
    caches: PyTree,
    batch_example: dict,
):
    pspecs = plans_lib.param_pspecs(plan, mesh, params)
    cspecs = plans_lib.param_pspecs(plan, mesh, caches)
    bspecs = batch_pspecs(plan, batch_example)
    ctx = plan.ctx()
    dp = plan.data_axes
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def body(theta_l, caches_local, batch_local):
        theta = _squeeze_replica(theta_l)
        last_hidden, new_caches = model_api.prefill(theta, cfg, batch_local, caches_local, ctx)
        return last_hidden, new_caches

    in_specs = (pspecs, cspecs, bspecs)
    out_specs = (P(dp_entry, None, None), cspecs)
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    return jax.jit(
        fn,
        in_shardings=(
            plans_lib.shardings(mesh, pspecs),
            plans_lib.shardings(mesh, cspecs),
            plans_lib.shardings(mesh, bspecs),
        ),
        out_shardings=(
            NamedSharding(mesh, out_specs[0]),
            plans_lib.shardings(mesh, cspecs),
        ),
        donate_argnums=(1,),
    ), (pspecs, cspecs)
