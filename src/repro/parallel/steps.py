"""Distributed step builders: train / prefill / decode / outer (gossip &
all-reduce), all built from the same per-replica model code via shard_map.

Pattern (see DESIGN.md): the per-replica LOSS runs inside ``shard_map`` with
manual collectives (ShardCtx); ``jax.value_and_grad`` is taken OUTSIDE the
shard_map, so JAX's shard_map transposition inserts the correct gradient
collectives (replicated-over-model params automatically get their cotangents
psum'd over the model axis — no hand-written f/g operators to get wrong).
The AdamW update is a vmap over the leading replica dim under plain GSPMD
(elementwise, partitions trivially).

The NoLoCo outer step is a shard_map whose ONLY cross-replica communication
is one ``lax.ppermute`` (collective-permute); the DiLoCo baseline outer step
uses ``lax.pmean`` (all-reduce).  Roofline reads these straight from the HLO.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm import CommConfig
from repro.core import outer as outer_lib
from repro.core.outer import OuterConfig, OuterState
from repro.kernels.dispatch import KernelConfig
from repro.models import model as model_api
from repro.models.common import unzip
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.parallel import compat
from repro.parallel import plans as plans_lib
from repro.parallel.plans import Plan

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter stacking (leading replica dim)
# ---------------------------------------------------------------------------


def stack_replicas(params: PyTree, replicas: int) -> PyTree:
    """Add the leading replica dim to every Param leaf (logical "replica").

    For simulation each replica starts from the SAME weights (the paper
    initializes all instances identically: φ_{0,i} ≡ φ_0)."""
    from repro.models.common import Param, param as mk

    def stk(p: Param) -> Param:
        v = jnp.broadcast_to(p.value[None], (replicas,) + p.value.shape)
        return mk(v, "replica", *p.logical)

    return jax.tree.map(stk, params, is_leaf=lambda x: isinstance(x, Param))


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------


def batch_pspecs(plan: Plan, batch: dict) -> dict:
    """tokens/labels (B, S): batch dim over all data axes; embeds likewise.

    A batch that does not divide the data axes (e.g. long_500k's batch of 1)
    is REPLICATED — every replica decodes the same stream (ensemble decode,
    noted in DESIGN.md)."""
    dp = plan.data_axes
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    # product of data-axis sizes: replicas × fsdp covers (pod, data)
    dp_total = plan.replicas * plan.fsdp
    out = {}
    for k, v in batch.items():
        nd = v.ndim if hasattr(v, "ndim") else len(v.shape)
        b = v.shape[0]
        entry = dp_entry if (dp and b % max(dp_total, 1) == 0) else None
        out[k] = P(entry, *([None] * (nd - 1)))
    return out


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainStepBundle:
    step_fn: Callable              # (theta, opt, batch) -> (theta, opt, metrics)
    theta_shardings: PyTree
    opt_shardings: PyTree
    pspecs: PyTree                 # theta PartitionSpecs (for checkpoint/outer)
    eval_fn: Callable | None = None  # (theta, batch) -> (R,) losses, grad-free


def _squeeze_replica(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: x[0], tree)


def _unsqueeze_replica(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda x: x[None], tree)


def build_loss_shard(
    cfg: ModelConfig, plan: Plan, mesh: Mesh, param_specs: PyTree, batch_specs: dict
):
    """shard_map'd per-replica loss: (stacked theta, batch) -> (R,) losses."""
    ctx = plan.ctx()
    rep = plan.replica_axes
    rep_entry = rep if len(rep) > 1 else (rep[0] if rep else None)

    def body(theta_local, batch_local):
        theta = _squeeze_replica(theta_local)  # drop leading local replica dim
        loss, metrics = model_api.loss_fn(theta, cfg, batch_local, ctx)
        # fsdp plan: tokens are sharded over `data` WITHIN the replica — the
        # per-replica loss is the mean over data shards of the local means
        # (equal token counts per shard).
        if plan.fsdp_axis is not None and plan.fsdp > 1:
            loss = jax.lax.pmean(loss, plan.fsdp_axis)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, plan.fsdp_axis), metrics)
        out = jnp.reshape(loss, (1,))
        mets = jax.tree.map(lambda m: jnp.reshape(m, (1,)), metrics)
        return out, mets

    in_specs = (param_specs, batch_specs)
    out_specs = (P(rep_entry), {"lm_loss": P(rep_entry), "aux_loss": P(rep_entry)})
    return compat.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )


def build_train_step(
    cfg: ModelConfig,
    plan: Plan,
    mesh: Mesh,
    params: PyTree,          # Param tree WITH leading replica dim (stack_replicas)
    batch_example: dict,     # arrays or ShapeDtypeStructs
    inner: AdamWConfig,
    *,
    data_sync: bool = False,  # DDP/FSDP baseline: all-reduce grads over replicas
) -> TrainStepBundle:
    pspecs = plans_lib.param_pspecs(plan, mesh, params)
    bspecs = batch_pspecs(plan, batch_example)
    loss_shard = build_loss_shard(cfg, plan, mesh, pspecs, bspecs)
    replicas = plan.replicas

    def total_loss(theta, batch):
        losses, metrics = loss_shard(theta, batch)
        return jnp.sum(losses) / replicas, (losses, metrics)

    def step(theta, opt, batch):
        (_, (losses, metrics)), grads = jax.value_and_grad(total_loss, has_aux=True)(
            theta, batch
        )
        if data_sync and replicas > 1:
            # traditional data-parallel baseline: gradient all-reduce across
            # the replica axes EVERY step (what NoLoCo removes entirely)
            grads = jax.tree.map(
                lambda g: jnp.broadcast_to(
                    jnp.mean(g, axis=0, keepdims=True), g.shape
                ),
                grads,
            )
        new_theta, new_opt, gnorm = jax.vmap(
            lambda g, o, p: adamw_update(g, o, p, inner)
        )(grads, opt, theta)
        metrics = dict(metrics)
        metrics["loss"] = losses
        metrics["grad_norm"] = gnorm
        return new_theta, new_opt, metrics

    theta_sh = plans_lib.shardings(mesh, pspecs)
    # AdamW moments mirror param specs (f32); count is per-replica (R,)
    rep = plan.replica_axes
    rep_entry = rep if len(rep) > 1 else (rep[0] if rep else None)
    opt_pspecs = AdamWState(
        mu=pspecs, nu=jax.tree.map(lambda s: s, pspecs), count=P(rep_entry)
    )
    opt_sh = plans_lib.shardings(mesh, opt_pspecs)
    bsh = plans_lib.shardings(mesh, bspecs)

    jitted = jax.jit(
        step,
        in_shardings=(theta_sh, opt_sh, bsh),
        donate_argnums=(0, 1),
    )
    # grad-free eval: the same shard_map'd loss, no value_and_grad, nothing
    # donated (eval must not consume the training state)
    eval_jit = jax.jit(
        lambda theta, batch: loss_shard(theta, batch)[0],
        in_shardings=(theta_sh, bsh),
    )
    return TrainStepBundle(
        step_fn=jitted, theta_shardings=theta_sh, opt_shardings=opt_sh,
        pspecs=pspecs, eval_fn=eval_jit,
    )


def init_opt_state(params_stacked_values: PyTree, replicas: int) -> AdamWState:
    """Per-replica AdamW state over stacked params (vmapped init)."""
    return jax.vmap(adamw_init)(params_stacked_values)


# ---------------------------------------------------------------------------
# Outer step (gossip / all-reduce)
# ---------------------------------------------------------------------------


def build_outer_step(
    plan: Plan,
    mesh: Mesh,
    param_specs: PyTree,     # stacked-theta PartitionSpecs
    outer_cfg: OuterConfig,
    perm: list[tuple[int, int]] | None,
    *,
    fuse_payload: bool = False,
    comm_cfg: CommConfig | None = None,
    perm_next: list[tuple[int, int]] | None = None,
    kernel_cfg: KernelConfig | None = None,
):
    """One outer step over (theta, phi, delta) -> (theta', phi', delta').

    NoLoCo: ``perm`` is the static partner permutation over the LINEARIZED
    replica axes (pod-major), realized as one collective-permute.  The
    launcher precompiles a rotating set of random matchings (pairings are
    data-independent, so a small cycling pool preserves the paper's random-
    matching statistics without per-step recompilation).

    ``comm_cfg`` selects the wire codec / payload fusing (``fuse_payload`` is
    the legacy switch for ``comm_cfg.fuse``).  With ``perm_next`` the §3.2
    φ-prefetch overlap is compiled in: the program takes an extra
    ``phi_prefetched`` input and returns the φ′ pre-send for the NEXT pairing
    as an extra output — (theta, phi, delta, phi_pre, step) in and out."""
    rep = plan.replica_axes
    rep_entry = rep if len(rep) > 1 else (rep[0] if rep else None)
    if comm_cfg is None:
        comm_cfg = CommConfig(fuse=fuse_payload)
    overlapped = perm_next is not None and outer_cfg.method == "noloco"

    def body(theta_l, phi_l, delta_l, *rest):
        theta = _squeeze_replica(theta_l)
        phi = _squeeze_replica(phi_l)
        delta = _squeeze_replica(delta_l)
        if overlapped:
            phi_pre_l, step_l = rest
            state = OuterState(phi=phi, delta=delta, step=step_l.reshape(()))
            new_state, new_theta, phi_pre = outer_lib.outer_step_sharded_overlapped(
                state, theta, _squeeze_replica(phi_pre_l), outer_cfg,
                axis_names=rep, perm=perm, perm_next=perm_next, comm_cfg=comm_cfg,
                kernel_cfg=kernel_cfg,
            )
            return (
                _unsqueeze_replica(new_theta),
                _unsqueeze_replica(new_state.phi),
                _unsqueeze_replica(new_state.delta),
                _unsqueeze_replica(phi_pre),
                new_state.step.reshape((1,)),
            )
        (step_l,) = rest
        state = OuterState(phi=phi, delta=delta, step=step_l.reshape(()))
        new_state, new_theta = outer_lib.outer_step_sharded(
            state, theta, outer_cfg, axis_names=rep, perm=perm, comm_cfg=comm_cfg,
            kernel_cfg=kernel_cfg,
        )
        return (
            _unsqueeze_replica(new_theta),
            _unsqueeze_replica(new_state.phi),
            _unsqueeze_replica(new_state.delta),
            new_state.step.reshape((1,)),
        )

    n_params = 4 if overlapped else 3
    in_specs = (param_specs,) * n_params + (P(rep_entry),)
    out_specs = (param_specs,) * n_params + (P(rep_entry),)
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    sh = plans_lib.shardings(mesh, param_specs)
    step_sh = NamedSharding(mesh, P(rep_entry))
    return jax.jit(
        fn,
        in_shardings=(sh,) * n_params + (step_sh,),
        donate_argnums=tuple(range(n_params)),
    )


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def build_decode_step(
    cfg: ModelConfig,
    plan: Plan,
    mesh: Mesh,
    params: PyTree,      # stacked Param tree
    caches: PyTree,      # Param-annotated cache tree (global shapes)
    batch_specs: dict,
):
    pspecs = plans_lib.param_pspecs(plan, mesh, params)
    pspecs = plans_lib.adjust_attn_specs_for_decode(plan, pspecs, params)
    cspecs = plans_lib.param_pspecs(plan, mesh, caches)
    ctx = plan.ctx()
    rep = plan.replica_axes
    dp = plan.data_axes
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def body(theta_l, caches_local, tokens, index):
        theta = _squeeze_replica(theta_l)
        logits, new_caches = model_api.decode_step(
            theta, cfg, tokens, index.reshape(()), caches_local, ctx
        )
        return logits, new_caches

    in_specs = (pspecs, cspecs, batch_specs["tokens"], P())
    vocab_entry = (
        plan.model_axis if cfg.vocab_size % plan.tp == 0 and plan.tp > 1 else None
    )
    out_specs = (P(dp_entry, None, vocab_entry), cspecs)
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    logits_sh = NamedSharding(mesh, out_specs[0])
    return jax.jit(
        fn,
        in_shardings=(
            plans_lib.shardings(mesh, pspecs),
            plans_lib.shardings(mesh, cspecs),
            NamedSharding(mesh, batch_specs["tokens"]),
            NamedSharding(mesh, P()),
        ),
        # cache outputs must carry the SAME shardings as the inputs so the
        # serve loop can feed them straight back in (donated)
        out_shardings=(logits_sh, plans_lib.shardings(mesh, cspecs)),
        donate_argnums=(1,),
    ), (pspecs, cspecs)


def build_prefill_step(
    cfg: ModelConfig,
    plan: Plan,
    mesh: Mesh,
    params: PyTree,
    caches: PyTree,
    batch_example: dict,
):
    pspecs = plans_lib.param_pspecs(plan, mesh, params)
    cspecs = plans_lib.param_pspecs(plan, mesh, caches)
    bspecs = batch_pspecs(plan, batch_example)
    ctx = plan.ctx()
    dp = plan.data_axes
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def body(theta_l, caches_local, batch_local):
        theta = _squeeze_replica(theta_l)
        last_hidden, new_caches = model_api.prefill(theta, cfg, batch_local, caches_local, ctx)
        return last_hidden, new_caches

    in_specs = (pspecs, cspecs, bspecs)
    out_specs = (P(dp_entry, None, None), cspecs)
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    return jax.jit(
        fn,
        in_shardings=(
            plans_lib.shardings(mesh, pspecs),
            plans_lib.shardings(mesh, cspecs),
            plans_lib.shardings(mesh, bspecs),
        ),
        out_shardings=(
            NamedSharding(mesh, out_specs[0]),
            plans_lib.shardings(mesh, cspecs),
        ),
        donate_argnums=(1,),
    ), (pspecs, cspecs)
