"""jax-version compat shims.

The repo targets current jax (``jax.shard_map`` / ``jax.set_mesh`` /
``jax.make_mesh(axis_types=...)``), but this box runs jax 0.4.37 where those
live under older names:

  * ``shard_map``  — ``jax.experimental.shard_map.shard_map`` with the
    replication check spelled ``check_rep`` instead of ``check_vma``.
  * ``set_mesh``   — absent; ``jax.sharding.Mesh`` is itself a context
    manager (``with mesh:``), which is all our callers use it for.
  * ``make_mesh``  — exists but without ``axis_types`` (and without
    ``jax.sharding.AxisType`` to build the argument from).

Everything in the repo that touches these APIs goes through this module so
the multidevice runtime (and its tests) works on both sides of the rename.

Known old-jax limitation (no shim possible, avoid the pattern instead): the
0.4.x ``shard_map`` TRANSPOSE rule re-checks specs on the rewritten body and
rejects rank-0 avals that cross a ``lax.scan`` boundary inside it
(``_SpecError: [ShapedArray(float32[]), NoFail, ...]``).  Any scan carried
state inside a shard_map'd loss must therefore be rank ≥ 1 — the chunked LM
loss (``models/model.py::_lm_loss``) carries a (2,) sum vector instead of
two scalars for exactly this reason; ``launch/dryrun.py`` (seq ≥ 2·2048
triggers the chunked path) was broken on this box until it did.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax

__all__ = ["shard_map", "set_mesh", "make_mesh", "HAS_NATIVE_SHARD_MAP"]

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(
    f: Callable,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name); both toggle
    the static replication-mismatch check.
    """
    if HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def set_mesh(mesh: Any):
    """Context manager activating ``mesh``: ``jax.set_mesh`` when available,
    the Mesh's own context-manager protocol otherwise."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)  # pragma: no cover - AbstractMesh etc.


def make_mesh(axis_shapes, axis_names, *, explicit: bool = False):
    """``jax.make_mesh`` with Auto axis types when the installed jax knows
    about axis types, plain ``jax.make_mesh`` otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kind = axis_type.Explicit if explicit else axis_type.Auto
        try:
            return jax.make_mesh(
                axis_shapes, axis_names, axis_types=(kind,) * len(axis_names)
            )
        except TypeError:  # pragma: no cover - jax with AxisType but old make_mesh
            pass
    return jax.make_mesh(axis_shapes, axis_names)
