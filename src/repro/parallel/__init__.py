from repro.parallel.sharding import ShardCtx

__all__ = ["ShardCtx"]
