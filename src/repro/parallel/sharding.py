"""ShardCtx — the single abstraction that lets every model run unchanged

  * on one device (tests, smoke runs):  ``ShardCtx.local()`` — all collectives
    are identity, weights are full-size;
  * inside ``shard_map`` over the production mesh: collectives are real
    ``lax`` ops over named axes, weights are the local TP/FSDP shards.

We deliberately use MANUAL SPMD (shard_map) rather than GSPMD auto-sharding:
with 512 host devices and 94-layer MoE graphs, hand-written collectives keep
compile times tractable and make the HLO collective schedule exactly what we
wrote — which is what the roofline analysis reads.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = ["ShardCtx"]


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Axis names and parallelism flags visible to model code.

    ``model_axis``   — tensor/expert-parallel axis name (None => tp == 1).
    ``data_axis``    — FSDP (ZeRO-3) axis name used *within* a replica; only
                       set for ``fsdp_hybrid`` plans.  For ``gossip_dp`` plans
                       the data axis indexes replicas and never appears inside
                       the per-replica model code.
    ``tp``           — model-axis size (static).
    ``fsdp``         — data-axis size for ZeRO-3 weight sharding (static).
    ``seq_parallel`` — all_gather/reduce_scatter activations on the sequence
                       dim instead of psum (hillclimb option; see §Perf).
    """

    model_axis: str | None = None
    data_axis: str | None = None
    tp: int = 1
    fsdp: int = 1
    seq_parallel: bool = False
    # decode-only: KV caches are sharded over the model axis on the SEQUENCE
    # dim (flash-decode); q-head compute is then replicated per shard and the
    # partial softmax is psum-combined (see models/attention.py).
    kv_shard_seq: bool = False
    # §Perf option: replicate (small) expert weights across the model axis and
    # skip the all-to-all — pays off when expert weights are tiny relative to
    # token traffic (granite: 32 experts × 1024×512×3 ≈ 100 MB replicated).
    replicate_experts: bool = False

    # -- constructors -------------------------------------------------------

    @staticmethod
    def local() -> "ShardCtx":
        return ShardCtx()

    # -- model-axis collectives ---------------------------------------------

    def psum_model(self, x: jax.Array) -> jax.Array:
        if self.model_axis is None:
            return x
        return jax.lax.psum(x, self.model_axis)

    def pmax_model(self, x: jax.Array) -> jax.Array:
        if self.model_axis is None:
            return x
        return jax.lax.pmax(x, self.model_axis)

    def all_gather_model(self, x: jax.Array, axis: int = 0) -> jax.Array:
        if self.model_axis is None:
            return x
        return jax.lax.all_gather(x, self.model_axis, axis=axis, tiled=True)

    def reduce_scatter_model(self, x: jax.Array, axis: int = 0) -> jax.Array:
        if self.model_axis is None:
            return x
        return jax.lax.psum_scatter(x, self.model_axis, scatter_dimension=axis, tiled=True)

    def all_to_all_model(self, x: jax.Array, split_axis: int, concat_axis: int) -> jax.Array:
        if self.model_axis is None:
            return x
        return jax.lax.all_to_all(
            x, self.model_axis, split_axis=split_axis, concat_axis=concat_axis, tiled=True
        )

    def model_index(self) -> jax.Array:
        if self.model_axis is None:
            return jnp.zeros((), jnp.int32)
        return jax.lax.axis_index(self.model_axis)

    # -- data-axis (ZeRO-3) helpers ------------------------------------------

    def gather_param(self, w: jax.Array, axis: int = 0) -> jax.Array:
        """ZeRO-3: weights are stored sharded on ``axis`` along the data axis
        and all-gathered just-in-time at use.  The transpose (grad) of this
        gather is a reduce-scatter, which is exactly ZeRO's grad sharding."""
        if self.data_axis is None or self.fsdp == 1:
            return w
        return jax.lax.all_gather(w, self.data_axis, axis=axis, tiled=True)

    # -- sequence-parallel activation movement --------------------------------

    def gather_seq(self, x: jax.Array, axis: int) -> jax.Array:
        """seq-parallel -> full sequence (entering attention/moe)."""
        if self.model_axis is None or not self.seq_parallel:
            return x
        return jax.lax.all_gather(x, self.model_axis, axis=axis, tiled=True)

    def scatter_seq_sum(self, x: jax.Array, axis: int) -> jax.Array:
        """partial-sum full sequence -> seq-parallel (leaving row-parallel
        matmul): reduce-scatter instead of psum."""
        if self.model_axis is None:
            return x
        if not self.seq_parallel:
            return jax.lax.psum(x, self.model_axis)
        return jax.lax.psum_scatter(x, self.model_axis, scatter_dimension=axis, tiled=True)

    # -- sizing helpers -------------------------------------------------------

    def heads_tp(self, num_heads: int) -> int:
        """TP degree used for an attention block: shard heads over the model
        axis when divisible, otherwise replicate attention (tiny models).
        Forced to 1 under kv_shard_seq (the model axis then shards the KV
        cache sequence instead of heads)."""
        if self.model_axis is None or self.kv_shard_seq:
            return 1
        return self.tp if num_heads % self.tp == 0 else 1

    def ff_tp(self, d_ff: int) -> int:
        if self.model_axis is None:
            return 1
        return self.tp if d_ff % self.tp == 0 else 1

    def vocab_tp(self, vocab: int) -> int:
        if self.model_axis is None:
            return 1
        return self.tp if vocab % self.tp == 0 else 1

    def experts_tp(self, num_experts: int) -> int:
        if self.model_axis is None or self.replicate_experts:
            return 1
        return self.tp if num_experts % self.tp == 0 else 1
