"""Parallelism plans: map LOGICAL sharding annotations to mesh PartitionSpecs.

Two plans (DESIGN.md §3):

  gossip_dp    — every (pod, data) coordinate is one NoLoCo replica with its
                 own divergent weights; params carry a leading replica dim
                 sharded over (pod, data); weight matrices TP-shard over
                 `model`.  The inner step has NO cross-replica collectives.
  fsdp_hybrid  — for archs too big to replicate 16× (internvl2-76b,
                 qwen3-moe-235b): ZeRO-3 over `data` + TP over `model` within
                 a replica; gossip replicas = pods only (the paper's
                 geo-distributed deployment: the all-reduce being removed is
                 the slow cross-DCN one).

Logical axis vocabulary (see models/common.py):
  params: "tp" | "tp_attn"(via size check) | "expert" -> model axis,
          "fsdp" -> data axis (fsdp_hybrid only), None -> replicated
  caches/activations: "dp" -> all replica+data axes, "seq_kv" -> model axis
          (decode flash-decode), "tp" -> model axis
Divisibility is checked per-dim: a dim that does not divide the axis size is
replicated (e.g. whisper's 8 heads or 51865 vocab on a 16-way model axis) —
the SAME rule ShardCtx applies, so specs and collectives always agree.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import Param
from repro.parallel.sharding import ShardCtx

PyTree = Any

__all__ = ["Plan", "make_plan", "spec_for", "param_pspecs", "shardings"]


@dataclasses.dataclass(frozen=True)
class Plan:
    name: str                       # gossip_dp | fsdp_hybrid
    mesh_axes: tuple[str, ...]      # mesh axis names, e.g. ("pod","data","model")
    replica_axes: tuple[str, ...]   # axes enumerating gossip replicas
    model_axis: str = "model"
    fsdp_axis: str | None = None    # ZeRO-3 axis (fsdp_hybrid: "data")
    tp: int = 16
    fsdp: int = 1
    replicas: int = 1
    kv_shard_seq: bool = False      # decode: shard KV cache sequence on model
    seq_parallel: bool = False      # hillclimb option
    replicate_experts: bool = False  # hillclimb option (small-expert MoE)

    def ctx(self) -> ShardCtx:
        return ShardCtx(
            model_axis=self.model_axis,
            data_axis=self.fsdp_axis,
            tp=self.tp,
            fsdp=self.fsdp,
            seq_parallel=self.seq_parallel,
            kv_shard_seq=self.kv_shard_seq,
            replicate_experts=self.replicate_experts,
        )

    @property
    def data_axes(self) -> tuple[str, ...]:
        """All non-model axes: batch/token parallelism dims."""
        return tuple(a for a in self.mesh_axes if a != self.model_axis)

    @property
    def replica_entry(self):
        """The replica axes as ONE PartitionSpec entry: a tuple when the
        replicas span several mesh axes, the bare axis name for one, None for
        a single-replica plan (the ``rep if len(rep) > 1 else ...`` dance
        previously copy-pasted across steps.py and the launchers)."""
        rep = self.replica_axes
        return rep if len(rep) > 1 else (rep[0] if rep else None)


def make_plan(
    plan_name: str,
    mesh: Mesh,
    *,
    shape_kind: str = "train",
    has_global_attention: bool = True,
    seq_parallel: bool = False,
    replicate_experts: bool = False,
) -> Plan:
    axes = tuple(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get("model", 1)
    if plan_name == "gossip_dp":
        replica_axes = tuple(a for a in axes if a in ("pod", "data"))
        fsdp_axis, fsdp = None, 1
    elif plan_name == "fsdp_hybrid":
        replica_axes = tuple(a for a in axes if a == "pod")
        fsdp_axis, fsdp = ("data", sizes.get("data", 1))
    else:  # pragma: no cover
        raise ValueError(plan_name)
    if seq_parallel:
        # Megatron-style sequence parallelism needs the residual stream kept
        # seq-sharded between blocks; measured wire-equal to psum under the
        # HLO result-bytes proxy (EXPERIMENTS.md §Perf P1-H2) and its real
        # win (activation memory) is outside this roofline model — left
        # unimplemented deliberately.
        raise NotImplementedError(
            "seq_parallel: refuted-by-methodology, see EXPERIMENTS.md §Perf P1-H2"
        )
    replicas = int(np.prod([sizes[a] for a in replica_axes])) if replica_axes else 1
    kv_shard_seq = shape_kind == "decode" and has_global_attention and tp > 1
    return Plan(
        name=plan_name,
        mesh_axes=axes,
        replica_axes=replica_axes,
        fsdp_axis=fsdp_axis,
        tp=tp,
        fsdp=fsdp,
        replicas=replicas,
        kv_shard_seq=kv_shard_seq,
        seq_parallel=seq_parallel,
        replicate_experts=replicate_experts,
    )


# ---------------------------------------------------------------------------
# Logical -> PartitionSpec
# ---------------------------------------------------------------------------


def _axis_size(plan: Plan, mesh: Mesh, axis: str | tuple) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, tuple):
        return int(np.prod([sizes[a] for a in axis]))
    return sizes[axis]


def spec_for(plan: Plan, mesh: Mesh, logical: tuple, shape: tuple[int, ...]) -> P:
    """One leaf: logical dims + concrete GLOBAL shape -> PartitionSpec."""
    entries = []
    for name, size in zip(logical, shape):
        axis: Any = None
        if name == "expert" and plan.replicate_experts:
            axis = None
        elif name in ("tp", "expert"):
            if size % plan.tp == 0 and plan.tp > 1:
                axis = plan.model_axis
        elif name == "fsdp":
            if plan.fsdp_axis is not None and plan.fsdp > 1 and size % plan.fsdp == 0:
                axis = plan.fsdp_axis
        elif name == "replica":
            if plan.replica_axes and size % plan.replicas == 0 and plan.replicas > 1:
                axis = plan.replica_axes if len(plan.replica_axes) > 1 else plan.replica_axes[0]
        elif name == "dp":
            dp_axes = plan.data_axes
            if dp_axes:
                total = _axis_size(plan, mesh, tuple(dp_axes))
                if size % total == 0:
                    axis = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                else:
                    # fall back to the replica axes only (e.g. batch 1: replicate)
                    axis = None
        elif name == "seq_kv":
            if plan.kv_shard_seq and size % plan.tp == 0 and plan.tp > 1:
                axis = plan.model_axis
        elif name is None:
            axis = None
        else:  # pragma: no cover
            raise ValueError(f"unknown logical axis {name!r}")
        entries.append(axis)
    return P(*entries)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def param_pspecs(plan: Plan, mesh: Mesh, tree: PyTree) -> PyTree:
    """Param tree -> PartitionSpec tree (same structure, values dropped)."""
    return jax.tree.map(
        lambda p: spec_for(plan, mesh, p.logical, p.value.shape), tree, is_leaf=_is_param
    )


def shardings(mesh: Mesh, pspecs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# Attention-param special case under kv_shard_seq
# ---------------------------------------------------------------------------
# ShardCtx.heads_tp forces attention replication when the model axis shards
# the KV-cache sequence instead; the HEAD dims of attention params must then
# be replicated too.  Head dims are identified by SIZE == num_heads; to avoid
# fragile size-matching we instead rewrite specs for the attention subtrees
# by path. Param trees keep attention params under keys "attn"/"cross_attn".


def adjust_attn_specs_for_decode(plan: Plan, pspec_tree: PyTree, param_tree: PyTree) -> PyTree:
    """Replace model-axis entries with None inside attn/cross_attn subtrees
    when the plan shards KV sequence (kv_shard_seq)."""
    if not plan.kv_shard_seq:
        return pspec_tree

    def walk(spec_node, path=()):
        if isinstance(spec_node, dict):
            return {
                k: walk(v, path + (k,)) for k, v in spec_node.items()
            }
        if isinstance(spec_node, list):
            return [walk(v, path) for v in spec_node]
        if isinstance(spec_node, tuple) and not isinstance(spec_node, P):
            return tuple(walk(v, path) for v in spec_node)
        if isinstance(spec_node, P) and any(k in ("attn", "cross_attn") for k in path):
            return P(*[None if e == plan.model_axis else e for e in spec_node])
        return spec_node

    return walk(pspec_tree)
