"""Exact bytes-on-the-wire / message counts for one outer step.

The outer exchange IS NoLoCo's product: these numbers feed the Fig. 5 latency
model (:mod:`repro.core.latency`) and the roofline so the estimates reflect
the configured codec / fusing / overlap instead of assuming raw fp32 leaves.

Everything here is static arithmetic over a :class:`~repro.comm.payload.
PayloadSpec`; ``param_tree`` may be a tree of ``jax.ShapeDtypeStruct``
(``abstract_params`` builds one via ``jax.eval_shape``), so costing a
6.8B-parameter exchange allocates nothing.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.comm import payload as payload_lib
from repro.comm.compress import CommConfig, get_codec

PyTree = Any

__all__ = ["CommCost", "StreamCost", "spec_cost", "outer_step_cost", "abstract_params"]


@dataclasses.dataclass(frozen=True)
class StreamCost:
    """One stream's share of the outer-cycle exchange (one sync event)."""

    stream: int
    payload_bytes: int       # everything this stream's sync moves (Δ + φ)
    blocking_bytes: int      # the part its sync point must WAIT for
    overlapped_bytes: int    # the part moved during inner compute (pre-send)
    messages: int
    blocking_messages: int


@dataclasses.dataclass(frozen=True)
class CommCost:
    """Per-replica communication cost of one FULL outer cycle (one direction).

    A "cycle" is every stream synced once — with ``streams=1`` (the default)
    that is exactly one outer step, so the historical reading of these fields
    is unchanged.  ``payload_bytes``/``messages`` count everything a replica
    sends per cycle (including any overlapped φ′ pre-send); ``blocking_bytes``
    / ``blocking_messages`` count only what the sync points must WAIT for —
    with ``overlap=True`` each stream's φ half moved during the inner phase,
    so only its Δ blocks.  ``overlapped_bytes`` is the complement
    (``payload_bytes − blocking_bytes``).  ``per_stream`` is the actual
    message schedule, one :class:`StreamCost` per stream sync event.
    ``raw_bytes`` is the uncompressed fused baseline, making
    ``compression_ratio = raw_bytes / payload_bytes``.
    """

    method: str
    codec: str
    fuse: bool
    overlap: bool
    payload_bytes: int
    messages: int
    blocking_bytes: int
    blocking_messages: int
    raw_bytes: int
    stream_count: int = 1
    overlapped_bytes: int = 0
    per_stream: tuple[StreamCost, ...] = ()

    @property
    def compression_ratio(self) -> float:
        return self.raw_bytes / max(self.payload_bytes, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)  # recurses into per_stream StreamCosts
        d["per_stream"] = list(d["per_stream"])
        d["compression_ratio"] = self.compression_ratio
        return d


def spec_cost(spec: payload_lib.PayloadSpec, cfg: CommConfig) -> tuple[int, int]:
    """(wire_bytes, messages) to send one packed payload under ``cfg``.

    Every codec emits exactly one wire array per buffer (int8 bitcasts its
    fp32 scales into the byte stream), so messages == number of buffers.
    """
    codec = get_codec(cfg)
    nbytes = sum(codec.wire_bytes(b.size, b.dtype) for b in spec.buffers)
    return nbytes, len(spec.buffers)


def outer_step_cost(
    param_tree: PyTree, cfg: CommConfig, *, method: str = "noloco", world: int = 2
) -> CommCost:
    """Cost of one outer cycle for a replica holding ``param_tree`` shards.

    NoLoCo exchanges the (Δ, φ) payload with ONE partner per sync; with
    ``streams=S`` the payload is sharded into S streams each synced at its own
    round offset, and with ``overlap`` each stream's φ′ is pre-sent during the
    inner phase so only its Δ blocks.  The per-stream message schedule is
    modelled explicitly (``per_stream``): a non-overlapped stream blocks on
    its whole (Δ_k, φ_k) pair; an overlapped stream blocks on Δ_k and moves
    φ′_k concurrently with compute.  DiLoCo ring-all-reduces Δ over all
    ``world`` replicas: each replica sends ``2·(world−1)/world`` of the
    payload in ``2·(world−1)`` messages per buffer (streams don't apply).
    ``method="none"`` costs nothing.
    """
    cfg.validate()
    if method == "none":
        return CommCost(method, cfg.codec, cfg.fuse, cfg.overlap, 0, 0, 0, 0, 0)

    delta_spec = payload_lib.make_spec(param_tree, fuse=cfg.fuse)

    if method == "diloco":
        if cfg.streams > 1:
            raise ValueError("streams > 1 is a noloco-only feature (gossip pairing)")
        # The DiLoCo baseline all-reduce is UNCOMPRESSED: no implementation
        # applies a codec to pmean, and affine-quantized payloads cannot be
        # summed hop-to-hop in a ring anyway — so cost it at raw bytes
        # regardless of cfg.codec (fusing still determines the message count).
        steps = 2 * (world - 1)
        raw = int(round(delta_spec.nbytes * steps / world))
        msgs = steps * len(delta_spec.buffers)
        return CommCost(method, "none", cfg.fuse, cfg.overlap, raw, msgs, raw, msgs, raw)

    if method != "noloco":
        raise ValueError(f"unknown outer method: {method}")

    # actual message schedule: one (Δ_k, φ_k) exchange per stream sync event
    import jax  # payload_lib already loaded it; keep top-of-module jax-free

    leaves = jax.tree.flatten(param_tree)[0]
    part = payload_lib.stream_partition(param_tree, cfg.streams, fuse=cfg.fuse)
    per_stream: list[StreamCost] = []
    for k in range(cfg.streams):
        sub = [leaves[i] for i in part.leaf_indices(k)]
        pair_k = payload_lib.make_spec((sub, sub), fuse=cfg.fuse)
        pair_bytes_k, pair_msgs_k = spec_cost(pair_k, cfg)
        delta_k = payload_lib.make_spec(sub, fuse=cfg.fuse)
        delta_bytes_k, delta_msgs_k = spec_cost(delta_k, cfg)
        if cfg.overlap:
            # Δ_k blocks at the sync point; φ′_k is pre-sent during the inner
            # steps — a SEPARATE wire at a different time, so it is costed as
            # its own spec (== Δ_k's: same leaves), never fused into the pair.
            # Linear codecs can't tell the difference; int8's per-buffer chunk
            # rounding can, and the two-message schedule is the real one.
            per_stream.append(StreamCost(
                stream=k, payload_bytes=2 * delta_bytes_k,
                blocking_bytes=delta_bytes_k,
                overlapped_bytes=delta_bytes_k,
                messages=delta_msgs_k + delta_msgs_k,
                blocking_messages=delta_msgs_k,
            ))
        else:
            per_stream.append(StreamCost(
                stream=k, payload_bytes=pair_bytes_k,
                blocking_bytes=pair_bytes_k, overlapped_bytes=0,
                messages=pair_msgs_k, blocking_messages=pair_msgs_k,
            ))
    payload_bytes = sum(s.payload_bytes for s in per_stream)
    blocking_bytes = sum(s.blocking_bytes for s in per_stream)
    raw = payload_lib.make_spec((param_tree, param_tree), fuse=cfg.fuse).nbytes
    return CommCost(
        method, cfg.codec, cfg.fuse, cfg.overlap,
        payload_bytes,
        sum(s.messages for s in per_stream),
        blocking_bytes,
        sum(s.blocking_messages for s in per_stream),
        raw,
        stream_count=cfg.streams,
        overlapped_bytes=payload_bytes - blocking_bytes,
        per_stream=tuple(per_stream),
    )


def abstract_params(arch: str = "paper-small-125m", *, dtype: str = "float32") -> PyTree:
    """ShapeDtypeStruct parameter tree for ``arch`` (no allocation).

    ``dtype`` defaults to float32 — the precision the outer Δ/φ master copies
    are exchanged in (the momentum math runs in fp32).
    """
    import jax  # local: keep bytes_model importable without pulling jax at module load

    from repro.configs import registry
    from repro.models import model as model_api
    from repro.models.common import values_of

    cfg = dataclasses.replace(registry.get_config(arch), dtype=dtype)
    return jax.eval_shape(
        lambda: values_of(model_api.init_params(jax.random.PRNGKey(0), cfg))
    )
