"""Exact bytes-on-the-wire / message counts for one outer step.

The outer exchange IS NoLoCo's product: these numbers feed the Fig. 5 latency
model (:mod:`repro.core.latency`) and the roofline so the estimates reflect
the configured codec / fusing / overlap instead of assuming raw fp32 leaves.

Everything here is static arithmetic over a :class:`~repro.comm.payload.
PayloadSpec`; ``param_tree`` may be a tree of ``jax.ShapeDtypeStruct``
(``abstract_params`` builds one via ``jax.eval_shape``), so costing a
6.8B-parameter exchange allocates nothing.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.comm import payload as payload_lib
from repro.comm.compress import CommConfig, get_codec

PyTree = Any

__all__ = ["CommCost", "spec_cost", "outer_step_cost", "abstract_params"]


@dataclasses.dataclass(frozen=True)
class CommCost:
    """Per-replica, per-outer-step communication cost (one direction).

    ``payload_bytes``/``messages`` count everything a replica sends for one
    outer round (including any overlapped φ′ pre-send); ``blocking_bytes``/
    ``blocking_messages`` count only the part the outer step must WAIT for —
    with ``overlap=True`` the φ half moved during the inner phase, so only Δ
    blocks.  ``raw_bytes`` is the uncompressed fused baseline, making
    ``compression_ratio = raw_bytes / payload_bytes``.
    """

    method: str
    codec: str
    fuse: bool
    overlap: bool
    payload_bytes: int
    messages: int
    blocking_bytes: int
    blocking_messages: int
    raw_bytes: int

    @property
    def compression_ratio(self) -> float:
        return self.raw_bytes / max(self.payload_bytes, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["compression_ratio"] = self.compression_ratio
        return d


def spec_cost(spec: payload_lib.PayloadSpec, cfg: CommConfig) -> tuple[int, int]:
    """(wire_bytes, messages) to send one packed payload under ``cfg``.

    Every codec emits exactly one wire array per buffer (int8 bitcasts its
    fp32 scales into the byte stream), so messages == number of buffers.
    """
    codec = get_codec(cfg)
    nbytes = sum(codec.wire_bytes(b.size, b.dtype) for b in spec.buffers)
    return nbytes, len(spec.buffers)


def outer_step_cost(
    param_tree: PyTree, cfg: CommConfig, *, method: str = "noloco", world: int = 2
) -> CommCost:
    """Cost of one outer step for a replica holding ``param_tree`` shards.

    NoLoCo exchanges the fused (Δ, φ) payload with ONE partner; with
    ``overlap`` only Δ blocks (φ′ pre-sent along the next pairing).  DiLoCo
    ring-all-reduces Δ over all ``world`` replicas: each replica sends
    ``2·(world−1)/world`` of the payload in ``2·(world−1)`` messages per
    buffer.  ``method="none"`` costs nothing.
    """
    cfg.validate()
    if method == "none":
        return CommCost(method, cfg.codec, cfg.fuse, cfg.overlap, 0, 0, 0, 0, 0)

    delta_spec = payload_lib.make_spec(param_tree, fuse=cfg.fuse)
    delta_bytes, delta_msgs = spec_cost(delta_spec, cfg)

    if method == "diloco":
        # The DiLoCo baseline all-reduce is UNCOMPRESSED: no implementation
        # applies a codec to pmean, and affine-quantized payloads cannot be
        # summed hop-to-hop in a ring anyway — so cost it at raw bytes
        # regardless of cfg.codec (fusing still determines the message count).
        steps = 2 * (world - 1)
        raw = int(round(delta_spec.nbytes * steps / world))
        msgs = steps * len(delta_spec.buffers)
        return CommCost(method, "none", cfg.fuse, cfg.overlap, raw, msgs, raw, msgs, raw)

    if method != "noloco":
        raise ValueError(f"unknown outer method: {method}")

    pair_spec = payload_lib.make_spec((param_tree, param_tree), fuse=cfg.fuse)
    pair_bytes, pair_msgs = spec_cost(pair_spec, cfg)
    if cfg.overlap:
        # total traffic unchanged (Δ now + φ′ pre-send), but only Δ blocks
        return CommCost(
            method, cfg.codec, cfg.fuse, cfg.overlap,
            pair_bytes, delta_msgs + delta_msgs, delta_bytes, delta_msgs,
            pair_spec.nbytes,
        )
    return CommCost(
        method, cfg.codec, cfg.fuse, cfg.overlap,
        pair_bytes, pair_msgs, pair_bytes, pair_msgs, pair_spec.nbytes,
    )


def abstract_params(arch: str = "paper-small-125m", *, dtype: str = "float32") -> PyTree:
    """ShapeDtypeStruct parameter tree for ``arch`` (no allocation).

    ``dtype`` defaults to float32 — the precision the outer Δ/φ master copies
    are exchanged in (the momentum math runs in fp32).
    """
    import jax  # local: keep bytes_model importable without pulling jax at module load

    from repro.configs import registry
    from repro.models import model as model_api
    from repro.models.common import values_of

    cfg = dataclasses.replace(registry.get_config(arch), dtype=dtype)
    return jax.eval_shape(
        lambda: values_of(model_api.init_params(jax.random.PRNGKey(0), cfg))
    )
