"""Communicator backends for the outer step + the §3.2 φ-prefetch overlap.

A :class:`Communicator` hides WHERE partner values come from; the outer-step
math in :mod:`repro.core.outer` is written once against this interface:

  * :class:`StackedGather`  — replicas on a leading pytree axis (simulation /
    vmap / GSPMD-with-replica-dim); partner values come from a gather with the
    deterministic :mod:`repro.core.pairing` tables.  Lossy codecs are applied
    as an encode→decode round trip on the gathered values, so simulation sees
    exactly the values a compressed wire would deliver.
  * :class:`ShardedPermute` — inside ``shard_map``; the packed, encoded payload
    moves with ``jax.lax.ppermute`` (collective-permute — NO all-reduce).
  * :class:`AllReduce`      — ``jax.lax.pmean`` for the DiLoCo baseline.

``exchange_gossip`` expresses the paper's §3.2 overlap once: when the
partner's φ was pre-sent during the previous inner phase (it does not change
during inner steps), only Δ blocks the outer step — half the blocking payload.
``presend`` issues the φ′ transfer along the NEXT pairing; on hardware it
overlaps the next m inner steps.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.comm import payload as payload_lib
from repro.comm.compress import CommConfig, get_codec

PyTree = Any

__all__ = [
    "Communicator",
    "StackedGather",
    "ShardedPermute",
    "AllReduce",
    "wire_roundtrip",
    "exchange_gossip",
    "presend",
]


def wire_roundtrip(tree: PyTree, cfg: CommConfig) -> PyTree:
    """pack → encode → decode → unpack: the values the partner would receive.

    Identity for ``codec="none"``; for lossy codecs this is the simulation-mode
    stand-in for a compressed wire (no collectives involved).
    """
    codec = get_codec(cfg)
    buffers, spec = payload_lib.pack(tree, fuse=cfg.fuse)
    out = [
        codec.decode(codec.encode(buf), jnp.dtype(bs.dtype), bs.size)
        for buf, bs in zip(buffers, spec.buffers)
    ]
    return payload_lib.unpack(out, spec)


class Communicator:
    """Pairwise gossip exchange and group mean over the replica dimension."""

    cfg: CommConfig

    def exchange(self, tree: PyTree) -> PyTree:
        """Return the PARTNER's copy of ``tree`` (this replica's view)."""
        raise NotImplementedError

    def allreduce_mean(self, tree: PyTree) -> PyTree:
        """Group mean of ``tree`` over all replicas (DiLoCo baseline)."""
        raise NotImplementedError


class StackedGather(Communicator):
    """Replicas stacked on axis 0 of every leaf; partner via index gather.

    ``active`` (optional (world,) bool mask) restricts :meth:`allreduce_mean`
    to the active replica subset — the elastic DiLoCo baseline: dropped
    replicas contribute nothing to the group mean (every replica still
    RECEIVES the mean; freezing non-participants is the outer step's job).
    The pairwise :meth:`exchange` needs no mask: sit-outs are already encoded
    as self-pairs in the elastic partner table.
    """

    def __init__(
        self,
        partner: jax.Array | None,
        cfg: CommConfig | None = None,
        *,
        active: jax.Array | None = None,
    ):
        self.partner = None if partner is None else jnp.asarray(partner)
        self.active = None if active is None else jnp.asarray(active, bool)
        self.cfg = cfg or CommConfig()
        self.cfg.validate()

    def exchange(self, tree: PyTree) -> PyTree:
        if self.partner is None:
            raise ValueError("StackedGather.exchange needs a partner table")
        gathered = jax.tree.map(lambda x: jnp.take(x, self.partner, axis=0), tree)
        if self.cfg.codec == "none":
            return gathered
        # Apply the wire codec per replica (vmap over the stacked axis), so the
        # stacked simulation matches the distributed wire bit-for-bit.
        return jax.vmap(lambda sub: wire_roundtrip(sub, self.cfg))(gathered)

    def allreduce_mean(self, tree: PyTree) -> PyTree:
        if self.active is None:
            return jax.tree.map(
                lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape),
                tree,
            )
        w = self.active.astype(jnp.float32)
        w = w / jnp.maximum(jnp.sum(w), 1.0)

        def _masked(x):
            wx = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
            return jnp.broadcast_to(jnp.sum(x * wx, axis=0, keepdims=True), x.shape)

        return jax.tree.map(_masked, tree)


class ShardedPermute(Communicator):
    """Inside shard_map: one ppermute per packed buffer moves the payload."""

    def __init__(
        self,
        axis_names: Sequence[str],
        perm: Sequence[tuple[int, int]],
        cfg: CommConfig | None = None,
    ):
        if perm is None:
            raise ValueError("ShardedPermute requires an explicit ppermute perm")
        self.axis_names = tuple(axis_names)
        self.perm = [tuple(p) for p in perm]
        self.cfg = cfg or CommConfig()
        self.cfg.validate()

    def _permute(self, x: jax.Array) -> jax.Array:
        return jax.lax.ppermute(x, self.axis_names, perm=list(self.perm))

    def exchange(self, tree: PyTree) -> PyTree:
        codec = get_codec(self.cfg)
        buffers, spec = payload_lib.pack(tree, fuse=self.cfg.fuse)
        out = []
        for buf, bs in zip(buffers, spec.buffers):
            moved = self._permute(codec.encode(buf))
            out.append(codec.decode(moved, jnp.dtype(bs.dtype), bs.size))
        return payload_lib.unpack(out, spec)

    def allreduce_mean(self, tree: PyTree) -> PyTree:
        # Provided for completeness; DiLoCo uses the AllReduce communicator.
        return jax.tree.map(lambda x: jax.lax.pmean(x, self.axis_names), tree)


class AllReduce(Communicator):
    """lax.pmean over the replica axes — the DiLoCo all-reduce baseline.

    ``weight`` (optional scalar, this shard's participation weight) turns the
    mean into the elastic weighted mean ``psum(w·x)/psum(w)`` — the shard_map
    twin of ``StackedGather(active=…)``: a dropped replica contributes zero
    weight, every replica still receives the group mean (freezing
    non-participants is the outer step's job, not the communicator's).
    """

    def __init__(
        self,
        axis_names: Sequence[str],
        cfg: CommConfig | None = None,
        *,
        weight: jax.Array | None = None,
    ):
        self.axis_names = tuple(axis_names)
        self.cfg = cfg or CommConfig()
        self.weight = None if weight is None else jnp.asarray(weight, jnp.float32)

    def exchange(self, tree: PyTree) -> PyTree:
        raise NotImplementedError("AllReduce has no pairwise exchange; use pmean")

    def allreduce_mean(self, tree: PyTree) -> PyTree:
        if self.weight is None:
            return jax.tree.map(lambda x: jax.lax.pmean(x, self.axis_names), tree)
        w = self.weight.reshape(())
        denom = jnp.maximum(jax.lax.psum(w, self.axis_names), 1.0)

        def _masked(x):
            s = jax.lax.psum(x * w.astype(x.dtype), self.axis_names)
            return (s / denom.astype(x.dtype)).astype(x.dtype)

        return jax.tree.map(_masked, tree)


def exchange_gossip(
    comm: Communicator,
    delta: PyTree,
    phi: PyTree,
    *,
    phi_prefetched: PyTree | None = None,
) -> tuple[PyTree, PyTree]:
    """Blocking part of the gossip exchange: partner's (Δ, φ).

    With ``phi_prefetched`` (§3.2 overlap) the partner's φ already arrived
    during the previous inner phase, so only Δ is exchanged here; otherwise
    Δ and φ travel together as one fused payload.
    """
    if phi_prefetched is not None:
        return comm.exchange(delta), phi_prefetched
    return comm.exchange((delta, phi))


def presend(comm_next: Communicator, phi_next: PyTree) -> PyTree:
    """Issue the φ′ transfer along the NEXT pairing (overlappable with the
    next m inner steps — nothing downstream of this round consumes it)."""
    return comm_next.exchange(phi_next)
