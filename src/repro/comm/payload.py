"""Payload packing: flatten a pytree into one contiguous buffer per group.

The gossip outer step exchanges a whole parameter-shaped pytree (Δ and φ) with
the partner replica.  Sending one network message per leaf costs 26–62 messages
for our architectures, and on the high-latency links the paper targets message
COUNT dominates (Fig. 5's t_c is per message).  Packing the tree into one flat
buffer per dtype reduces the exchange to 1–2 collectives total.

``make_spec`` computes a static :class:`PayloadSpec` from leaf shapes/dtypes —
it works on concrete arrays and on ``jax.ShapeDtypeStruct`` trees alike, so the
byte model (:mod:`repro.comm.bytes_model`) can cost 6.8B-parameter exchanges
without allocating anything.  ``pack``/``unpack`` are exact inverses:

    buffers, spec = pack(tree)
    tree == unpack(buffers, spec)        # bit-identical round trip

With ``fuse=False`` every leaf becomes its own single-leaf buffer (the
unfused, message-per-leaf wire layout) — the same spec/codec machinery then
costs and compresses both layouts uniformly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["LeafSlot", "BufferSpec", "PayloadSpec", "make_spec", "pack", "unpack"]


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside its packed buffer."""

    index: int                    # leaf position in treedef flatten order
    shape: tuple[int, ...]
    offset: int                   # element offset into the buffer
    size: int                     # number of elements


@dataclasses.dataclass(frozen=True)
class BufferSpec:
    """One packed 1-D buffer: a dtype and the leaf slots it carries."""

    dtype: str                    # canonical dtype name, e.g. "float32"
    size: int                     # total elements
    slots: tuple[LeafSlot, ...]

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class PayloadSpec:
    """Static description of a packed pytree; round-trips pack→unpack exactly."""

    treedef: Any                  # jax PyTreeDef
    buffers: tuple[BufferSpec, ...]
    num_leaves: int

    @property
    def nbytes(self) -> int:
        """Raw (uncompressed) payload bytes."""
        return sum(b.nbytes for b in self.buffers)

    @property
    def num_elements(self) -> int:
        return sum(b.size for b in self.buffers)


def _dtype_name(x) -> str:
    return jnp.dtype(x.dtype).name


def make_spec(tree: PyTree, *, fuse: bool = True) -> PayloadSpec:
    """Build the packing layout for ``tree`` (arrays or ShapeDtypeStructs).

    ``fuse=True`` groups leaves by dtype (one buffer per dtype); ``fuse=False``
    gives every leaf its own buffer (per-leaf messages).
    """
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        return PayloadSpec(treedef=treedef, buffers=(), num_leaves=0)
    buffers: list[BufferSpec] = []
    if fuse:
        groups: dict[str, list[int]] = {}
        for i, leaf in enumerate(leaves):
            groups.setdefault(_dtype_name(leaf), []).append(i)
        for dt, idxs in groups.items():
            slots, off = [], 0
            for i in idxs:
                size = int(np.prod(leaves[i].shape, dtype=np.int64)) if leaves[i].shape else 1
                slots.append(LeafSlot(index=i, shape=tuple(leaves[i].shape), offset=off, size=size))
                off += size
            buffers.append(BufferSpec(dtype=dt, size=off, slots=tuple(slots)))
    else:
        for i, leaf in enumerate(leaves):
            size = int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
            buffers.append(
                BufferSpec(
                    dtype=_dtype_name(leaf),
                    size=size,
                    slots=(LeafSlot(index=i, shape=tuple(leaf.shape), offset=0, size=size),),
                )
            )
    return PayloadSpec(treedef=treedef, buffers=tuple(buffers), num_leaves=len(leaves))


def pack(
    tree: PyTree, *, fuse: bool = True, spec: PayloadSpec | None = None
) -> tuple[list[jax.Array], PayloadSpec]:
    """Flatten ``tree`` into packed 1-D buffers according to ``spec``.

    Returns ``(buffers, spec)`` with one jax array per :class:`BufferSpec`.
    Traceable (jit/vmap-safe): the layout is static, only values flow.
    """
    if spec is None:
        spec = make_spec(tree, fuse=fuse)
    leaves = jax.tree.flatten(tree)[0]
    buffers = []
    for bspec in spec.buffers:
        parts = [leaves[s.index].reshape(-1) for s in bspec.slots]
        buffers.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts))
    return buffers, spec


def unpack(buffers: Sequence[jax.Array], spec: PayloadSpec) -> PyTree:
    """Inverse of :func:`pack`: rebuild the original pytree."""
    leaves: list = [None] * spec.num_leaves
    for buf, bspec in zip(buffers, spec.buffers):
        for s in bspec.slots:
            leaves[s.index] = jax.lax.slice(buf, (s.offset,), (s.offset + s.size,)).reshape(s.shape)
    return jax.tree.unflatten(spec.treedef, leaves)
