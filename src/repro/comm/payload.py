"""Payload packing: flatten a pytree into one contiguous buffer per group.

The gossip outer step exchanges a whole parameter-shaped pytree (Δ and φ) with
the partner replica.  Sending one network message per leaf costs 26–62 messages
for our architectures, and on the high-latency links the paper targets message
COUNT dominates (Fig. 5's t_c is per message).  Packing the tree into one flat
buffer per dtype reduces the exchange to 1–2 collectives total.

``make_spec`` computes a static :class:`PayloadSpec` from leaf shapes/dtypes —
it works on concrete arrays and on ``jax.ShapeDtypeStruct`` trees alike, so the
byte model (:mod:`repro.comm.bytes_model`) can cost 6.8B-parameter exchanges
without allocating anything.  ``pack``/``unpack`` are exact inverses:

    buffers, spec = pack(tree)
    tree == unpack(buffers, spec)        # bit-identical round trip

With ``fuse=False`` every leaf becomes its own single-leaf buffer (the
unfused, message-per-leaf wire layout) — the same spec/codec machinery then
costs and compresses both layouts uniformly.

``stream_partition`` shards the payload into ``stream_count`` contiguous
parameter-group streams (Streaming DiLoCo, arxiv 2501.18512): leaves are
assigned to streams in flatten order by an element-balanced midpoint rule, so
each stream's sub-payload can be exchanged on its own round offset while inner
steps continue.  Every per-stream :class:`PayloadSpec` is built over the FULL
treedef with slots referencing GLOBAL leaf indices — ``pack(tree, spec=
part.specs[k])`` packs just that stream's leaves, and :func:`unpack_onto`
writes them back into a base tree, leaving the other streams' leaves
untouched.  At ``stream_count=1`` the single stream spec is exactly
``make_spec(tree)``: stream 0 is bit-identical to today's fused payload.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "LeafSlot",
    "BufferSpec",
    "PayloadSpec",
    "StreamPartition",
    "make_spec",
    "stream_partition",
    "pack",
    "unpack",
    "unpack_onto",
]


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one pytree leaf lives inside its packed buffer."""

    index: int                    # leaf position in treedef flatten order
    shape: tuple[int, ...]
    offset: int                   # element offset into the buffer
    size: int                     # number of elements


@dataclasses.dataclass(frozen=True)
class BufferSpec:
    """One packed 1-D buffer: a dtype and the leaf slots it carries."""

    dtype: str                    # canonical dtype name, e.g. "float32"
    size: int                     # total elements
    slots: tuple[LeafSlot, ...]

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class PayloadSpec:
    """Static description of a packed pytree; round-trips pack→unpack exactly."""

    treedef: Any                  # jax PyTreeDef
    buffers: tuple[BufferSpec, ...]
    num_leaves: int

    @property
    def nbytes(self) -> int:
        """Raw (uncompressed) payload bytes."""
        return sum(b.nbytes for b in self.buffers)

    @property
    def num_elements(self) -> int:
        return sum(b.size for b in self.buffers)


@dataclasses.dataclass(frozen=True)
class StreamPartition:
    """Deterministic shard of one payload into contiguous leaf streams.

    ``leaf_stream[i]`` is the stream owning global leaf ``i`` (non-decreasing
    in flatten order); ``specs[k]`` is the :class:`PayloadSpec` packing
    stream ``k``'s leaves — built over the FULL treedef, so global leaf
    indices flow straight into :func:`pack`/:func:`unpack_onto`.  Streams may
    be empty (fewer leaves than streams).
    """

    treedef: Any
    num_leaves: int
    stream_count: int
    leaf_stream: tuple[int, ...]
    specs: tuple[PayloadSpec, ...]

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.specs)

    def leaf_indices(self, stream: int) -> tuple[int, ...]:
        """Global leaf indices owned by ``stream`` (flatten order)."""
        return tuple(
            i for i, k in enumerate(self.leaf_stream) if k == stream
        )


def _dtype_name(x) -> str:
    return jnp.dtype(x.dtype).name


def _leaf_size(leaf) -> int:
    return int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1


def _spec_for_indices(leaves, treedef, idxs, *, fuse: bool) -> PayloadSpec:
    """Packing layout covering exactly ``idxs`` (global leaf indices)."""
    buffers: list[BufferSpec] = []
    if fuse:
        groups: dict[str, list[int]] = {}
        for i in idxs:
            groups.setdefault(_dtype_name(leaves[i]), []).append(i)
        for dt, gidxs in groups.items():
            slots, off = [], 0
            for i in gidxs:
                size = _leaf_size(leaves[i])
                slots.append(LeafSlot(index=i, shape=tuple(leaves[i].shape), offset=off, size=size))
                off += size
            buffers.append(BufferSpec(dtype=dt, size=off, slots=tuple(slots)))
    else:
        for i in idxs:
            size = _leaf_size(leaves[i])
            buffers.append(
                BufferSpec(
                    dtype=_dtype_name(leaves[i]),
                    size=size,
                    slots=(LeafSlot(index=i, shape=tuple(leaves[i].shape), offset=0, size=size),),
                )
            )
    return PayloadSpec(treedef=treedef, buffers=tuple(buffers), num_leaves=len(leaves))


def make_spec(tree: PyTree, *, fuse: bool = True) -> PayloadSpec:
    """Build the packing layout for ``tree`` (arrays or ShapeDtypeStructs).

    ``fuse=True`` groups leaves by dtype (one buffer per dtype); ``fuse=False``
    gives every leaf its own buffer (per-leaf messages).
    """
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        return PayloadSpec(treedef=treedef, buffers=(), num_leaves=0)
    return _spec_for_indices(leaves, treedef, range(len(leaves)), fuse=fuse)


def stream_partition(
    tree: PyTree, stream_count: int, *, fuse: bool = True
) -> StreamPartition:
    """Shard ``tree``'s payload into ``stream_count`` contiguous leaf streams.

    Deterministic in (tree structure, leaf shapes/dtypes, stream_count): leaf
    ``i`` spanning elements ``[a, a+n)`` of the flattened payload goes to
    stream ``⌊midpoint · S / total⌋`` — contiguous in flatten order,
    element-balanced, and stable under jit (pure host arithmetic).  With
    ``stream_count=1`` the single spec equals ``make_spec(tree, fuse=fuse)``.
    """
    if stream_count < 1:
        raise ValueError(f"stream_count must be >= 1, got {stream_count}")
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [_leaf_size(leaf) for leaf in leaves]
    total = sum(sizes)
    leaf_stream: list[int] = []
    acc = 0
    for sz in sizes:
        # integer midpoint rule: stream = floor((acc + sz/2) * S / total)
        k = ((2 * acc + sz) * stream_count) // (2 * total) if total else 0
        leaf_stream.append(min(k, stream_count - 1))
        acc += sz
    specs = tuple(
        _spec_for_indices(
            leaves, treedef,
            [i for i, k in enumerate(leaf_stream) if k == s],
            fuse=fuse,
        )
        for s in range(stream_count)
    )
    return StreamPartition(
        treedef=treedef,
        num_leaves=len(leaves),
        stream_count=stream_count,
        leaf_stream=tuple(leaf_stream),
        specs=specs,
    )


def pack(
    tree: PyTree, *, fuse: bool = True, spec: PayloadSpec | None = None
) -> tuple[list[jax.Array], PayloadSpec]:
    """Flatten ``tree`` into packed 1-D buffers according to ``spec``.

    Returns ``(buffers, spec)`` with one jax array per :class:`BufferSpec`.
    Traceable (jit/vmap-safe): the layout is static, only values flow.
    """
    if spec is None:
        spec = make_spec(tree, fuse=fuse)
    leaves = jax.tree.flatten(tree)[0]
    buffers = []
    for bspec in spec.buffers:
        parts = [leaves[s.index].reshape(-1) for s in bspec.slots]
        buffers.append(parts[0] if len(parts) == 1 else jnp.concatenate(parts))
    return buffers, spec


def unpack(buffers: Sequence[jax.Array], spec: PayloadSpec) -> PyTree:
    """Inverse of :func:`pack`: rebuild the original pytree."""
    leaves: list = [None] * spec.num_leaves
    for buf, bspec in zip(buffers, spec.buffers):
        for s in bspec.slots:
            leaves[s.index] = jax.lax.slice(buf, (s.offset,), (s.offset + s.size,)).reshape(s.shape)
    return jax.tree.unflatten(spec.treedef, leaves)


def unpack_onto(buffers: Sequence[jax.Array], spec: PayloadSpec, base: PyTree) -> PyTree:
    """Partial unpack: write the leaves covered by ``spec`` into ``base``.

    ``base`` must share ``spec.treedef``; leaves not covered by any slot pass
    through from ``base`` unchanged.  This is the per-stream inverse of
    ``pack(tree, spec=partition.specs[k])``.
    """
    leaves = list(jax.tree.flatten(base)[0])
    if len(leaves) != spec.num_leaves:
        raise ValueError(
            f"base has {len(leaves)} leaves but spec covers a tree of "
            f"{spec.num_leaves}"
        )
    for buf, bspec in zip(buffers, spec.buffers):
        for s in bspec.slots:
            leaves[s.index] = jax.lax.slice(buf, (s.offset,), (s.offset + s.size,)).reshape(s.shape)
    return jax.tree.unflatten(spec.treedef, leaves)
