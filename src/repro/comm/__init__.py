"""Unified gossip communication layer for the NoLoCo outer step.

NoLoCo's value proposition is that the outer step is a single pairwise
exchange over a slow link — so the bytes on the wire and the message count of
that exchange ARE the product.  This package owns all of it:

  * :mod:`repro.comm.payload`     — pack/unpack a pytree into one flat buffer
    per dtype (a :class:`PayloadSpec` makes the round trip exact).
  * :mod:`repro.comm.compress`    — wire codecs (``none``/``fp16``/``bf16``/
    ``int8`` per-chunk affine) selected by :class:`CommConfig`.
  * :mod:`repro.comm.exchange`    — :class:`Communicator` backends
    (:class:`StackedGather`, :class:`ShardedPermute`, :class:`AllReduce`) and
    the §3.2 φ-prefetch overlap, expressed once.
  * :mod:`repro.comm.bytes_model` — exact per-outer-step byte/message counts
    feeding :mod:`repro.core.latency` and the Fig. 5 benchmark.

Worked example — cost and run an int8-compressed gossip exchange::

    import jax.numpy as jnp
    from repro.comm import CommConfig, StackedGather, bytes_model

    cfg = CommConfig(codec="int8", fuse=True)

    # 1. What does one outer step cost on paper_llama shapes?
    params = bytes_model.abstract_params("paper-small-125m")   # no allocation
    cost = bytes_model.outer_step_cost(params, cfg)
    print(cost.payload_bytes, cost.messages, cost.compression_ratio)  # ~3.97x

    # 2. Run it (stacked simulation; replicas on axis 0, pairs (0,1), (2,3)).
    comm = StackedGather(partner=jnp.asarray([1, 0, 3, 2]), cfg=cfg)
    tree = {"w": jnp.ones((4, 128)), "b": jnp.zeros((4, 8))}
    partner_view = comm.exchange(tree)        # values after the int8 wire

The same :class:`CommConfig` threads through ``TrainerConfig`` (stacked
trainer), ``parallel/steps.build_outer_step`` (shard_map runtime) and the
``--codec / --no-fuse / --overlap`` CLI flags of the launchers.
"""

from repro.comm.compress import (
    CODECS,
    Codec,
    CommConfig,
    get_codec,
)
from repro.comm.exchange import (
    AllReduce,
    Communicator,
    ShardedPermute,
    StackedGather,
    exchange_gossip,
    presend,
    wire_roundtrip,
)
from repro.comm.payload import (
    BufferSpec,
    LeafSlot,
    PayloadSpec,
    StreamPartition,
    make_spec,
    pack,
    stream_partition,
    unpack,
    unpack_onto,
)
from repro.comm import bytes_model, compress, exchange, payload

__all__ = [
    "CODECS",
    "Codec",
    "CommConfig",
    "get_codec",
    "AllReduce",
    "Communicator",
    "ShardedPermute",
    "StackedGather",
    "exchange_gossip",
    "presend",
    "wire_roundtrip",
    "BufferSpec",
    "LeafSlot",
    "PayloadSpec",
    "StreamPartition",
    "make_spec",
    "pack",
    "stream_partition",
    "unpack",
    "unpack_onto",
    "bytes_model",
    "compress",
    "exchange",
    "payload",
]
