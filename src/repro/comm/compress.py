"""Wire codecs for packed gossip payloads + the CommConfig that selects them.

Each codec maps a packed 1-D buffer (:mod:`repro.comm.payload`) to exactly ONE
wire array, so the message count of the exchange never grows with compression:

  * ``none``  — identity (wire dtype == buffer dtype).
  * ``fp16`` / ``bf16`` — cast floating buffers to half precision (Hivemind's
    Float16Compression; 2× on fp32 payloads, free on bf16).
  * ``int8``  — per-chunk affine quantization: each chunk of ``chunk`` values
    is mapped to uint8 with an fp32 (scale, min) pair; the fp32 metadata is
    bitcast to bytes and concatenated onto the quantized payload, keeping the
    whole thing one uint8 wire array (~3.97× on fp32 at chunk=1024).  The
    quantize/dequantize math runs through the kernel-dispatch layer
    (:func:`repro.kernels.ops.int8_quantize` — a fused Pallas kernel on TPU,
    its jnp twin elsewhere); the byte-level wire packing stays here.

Codecs are stateless value transforms — safe inside jit/vmap/shard_map.  The
optional error-feedback hook (:meth:`Codec.encode_with_residual`) accumulates
the quantization residual locally so it can be re-added next round (LoCo-style
low-bit adaptors); it is designed-in but not enabled by any trainer path yet.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.kernels.dispatch import KernelConfig

__all__ = [
    "CommConfig",
    "Codec",
    "NoneCodec",
    "CastCodec",
    "Int8Codec",
    "get_codec",
    "CODECS",
]


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """How the outer-step payload travels: codec × fusing × overlap.

    ``codec``:   "none" | "fp16" | "bf16" | "int8" — wire compression.
    ``fuse``:    pack the pytree into one buffer per dtype (message count 1–2)
                 instead of one message per leaf.
    ``overlap``: pre-send φ′ for the NEXT pairing during the inner phase
                 (paper §3.2) so only Δ blocks the outer step.
    ``streams``: shard the outer payload into this many contiguous
                 parameter-group streams synced on staggered round offsets
                 (Streaming DiLoCo composed with gossip pairing); 1 keeps the
                 whole payload on one sync point.
    ``chunk``:   int8 quantization group size (fp32 scale+min per chunk).
    ``error_feedback``: reserved for LoCo-style residual accumulation; only
                 meaningful for lossy codecs.  No trainer path threads the
                 residual state yet, so enabling it raises
                 ``NotImplementedError`` rather than silently dropping the
                 residuals (which would quietly bias every lossy exchange).
    """

    codec: str = "none"
    fuse: bool = True
    overlap: bool = False
    streams: int = 1
    chunk: int = 1024
    error_feedback: bool = False

    def validate(self) -> None:
        if self.codec not in CODECS:
            raise ValueError(f"unknown codec {self.codec!r}; options: {sorted(CODECS)}")
        if self.codec == "int8" and self.chunk < 2:
            raise ValueError("int8 chunk size must be >= 2")
        if self.streams < 1:
            raise ValueError(f"streams must be >= 1, got {self.streams}")
        if self.error_feedback and self.codec in ("none",):
            raise ValueError("error feedback only applies to lossy codecs")
        if self.error_feedback:
            # encode_with_residual exists on every codec, but no trainer path
            # carries the residual pytree between rounds yet — accepting the
            # flag here would mean each round's quantization error is simply
            # discarded, which is exactly the bias error feedback exists to
            # remove.  Fail loudly until the LoCo-style (arXiv 2407.04480)
            # residual state is threaded through the outer step.
            raise NotImplementedError(
                "error_feedback=True: no trainer path accumulates the "
                "LoCo-style (arXiv 2407.04480) quantization residuals yet, "
                "so the flag would silently drop them; use "
                "Codec.encode_with_residual directly or leave it False"
            )


def _is_float(dtype) -> bool:
    return jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


class Codec:
    """encode(buffer) -> one wire array; decode(wire, dtype, size) -> buffer."""

    name = "abstract"

    def encode(self, buf: jax.Array) -> jax.Array:
        raise NotImplementedError

    def decode(self, wire: jax.Array, dtype, size: int) -> jax.Array:
        raise NotImplementedError

    def wire_bytes(self, size: int, dtype) -> int:
        """Exact bytes on the wire for a buffer of ``size`` elements."""
        raise NotImplementedError

    def encode_with_residual(
        self, buf: jax.Array, residual: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        """Error-feedback encode: fold the accumulated residual into the
        buffer before quantizing and return the new residual (what this
        round's wire failed to carry)."""
        corrected = buf + residual.astype(buf.dtype)
        wire = self.encode(corrected)
        decoded = self.decode(wire, corrected.dtype, corrected.shape[0])
        return wire, (corrected - decoded).astype(residual.dtype)


class NoneCodec(Codec):
    name = "none"

    def encode(self, buf):
        return buf

    def decode(self, wire, dtype, size):
        return wire

    def wire_bytes(self, size, dtype):
        return size * jnp.dtype(dtype).itemsize


class CastCodec(Codec):
    """Cast floating buffers to a 2-byte dtype; pass everything else through."""

    def __init__(self, target: str):
        self.name = {"float16": "fp16", "bfloat16": "bf16"}[target]
        self._target = jnp.dtype(target)

    def _applies(self, dtype) -> bool:
        return _is_float(dtype) and jnp.dtype(dtype).itemsize > self._target.itemsize

    def encode(self, buf):
        return buf.astype(self._target) if self._applies(buf.dtype) else buf

    def decode(self, wire, dtype, size):
        return wire.astype(dtype)

    def wire_bytes(self, size, dtype):
        it = jnp.dtype(dtype).itemsize
        return size * (self._target.itemsize if self._applies(dtype) else it)


class Int8Codec(Codec):
    """Per-chunk affine uint8 quantization with fp32 (scale, min) metadata.

    The quantize/dequantize math is the dispatched kernel op (fused Pallas on
    TPU, jnp twin elsewhere — selected by ``kernel_cfg``); this class owns
    the wire layout: metadata is bitcast to uint8 and appended to the
    quantized values so the wire stays a single contiguous byte array (one
    message per buffer).
    """

    name = "int8"
    _META_BYTES_PER_CHUNK = 8  # fp32 scale + fp32 min

    def __init__(self, chunk: int = 1024, kernel_cfg: "KernelConfig | None" = None):
        self.chunk = int(chunk)
        self.kernel_cfg = kernel_cfg

    def _nchunks(self, size: int) -> int:
        return -(-size // self.chunk)

    def encode(self, buf):
        if not _is_float(buf.dtype):
            return buf
        n = buf.shape[0]
        nc = self._nchunks(n)
        # edge-pad (repeat the last value) so padding never widens the tail
        # chunk's [min, max] range and thus never degrades its scale
        x = jnp.pad(buf.astype(jnp.float32), (0, nc * self.chunk - n), mode="edge")
        q, safe, lo = kernel_ops.int8_quantize(
            x.reshape(nc, self.chunk), config=self.kernel_cfg
        )
        meta = jnp.concatenate([safe, lo])                          # (2·nc,) fp32
        meta_bytes = jax.lax.bitcast_convert_type(meta, jnp.uint8)  # (2·nc, 4)
        return jnp.concatenate([q.reshape(-1), meta_bytes.reshape(-1)])

    def decode(self, wire, dtype, size):
        if not _is_float(dtype):
            return wire
        nc = self._nchunks(size)
        q = wire[: nc * self.chunk].reshape(nc, self.chunk)
        meta = jax.lax.bitcast_convert_type(
            wire[nc * self.chunk :].reshape(2 * nc, 4), jnp.float32
        )
        x = kernel_ops.int8_dequantize(
            q, meta[:nc], meta[nc:], config=self.kernel_cfg
        )
        return x.reshape(-1)[:size].astype(dtype)

    def wire_bytes(self, size, dtype):
        if not _is_float(dtype):
            return size * jnp.dtype(dtype).itemsize
        nc = self._nchunks(size)
        return nc * self.chunk + nc * self._META_BYTES_PER_CHUNK


CODECS = ("none", "fp16", "bf16", "int8")


def get_codec(cfg: CommConfig | str, kernel_cfg: KernelConfig | None = None) -> Codec:
    """Codec instance for a :class:`CommConfig` (or bare codec name).

    ``kernel_cfg`` selects the int8 quantize/dequantize implementation
    (Pallas kernel vs jnp twin); None uses the dispatch default."""
    if isinstance(cfg, str):
        cfg = CommConfig(codec=cfg)
    cfg.validate()
    if cfg.codec == "none":
        return NoneCodec()
    if cfg.codec == "fp16":
        return CastCodec("float16")
    if cfg.codec == "bf16":
        return CastCodec("bfloat16")
    return Int8Codec(chunk=cfg.chunk, kernel_cfg=kernel_cfg)
