"""Deterministic synthetic corpus with LEARNABLE structure.

No datasets ship with this box, so training runs use a synthetic language: a
token stream from a random-but-fixed first-order Markov chain with Zipfian
marginals plus periodic copy motifs.  A model that learns must (a) pick up
the bigram transitions (fast loss drop) and (b) exploit the copy motif
(longer-range signal), so loss curves behave qualitatively like language
modeling — which is what the paper's convergence comparisons need.

Everything is keyed by (seed, shard, position): any worker can materialize
any shard independently — the shard-aware loader needs no coordination, which
mirrors how each NoLoCo replica owns its own data shard.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLM", "make_batches"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int = 512
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_period: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # Zipfian stationary distribution over a random permutation of tokens
        ranks = rng.permutation(v) + 1
        base = 1.0 / ranks ** self.zipf_a
        base /= base.sum()
        # sparse-ish Markov transitions: each token prefers ~8 successors
        k = min(8, v)
        self._succ = rng.integers(0, v, size=(v, k))
        self._succ_p = rng.dirichlet(np.ones(k) * 0.5, size=v)
        self._base = base
        self._motif = rng.integers(0, v, size=self.motif_len)

    def sample_tokens(self, shard: int, length: int) -> np.ndarray:
        """Deterministic token stream for ``shard``."""
        rng = np.random.default_rng((self.seed + 1) * 1_000_003 + shard)
        out = np.empty(length, dtype=np.int32)
        tok = int(rng.choice(self.vocab_size, p=self._base))
        for i in range(length):
            if (i % self.motif_period) < self.motif_len:
                tok = int(self._motif[i % self.motif_period])
            else:
                j = int(rng.choice(self._succ.shape[1], p=self._succ_p[tok]))
                tok = int(self._succ[tok, j])
            out[i] = tok
        return out


def make_batches(
    lm: SyntheticLM,
    *,
    steps: int,
    replicas: int,
    per_replica_batch: int,
    seq_len: int,
):
    """Yield ``steps`` stacked batches: tokens/labels (R, B, S) int32.

    Replica r at step t reads the deterministic stream of shard
    (r * steps + t) — disjoint data per replica, as in data parallelism."""
    for t in range(steps):
        toks = np.empty((replicas, per_replica_batch, seq_len + 1), np.int32)
        for r in range(replicas):
            flat = lm.sample_tokens(
                r * (steps + 1) + t, per_replica_batch * (seq_len + 1)
            )
            toks[r] = flat.reshape(per_replica_batch, seq_len + 1)
        yield {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
