"""Sequence packing: concatenate variable-length documents into fixed
(seq_len+1) training rows with an EOS separator and a loss mask that blanks
the first token after each boundary (no cross-document prediction).

The paper formats Reddit/C4 into fixed 1024-token sequences; this is the
same mechanism for arbitrary document streams.
"""

from __future__ import annotations

import numpy as np

__all__ = ["pack_documents"]


def pack_documents(
    docs: list[np.ndarray], seq_len: int, eos_id: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Greedy-pack documents into rows of seq_len+1 tokens.

    Returns (tokens (N, S), labels (N, S), loss_mask (N, S))."""
    stream: list[int] = []
    for d in docs:
        stream.extend(int(x) for x in d)
        stream.append(eos_id)
    row = seq_len + 1
    n = len(stream) // row
    if n == 0:
        raise ValueError("not enough tokens to fill one packed row")
    arr = np.asarray(stream[: n * row], dtype=np.int32).reshape(n, row)
    tokens, labels = arr[:, :-1], arr[:, 1:]
    # don't train to predict the token right AFTER an eos (new doc start)
    mask = np.ones_like(labels, dtype=bool)
    mask[:, 1:] &= tokens[:, 1:] != eos_id  # position following eos
    prev_is_eos = tokens == eos_id
    mask &= ~prev_is_eos  # and never predict from an eos input either? keep simple
    return tokens, labels, mask
