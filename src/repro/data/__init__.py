from repro.data.loader import LoaderConfig, TokenFileSource, eval_batches, shard_iterator
from repro.data.packing import pack_documents
from repro.data.synthetic import SyntheticLM, make_batches

__all__ = ["LoaderConfig", "TokenFileSource", "eval_batches", "shard_iterator", "pack_documents", "SyntheticLM", "make_batches"]
