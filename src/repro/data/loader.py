"""Shard-aware loader gluing the synthetic corpus (or a token memmap) to the
trainer: deterministic, resumable (seeded by step), zero coordination between
replicas.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.data.synthetic import SyntheticLM

__all__ = ["LoaderConfig", "shard_iterator", "eval_batches", "TokenFileSource"]


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    vocab_size: int = 512
    seq_len: int = 128
    per_replica_batch: int = 4
    replicas: int = 4
    seed: int = 0


class TokenFileSource:
    """Memmap-backed pretokenized corpus (one flat int32 file)."""

    def __init__(self, path: str):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")

    def slice(self, start: int, n: int) -> np.ndarray:
        start = start % max(len(self.tokens) - n, 1)
        return np.asarray(self.tokens[start : start + n])


def shard_iterator(
    cfg: LoaderConfig, *, source: TokenFileSource | None = None, start_step: int = 0
) -> Iterator[dict]:
    """Infinite iterator of stacked batches {tokens,labels}: (R, B, S).

    Replica r's data at step t is a pure function of (seed, r, t): resuming
    from a checkpoint at step t reproduces the exact stream."""
    lm = None if source is not None else SyntheticLM(cfg.vocab_size, seed=cfg.seed)
    row = cfg.seq_len + 1
    need = cfg.per_replica_batch * row
    t = start_step
    while True:
        toks = np.empty((cfg.replicas, cfg.per_replica_batch, row), np.int32)
        for r in range(cfg.replicas):
            if source is not None:
                # the seed offsets the file cursor (in steps) so differently-
                # seeded streams — e.g. the +777 eval convention — read
                # different windows of the corpus, matching the synthetic path
                flat = source.slice(((t + cfg.seed) * cfg.replicas + r) * need, need)
            else:
                flat = lm.sample_tokens(r * 1_000_003 + t, need)
            toks[r] = flat.reshape(cfg.per_replica_batch, row)
        yield {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
        t += 1


def eval_batches(
    cfg: LoaderConfig, n: int, *, source: TokenFileSource | None = None
) -> list[dict]:
    """A fixed held-out eval set: the first ``n`` batches of the stream keyed
    by ``cfg.seed`` (callers pass a seed offset, conventionally +777, so the
    eval stream is disjoint from training)."""
    it = shard_iterator(cfg, source=source)
    return [next(it) for _ in range(n)]
