"""Shared ensemble diagnostics over replica-stacked parameter trees.

One implementation of the cross-replica weight std (the quantity in Fig. 3B /
Fig. 4A of the paper) shared by the stacked :class:`~repro.core.GossipTrainer`,
the routed :class:`~repro.pipeline.PipelineTrainer` (which holds one stacked
tree PER STAGE) and the training engine's telemetry stream.
"""

from __future__ import annotations

from typing import Any, Iterable

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["replica_weight_std"]


def replica_weight_std(trees: PyTree | Iterable[PyTree]) -> jax.Array:
    """Mean over parameters of the std across replicas (leading axis 0).

    ``trees`` is either one stacked pytree or an iterable of stacked pytrees
    (e.g. the per-stage parameter list of the pipeline trainer); every leaf
    must carry the replica axis first.
    """
    if not isinstance(trees, (list, tuple)):
        trees = [trees]
    stds = [
        jnp.mean(jnp.std(x.astype(jnp.float32), axis=0))
        for t in trees
        for x in jax.tree.leaves(t)
    ]
    if not stds:
        raise ValueError("replica_weight_std: no array leaves found")
    return jnp.mean(jnp.stack(stds))
