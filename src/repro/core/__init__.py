"""NoLoCo core: gossip outer optimizer, pairing, theory, and latency models
(the paper's primary contribution)."""

from repro.core.outer import (
    OuterConfig,
    OuterState,
    StreamSchedule,
    default_gamma,
    gamma_band,
    init_outer_state,
    outer_gradient,
    outer_step,
    outer_step_sharded,
    outer_step_sharded_overlapped,  # removed-API stub (clear deprecation error)
    outer_step_sharded_stream,
    outer_step_stacked,
    outer_step_stacked_stream,
)
from repro.core.elastic import ElasticContext, RoundPlan, stream_assignment
from repro.core.noloco import GossipTrainer, TrainState, TrainerConfig
from repro.core.pairing import Membership
from repro.core import latency, pairing, theory

__all__ = [
    "ElasticContext",
    "RoundPlan",
    "stream_assignment",
    "OuterConfig",
    "OuterState",
    "StreamSchedule",
    "default_gamma",
    "gamma_band",
    "init_outer_state",
    "outer_gradient",
    "outer_step",
    "outer_step_sharded",
    "outer_step_sharded_overlapped",
    "outer_step_sharded_stream",
    "outer_step_stacked",
    "outer_step_stacked_stream",
    "GossipTrainer",
    "Membership",
    "TrainState",
    "TrainerConfig",
    "latency",
    "pairing",
    "theory",
]
