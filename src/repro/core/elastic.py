"""ElasticContext: the runtime-agnostic owner of elasticity state.

Every :class:`~repro.train.program.TrainProgram` adapter that supports churn
(stacked :class:`~repro.train.GossipProgram`, shard_map
:class:`~repro.train.DistributedProgram`, routed
:class:`~repro.train.PipelineProgram`) holds ONE of these; the runtimes never
own membership themselves.  The context carries exactly four things
(DESIGN.md §7):

  * ``membership``    — the epoch-stamped :class:`~repro.core.pairing.
    Membership` bitmask over replica slots (who is in the cluster),
  * ``partition``     — the transient network-partition view (pairings never
    cross a component),
  * ``round_absent``  — stragglers missing the NEXT outer round only
    (participation, not membership; consumed by :meth:`plan_round`),
  * ``last_partner``  — the partner table the last outer round ACTUALLY used
    (the audit source for :class:`~repro.sim.SimCluster` history/telemetry).

:meth:`plan_round` is the one place the round's participant set is decided:
it consumes the straggler view, degrades an all-absent round to a frozen
no-exchange round (the outer counter still advances so the schedule stays
aligned), and hands the caller a :class:`RoundPlan` with the active mask and
the partner table from the caller-supplied ``partner_fn`` — each runtime
supplies its own (stacked gather table, ppermute pool pairs, per-stage
pipeline tables), the membership semantics stay shared.

The checkpoint view (:meth:`state_dict` / :meth:`load_state_dict`) rides in
every program's ``state_pytree``, so resume-after-churn restores the same
membership epoch on all three runtimes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.pairing import Membership

__all__ = ["ElasticContext", "RoundPlan", "stream_assignment"]


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """One outer round's participation, as decided by ``plan_round``."""

    participants: Membership          # membership minus this round's stragglers
    partner: np.ndarray | None        # (world,) table used, None for all-reduce
    active: np.ndarray | None         # (world,) bool mask, None when full
    all_absent: bool = False          # every live replica timed out this round


class ElasticContext:
    """Membership epoch + active mask + partner source, shared by runtimes."""

    def __init__(
        self,
        membership: Membership | None = None,
        *,
        world: int | None = None,
    ):
        # NB: no seed lives here on purpose — pairing PRNG seeds belong to
        # the partner source (trainer config / program pool); the context
        # only decides WHO participates, never how they pair.
        if membership is None:
            if world is None:
                raise ValueError("ElasticContext needs a membership or a world size")
            membership = Membership.full(world)
        self.membership = membership
        self.partition: tuple[tuple[int, ...], ...] | None = None
        self.round_absent: frozenset[int] = frozenset()
        self.last_partner: np.ndarray | None = None
        # transient per-tick step gate for the asynchronous clock (SimCluster
        # sets it before every inner step; None = every member steps).  NOT
        # checkpointed: the clock that derives it persists its own counters
        # and recomputes the gate on the first tick after resume.
        self.tick_active: np.ndarray | None = None

    # -- views ---------------------------------------------------------------

    @property
    def world(self) -> int:
        return self.membership.world

    @property
    def epoch(self) -> int:
        return self.membership.epoch

    @property
    def is_full(self) -> bool:
        return self.membership.is_full

    def active_array(self) -> np.ndarray | None:
        """(world,) bool mask for inner-step freezing, or None when everyone
        is in (keeps the healthy path's compiled signature untouched).

        Composes membership with the asynchronous clock's per-tick step gate
        (``tick_active``): a replica steps this tick only if it is a member
        AND its clock granted it a step.  At full membership with every
        clock ticking the result is None — the rate-1 world keeps the
        legacy compiled signature bit for bit."""
        mask = np.asarray(self.membership.mask, dtype=bool)
        if self.tick_active is not None:
            mask = mask & np.asarray(self.tick_active, dtype=bool)
        if mask.all():
            return None
        return mask.copy()

    def active_ids(self) -> tuple[int, ...]:
        return self.membership.active_ids

    # -- mutation ------------------------------------------------------------

    def set_membership(self, membership: Membership) -> None:
        if membership.world != self.world:
            raise ValueError(
                f"membership world {membership.world} != world {self.world}"
            )
        self.membership = membership

    def set_partition(self, groups: Sequence[Sequence[int]] | None) -> None:
        """Restrict pairings to partition components (None heals)."""
        self.partition = (
            None if groups is None
            else tuple(tuple(int(r) for r in g) for g in groups)
        )

    # -- the round decision ---------------------------------------------------

    def plan_round(
        self,
        partner_fn: Callable[[Membership], np.ndarray] | None = None,
    ) -> RoundPlan:
        """Decide one outer round's participants; consumes ``round_absent``.

        ``partner_fn(participants)`` supplies the runtime's partner table for
        the decided participant set (None for all-reduce methods).  The
        returned table is recorded as ``last_partner`` — the audit value, the
        one the round REALLY used."""
        absent, self.round_absent = self.round_absent, frozenset()
        active_now = set(self.membership.active_ids)
        absent = absent & active_now
        if absent == active_now:
            # every live replica timed out: nobody exchanges, but the round
            # still happens (the outer counter must advance so the schedule
            # stays aligned across the cluster)
            self.last_partner = np.arange(self.world, dtype=np.int64)
            return RoundPlan(
                participants=self.membership,
                partner=self.last_partner,
                active=np.zeros((self.world,), dtype=bool),
                all_absent=True,
            )
        participants = self.membership.without(absent)
        partner = None if partner_fn is None else partner_fn(participants)
        self.last_partner = partner
        active = None if participants.is_full else participants.active_array()
        return RoundPlan(participants=participants, partner=partner, active=active)

    # -- checkpoint view ------------------------------------------------------

    def state_dict(self) -> dict:
        part = np.full((self.world,), -1, dtype=np.int64)
        if self.partition is not None:
            for gid, group in enumerate(self.partition):
                for r in group:
                    part[r] = gid
        return {
            "mask": np.asarray(self.membership.mask, dtype=bool),
            "epoch": np.int64(self.membership.epoch),
            "partition": part,
        }

    def load_state_dict(self, tree: dict) -> None:
        self.membership = Membership(
            world=self.world,
            mask=tuple(bool(b) for b in np.asarray(tree["mask"])),
            epoch=int(tree["epoch"]),
        )
        part = np.asarray(tree["partition"])
        if (part >= 0).any():
            self.partition = tuple(
                tuple(int(i) for i in np.nonzero(part == g)[0])
                for g in sorted(set(int(p) for p in part if p >= 0))
            )
        else:
            self.partition = None


def stream_assignment(membership: Membership, t: int) -> np.ndarray:
    """Elastic data reassignment: which loader stream each replica consumes
    at inner step ``t`` — a pure function of ``(membership, t)``.

    The loader's contract (:func:`repro.data.shard_iterator`) makes stream
    ``r`` at step ``t`` a pure function of ``(seed, r, t)``, so redistributing
    data needs no loader state: each dropped replica's stream is adopted by a
    survivor (round-robin over actives by dropped rank), and the survivor
    TIME-MULTIPLEXES its own stream with its adopted ones — at step ``t`` it
    reads ``pool[t % len(pool)]`` where ``pool`` is its own stream followed by
    the adopted ones.  Every stream keeps being consumed (at a reduced rate),
    no token is read twice in a step, and the assignment is reproducible
    after resume because nothing here is stateful.

    Identity at full membership; inactive replicas map to their own stream
    (they are frozen — the row is never consumed)."""
    world = membership.world
    table = np.arange(world, dtype=np.int64)
    if membership.is_full:
        return table
    actives = sorted(membership.active_ids)
    dropped = [r for r in range(world) if r not in set(actives)]
    pools: dict[int, list[int]] = {a: [a] for a in actives}
    for rank, d in enumerate(dropped):
        pools[actives[rank % len(actives)]].append(d)
    for a, pool in pools.items():
        table[a] = pool[t % len(pool)]
    return table
