"""High-level trainer: m inner AdamW steps per replica, then the gossip
(NoLoCo) / all-reduce (DiLoCo) / none outer step.

This is the *stacked* trainer used for simulation-scale experiments, tests and
benchmarks: every leaf of the parameter pytree carries a leading replica axis
of size ``world``.  Per-replica computation is ``jax.vmap`` over that axis, so
under GSPMD with the replica axis sharded on the ``data`` mesh axis this exact
code is also the distributed inner step (see repro/parallel) — XLA emits no
cross-replica collectives for it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.comm import CommConfig
from repro.core import metrics as metrics_lib
from repro.core import outer as outer_lib
from repro.kernels.dispatch import KernelConfig
from repro.optim import AdamWConfig, AdamWState, adamw_init, adamw_update

PyTree = Any
LossFn = Callable[[PyTree, Any, jax.Array], jax.Array]
#        loss_fn(params, batch, rng) -> scalar loss, for ONE replica.

__all__ = ["TrainerConfig", "TrainState", "GossipTrainer"]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    outer: outer_lib.OuterConfig = dataclasses.field(default_factory=outer_lib.OuterConfig)
    inner: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    # Wire codec / payload fusing for the gossip exchange (repro.comm); the
    # stacked trainer applies lossy codecs to the partner's values exactly as
    # the distributed wire would, so compression ablations run in simulation.
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)
    # Kernel dispatch for the fused outer update (repro.kernels.dispatch);
    # the model forward's choice lives on ModelConfig.kernels.
    kernels: KernelConfig = dataclasses.field(default_factory=KernelConfig)
    # FSDP/DDP baseline: all-reduce (mean) gradients across replicas EVERY
    # inner step — the fully-synchronous comparison point in the paper.
    sync_grads: bool = False


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    theta: PyTree                 # fast weights, leading replica axis
    opt: AdamWState               # per-replica AdamW moments (leading axis)
    outer: outer_lib.OuterState   # slow weights φ and momentum δ
    inner_step: jax.Array         # global inner step counter (scalar)

    @property
    def world(self) -> int:
        return jax.tree.leaves(self.theta)[0].shape[0]


class GossipTrainer:
    """Functional trainer; all methods return new states (jit-friendly)."""

    def __init__(self, cfg: TrainerConfig, loss_fn: LossFn):
        cfg.outer.validate()
        self.cfg = cfg
        self.loss_fn = loss_fn

        def _one_replica_grad(params, batch, rng):
            return jax.value_and_grad(loss_fn)(params, batch, rng)

        self._vgrad = jax.vmap(_one_replica_grad)
        self._vloss = jax.vmap(loss_fn)
        self._vapply = jax.vmap(lambda g, o, p: adamw_update(g, o, p, cfg.inner))

    # -- state ------------------------------------------------------------

    def init(self, stacked_params: PyTree) -> TrainState:
        return TrainState(
            theta=stacked_params,
            opt=jax.vmap(adamw_init)(stacked_params),
            outer=outer_lib.init_outer_state(stacked_params),
            inner_step=jnp.zeros((), jnp.int32),
        )

    # -- steps ------------------------------------------------------------

    def inner_step(
        self,
        state: TrainState,
        batch: PyTree,
        rng: jax.Array,
        active: jax.Array | None = None,
    ) -> tuple[TrainState, dict[str, jax.Array]]:
        """One local optimizer step on every replica.  ``batch`` leaves have a
        leading replica axis (each replica sees its own shard).

        ``active``: optional (world,) bool mask — inactive (dropped) replicas
        keep θ and their AdamW moments frozen; the simulation still computes
        their forward/grad (it is one vmap), but no state moves.  Their
        reported loss is whatever the frozen weights score; elastic callers
        aggregate over active replicas only."""
        rngs = jax.random.split(rng, state.world)
        loss, grads = self._vgrad(state.theta, batch, rngs)
        if self.cfg.sync_grads:
            grads = jax.tree.map(
                lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape),
                grads,
            )
        theta, opt, gnorm = self._vapply(grads, state.opt, state.theta)
        if active is not None:
            act = jnp.asarray(active, bool)

            def _sel(new, old):
                return jnp.where(act.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)

            theta = jax.tree.map(_sel, theta, state.theta)
            opt = jax.tree.map(_sel, opt, state.opt)
        new_state = TrainState(
            theta=theta, opt=opt, outer=state.outer, inner_step=state.inner_step + 1
        )
        return new_state, {"loss": loss, "grad_norm": gnorm}

    def outer_step(
        self,
        state: TrainState,
        partner: jax.Array | None = None,
        active: jax.Array | None = None,
        staleness: jax.Array | None = None,
    ) -> TrainState:
        """Gossip/all-reduce sync of slow weights; fast weights reset to the
        new slow weights (look-ahead semantics).

        When ``partner`` is None the pairing is derived HOST-side from the
        outer step counter inside :func:`outer_step_stacked`; jitted callers
        must pass a precomputed table (a clear error is raised otherwise).
        ``active`` masks this round's participants (see
        :func:`repro.core.outer.outer_step_stacked`); ``staleness`` is the
        per-replica τ vector of an asynchronous merged sync tick (the
        ``stale="momentum"`` discount — :func:`repro.core.outer.stale_discount`)."""
        new_outer, new_theta = outer_lib.outer_step_stacked(
            state.outer, state.theta, self.cfg.outer, partner=partner,
            active=active, comm_cfg=self.cfg.comm, kernel_cfg=self.cfg.kernels,
            staleness=staleness,
        )
        return TrainState(
            theta=new_theta, opt=state.opt, outer=new_outer, inner_step=state.inner_step
        )

    def outer_step_stream(
        self,
        state: TrainState,
        *,
        stream: int,
        partition,
        partner: jax.Array,
        active: jax.Array | None = None,
        phi_pre: PyTree | None = None,
        consume_prefetch: bool = False,
        partner_next: jax.Array | None = None,
    ) -> tuple[TrainState, PyTree | None]:
        """One STREAM's gossip sync (NoLoCo streaming outer steps).

        Exchanges and updates only the leaves ``partition`` (a
        :class:`repro.comm.StreamPartition` over the stacked parameter tree)
        assigns to ``stream``; see
        :func:`repro.core.outer.outer_step_stacked_stream` for the prefetch /
        pre-send semantics.  Returns ``(new_state, phi_pre_out)`` where
        ``phi_pre_out`` is the updated full-tree prefetch buffer (None when no
        pre-send was requested)."""
        new_outer, new_theta, phi_pre_out = outer_lib.outer_step_stacked_stream(
            state.outer, state.theta, self.cfg.outer,
            stream=stream, partition=partition, partner=partner, active=active,
            phi_pre=phi_pre, consume_prefetch=consume_prefetch,
            partner_next=partner_next,
            comm_cfg=self.cfg.comm, kernel_cfg=self.cfg.kernels,
        )
        new_state = TrainState(
            theta=new_theta, opt=state.opt, outer=new_outer,
            inner_step=state.inner_step,
        )
        return new_state, phi_pre_out

    def eval_loss(
        self, theta: PyTree, batch: PyTree, rng: jax.Array
    ) -> jax.Array:
        """Grad-free per-replica losses (R,) — the public eval path.

        Unlike the training path this never materializes gradients; jit it
        once and reuse (``jax.jit(trainer.eval_loss)``)."""
        world = jax.tree.leaves(theta)[0].shape[0]
        rngs = jax.random.split(rng, world)
        return self._vloss(theta, batch, rngs)

    def should_sync(self, state: TrainState) -> bool:
        m = self.cfg.outer.inner_steps
        return int(state.inner_step) > 0 and int(state.inner_step) % m == 0

    # -- convenience loop (benchmarks / examples) --------------------------

    def train(
        self,
        state: TrainState,
        batches,
        *,
        rng: jax.Array,
        log_every: int = 0,
        metrics_hook: Callable[[int, dict], None] | None = None,
    ) -> TrainState:
        """Drive inner+outer steps over an iterable of stacked batches."""
        step_fn = jax.jit(self.inner_step)
        for i, batch in enumerate(batches):
            rng, sub = jax.random.split(rng)
            state, metrics = step_fn(state, batch, sub)
            if self.should_sync(state):
                state = self.outer_step(state)
            if metrics_hook is not None and log_every and (i + 1) % log_every == 0:
                metrics_hook(i + 1, jax.tree.map(lambda x: float(jnp.mean(x)), metrics))
        return state

    # -- diagnostics -------------------------------------------------------

    @staticmethod
    def replica_weight_std(theta: PyTree) -> jax.Array:
        """Mean over parameters of the std across replicas — the quantity in
        Fig. 3B / Fig. 4A of the paper (shared impl: repro.core.metrics)."""
        return metrics_lib.replica_weight_std(theta)
