"""Outer optimizers: NoLoCo (gossip, modified Nesterov), DiLoCo (all-reduce
Nesterov) and plain FSDP-style no-op.

All math is expressed once over ``(mean_delta, mean_phi)`` *group statistics*
and reused by three communication backends:

  * ``stacked``  — replicas live on a leading pytree axis (simulation / vmap /
                   GSPMD-with-replica-dim).  Partner values come from a gather
                   with the deterministic :mod:`repro.core.pairing` tables.
  * ``sharded``  — inside ``shard_map``; partner values come from a single
                   ``jax.lax.ppermute`` (collective-permute — the point of the
                   paper: NO all-reduce anywhere in the outer step).
  * DiLoCo uses ``jax.lax.pmean`` (all-reduce) in sharded mode / a full mean in
    stacked mode, as the communication-heavy baseline.

Equations (paper §3.2)::

    Δ_{t,i}   = θ_{t+1,i} − φ_{t,i}                                  (1)
    δ_{t,i}   = α δ_{t−1,i} − (β/n) Σ_j Δ_{t,j}
                            − γ (φ_{t,i} − (1/n) Σ_j φ_{t,j})        (2)
    φ_{t+1,i} = φ_{t,i} + δ_{t,i}                                    (3)

For the group of all replicas Eq. 2 reduces to DiLoCo's outer Nesterov
momentum and the γ term vanishes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import pairing

PyTree = Any

__all__ = [
    "OuterConfig",
    "OuterState",
    "gamma_band",
    "default_gamma",
    "init_outer_state",
    "outer_gradient",
    "noloco_momentum_update",
    "diloco_momentum_update",
    "outer_step_stacked",
    "outer_step_sharded",
]


# ---------------------------------------------------------------------------
# Config / state
# ---------------------------------------------------------------------------


def gamma_band(alpha: float, n: int = 2) -> tuple[float, float]:
    """Stability band for γ from Eq. 74: sqrt(n/(2(n−1)))·α < γ <
    sqrt(n/(2(n−1))·(2+α²))."""
    if n < 2:
        raise ValueError("group size must be >= 2 for the γ term to exist")
    scale = math.sqrt(n / (2.0 * (n - 1)))
    return scale * alpha, scale * math.sqrt(2.0 + alpha * alpha)


def default_gamma(alpha: float, n: int = 2) -> float:
    """Midpoint of the Eq. 74 stability band (paper leaves γ unspecified;
    tests verify any in-band choice keeps the variance bounded)."""
    lo, hi = gamma_band(alpha, n)
    return 0.5 * (lo + hi)


@dataclasses.dataclass(frozen=True)
class OuterConfig:
    """Hyper-parameters of the outer optimizer (paper §4 defaults)."""

    method: str = "noloco"  # "noloco" | "diloco" | "none" (pure FSDP/local)
    alpha: float = 0.5      # Nesterov momentum (NoLoCo: 0.5; DiLoCo: 0.3)
    beta: float = 0.7       # outer learning rate (both methods)
    gamma: float | None = None  # local-averaging strength; None -> Eq. 74 midpoint
    group_size: int = 2     # n; paper uses the minimum, 2
    inner_steps: int = 50   # m; NoLoCo 50, DiLoCo 100 in the paper
    seed: int = 0           # pairing PRNG seed

    def resolved_gamma(self) -> float:
        if self.method != "noloco":
            return 0.0
        if self.gamma is not None:
            return float(self.gamma)
        return default_gamma(self.alpha, self.group_size)

    def validate(self) -> None:
        if self.method not in ("noloco", "diloco", "none"):
            raise ValueError(f"unknown outer method: {self.method}")
        if not 0.0 <= self.alpha < 1.0:
            raise ValueError("alpha must be in [0, 1)")
        if self.method == "noloco":
            lo, hi = gamma_band(self.alpha, self.group_size)
            g = self.resolved_gamma()
            if not (lo < g < hi):
                raise ValueError(
                    f"gamma={g:.4f} outside stability band ({lo:.4f}, {hi:.4f}) "
                    "from Eq. 74 — the slow-weight variance would diverge"
                )
        if self.beta <= self.alpha:
            # Sufficient convergence condition from Appendix A.2 (β > α).
            raise ValueError("outer learning rate beta must exceed alpha (App. A.2)")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OuterState:
    """Slow weights φ and outer momentum δ (per replica).

    In stacked mode every leaf has a leading replica axis; in sharded mode the
    leaves are the local replica's shard.
    """

    phi: PyTree
    delta: PyTree
    step: jax.Array  # outer step counter (scalar int32)


def init_outer_state(params: PyTree) -> OuterState:
    return OuterState(
        phi=jax.tree.map(jnp.asarray, params),
        delta=jax.tree.map(jnp.zeros_like, params),
        step=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Shared update math
# ---------------------------------------------------------------------------


def outer_gradient(theta: PyTree, phi: PyTree) -> PyTree:
    """Eq. 1: Δ = θ − φ (computed in φ's dtype)."""
    return jax.tree.map(lambda t, p: (t - p.astype(t.dtype)).astype(p.dtype), theta, phi)


def noloco_momentum_update(
    phi: PyTree,
    delta_mom: PyTree,
    mean_delta: PyTree,
    mean_phi: PyTree,
    *,
    alpha: float,
    beta: float,
    gamma: float,
) -> tuple[PyTree, PyTree]:
    """Eqs. 2–3 given the group means. Returns (phi_next, delta_next).

    Sign note: the paper's Eq. 2 writes ``− (β/n) Σ Δ`` with ``Δ = θ − φ``
    (Eq. 1), but its own Appendix A (Eq. 32-34) and the DiLoCo/look-ahead
    semantics it claims to reduce to require ``+ β·mean(Δ)`` — with Δ the
    *downhill* progress of the inner steps, the slow weights must move toward
    the fast weights.  The literal Eq. 2 sign provably diverges (our tests
    check this); we follow the appendix.
    """

    def _upd(p, d, md, mp):
        d32 = d.astype(jnp.float32)
        new_d = (
            alpha * d32
            + beta * md.astype(jnp.float32)
            - gamma * (p.astype(jnp.float32) - mp.astype(jnp.float32))
        )
        new_p = p.astype(jnp.float32) + new_d
        return new_p.astype(p.dtype), new_d.astype(d.dtype)

    out = jax.tree.map(_upd, phi, delta_mom, mean_delta, mean_phi)
    phi_next = jax.tree.map(lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple))
    delta_next = jax.tree.map(lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return phi_next, delta_next


def diloco_momentum_update(
    phi: PyTree,
    delta_mom: PyTree,
    mean_delta: PyTree,
    *,
    alpha: float,
    beta: float,
) -> tuple[PyTree, PyTree]:
    """DiLoCo outer Nesterov: δ = α δ + β·mean(Δ); φ' = φ + δ (same sign
    convention as :func:`noloco_momentum_update` — see the note there)."""

    def _upd(p, d, md):
        new_d = alpha * d.astype(jnp.float32) + beta * md.astype(jnp.float32)
        new_p = p.astype(jnp.float32) + new_d
        return new_p.astype(p.dtype), new_d.astype(d.dtype)

    out = jax.tree.map(_upd, phi, delta_mom, mean_delta)
    phi_next = jax.tree.map(lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple))
    delta_next = jax.tree.map(lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return phi_next, delta_next


# ---------------------------------------------------------------------------
# Stacked backend (leading replica axis)
# ---------------------------------------------------------------------------


def _gather_replica_axis(tree: PyTree, index: jax.Array) -> PyTree:
    """tree[index] along the leading replica axis for every leaf."""
    return jax.tree.map(lambda x: jnp.take(x, index, axis=0), tree)


def outer_step_stacked(
    state: OuterState,
    theta: PyTree,
    cfg: OuterConfig,
    *,
    partner: jax.Array | None = None,
) -> tuple[OuterState, PyTree]:
    """One outer step where replicas are stacked on axis 0 of every leaf.

    Returns (new_state, new_theta) — fast weights are reset to the new slow
    weights (look-ahead semantics), ready for the next ``m`` inner steps.

    ``partner``: optional precomputed partner index table (world,), e.g. from
    :func:`repro.core.pairing.partner_table`. When None it is derived from the
    (traced) outer step counter via a host-independent PRNG — but note that
    under ``jit`` the step is traced, so callers that jit this function should
    pass ``partner`` explicitly (the launcher does).
    """
    cfg.validate()
    world = jax.tree.leaves(theta)[0].shape[0]
    delta = outer_gradient(theta, state.phi)

    if cfg.method == "none":
        # Pure local / FSDP-style: slow weights track fast weights exactly.
        new_state = OuterState(phi=theta, delta=state.delta, step=state.step + 1)
        return new_state, theta

    if cfg.method == "diloco":
        mean_delta = jax.tree.map(
            lambda d: jnp.broadcast_to(jnp.mean(d, axis=0, keepdims=True), d.shape), delta
        )
        phi_next, delta_next = diloco_momentum_update(
            state.phi, state.delta, mean_delta, alpha=cfg.alpha, beta=cfg.beta
        )
    else:  # noloco
        if partner is None:
            partner = jnp.asarray(
                pairing.partner_table(int(state.step), world, seed=cfg.seed)
            )
        partner = jnp.asarray(partner)
        delta_p = _gather_replica_axis(delta, partner)
        phi_p = _gather_replica_axis(state.phi, partner)
        mean_delta = jax.tree.map(lambda a, b: 0.5 * (a + b), delta, delta_p)
        mean_phi = jax.tree.map(lambda a, b: 0.5 * (a + b), state.phi, phi_p)
        phi_next, delta_next = noloco_momentum_update(
            state.phi,
            state.delta,
            mean_delta,
            mean_phi,
            alpha=cfg.alpha,
            beta=cfg.beta,
            gamma=cfg.resolved_gamma(),
        )

    new_state = OuterState(phi=phi_next, delta=delta_next, step=state.step + 1)
    return new_state, phi_next


def outer_step_sharded_overlapped(
    state: OuterState,
    theta: PyTree,
    phi_prefetched: PyTree,
    cfg: OuterConfig,
    *,
    axis_names: Sequence[str],
    perm: Sequence[tuple[int, int]],
    perm_next: Sequence[tuple[int, int]],
) -> tuple[OuterState, PyTree, PyTree]:
    """NoLoCo outer step with the φ-exchange OVERLAP of §3.2.

    The partner's slow weights φ_j were already exchanged at the END of the
    previous outer step (they do not change during inner steps), so the only
    BLOCKING collective here is the Δ ppermute — half the payload of the
    baseline gossip step.  The φ′ pre-send for the NEXT pairing is issued in
    the same program; on hardware it overlaps the next m inner steps.

    Returns (new_state, new_theta, phi_prefetched_for_next_step).
    """
    cfg.validate()
    if cfg.method != "noloco":
        raise ValueError("overlap variant is NoLoCo-only")
    axis_names = tuple(axis_names)
    delta = outer_gradient(theta, state.phi)

    # blocking exchange: Δ only
    delta_p = jax.tree.map(
        lambda x: jax.lax.ppermute(x, axis_names, perm=list(perm)), delta
    )
    phi_p = phi_prefetched
    mean_delta = jax.tree.map(lambda a, b: 0.5 * (a + b), delta, delta_p)
    mean_phi = jax.tree.map(lambda a, b: 0.5 * (a + b), state.phi, phi_p)
    phi_next, delta_next = noloco_momentum_update(
        state.phi, state.delta, mean_delta, mean_phi,
        alpha=cfg.alpha, beta=cfg.beta, gamma=cfg.resolved_gamma(),
    )
    # overlappable pre-send of φ′ along the NEXT pairing
    phi_next_prefetched = jax.tree.map(
        lambda x: jax.lax.ppermute(x, axis_names, perm=list(perm_next)), phi_next
    )
    new_state = OuterState(phi=phi_next, delta=delta_next, step=state.step + 1)
    return new_state, phi_next, phi_next_prefetched


# ---------------------------------------------------------------------------
# Sharded backend (inside shard_map; axis-name collectives)
# ---------------------------------------------------------------------------


def _fused_ppermute(tree: PyTree, axis_names, perm) -> PyTree:
    """ppermute a whole pytree as ONE flat buffer per dtype.

    One leaf-per-permute costs one network message each (26–62 for our archs);
    on the high-latency links the paper targets, message COUNT dominates
    (Fig. 5's t_c is per message).  Fusing to one buffer per dtype reduces the
    gossip exchange to 1–2 collective-permutes total (§Perf P3 iteration)."""
    leaves, treedef = jax.tree.flatten(tree)
    by_dtype: dict = {}
    for i, x in enumerate(leaves):
        by_dtype.setdefault(x.dtype, []).append(i)
    out = [None] * len(leaves)
    for dt, idxs in by_dtype.items():
        flat = jnp.concatenate([leaves[i].reshape(-1) for i in idxs])
        moved = jax.lax.ppermute(flat, axis_names, perm=list(perm))
        off = 0
        for i in idxs:
            n = leaves[i].size
            out[i] = moved[off : off + n].reshape(leaves[i].shape)
            off += n
    return jax.tree.unflatten(treedef, out)


def outer_step_sharded(
    state: OuterState,
    theta: PyTree,
    cfg: OuterConfig,
    *,
    axis_names: Sequence[str],
    perm: Sequence[tuple[int, int]] | None = None,
    fuse_payload: bool = False,
) -> tuple[OuterState, PyTree]:
    """One outer step inside ``shard_map``: each program instance holds ONE
    replica's (φ, δ, θ) shards.

    NoLoCo: a single ``lax.ppermute`` (collective-permute) moves the packed
    (Δ, φ) payload to the partner — the ONLY cross-replica communication, and
    explicitly not an all-reduce.  The φ half of the payload is the part the
    paper notes can be pre-sent during the previous inner phase (§3.2); we keep
    it in the same permute here and account for the overlap in the latency
    model instead.

    DiLoCo: ``lax.pmean`` over the replica axes — lowers to all-reduce.
    """
    cfg.validate()
    axis_names = tuple(axis_names)
    delta = outer_gradient(theta, state.phi)

    if cfg.method == "none":
        new_state = OuterState(phi=theta, delta=state.delta, step=state.step + 1)
        return new_state, theta

    if cfg.method == "diloco":
        mean_delta = jax.tree.map(lambda d: jax.lax.pmean(d, axis_names), delta)
        phi_next, delta_next = diloco_momentum_update(
            state.phi, state.delta, mean_delta, alpha=cfg.alpha, beta=cfg.beta
        )
    else:
        if perm is None:
            raise ValueError("sharded NoLoCo requires an explicit ppermute perm")
        payload = (delta, state.phi)
        if fuse_payload:
            recv = _fused_ppermute(payload, axis_names, perm)
        else:
            recv = jax.tree.map(
                lambda x: jax.lax.ppermute(x, axis_names, perm=list(perm)), payload
            )
        delta_p, phi_p = recv
        mean_delta = jax.tree.map(lambda a, b: 0.5 * (a + b), delta, delta_p)
        mean_phi = jax.tree.map(lambda a, b: 0.5 * (a + b), state.phi, phi_p)
        phi_next, delta_next = noloco_momentum_update(
            state.phi,
            state.delta,
            mean_delta,
            mean_phi,
            alpha=cfg.alpha,
            beta=cfg.beta,
            gamma=cfg.resolved_gamma(),
        )

    new_state = OuterState(phi=phi_next, delta=delta_next, step=state.step + 1)
    return new_state, phi_next
