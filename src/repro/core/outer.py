"""Outer optimizers: NoLoCo (gossip, modified Nesterov), DiLoCo (all-reduce
Nesterov) and plain FSDP-style no-op.

Architecture: the update math is expressed ONCE over ``(mean_delta, mean_phi)``
group statistics and composed with a :class:`repro.comm.Communicator` that
hides where partner values come from:

  * :class:`repro.comm.StackedGather`  — replicas on a leading pytree axis
    (simulation / vmap / GSPMD-with-replica-dim); partner values come from a
    gather with the deterministic :mod:`repro.core.pairing` tables.  Used by
    :func:`outer_step_stacked`.
  * :class:`repro.comm.ShardedPermute` — inside ``shard_map``; the packed
    (optionally fused + compressed, see :class:`repro.comm.CommConfig`)
    payload moves with ONE ``jax.lax.ppermute`` per buffer (collective-permute
    — the point of the paper: NO all-reduce anywhere in the outer step).  Used
    by :func:`outer_step_sharded`.
  * :class:`repro.comm.AllReduce`      — ``jax.lax.pmean`` for DiLoCo, the
    communication-heavy baseline (a full mean in stacked mode).

The §3.2 φ-prefetch overlap is a property of the EXCHANGE, not a separate
algorithm: :func:`repro.comm.exchange_gossip` sends only Δ on the blocking
path when the partner's φ was pre-sent during the previous inner phase, and
:func:`repro.comm.presend` issues the φ′ transfer along the next pairing.
Streaming (Streaming DiLoCo composed with gossip pairing) generalizes this:
:class:`StreamSchedule` staggers the payload's parameter-group streams
(:func:`repro.comm.stream_partition`) across the round, and
:func:`outer_step_stacked_stream` / :func:`outer_step_sharded_stream` run one
stream's exchange + momentum update while every other leaf passes through
untouched.  Every NoLoCo caller opts in via ``CommConfig(streams=S,
overlap=True)``; ``streams=1, overlap=True`` reproduces the retired
``outer_step_sharded_overlapped`` pre-send path; there is no duplicated
ppermute/mean logic anywhere.

Equations (paper §3.2)::

    Δ_{t,i}   = θ_{t+1,i} − φ_{t,i}                                  (1)
    δ_{t,i}   = α δ_{t−1,i} − (β/n) Σ_j Δ_{t,j}
                            − γ (φ_{t,i} − (1/n) Σ_j φ_{t,j})        (2)
    φ_{t+1,i} = φ_{t,i} + δ_{t,i}                                    (3)

For the group of all replicas Eq. 2 reduces to DiLoCo's outer Nesterov
momentum and the γ term vanishes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.comm import CommConfig
from repro.comm import exchange as exchange_lib
from repro.comm import payload as payload_lib
from repro.core import pairing
from repro.kernels import ops as kernel_ops
from repro.kernels.dispatch import KernelConfig

PyTree = Any

__all__ = [
    "OuterConfig",
    "OuterState",
    "StreamSchedule",
    "gamma_band",
    "default_gamma",
    "init_outer_state",
    "outer_gradient",
    "stale_discount",
    "noloco_momentum_update",
    "diloco_momentum_update",
    "outer_step",
    "outer_step_stacked",
    "outer_step_stacked_stream",
    "outer_step_sharded",
    "outer_step_sharded_stream",
]


# ---------------------------------------------------------------------------
# Config / state
# ---------------------------------------------------------------------------


def gamma_band(alpha: float, n: int = 2) -> tuple[float, float]:
    """Stability band for γ from Eq. 74: sqrt(n/(2(n−1)))·α < γ <
    sqrt(n/(2(n−1))·(2+α²))."""
    if n < 2:
        raise ValueError("group size must be >= 2 for the γ term to exist")
    scale = math.sqrt(n / (2.0 * (n - 1)))
    return scale * alpha, scale * math.sqrt(2.0 + alpha * alpha)


def default_gamma(alpha: float, n: int = 2) -> float:
    """Midpoint of the Eq. 74 stability band (paper leaves γ unspecified;
    tests verify any in-band choice keeps the variance bounded)."""
    lo, hi = gamma_band(alpha, n)
    return 0.5 * (lo + hi)


@dataclasses.dataclass(frozen=True)
class OuterConfig:
    """Hyper-parameters of the outer optimizer (paper §4 defaults)."""

    method: str = "noloco"  # "noloco" | "diloco" | "none" (pure FSDP/local)
    alpha: float = 0.5      # Nesterov momentum (NoLoCo: 0.5; DiLoCo: 0.3)
    beta: float = 0.7       # outer learning rate (both methods)
    gamma: float | None = None  # local-averaging strength; None -> Eq. 74 midpoint
    group_size: int = 2     # n; paper uses the minimum, 2
    inner_steps: int = 50   # m; NoLoCo 50, DiLoCo 100 in the paper
    seed: int = 0           # pairing PRNG seed
    stale: str = "naive"    # async stale-Δ rule: "naive" | "momentum" (DeMo-style)

    def resolved_gamma(self) -> float:
        if self.method != "noloco":
            return 0.0
        if self.gamma is not None:
            return float(self.gamma)
        return default_gamma(self.alpha, self.group_size)

    def validate(self) -> None:
        if self.method not in ("noloco", "diloco", "none"):
            raise ValueError(f"unknown outer method: {self.method}")
        if not 0.0 <= self.alpha < 1.0:
            raise ValueError("alpha must be in [0, 1)")
        if self.method == "noloco":
            lo, hi = gamma_band(self.alpha, self.group_size)
            g = self.resolved_gamma()
            if not (lo < g < hi):
                raise ValueError(
                    f"gamma={g:.4f} outside stability band ({lo:.4f}, {hi:.4f}) "
                    "from Eq. 74 — the slow-weight variance would diverge"
                )
        if self.beta <= self.alpha:
            # Sufficient convergence condition from Appendix A.2 (β > α).
            raise ValueError("outer learning rate beta must exceed alpha (App. A.2)")
        if self.stale not in ("naive", "momentum"):
            raise ValueError(
                f"unknown stale-Δ rule: {self.stale!r} "
                "(\"naive\" applies a delayed Δ as-is; \"momentum\" discounts "
                "it by its staleness, DeMo-style)"
            )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OuterState:
    """Slow weights φ and outer momentum δ (per replica).

    In stacked mode every leaf has a leading replica axis; in sharded mode the
    leaves are the local replica's shard.
    """

    phi: PyTree
    delta: PyTree
    step: jax.Array  # outer step counter (scalar int32)


def init_outer_state(params: PyTree) -> OuterState:
    return OuterState(
        phi=jax.tree.map(jnp.asarray, params),
        delta=jax.tree.map(jnp.zeros_like, params),
        step=jnp.zeros((), jnp.int32),
    )


@dataclasses.dataclass(frozen=True)
class StreamSchedule:
    """When each payload stream syncs (Streaming DiLoCo round offsets).

    Stream ``k`` of ``stream_count`` gets the round offset ``o_k = ⌊k·m/S⌋``
    and syncs at inner steps ``t = r·m + o_k`` for rounds ``r ≥ 1`` — the
    offsets are distinct (requires ``S ≤ m``), so at most ONE stream syncs at
    any inner step, staggering the exchanges across the round instead of
    stacking them all on the ``t % m == 0`` wall.  Stream 0 keeps offset 0:
    with ``stream_count=1`` the schedule is exactly today's single sync point.

    The GLOBAL sync index of stream ``k``'s round-``r`` sync is
    ``(r−1)·S + k`` — a strictly increasing sequence position that doubles as
    the gossip pairing key (``OuterState.step`` advances once per stream
    sync), and stream ``k``'s next sync after index ``i`` is ``i + S`` (the
    φ′ pre-send pairing key).
    """

    inner_steps: int
    stream_count: int = 1

    def __post_init__(self):
        if self.stream_count < 1:
            raise ValueError(f"stream_count must be >= 1, got {self.stream_count}")
        if self.stream_count > self.inner_steps:
            raise ValueError(
                f"stream_count ({self.stream_count}) must not exceed "
                f"inner_steps ({self.inner_steps}): round offsets ⌊k·m/S⌋ "
                "must be distinct for the staggered schedule to exist"
            )

    @property
    def offsets(self) -> tuple[int, ...]:
        m, s = self.inner_steps, self.stream_count
        return tuple((k * m) // s for k in range(s))

    def due(self, inner_step: int) -> int | None:
        """Stream syncing at ``inner_step`` (None if no stream is due)."""
        m = self.inner_steps
        off = inner_step % m
        for k, o in enumerate(self.offsets):
            if off == o and inner_step - o >= m:
                return k
        return None

    def sync_index(self, stream: int, inner_step: int) -> int:
        """Global sync index (= pairing key) of ``stream``'s sync at
        ``inner_step``; the stream must be due there."""
        o = self.offsets[stream]
        r = (inner_step - o) // self.inner_steps
        if inner_step != r * self.inner_steps + o or r < 1:
            raise ValueError(
                f"stream {stream} is not due at inner step {inner_step}"
            )
        return (r - 1) * self.stream_count + stream


# ---------------------------------------------------------------------------
# Shared update math
# ---------------------------------------------------------------------------


def outer_gradient(theta: PyTree, phi: PyTree) -> PyTree:
    """Eq. 1: Δ = θ − φ (computed in φ's dtype)."""
    return jax.tree.map(lambda t, p: (t - p.astype(t.dtype)).astype(p.dtype), theta, phi)


def stale_discount(delta: PyTree, staleness: jax.Array) -> PyTree:
    """DeMo-style staleness discount: scale each replica's Δ by 1/(1+τ).

    A Δ arriving τ merged sync ticks late is anchored at a φ that is (1+τ)
    round intervals old, so dividing by (1+τ) damps the stale drift it would
    otherwise inject — the ``stale="momentum"`` rule (the decoupled-momentum
    treatment of delayed updates, PAPERS.md arXiv 2510.03371).  Applied to
    the WIRE copy only, before the exchange: the partner receives the
    discounted contribution, while a replica's own Δ enters its own mean
    undiscounted (discounting one's own fresh-to-oneself Δ would merely slow
    that replica down, raising the ensemble floor instead of lowering it).
    ``staleness`` is either a per-replica (world,) vector (stacked backend)
    or a scalar (this shard's τ, sharded backend); τ=0 scales by exactly
    1.0 — bit-identical to the undiscounted path.
    """
    tau = jnp.asarray(staleness, jnp.float32)
    scale = 1.0 / (1.0 + tau)

    def _scl(d):
        s = scale
        if s.ndim == 1 and d.ndim >= 1:
            s = s.reshape((-1,) + (1,) * (d.ndim - 1))
        return (d.astype(jnp.float32) * s).astype(d.dtype)

    return jax.tree.map(_scl, delta)


def _unzip_pairs(template: PyTree, pairs: PyTree) -> tuple[PyTree, PyTree]:
    """Split a template-shaped tree of (a, b) tuples into two trees."""
    return jax.tree.transpose(
        jax.tree.structure(template), jax.tree.structure((0, 0)), pairs
    )


def noloco_momentum_update(
    phi: PyTree,
    delta_mom: PyTree,
    mean_delta: PyTree,
    mean_phi: PyTree,
    *,
    alpha: float,
    beta: float,
    gamma: float,
    kernel_cfg: KernelConfig | None = None,
) -> tuple[PyTree, PyTree]:
    """Eqs. 2–3 given the group means. Returns (phi_next, delta_next).

    The memory-bound update runs through the kernel-dispatch layer
    (:func:`repro.kernels.ops.noloco_update_pytree`): the fused Pallas kernel
    writes (φ′, δ′) in one pass over each leaf, the jnp twin is the
    elementwise reference — selected by ``kernel_cfg`` (threaded from
    ``TrainerConfig.kernels`` / the runtimes).

    Sign note: the paper's Eq. 2 writes ``− (β/n) Σ Δ`` with ``Δ = θ − φ``
    (Eq. 1), but its own Appendix A (Eq. 32-34) and the DiLoCo/look-ahead
    semantics it claims to reduce to require ``+ β·mean(Δ)`` — with Δ the
    *downhill* progress of the inner steps, the slow weights must move toward
    the fast weights.  The literal Eq. 2 sign provably diverges (our tests
    check this); we follow the appendix.
    """
    return kernel_ops.noloco_update_pytree(
        phi, delta_mom, mean_delta, mean_phi,
        alpha=alpha, beta=beta, gamma=gamma, config=kernel_cfg,
    )


def diloco_momentum_update(
    phi: PyTree,
    delta_mom: PyTree,
    mean_delta: PyTree,
    *,
    alpha: float,
    beta: float,
) -> tuple[PyTree, PyTree]:
    """DiLoCo outer Nesterov: δ = α δ + β·mean(Δ); φ' = φ + δ (same sign
    convention as :func:`noloco_momentum_update` — see the note there)."""

    def _upd(p, d, md):
        new_d = alpha * d.astype(jnp.float32) + beta * md.astype(jnp.float32)
        new_p = p.astype(jnp.float32) + new_d
        return new_p.astype(p.dtype), new_d.astype(d.dtype)

    return _unzip_pairs(phi, jax.tree.map(_upd, phi, delta_mom, mean_delta))


# ---------------------------------------------------------------------------
# The one outer step (all backends)
# ---------------------------------------------------------------------------


def outer_step(
    state: OuterState,
    theta: PyTree,
    cfg: OuterConfig,
    comm: exchange_lib.Communicator | None,
    *,
    phi_prefetched: PyTree | None = None,
    comm_next: exchange_lib.Communicator | None = None,
    kernel_cfg: KernelConfig | None = None,
    staleness: jax.Array | None = None,
) -> tuple[OuterState, PyTree, PyTree | None]:
    """One outer step against any :class:`~repro.comm.Communicator`.

    Returns ``(new_state, new_theta, phi_presend)`` — fast weights are reset to
    the new slow weights (look-ahead semantics); ``phi_presend`` is the φ′
    payload exchanged along ``comm_next`` for the NEXT pairing (None unless
    ``comm_next`` is given).

    ``staleness`` (asynchronous rounds only): per-replica τ of the Δ each
    replica contributes to THIS exchange.  Under ``cfg.stale == "momentum"``
    the WIRE copy of Δ is pre-scaled by :func:`stale_discount` before it
    goes out — the partner receives the discounted contribution while each
    replica's own Δ enters its own mean undiscounted; under ``"naive"`` the
    delayed Δ is applied as-is (the value is then telemetry-only and callers
    normally pass None).
    """
    cfg.validate()
    delta = outer_gradient(theta, state.phi)
    delta_wire = delta
    if staleness is not None and cfg.method == "noloco" and cfg.stale == "momentum":
        delta_wire = stale_discount(delta, staleness)

    if cfg.method == "none":
        # Pure local / FSDP-style: slow weights track fast weights exactly.
        new_state = OuterState(phi=theta, delta=state.delta, step=state.step + 1)
        return new_state, theta, None

    if cfg.method == "diloco":
        mean_delta = comm.allreduce_mean(delta)
        phi_next, delta_next = diloco_momentum_update(
            state.phi, state.delta, mean_delta, alpha=cfg.alpha, beta=cfg.beta
        )
        phi_presend = None
    else:  # noloco
        delta_p, phi_p = exchange_lib.exchange_gossip(
            comm, delta_wire, state.phi, phi_prefetched=phi_prefetched
        )
        mean_delta = jax.tree.map(lambda a, b: 0.5 * (a + b), delta, delta_p)
        mean_phi = jax.tree.map(lambda a, b: 0.5 * (a + b), state.phi, phi_p)
        phi_next, delta_next = noloco_momentum_update(
            state.phi,
            state.delta,
            mean_delta,
            mean_phi,
            alpha=cfg.alpha,
            beta=cfg.beta,
            gamma=cfg.resolved_gamma(),
            kernel_cfg=kernel_cfg,
        )
        phi_presend = (
            exchange_lib.presend(comm_next, phi_next) if comm_next is not None else None
        )

    new_state = OuterState(phi=phi_next, delta=delta_next, step=state.step + 1)
    return new_state, phi_next, phi_presend


def _host_partner_table(step, world: int, cfg: OuterConfig) -> jax.Array:
    """Derive the pairing from the HOST-side outer step counter.

    The pairing PRNG needs a concrete step index; inside jit/scan the counter
    is a tracer, so callers must precompute the table (the launchers do).
    """
    try:
        step_int = int(step)
    except (jax.errors.ConcretizationTypeError, jax.errors.TracerIntegerConversionError) as e:
        raise ValueError(
            "outer_step_stacked: cannot derive the gossip pairing from a traced "
            "step counter (this function was called inside jit/vmap/scan). "
            "Compute the table host-side and pass it explicitly, e.g. "
            "partner=pairing.partner_table(int(outer_step), world, seed=cfg.seed)."
        ) from e
    return jnp.asarray(pairing.partner_table(step_int, world, seed=cfg.seed))


# ---------------------------------------------------------------------------
# Stacked backend (leading replica axis)
# ---------------------------------------------------------------------------


def outer_step_stacked(
    state: OuterState,
    theta: PyTree,
    cfg: OuterConfig,
    *,
    partner: jax.Array | None = None,
    active: jax.Array | None = None,
    comm_cfg: CommConfig | None = None,
    kernel_cfg: KernelConfig | None = None,
    staleness: jax.Array | None = None,
) -> tuple[OuterState, PyTree]:
    """One outer step where replicas are stacked on axis 0 of every leaf.

    Returns (new_state, new_theta) — fast weights are reset to the new slow
    weights (look-ahead semantics), ready for the next ``m`` inner steps.

    ``partner``: optional precomputed partner index table (world,), e.g. from
    :func:`repro.core.pairing.partner_table`. When None it is derived from the
    host-side outer step counter; under ``jit`` the counter is traced, so
    jitted callers MUST pass ``partner`` explicitly (a clear error is raised
    otherwise — the launchers precompute it).

    ``active``: optional (world,) bool mask of this round's PARTICIPANTS
    (elastic runs: active members minus stragglers).  Non-participants keep
    (φ, δ, θ) untouched — a dropped replica is frozen, a straggler's θ keeps
    training toward a 2m-step Δ at its next round.  A participant whose
    partner table entry is itself (sit-out / skipped partner) runs the
    self-group update: mean Δ and mean φ degenerate to its own, the γ term
    vanishes, leaving the pure self-momentum path.  Pairings with sit-outs
    encoded come from :func:`repro.core.pairing.elastic_partner_table`; the
    outer step never decides WHO participates, only applies the mask.

    ``comm_cfg`` selects the wire codec/fusing; lossy codecs are applied to
    the partner's gathered values exactly as the distributed wire would.

    ``staleness``: per-replica (world,) τ vector for asynchronous merged
    sync ticks — see :func:`outer_step` / :func:`stale_discount`.
    """
    cfg.validate()
    comm = None
    if cfg.method == "noloco":
        if partner is None:
            world = jax.tree.leaves(theta)[0].shape[0]
            partner = _host_partner_table(state.step, world, cfg)
        comm = exchange_lib.StackedGather(jnp.asarray(partner), comm_cfg)
    elif cfg.method == "diloco":
        comm = exchange_lib.StackedGather(
            None, comm_cfg, active=active
        )
    new_state, new_theta, _ = outer_step(
        state, theta, cfg, comm, kernel_cfg=kernel_cfg, staleness=staleness
    )
    if active is not None:
        act = jnp.asarray(active, bool)

        def _sel(new, old):
            return jnp.where(act.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)

        new_theta = jax.tree.map(_sel, new_theta, theta)
        new_state = OuterState(
            phi=jax.tree.map(_sel, new_state.phi, state.phi),
            delta=jax.tree.map(_sel, new_state.delta, state.delta),
            step=new_state.step,
        )
    return new_state, new_theta


def outer_step_stacked_stream(
    state: OuterState,
    theta: PyTree,
    cfg: OuterConfig,
    *,
    stream: int,
    partition: payload_lib.StreamPartition,
    partner: jax.Array,
    active: jax.Array | None = None,
    phi_pre: PyTree | None = None,
    consume_prefetch: bool = False,
    partner_next: jax.Array | None = None,
    comm_cfg: CommConfig | None = None,
    kernel_cfg: KernelConfig | None = None,
) -> tuple[OuterState, PyTree, PyTree | None]:
    """One STREAM's outer sync in stacked mode (NoLoCo only).

    Exchanges and momentum-updates only the leaves ``partition`` assigns to
    ``stream``; every other leaf of (φ, δ, θ) passes through bit-untouched.
    The per-leaf math is exactly :func:`outer_step` restricted to the stream's
    leaf list, so a single stream covering the whole payload reproduces
    :func:`outer_step_stacked` bitwise (tested).

    ``consume_prefetch``: partner's φ for this stream was pre-sent at the
    previous sync of the same stream — read it from ``phi_pre`` (a FULL
    parameter-shaped tree; only the stream's leaves are consulted) and block
    only on the Δ exchange.  ``partner_next``: issue the φ′ pre-send for this
    stream's NEXT sync along that pairing; the updated ``phi_pre`` (stream
    leaves overwritten with the partner's incoming φ′) is returned as the
    third element, or None when no pre-send was requested.

    ``active`` freezes non-participants exactly like
    :func:`outer_step_stacked` — but only over this stream's leaves.
    """
    cfg.validate()
    if cfg.method != "noloco":
        raise ValueError("streamed outer sync is NoLoCo-only (gossip pairing)")
    theta_leaves, treedef = jax.tree.flatten(theta)
    phi_leaves = jax.tree.leaves(state.phi)
    mom_leaves = jax.tree.leaves(state.delta)
    idxs = partition.leaf_indices(stream)

    theta_k = [theta_leaves[i] for i in idxs]
    phi_k = [phi_leaves[i] for i in idxs]
    mom_k = [mom_leaves[i] for i in idxs]
    delta_k = outer_gradient(theta_k, phi_k)

    comm = exchange_lib.StackedGather(jnp.asarray(partner), comm_cfg)
    prefetched = None
    if consume_prefetch:
        if phi_pre is None:
            raise ValueError("consume_prefetch=True requires phi_pre")
        pre_leaves = jax.tree.leaves(phi_pre)
        prefetched = [pre_leaves[i] for i in idxs]
    delta_p, phi_p = exchange_lib.exchange_gossip(
        comm, delta_k, phi_k, phi_prefetched=prefetched
    )
    mean_delta = jax.tree.map(lambda a, b: 0.5 * (a + b), delta_k, delta_p)
    mean_phi = jax.tree.map(lambda a, b: 0.5 * (a + b), phi_k, phi_p)
    phi_next_k, mom_next_k = noloco_momentum_update(
        phi_k, mom_k, mean_delta, mean_phi,
        alpha=cfg.alpha, beta=cfg.beta, gamma=cfg.resolved_gamma(),
        kernel_cfg=kernel_cfg,
    )
    theta_next_k = phi_next_k
    if active is not None:
        act = jnp.asarray(active, bool)

        def _sel(new, old):
            return jnp.where(act.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)

        phi_next_k = jax.tree.map(_sel, phi_next_k, phi_k)
        mom_next_k = jax.tree.map(_sel, mom_next_k, mom_k)
        theta_next_k = jax.tree.map(_sel, theta_next_k, theta_k)

    phi_pre_out = None
    if partner_next is not None:
        comm_next = exchange_lib.StackedGather(jnp.asarray(partner_next), comm_cfg)
        pre_k = exchange_lib.presend(comm_next, phi_next_k)
        base = phi_pre if phi_pre is not None else state.phi
        pre_leaves = list(jax.tree.leaves(base))
        for i, leaf in zip(idxs, pre_k):
            pre_leaves[i] = leaf
        phi_pre_out = jax.tree.unflatten(treedef, pre_leaves)

    new_phi = list(phi_leaves)
    new_mom = list(mom_leaves)
    new_theta = list(theta_leaves)
    for i, p, d, t in zip(idxs, phi_next_k, mom_next_k, theta_next_k):
        new_phi[i], new_mom[i], new_theta[i] = p, d, t
    new_state = OuterState(
        phi=jax.tree.unflatten(treedef, new_phi),
        delta=jax.tree.unflatten(treedef, new_mom),
        step=state.step + 1,
    )
    return new_state, jax.tree.unflatten(treedef, new_theta), phi_pre_out


# ---------------------------------------------------------------------------
# Sharded backend (inside shard_map; axis-name collectives)
# ---------------------------------------------------------------------------


def _fused_ppermute(tree: PyTree, axis_names, perm) -> PyTree:
    """Back-compat shim: ppermute a whole pytree as one flat buffer per dtype.

    Now a thin wrapper over :class:`repro.comm.ShardedPermute` with
    ``fuse=True`` — see :mod:`repro.comm.payload` for the packing layout.
    """
    comm = exchange_lib.ShardedPermute(axis_names, perm, CommConfig(fuse=True))
    return comm.exchange(tree)


def outer_step_sharded(
    state: OuterState,
    theta: PyTree,
    cfg: OuterConfig,
    *,
    axis_names: Sequence[str],
    perm: Sequence[tuple[int, int]] | None = None,
    fuse_payload: bool = False,
    comm_cfg: CommConfig | None = None,
    kernel_cfg: KernelConfig | None = None,
    active_flag: jax.Array | None = None,
    staleness: jax.Array | None = None,
) -> tuple[OuterState, PyTree]:
    """One outer step inside ``shard_map``: each program instance holds ONE
    replica's (φ, δ, θ) shards.

    NoLoCo: a :class:`~repro.comm.ShardedPermute` moves the packed (Δ, φ)
    payload to the partner — the ONLY cross-replica communication, and
    explicitly not an all-reduce.  DiLoCo: :class:`~repro.comm.AllReduce`
    (``lax.pmean``) over the replica axes.

    ``fuse_payload`` is the legacy switch for ``comm_cfg.fuse``; pass a full
    :class:`~repro.comm.CommConfig` to also select a wire codec.

    ``active_flag`` (optional scalar: does THIS shard's replica participate
    in the round?) feeds the elastic DiLoCo weighted mean — NoLoCo needs no
    flag here because sit-outs are already encoded as self-loops in ``perm``;
    FREEZING a non-participant's (φ, δ, θ) is the caller's select, since only
    the caller still holds the pre-step values.

    ``staleness`` (optional scalar: THIS shard's τ) applies the asynchronous
    stale-Δ discount before the exchange — see :func:`stale_discount`.
    """
    cfg.validate()
    axis_names = tuple(axis_names)
    if comm_cfg is None:
        comm_cfg = CommConfig(fuse=fuse_payload)
    comm = None
    if cfg.method == "noloco":
        if perm is None:
            raise ValueError("sharded NoLoCo requires an explicit ppermute perm")
        comm = exchange_lib.ShardedPermute(axis_names, perm, comm_cfg)
    elif cfg.method == "diloco":
        weight = None
        if active_flag is not None:
            weight = jnp.asarray(active_flag, jnp.float32).reshape(())
        comm = exchange_lib.AllReduce(axis_names, weight=weight)
    new_state, new_theta, _ = outer_step(
        state, theta, cfg, comm, kernel_cfg=kernel_cfg, staleness=staleness
    )
    return new_state, new_theta


def outer_step_sharded_stream(
    state: OuterState,
    theta: PyTree,
    cfg: OuterConfig,
    *,
    stream: int,
    partition: payload_lib.StreamPartition,
    axis_names: Sequence[str],
    perm: Sequence[tuple[int, int]],
    phi_pre: PyTree | None = None,
    consume_prefetch: bool = False,
    perm_next: Sequence[tuple[int, int]] | None = None,
    comm_cfg: CommConfig | None = None,
    kernel_cfg: KernelConfig | None = None,
    active_flag: jax.Array | None = None,
) -> tuple[OuterState, PyTree, PyTree | None]:
    """One STREAM's outer sync inside ``shard_map`` (NoLoCo only).

    The shard_map twin of :func:`outer_step_stacked_stream`: only the leaves
    ``partition`` assigns to ``stream`` are exchanged (ShardedPermute over
    ``perm``) and momentum-updated; every other leaf of (φ, δ, θ) passes
    through bit-untouched, so a single stream covering the whole payload
    reproduces :func:`outer_step_sharded` bitwise.

    ``consume_prefetch`` reads the partner's φ for this stream from
    ``phi_pre`` (full parameter-shaped tree, pre-sent at the stream's
    previous sync — §3.2: φ does not change during inner steps) and blocks
    only on the Δ ppermute; ``perm_next`` issues the φ′ pre-send for the
    stream's NEXT sync, returned as an updated ``phi_pre`` (third element —
    on hardware that transfer overlaps the next inner steps).  This subsumes
    the retired ``outer_step_sharded_overlapped``: a single stream with
    ``consume_prefetch=True`` and a ``perm_next`` is exactly the legacy
    pre-send path.

    ``active_flag`` (optional scalar: does THIS shard's replica participate
    in the round?) freezes a non-participant's stream leaves — the select
    runs BEFORE the pre-send so a frozen replica pre-sends its TRUE
    (unchanged) φ, exactly like the stacked twin.  Unlike
    :func:`outer_step_sharded` the select lives here, not in the caller,
    because the pre-send ordering depends on it.
    """
    cfg.validate()
    if cfg.method != "noloco":
        raise ValueError("streamed outer sync is NoLoCo-only (gossip pairing)")
    axis_names = tuple(axis_names)
    comm_cfg = comm_cfg or CommConfig(fuse=True)
    theta_leaves, treedef = jax.tree.flatten(theta)
    phi_leaves = jax.tree.leaves(state.phi)
    mom_leaves = jax.tree.leaves(state.delta)
    idxs = partition.leaf_indices(stream)

    theta_k = [theta_leaves[i] for i in idxs]
    phi_k = [phi_leaves[i] for i in idxs]
    mom_k = [mom_leaves[i] for i in idxs]
    delta_k = outer_gradient(theta_k, phi_k)

    comm = exchange_lib.ShardedPermute(axis_names, perm, comm_cfg)
    prefetched = None
    if consume_prefetch:
        if phi_pre is None:
            raise ValueError("consume_prefetch=True requires phi_pre")
        pre_leaves = jax.tree.leaves(phi_pre)
        prefetched = [pre_leaves[i] for i in idxs]
    delta_p, phi_p = exchange_lib.exchange_gossip(
        comm, delta_k, phi_k, phi_prefetched=prefetched
    )
    mean_delta = jax.tree.map(lambda a, b: 0.5 * (a + b), delta_k, delta_p)
    mean_phi = jax.tree.map(lambda a, b: 0.5 * (a + b), phi_k, phi_p)
    phi_next_k, mom_next_k = noloco_momentum_update(
        phi_k, mom_k, mean_delta, mean_phi,
        alpha=cfg.alpha, beta=cfg.beta, gamma=cfg.resolved_gamma(),
        kernel_cfg=kernel_cfg,
    )
    theta_next_k = phi_next_k
    if active_flag is not None:
        flag = jnp.asarray(active_flag, bool).reshape(())
        _sel = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(flag, a, b), new, old
        )
        phi_next_k = _sel(phi_next_k, phi_k)
        mom_next_k = _sel(mom_next_k, mom_k)
        theta_next_k = _sel(theta_next_k, theta_k)

    phi_pre_out = None
    if perm_next is not None:
        comm_next = exchange_lib.ShardedPermute(axis_names, perm_next, comm_cfg)
        pre_k = exchange_lib.presend(comm_next, phi_next_k)
        base = phi_pre if phi_pre is not None else state.phi
        pre_leaves = list(jax.tree.leaves(base))
        for i, leaf in zip(idxs, pre_k):
            pre_leaves[i] = leaf
        phi_pre_out = jax.tree.unflatten(treedef, pre_leaves)

    new_phi = list(phi_leaves)
    new_mom = list(mom_leaves)
    new_theta = list(theta_leaves)
    for i, p, d, t in zip(idxs, phi_next_k, mom_next_k, theta_next_k):
        new_phi[i], new_mom[i], new_theta[i] = p, d, t
    new_state = OuterState(
        phi=jax.tree.unflatten(treedef, new_phi),
        delta=jax.tree.unflatten(treedef, new_mom),
        step=state.step + 1,
    )
    return new_state, jax.tree.unflatten(treedef, new_theta), phi_pre_out


def outer_step_sharded_overlapped(*args, **kwargs):
    """Removed: the legacy φ pre-send path is subsumed by the stream machinery.

    ``CommConfig(streams=1, overlap=True)`` through
    :func:`outer_step_sharded_stream` / ``parallel.steps.build_outer_step``
    reproduces it (single stream, ``consume_prefetch=True`` + a pre-send
    pairing) — and unlike the legacy spelling it composes with elasticity via
    the membership-epoch fallback.
    """
    raise NotImplementedError(
        "outer_step_sharded_overlapped was removed: use "
        "outer_step_sharded_stream(..., consume_prefetch=True, perm_next=...) "
        "or CommConfig(streams=1, overlap=True) through "
        "parallel.steps.build_outer_step — the stream machinery reproduces "
        "the legacy pre-send path and additionally composes with elasticity."
    )
