"""Appendix A machinery: convergence analysis of the modified Nesterov outer
step on the stochastic quadratic loss

    L(θ) = ½ (θ − c)ᵀ A (θ − c),   c ~ N(0, Σ),  A ≻ 0 symmetric.

These utilities are used by tests and benchmarks to validate Theorem 1
empirically:

  * ``expected_phi_spectrum``  — eigenvalues 𝒟_i of D = (1+α)I + β(Bᵐ − I)
    (Eq. 53); |roots of r² − 𝒟 r + α| < 1  ⇔  E(φ_t) → 0.
  * ``variance_coefficient``   — d_V = 1 + α² − 2γ²(n−1)/n (Eq. 69); |d_V| < 1
    is the boundedness condition that yields the γ band of Eq. 74.
  * ``simulate_quadratic``     — direct Monte-Carlo of the full NoLoCo
    iteration (inner SGD + gossip outer) on the quadratic model, returning the
    trajectory of E‖φ‖ and V(φ) across replicas so tests can check
    E(φ)→0 and V(φ) ∝ ω².
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import outer as outer_lib
from repro.core import pairing

__all__ = [
    "QuadraticModel",
    "expected_phi_spectrum",
    "expected_phi_converges",
    "variance_coefficient",
    "variance_bounded",
    "simulate_quadratic",
    "staleness_floor",
]


def staleness_floor(
    omega: float, sigma: float, dim: int, tau_bar: float, stale: str = "naive"
) -> float:
    """Predicted stationary floor of the tail-averaged ‖E(φ)‖ under
    asynchronous merged-tick rounds with mean staleness τ̄.

    The synchronous floor is the O(ω σ √d) stochastic level of Thm. 1 (the
    1.5 prefactor is the Monte-Carlo calibration the synchronous tests pin).
    ``stale="naive"`` applies a delayed Δ undiscounted, so a replica that is
    τ ticks late injects a contribution accumulated over (1+τ) rounds of
    drift — the floor grows as O(ω σ · (1+τ̄)).  ``stale="momentum"``
    rescales each Δ by 1/(1+τ) before the exchange, recovering the
    synchronous floor."""
    base = 1.5 * omega * sigma * float(np.sqrt(dim))
    if stale == "momentum":
        return base
    return base * (1.0 + tau_bar)


@dataclasses.dataclass(frozen=True)
class QuadraticModel:
    """The App. A toy problem. ``a_eigs`` are the eigenvalues of A (we work in
    A's eigenbasis WLOG); ``sigma`` the isotropic std of c."""

    a_eigs: tuple[float, ...] = (1.0, 0.25, 0.05)
    sigma: float = 1.0

    @property
    def dim(self) -> int:
        return len(self.a_eigs)


def expected_phi_spectrum(
    alpha: float, beta: float, omega: float, m: int, a_eigs
) -> np.ndarray:
    """Eigenvalues 𝒟_i = 1 + α − (1 − (1 − ω Λ_i)ᵐ) β of D (Eq. 53)."""
    lam = np.asarray(a_eigs, dtype=np.float64)
    return 1.0 + alpha - (1.0 - (1.0 - omega * lam) ** m) * beta


def expected_phi_converges(
    alpha: float, beta: float, omega: float, m: int, a_eigs
) -> bool:
    """E(φ_t) → 0 iff both roots of r² − 𝒟 r + α = 0 lie inside the unit
    circle for every eigenvalue 𝒟 (Eq. 44-46)."""
    for d in expected_phi_spectrum(alpha, beta, omega, m, a_eigs):
        disc = complex(d * d - 4.0 * alpha)
        sq = disc ** 0.5
        r1 = 0.5 * (d + sq)
        r2 = 0.5 * (d - sq)
        if max(abs(r1), abs(r2)) >= 1.0:
            return False
    return True


def variance_coefficient(alpha: float, gamma: float, n: int = 2) -> float:
    """d_V = 1 + α² − 2 γ² (n−1)/n (Eq. 69). |d_V| < 1 ⇔ γ in Eq. 74 band."""
    return 1.0 + alpha * alpha - 2.0 * gamma * gamma * (n - 1) / n


def variance_bounded(alpha: float, gamma: float, n: int = 2) -> bool:
    return abs(variance_coefficient(alpha, gamma, n)) < 1.0


def simulate_quadratic(
    model: QuadraticModel,
    *,
    world: int = 8,
    outer_steps: int = 200,
    inner_steps: int = 10,
    omega: float = 0.1,
    cfg: outer_lib.OuterConfig | None = None,
    seed: int = 0,
    phi0_scale: float = 5.0,
    rates: tuple[float, ...] | None = None,
) -> dict[str, np.ndarray]:
    """Run the full NoLoCo/DiLoCo iteration on the quadratic model.

    Inner optimizer: SGD with constant LR ω on the stochastic gradient
    A(θ − c), c ~ N(0, σ² I) redrawn per inner step (Eq. 9-10).

    Returns trajectories of length ``outer_steps + 1`` — entry 0 is the
    INITIAL condition (before any step), entry t >= 1 the state after outer
    step t, so ratios against ``[0]`` measure the whole transient:
      ``mean_norm``  — ‖ mean over replicas of φ ‖ (→ 0 per Thm. 2)
      ``replica_std``— mean over dims of std over replicas of φ (Fig. 3B)
      ``var``        — mean variance of φ entries over replicas (∝ ω², Thm. 3)

    NB the iteration is stochastic: ``mean_norm`` decays geometrically to a
    STATIONARY noise floor of scale O(ω σ) (Thm. 1 — the variance of φ is
    ∝ ω²), it does not go to machine zero.  Tests of "E(φ) → 0" must use a
    tail AVERAGE as the Monte-Carlo estimator and compare against an
    ω-scaled floor, not a single noisy sample against an absolute epsilon.

    ``rates`` (optional per-replica step-rate multipliers in (0, 1]) switches
    the iteration to the ASYNCHRONOUS merged-tick clock of DESIGN.md §7:
    replica r earns one inner step per wall tick with probability-free credit
    accumulation at rate ``rates[r]``, a merged sync tick fires whenever any
    replica completes its m-th inner step since its last sync, and only the
    due set applies the outer update — everyone else serves its in-progress
    state as a passive source.  ``cfg.stale`` selects the stale-Δ rule
    (``"momentum"`` discounts each replica's Δ by 1/(1+τ) before the
    exchange); ``outer_steps`` then counts merged sync ticks, so the
    returned trajectories stay length ``outer_steps + 1``.  The result dict
    additionally carries ``staleness`` — the per-sync mean τ over the due
    set — and :func:`staleness_floor` predicts the stationary tail level.
    ``rates=None`` (or all-ones) runs the exact synchronous code path.
    """
    cfg = cfg or outer_lib.OuterConfig()
    key = jax.random.PRNGKey(seed)
    a = jnp.asarray(model.a_eigs, dtype=jnp.float32)

    key, k0 = jax.random.split(key)
    phi = phi0_scale * jax.random.normal(k0, (world, model.dim), jnp.float32)
    state = outer_lib.init_outer_state(phi)
    theta = phi

    def inner_sweep(theta, key):
        def body(th, k):
            c = model.sigma * jax.random.normal(k, th.shape, th.dtype)
            grad = a[None, :] * (th - c)
            return th - omega * grad, None

        keys = jax.random.split(key, inner_steps)
        th, _ = jax.lax.scan(body, theta, keys)
        return th

    inner_sweep = jax.jit(inner_sweep)
    step_fn = jax.jit(
        lambda st, th, partner: outer_lib.outer_step_stacked(st, th, cfg, partner=partner)
    )

    mean_norm, replica_std, var = [], [], []

    def record(phi_arr):
        phi_np = np.asarray(phi_arr)
        mean_norm.append(np.linalg.norm(phi_np.mean(axis=0)))
        replica_std.append(phi_np.std(axis=0).mean())
        var.append(phi_np.var(axis=0).mean())

    record(phi)  # t = 0: the initial condition the transient decays from
    if rates is not None and any(float(r) != 1.0 for r in rates):
        staleness = _simulate_async(
            model, cfg, state, theta, key,
            world=world, outer_steps=outer_steps, inner_steps=inner_steps,
            omega=omega, rates=rates, record=record,
        )
        return {
            "mean_norm": np.asarray(mean_norm),
            "replica_std": np.asarray(replica_std),
            "var": np.asarray(var),
            "staleness": np.asarray(staleness),
        }
    for t in range(outer_steps):
        key, k = jax.random.split(key)
        theta = inner_sweep(theta, k)
        partner = jnp.asarray(pairing.partner_table(t, world, seed=cfg.seed))
        state, theta = step_fn(state, theta, partner)
        record(state.phi)

    out = {
        "mean_norm": np.asarray(mean_norm),
        "replica_std": np.asarray(replica_std),
        "var": np.asarray(var),
    }
    if rates is not None:  # all-ones: synchronous path, zero staleness
        out["staleness"] = np.zeros(outer_steps, dtype=np.float64)
    return out


def _simulate_async(
    model, cfg, state, theta, key, *,
    world, outer_steps, inner_steps, omega, rates, record,
):
    """Merged-tick loop of :func:`simulate_quadratic` (``rates`` path).

    Mirrors :class:`repro.sim.cluster.ReplicaClock` exactly — credit
    accumulation, due-at-m, τ = merged ticks skipped since the replica's own
    previous sync — but runs host-side on the quadratic model (repro.core
    cannot import repro.sim).  Returns the per-sync mean τ over the due set.
    """
    a = jnp.asarray(model.a_eigs, dtype=jnp.float32)

    def inner_tick(th, k, grant):
        c = model.sigma * jax.random.normal(k, th.shape, th.dtype)
        new = th - omega * (a[None, :] * (th - c))
        return jnp.where(grant[:, None], new, th)

    inner_tick = jax.jit(inner_tick)
    step_async = jax.jit(
        lambda st, th, partner, active, stale: outer_lib.outer_step_stacked(
            st, th, cfg, partner=partner, active=active, staleness=stale
        )
    )

    rate = np.asarray(rates, dtype=np.float64)
    if rate.shape != (world,):
        raise ValueError(f"rates must have shape ({world},), got {rate.shape}")
    if (rate <= 0).any() or (rate > 1).any():
        raise ValueError("rates must lie in (0, 1]")
    credit = np.zeros(world)
    local = np.zeros(world, np.int64)
    sync_count = np.zeros(world, np.int64)
    last_sync = np.full(world, -1, np.int64)
    merged_tick = 0
    staleness_trace = []
    while merged_tick < outer_steps:
        credit += rate
        grant = credit >= 1.0 - 1e-9
        credit[grant] -= 1.0
        local[grant] += 1
        key, k = jax.random.split(key)
        theta = inner_tick(theta, k, jnp.asarray(grant))
        due = local >= (sync_count + 1) * inner_steps
        if not due.any():
            continue
        tau = np.maximum(merged_tick - last_sync - 1, 0)
        partner = jnp.asarray(
            pairing.partner_table(merged_tick, world, seed=cfg.seed)
        )
        stale = None
        if cfg.stale == "momentum" and tau.any():
            stale = jnp.asarray(tau, jnp.float32)
        state, theta = step_async(
            state, theta, partner, jnp.asarray(due), stale
        )
        staleness_trace.append(float(tau[due].mean()))
        sync_count[due] += 1
        last_sync[due] = merged_tick
        merged_tick += 1
        record(state.phi)
    return staleness_trace
