"""Section 5.3 latency models: tree all-reduce vs NoLoCo pair averaging, and
the global-blocking (straggler) overhead of DiLoCo-style synchronization.

Message send times are modeled log-normal, t ~ LogNormal(μ, σ²), following the
paper.  Key closed forms:

  * tree all-reduce:           t_all ≈ 2 t_c log2(n)                  (Eq. 5)
  * max of two iid lognormals: E[max(t1,t2)] = (1+erf(σ/2)) exp(μ+σ²/2) (Eq. 7)
  * pair averaging:            2 E[max(t1,t2)]  (one leaf-level exchange)

``simulate_tree_allreduce`` Monte-Carlos the actual reduce+broadcast over a
binary tree (each level waits for the max of its children), which is what
Fig. 5A plots; ``simulate_blocking_overhead`` reproduces Fig. 5B: total time of
R outer rounds when DiLoCo must wait for the slowest of n workers each round
while NoLoCo only waits pairwise.

Size-aware variants: the closed forms above model LATENCY only (the paper's
per-message t_c).  ``pair_average_time_bytes`` / ``tree_allreduce_time_bytes``
add a bandwidth term ``payload_bytes / bandwidth`` per message, with the byte
counts supplied by :mod:`repro.comm.bytes_model` so the estimate reflects the
configured codec / fusing / overlap (fp16 halves the serialization term, int8
quarters it, overlap removes the φ half from the blocking path).
"""

from __future__ import annotations

import math

import numpy as np

__all__ = [
    "expected_message_time",
    "expected_pairwise_max",
    "tree_allreduce_time_closed_form",
    "pair_average_time_closed_form",
    "speedup_closed_form",
    "transfer_time",
    "pair_average_time_bytes",
    "tree_allreduce_time_bytes",
    "simulate_tree_allreduce",
    "simulate_pair_average",
    "simulate_blocking_overhead",
    "WAN_BANDWIDTH",
]

# Default slow-link bandwidth for the internet-scale setting the paper targets:
# 1 Gbit/s in bytes per second.
WAN_BANDWIDTH = 1.25e8


def expected_message_time(mu: float, sigma: float) -> float:
    """E[t] for t ~ LogNormal(μ, σ²): exp(μ + σ²/2). This is the paper's t_c."""
    return math.exp(mu + sigma * sigma / 2.0)


def expected_pairwise_max(mu: float, sigma: float) -> float:
    """Eq. 7: E[max(t1, t2)] = (1 + erf(σ/2)) · exp(μ + σ²/2)."""
    return (1.0 + math.erf(sigma / 2.0)) * math.exp(mu + sigma * sigma / 2.0)


def tree_allreduce_time_closed_form(n: int, mu: float, sigma: float) -> float:
    """Eq. 5 with the level-max refinement: reduce+broadcast over a binary
    tree of n leaves ≈ 2 · log2(n) · E[max of two children]."""
    return 2.0 * math.log2(max(n, 2)) * expected_pairwise_max(mu, sigma)


def pair_average_time_closed_form(mu: float, sigma: float) -> float:
    """NoLoCo local averaging: 2 E[t_local] (one exchange each way)."""
    return 2.0 * expected_pairwise_max(mu, sigma)


def speedup_closed_form(n: int, mu: float, sigma: float) -> float:
    """Expected tree-allreduce time / pair-average time ≈ log2(n)."""
    return tree_allreduce_time_closed_form(n, mu, sigma) / pair_average_time_closed_form(
        mu, sigma
    )


def transfer_time(payload_bytes: float, bandwidth: float = WAN_BANDWIDTH) -> float:
    """Serialization time of one message: bytes / (bytes per second)."""
    return float(payload_bytes) / float(bandwidth)


def pair_average_time_bytes(
    mu: float,
    sigma: float,
    *,
    payload_bytes: float,
    bandwidth: float = WAN_BANDWIDTH,
) -> float:
    """NoLoCo gossip round with a size-aware message model: the Eq. 7 latency
    term plus the serialization of the BLOCKING payload each way.

    ``payload_bytes`` should be ``CommCost.blocking_bytes`` from
    :func:`repro.comm.bytes_model.outer_step_cost` — with overlap enabled only
    the Δ half serializes on the blocking path."""
    return pair_average_time_closed_form(mu, sigma) + 2.0 * transfer_time(
        payload_bytes, bandwidth
    )


def tree_allreduce_time_bytes(
    n: int,
    mu: float,
    sigma: float,
    *,
    payload_bytes: float,
    bandwidth: float = WAN_BANDWIDTH,
) -> float:
    """Binary-tree all-reduce with a size-aware message model: each of the
    2·log2(n) levels pays the level latency plus one payload serialization."""
    levels = 2.0 * math.log2(max(n, 2))
    return tree_allreduce_time_closed_form(n, mu, sigma) + levels * transfer_time(
        payload_bytes, bandwidth
    )


def _lognormal(rng: np.random.Generator, mu: float, sigma: float, size) -> np.ndarray:
    return rng.lognormal(mean=mu, sigma=sigma, size=size)


def simulate_tree_allreduce(
    n: int, mu: float, sigma: float, *, rounds: int = 1000, seed: int = 0
) -> float:
    """Monte-Carlo expected completion time of a binary-tree all-reduce over n
    workers (reduce to root, then broadcast back down)."""
    rng = np.random.default_rng(seed)
    depth = int(math.ceil(math.log2(max(n, 2))))
    total = 0.0
    for _ in range(rounds):
        t = 0.0
        width = n
        # Reduce phase: at each level, each parent waits for max of children.
        for _lvl in range(depth):
            pairs = max(width // 2, 1)
            sends = _lognormal(rng, mu, sigma, (pairs, 2))
            t += sends.max(axis=1).max()
            width = pairs
        # Broadcast phase mirrors the reduce phase.
        width = 1
        for _lvl in range(depth):
            fanout = min(width * 2, n)
            sends = _lognormal(rng, mu, sigma, fanout)
            t += sends.max()
            width = fanout
        total += t
    return total / rounds


def simulate_pair_average(
    mu: float, sigma: float, *, rounds: int = 1000, seed: int = 0
) -> float:
    """Monte-Carlo expected completion time of one gossip pair exchange
    (send Δ,φ to partner; receive theirs): 2 × max of the two directions."""
    rng = np.random.default_rng(seed)
    sends = _lognormal(rng, mu, sigma, (rounds, 2, 2))
    return float((sends.max(axis=2).sum(axis=1)).mean())


def simulate_blocking_overhead(
    world: int,
    *,
    outer_rounds: int = 500,
    inner_steps: int = 100,
    mu: float = 1.0,
    sigma2: float = 0.5,
    seed: int = 0,
) -> dict[str, float]:
    """Fig. 5B: ratio of DiLoCo to NoLoCo total training time from global
    blocking alone (communication itself excluded, as in the paper).

    Each worker's inner-step durations are iid LogNormal(μ, σ²).  DiLoCo's
    outer step is a barrier: every round costs max over workers of their inner
    phase.  NoLoCo only synchronizes pairs: a pair's round costs the max of
    the two members; workers then proceed (we track per-worker clocks and
    return the time the LAST worker finishes, which is what wall-clock is).
    """
    rng = np.random.default_rng(seed)
    sigma = math.sqrt(sigma2)

    durations = rng.lognormal(mu, sigma, size=(outer_rounds, world, inner_steps)).sum(
        axis=2
    )

    # DiLoCo: global barrier per round.
    diloco_total = durations.max(axis=1).sum()

    # NoLoCo: pairwise barrier per round.
    clocks = np.zeros(world)
    perm_rng = np.random.default_rng(seed + 1)
    for r in range(outer_rounds):
        clocks += durations[r]
        order = perm_rng.permutation(world)
        for k in range(0, (world // 2) * 2, 2):
            a, b = order[k], order[k + 1]
            t = max(clocks[a], clocks[b])
            clocks[a] = clocks[b] = t
    noloco_total = clocks.max()

    return {
        "diloco": float(diloco_total),
        "noloco": float(noloco_total),
        "ratio": float(diloco_total / noloco_total),
    }
