"""Gossip pair / group selection for the NoLoCo outer step.

The paper (Section 3.2) synchronizes each replica with a randomly chosen local
subgroup of ``n`` replicas (``n = 2`` in all experiments).  We realize this with
random *perfect matchings* drawn from a deterministic PRNG stream keyed by the
outer-step index, so that

  * every replica is in exactly one group per outer step (load-balanced),
  * the schedule is reproducible and identical on every host (no coordinator),
  * the exchange maps directly onto ``jax.lax.ppermute`` partner lists.

For group size n=2 and an even world size this is a perfect matching; for odd
world sizes one replica sits out the round (it still applies the momentum decay
with its own Δ, i.e. a group of one).  For n>2 we partition a random
permutation into contiguous groups of n.

Elasticity (membership-aware scheduling): a :class:`Membership` names the
ACTIVE subset of the world as an epoch-stamped bitmask, and
:func:`elastic_partner_table` draws the round's matching over that subset by
filtering the SAME full-world permutation — so the schedule stays
coordinator-free and is a pure function of ``(seed, step, membership)``:
every node that agrees on the membership view (which is what the epoch
versions) computes the identical matching with zero control-plane messages.
Inactive replicas deterministically sit out (``partner[i] == i``), an odd
active count sits out one uniformly-random active replica per step (fair
across steps), and with full membership the schedule is bit-identical to the
static :func:`partner_table` — elasticity costs nothing when nobody churns.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "pairing_permutation",
    "group_assignment",
    "partner_table",
    "ppermute_pairs",
    "hypercube_dim",
    "hypercube_partner_table",
    "hypercube_ppermute_pairs",
    "all_pairs_seen",
    "Membership",
    "elastic_partner_table",
    "elastic_ppermute_pairs",
    "elastic_hypercube_partner_table",
    "elastic_hypercube_ppermute_pairs",
    "elastic_route_permutation",
]


def pairing_permutation(step: int, world: int, *, seed: int = 0) -> jax.Array:
    """Random permutation of ``world`` replica ids for outer step ``step``.

    Deterministic in (seed, step): every replica computes the same permutation
    locally, so no control-plane communication is needed to agree on pairs.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.permutation(key, world)


def group_assignment(step: int, world: int, n: int = 2, *, seed: int = 0) -> np.ndarray:
    """Return an array ``groups[world] -> group_id`` for outer step ``step``.

    Groups are contiguous blocks of the random permutation.  If ``world % n``
    != 0 the trailing remainder forms a smaller group (paper assumes N >> n, so
    the effect is negligible; tests cover it).
    """
    perm = np.asarray(pairing_permutation(step, world, seed=seed))
    group_of = np.empty(world, dtype=np.int64)
    for idx, replica in enumerate(perm):
        group_of[replica] = idx // n
    return group_of


def partner_table(step: int, world: int, *, seed: int = 0) -> np.ndarray:
    """Pairwise partner id per replica for group size n=2.

    ``partner[i] == i`` for the odd replica out (self-group).
    """
    perm = np.asarray(pairing_permutation(step, world, seed=seed))
    partner = np.arange(world, dtype=np.int64)
    limit = (world // 2) * 2
    for k in range(0, limit, 2):
        a, b = int(perm[k]), int(perm[k + 1])
        partner[a] = b
        partner[b] = a
    return partner


def ppermute_pairs(step: int, world: int, *, seed: int = 0) -> list[tuple[int, int]]:
    """(source, destination) list for ``jax.lax.ppermute`` realizing the pair
    exchange of outer step ``step``.

    Each replica sends its payload to its partner (and receives the partner's):
    a symmetric permutation, i.e. an involution with no fixed points (even
    world) — exactly one collective-permute, no all-reduce.
    """
    partner = partner_table(step, world, seed=seed)
    return [(int(src), int(partner[src])) for src in range(world)]


def hypercube_dim(step: int, world: int, *, seed: int = 0) -> int:
    """The hypercube dimension ``j`` used at outer step ``step``: a random
    cyclic order over the log2(world) dimensions, refreshed every log2(world)
    steps.  Exposed separately because ``j`` is the compiled-program pool key
    of the hypercube schedule (``parallel.steps.OuterProgramPool``)."""
    if world & (world - 1):
        raise ValueError("hypercube schedule needs a power-of-two world size")
    dims = max(int(np.log2(world)), 1)
    cycle, slot = divmod(step, dims)
    order = np.random.default_rng((seed + 1) * 7_919 + cycle).permutation(dims)
    return int(order[slot])


def hypercube_partner_table(step: int, world: int, *, seed: int = 0) -> np.ndarray:
    """Deterministic HYPERCUBE gossip schedule: partner = id XOR 2^j, with the
    dimension j drawn pseudo-randomly per step (:func:`hypercube_dim`).

    Why it exists: ``lax.ppermute`` needs a STATIC permutation, so uniformly
    random matchings require a precompiled pool of programs.  The hypercube
    family needs only log2(world) compiled programs TOTAL and still mixes
    optimally — after any log2(world) consecutive distinct dimensions, every
    pair of replicas has exchanged information (a classic dissemination
    bound).  Requires a power-of-two world."""
    j = hypercube_dim(step, world, seed=seed)
    ids = np.arange(world, dtype=np.int64)
    if world == 1:
        return ids
    return ids ^ (1 << j)


def hypercube_ppermute_pairs(step: int, world: int, *, seed: int = 0) -> list[tuple[int, int]]:
    partner = hypercube_partner_table(step, world, seed=seed)
    return [(int(src), int(partner[src])) for src in range(world)]


# ---------------------------------------------------------------------------
# Elastic (membership-aware) scheduling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Membership:
    """Epoch-stamped view of which replica slots are alive.

    ``mask[i]`` is True iff replica ``i`` participates in training.  The
    ``epoch`` increments on every membership CHANGE (drop / rejoin) — it is
    the version number nodes agree on so that everyone derives the round's
    pairing from the same view; the pairing itself is a pure function of
    ``(seed, step, mask)``, so two epochs with identical masks schedule
    identically (a node that left and came right back changes nothing).
    """

    world: int
    mask: tuple[bool, ...]
    epoch: int = 0

    def __post_init__(self):
        if self.world < 1:
            raise ValueError("membership needs world >= 1")
        if len(self.mask) != self.world:
            raise ValueError(
                f"mask length {len(self.mask)} != world {self.world}"
            )
        if not any(self.mask):
            raise ValueError("membership must keep at least one active replica")

    @classmethod
    def full(cls, world: int) -> "Membership":
        return cls(world=world, mask=(True,) * world, epoch=0)

    @property
    def active_ids(self) -> tuple[int, ...]:
        return tuple(i for i, m in enumerate(self.mask) if m)

    @property
    def num_active(self) -> int:
        return sum(self.mask)

    @property
    def is_full(self) -> bool:
        return all(self.mask)

    def active_array(self) -> np.ndarray:
        """(world,) bool mask — the ``active`` argument of the outer step."""
        return np.asarray(self.mask, dtype=bool)

    def drop(self, replicas: Iterable[int]) -> "Membership":
        """New membership with ``replicas`` deactivated; epoch bumped."""
        ids = self._check_ids(replicas)
        for r in ids:
            if not self.mask[r]:
                raise ValueError(f"replica {r} is already inactive")
        mask = tuple(m and i not in ids for i, m in enumerate(self.mask))
        return Membership(world=self.world, mask=mask, epoch=self.epoch + 1)

    def add(self, replicas: Iterable[int]) -> "Membership":
        """New membership with ``replicas`` (re)activated; epoch bumped."""
        ids = self._check_ids(replicas)
        for r in ids:
            if self.mask[r]:
                raise ValueError(f"replica {r} is already active")
        mask = tuple(m or i in ids for i, m in enumerate(self.mask))
        return Membership(world=self.world, mask=mask, epoch=self.epoch + 1)

    def without(self, replicas: Iterable[int]) -> "Membership":
        """Transient view excluding ``replicas`` (stragglers missing ONE
        round): the epoch is NOT bumped — membership did not change, this
        round's participation did."""
        ids = self._check_ids(replicas)
        if not ids:
            return self
        mask = tuple(m and i not in ids for i, m in enumerate(self.mask))
        return Membership(world=self.world, mask=mask, epoch=self.epoch)

    def _check_ids(self, replicas: Iterable[int]) -> frozenset[int]:
        ids = frozenset(int(r) for r in replicas)
        for r in ids:
            if not 0 <= r < self.world:
                raise ValueError(f"replica id {r} outside world {self.world}")
        return ids


def elastic_partner_table(
    step: int,
    membership: Membership,
    *,
    seed: int = 0,
    groups: Sequence[Sequence[int]] | None = None,
) -> np.ndarray:
    """Partner table drawn over the ACTIVE replica set of ``membership``.

    The full-world permutation of :func:`pairing_permutation` is filtered to
    the active ids (order preserved) and consecutive actives pair up — so
    with full membership this is bit-identical to :func:`partner_table`, and
    under churn every node derives the same matching from ``(seed, step,
    membership)`` alone.  Inactive replicas (and the odd active out — a
    uniformly-random active id per step) map to themselves.

    ``groups`` optionally restricts pairing to network-partition components:
    each group pairs internally (its active members only) and NO pair crosses
    a component boundary.  Groups must be disjoint; active replicas not
    covered by any group sit out.
    """
    world = membership.world
    perm = np.asarray(pairing_permutation(step, world, seed=seed))
    partner = np.arange(world, dtype=np.int64)
    if groups is None:
        components = [membership.active_ids]
    else:
        components = [tuple(int(r) for r in g) for g in groups]
        flat = [r for g in components for r in g]
        if len(flat) != len(set(flat)):
            raise ValueError("partition groups must be disjoint")
        for r in flat:
            if not 0 <= r < world:
                raise ValueError(f"partition replica id {r} outside world {world}")
    active = set(membership.active_ids)
    for comp in components:
        members = set(comp) & active
        order = [int(r) for r in perm if int(r) in members]
        for k in range(0, len(order) - 1, 2):
            a, b = order[k], order[k + 1]
            partner[a] = b
            partner[b] = a
    return partner


def elastic_ppermute_pairs(
    step: int,
    membership: Membership,
    *,
    seed: int = 0,
    groups: Sequence[Sequence[int]] | None = None,
) -> list[tuple[int, int]]:
    """(source, destination) ppermute list for the elastic matching: sit-outs
    and inactive replicas self-loop, so the permutation stays total over the
    mesh (``lax.ppermute`` needs every device addressed)."""
    table = elastic_partner_table(step, membership, seed=seed, groups=groups)
    return [(int(src), int(table[src])) for src in range(membership.world)]


def elastic_hypercube_partner_table(
    step: int,
    membership: Membership,
    *,
    seed: int = 0,
    groups: Sequence[Sequence[int]] | None = None,
) -> np.ndarray:
    """Membership-filtered hypercube matching: partner = id XOR 2^j, with any
    pair touching an inactive replica (or crossing a partition component)
    degraded to two self-loops.

    This is the BOUNDED-COMPILE elastic schedule: the table is a pure function
    of ``(j, membership)``, so a compiled-program pool needs at most
    log2(world) programs PER MEMBERSHIP VIEW (vs ``pairing_pool`` for the
    random schedule).  With full membership and no partition it is
    bit-identical to :func:`hypercube_partner_table` — and, like it, an
    involution by construction (XOR pairs are symmetric; degrading one
    endpoint to a self-loop degrades both)."""
    world = membership.world
    j = hypercube_dim(step, world, seed=seed)
    ids = np.arange(world, dtype=np.int64)
    if world == 1:
        return ids
    raw = ids ^ (1 << j)
    # component id per replica: one component without a partition; replicas
    # outside every partition group get -1 (they sit out, like the random
    # elastic schedule)
    comp = np.zeros(world, dtype=np.int64)
    if groups is not None:
        comp[:] = -1
        for gid, g in enumerate(groups):
            for r in g:
                comp[int(r)] = gid
    active = np.asarray(membership.mask, dtype=bool)
    ok = active & active[raw] & (comp == comp[raw]) & (comp >= 0)
    return np.where(ok, raw, ids)


def elastic_hypercube_ppermute_pairs(
    step: int,
    membership: Membership,
    *,
    seed: int = 0,
    groups: Sequence[Sequence[int]] | None = None,
) -> list[tuple[int, int]]:
    table = elastic_hypercube_partner_table(step, membership, seed=seed, groups=groups)
    return [(int(src), int(table[src])) for src in range(membership.world)]


def elastic_route_permutation(
    step: int, membership: Membership, *, seed: int = 0
) -> np.ndarray:
    """Membership-aware pipeline routing permutation: the full-world
    permutation of :func:`pairing_permutation` restricted to the ACTIVE ids.

    ``route[i]`` is the replica whose activations replica ``i`` consumes at
    the next stage boundary; inactive replicas route to themselves (their
    stages are frozen and carry no traffic).  With full membership this is
    bit-identical to ``pairing_permutation(step, world)`` — the routed
    pipeline's existing schedule — and for any membership it restricts to a
    bijection on the active set (the paper's backward-retraces-forward rule
    stays exact under churn)."""
    world = membership.world
    perm = np.asarray(pairing_permutation(step, world, seed=seed), dtype=np.int64)
    route = np.arange(world, dtype=np.int64)
    active = set(membership.active_ids)
    targets = [int(r) for r in perm if int(r) in active]
    for slot, src in zip(sorted(active), targets):
        route[slot] = src
    return route


def all_pairs_seen(steps: int, world: int, *, seed: int = 0) -> np.ndarray:
    """Symmetric boolean matrix: which (i, j) pairs met within ``steps`` outer
    steps.  Used by tests/benchmarks to check mixing (information spreads in
    O(log N) rounds in expectation — the epidemic-learning property)."""
    seen = np.eye(world, dtype=bool)
    for t in range(steps):
        partner = partner_table(t, world, seed=seed)
        for i in range(world):
            seen[i, partner[i]] = True
            seen[partner[i], i] = True
    return seen
