"""Gossip pair / group selection for the NoLoCo outer step.

The paper (Section 3.2) synchronizes each replica with a randomly chosen local
subgroup of ``n`` replicas (``n = 2`` in all experiments).  We realize this with
random *perfect matchings* drawn from a deterministic PRNG stream keyed by the
outer-step index, so that

  * every replica is in exactly one group per outer step (load-balanced),
  * the schedule is reproducible and identical on every host (no coordinator),
  * the exchange maps directly onto ``jax.lax.ppermute`` partner lists.

For group size n=2 and an even world size this is a perfect matching; for odd
world sizes one replica sits out the round (it still applies the momentum decay
with its own Δ, i.e. a group of one).  For n>2 we partition a random
permutation into contiguous groups of n.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "pairing_permutation",
    "group_assignment",
    "partner_table",
    "ppermute_pairs",
    "all_pairs_seen",
]


def pairing_permutation(step: int, world: int, *, seed: int = 0) -> jax.Array:
    """Random permutation of ``world`` replica ids for outer step ``step``.

    Deterministic in (seed, step): every replica computes the same permutation
    locally, so no control-plane communication is needed to agree on pairs.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.permutation(key, world)


def group_assignment(step: int, world: int, n: int = 2, *, seed: int = 0) -> np.ndarray:
    """Return an array ``groups[world] -> group_id`` for outer step ``step``.

    Groups are contiguous blocks of the random permutation.  If ``world % n``
    != 0 the trailing remainder forms a smaller group (paper assumes N >> n, so
    the effect is negligible; tests cover it).
    """
    perm = np.asarray(pairing_permutation(step, world, seed=seed))
    group_of = np.empty(world, dtype=np.int64)
    for idx, replica in enumerate(perm):
        group_of[replica] = idx // n
    return group_of


def partner_table(step: int, world: int, *, seed: int = 0) -> np.ndarray:
    """Pairwise partner id per replica for group size n=2.

    ``partner[i] == i`` for the odd replica out (self-group).
    """
    perm = np.asarray(pairing_permutation(step, world, seed=seed))
    partner = np.arange(world, dtype=np.int64)
    limit = (world // 2) * 2
    for k in range(0, limit, 2):
        a, b = int(perm[k]), int(perm[k + 1])
        partner[a] = b
        partner[b] = a
    return partner


def ppermute_pairs(step: int, world: int, *, seed: int = 0) -> list[tuple[int, int]]:
    """(source, destination) list for ``jax.lax.ppermute`` realizing the pair
    exchange of outer step ``step``.

    Each replica sends its payload to its partner (and receives the partner's):
    a symmetric permutation, i.e. an involution with no fixed points (even
    world) — exactly one collective-permute, no all-reduce.
    """
    partner = partner_table(step, world, seed=seed)
    return [(int(src), int(partner[src])) for src in range(world)]


def hypercube_partner_table(step: int, world: int, *, seed: int = 0) -> np.ndarray:
    """Deterministic HYPERCUBE gossip schedule: partner = id XOR 2^j, with the
    dimension j drawn pseudo-randomly per step.

    Why it exists: ``lax.ppermute`` needs a STATIC permutation, so uniformly
    random matchings require a precompiled pool of programs.  The hypercube
    family needs only log2(world) compiled programs TOTAL and still mixes
    optimally — after any log2(world) consecutive distinct dimensions, every
    pair of replicas has exchanged information (a classic dissemination
    bound).  Requires a power-of-two world."""
    if world & (world - 1):
        raise ValueError("hypercube schedule needs a power-of-two world size")
    dims = int(np.log2(world))
    # random cyclic order over dimensions, refreshed every `dims` steps
    epoch, slot = divmod(step, dims)
    order = np.random.default_rng((seed + 1) * 7_919 + epoch).permutation(dims)
    j = int(order[slot])
    ids = np.arange(world, dtype=np.int64)
    return ids ^ (1 << j)


def hypercube_ppermute_pairs(step: int, world: int, *, seed: int = 0) -> list[tuple[int, int]]:
    partner = hypercube_partner_table(step, world, seed=seed)
    return [(int(src), int(partner[src])) for src in range(world)]


def all_pairs_seen(steps: int, world: int, *, seed: int = 0) -> np.ndarray:
    """Symmetric boolean matrix: which (i, j) pairs met within ``steps`` outer
    steps.  Used by tests/benchmarks to check mixing (information spreads in
    O(log N) rounds in expectation — the epidemic-learning property)."""
    seen = np.eye(world, dtype=bool)
    for t in range(steps):
        partner = partner_table(t, world, seed=seed)
        for i in range(world):
            seen[i, partner[i]] = True
            seen[partner[i], i] = True
    return seen
