"""Stacked-simulation training CLI — a thin shell over the unified engine.

    PYTHONPATH=src python -m repro.launch.train --arch paper-small-125m --reduced \
        --method noloco --replicas 8 --steps 200 \
        --ckpt-dir /tmp/run0 --ckpt-every 50 --resume --log-jsonl /tmp/run0.jsonl

Simulation mode (CPU-friendly): replicas are a stacked leading axis; the full
NoLoCo machinery (inner AdamW, gossip outer step with random pairings,
weight-std tracking) runs exactly as in the paper.  ``--method`` selects
noloco / diloco / fsdp (grad all-reduce every step) / none (independent runs —
the §5.2 baseline).

``run_training`` is the library entry benchmarks and examples share; the step
loop, eval cadence, telemetry and checkpoint/resume all live in
:mod:`repro.train` (see DESIGN.md §2) — this module only assembles the
program + loader and forwards the knobs.
"""

from __future__ import annotations

import argparse
import json
from typing import Any

import dataclasses

from repro.comm import CommConfig
from repro.configs import registry
from repro.core import OuterConfig, TrainerConfig
from repro.data import LoaderConfig
from repro.kernels import dispatch as kernel_dispatch
from repro.kernels.dispatch import KernelConfig
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import GossipProgram, LoopConfig, make_loop


def method_config(
    method: str,
    *,
    inner_lr: float,
    total_steps: int,
    warmup: int = 100,
    inner_steps: int | None = None,
    seed: int = 0,
    comm: CommConfig | None = None,
    kernels: KernelConfig | None = None,
    stale: str = "naive",
) -> TrainerConfig:
    """Paper §4 hyper-parameters: β=0.7 both; NoLoCo α=0.5, m=50;
    DiLoCo α=0.3, m=100; inner AdamW + clip 1.0 + warmup-cosine.
    ``comm`` selects the gossip wire codec / payload fusing (repro.comm);
    ``kernels`` the outer-update implementation (repro.kernels.dispatch);
    ``stale`` the asynchronous stale-Δ rule (``"naive"`` applies a delayed Δ
    undiscounted, ``"momentum"`` scales it by 1/(1+τ) — NoLoCo-only, inert
    on synchronous runs)."""
    sched = warmup_cosine(inner_lr, total_steps, warmup_steps=warmup)
    inner = AdamWConfig(lr=sched, weight_decay=0.1, clip_norm=1.0)
    if method == "noloco":
        outer = OuterConfig(method="noloco", alpha=0.5, beta=0.7,
                            inner_steps=inner_steps or 50, seed=seed,
                            stale=stale)
    elif method == "diloco":
        outer = OuterConfig(method="diloco", alpha=0.3, beta=0.7,
                            inner_steps=inner_steps or 100, seed=seed)
    elif method in ("fsdp", "none"):
        outer = OuterConfig(method="none", inner_steps=10**9)
    else:  # pragma: no cover
        raise ValueError(method)
    return TrainerConfig(outer=outer, inner=inner, comm=comm or CommConfig(),
                         kernels=kernels or KernelConfig(),
                         sync_grads=method == "fsdp")


def run_training(
    cfg: ModelConfig,
    *,
    method: str = "noloco",
    replicas: int = 4,
    per_replica_batch: int = 4,
    seq_len: int = 128,
    steps: int = 100,
    total_steps: int | None = None,
    inner_lr: float = 3e-3,
    inner_steps: int | None = None,
    warmup: int | None = None,
    eval_every: int = 0,
    eval_batches: int = 2,
    seed: int = 0,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    resume: bool = False,
    log: bool = False,
    log_jsonl: str | None = None,
    codec: str = "none",
    fuse: bool = True,
    streams: int = 1,
    overlap: bool = False,
    impl: str = "auto",
    interpret: bool | None = None,
) -> dict[str, Any]:
    """Train; returns loss/weight-std trajectories and final eval loss.

    ``codec``/``fuse`` configure the gossip wire (repro.comm.CommConfig): the
    stacked simulation applies lossy codecs to the partner's exchanged values
    exactly as the distributed ppermute path would.  ``streams`` partitions
    the outer payload into that many streams synced on staggered round
    offsets (streaming outer steps, DESIGN.md §2); ``overlap`` adds the §3.2
    φ-prefetch so only each stream's Δ exchange blocks.  ``resume`` restores the
    latest checkpoint under ``ckpt_dir`` (θ/φ/δ/opt/step counters + loader
    fast-forward + PRNG keys) and continues the exact trajectory.

    ``total_steps`` fixes the LR-schedule horizon independently of ``steps``
    (default: equal).  Runs that will be interrupted and resumed must pin it,
    so stopping early does not change the schedule the checkpoint embeds.

    ``impl``/``interpret`` select the kernel implementation for the model
    forward AND the fused outer update (repro.kernels.dispatch), threaded
    explicitly — this library entry never touches the process-wide dispatch
    default (the CLI installs that itself via kernel_config_from_args)."""
    n_eval = eval_batches
    kcfg = KernelConfig(impl=impl, interpret=interpret)
    cfg = dataclasses.replace(cfg, kernels=kcfg)
    tcfg = method_config(
        method, inner_lr=inner_lr, total_steps=total_steps or steps,
        warmup=warmup if warmup is not None else max((total_steps or steps) // 10, 1),
        inner_steps=inner_steps, seed=seed,
        comm=CommConfig(codec=codec, fuse=fuse, streams=streams,
                        overlap=overlap),
        kernels=kcfg,
    )
    program = GossipProgram(cfg, tcfg, replicas=replicas, seed=seed)
    loop = make_loop(
        program,
        LoaderConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            per_replica_batch=per_replica_batch, replicas=replicas, seed=seed,
        ),
        LoopConfig(
            steps=steps, eval_every=eval_every, seed=seed,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, resume=resume,
            log_jsonl=log_jsonl, log=log, run_name=f"{cfg.name}-{method}",
        ),
        n_eval=n_eval,
    )
    return loop.run()


def add_engine_flags(ap: argparse.ArgumentParser) -> None:
    """The engine flags shared by every runtime's CLI (see DESIGN.md §2)."""
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save every N steps (0: only a final save)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint under --ckpt-dir")
    ap.add_argument("--log-jsonl", default=None,
                    help="append one JSON telemetry event per line to this file")
    ap.add_argument("--impl", default="auto", choices=["auto", "pallas", "jnp"],
                    help="kernel implementation (repro.kernels.dispatch): "
                         "auto = Pallas on TPU, jnp elsewhere")
    ap.add_argument("--interpret", action="store_const", const=True, default=None,
                    help="force Pallas interpret mode (default: auto — "
                         "interpret off-TPU, compiled on TPU)")


def kernel_config_from_args(args) -> KernelConfig:
    """KernelConfig from the shared --impl/--interpret flags; also installs
    it as the process-wide dispatch default (codec paths etc.)."""
    kcfg = KernelConfig(impl=args.impl, interpret=args.interpret)
    kernel_dispatch.set_default_config(kcfg)
    return kcfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-small-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the arch")
    ap.add_argument("--method", default="noloco",
                    choices=["noloco", "diloco", "fsdp", "none"])
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--inner-steps", type=int, default=None)
    ap.add_argument("--codec", default="none",
                    choices=["none", "fp16", "bf16", "int8"],
                    help="gossip wire codec (repro.comm)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="per-leaf exchange instead of one fused buffer per dtype")
    ap.add_argument("--stream-count", type=int, default=1,
                    help="streaming outer steps: partition the payload into N "
                         "streams synced on staggered round offsets")
    ap.add_argument("--overlap", action="store_true",
                    help="§3.2 φ-prefetch overlap (auto-enabled by "
                         "--stream-count > 1)")
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    add_engine_flags(ap)
    args = ap.parse_args()
    kernel_config_from_args(args)  # process-wide default (codec paths etc.)

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=min(cfg.vocab_size, 512), remat=False, dtype="float32")
    res = run_training(
        cfg, method=args.method, replicas=args.replicas,
        per_replica_batch=args.batch, seq_len=args.seq, steps=args.steps,
        inner_lr=args.lr, inner_steps=args.inner_steps,
        eval_every=args.eval_every, seed=args.seed,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, resume=args.resume,
        log=True, log_jsonl=args.log_jsonl,
        codec=args.codec, fuse=not args.no_fuse,
        streams=args.stream_count,
        overlap=args.overlap or args.stream_count > 1,
        impl=args.impl, interpret=args.interpret,
    )
    summary = {
        "arch": cfg.name, "method": args.method, "codec": args.codec,
        "stream_count": res.get("stream_count", 1),
        "blocking_fraction": round(res["blocking_fraction"], 4),
        "final_train_loss": res["losses"][-1] if res["losses"] else None,
        "final_eval": res["evals"][-1][1] if res["evals"] else None,
        "final_weight_std": res["final_weight_std"],
        "tokens_per_s": round(res["tokens_per_s"], 1),
        "wall_s": round(res["wall_s"], 1),
    }
    print(json.dumps(summary))
    if args.out:
        res.pop("state")
        with open(args.out, "w") as f:
            json.dump({k: v for k, v in res.items()}, f)


if __name__ == "__main__":
    main()
