"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch paper-small-125m --reduced \
        --method noloco --replicas 8 --steps 200

Simulation mode (default, CPU-friendly): replicas are a stacked leading axis;
the full NoLoCo machinery (inner AdamW, gossip outer step with random
pairings, weight-std tracking) runs exactly as in the paper.  ``--method``
selects noloco / diloco / fsdp (grad all-reduce every step) / none
(independent runs — the §5.2 baseline).

``run_training`` is the library entry benchmarks and examples share.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig
from repro.configs import registry
from repro.core import GossipTrainer, OuterConfig, TrainerConfig
from repro.data import LoaderConfig, shard_iterator
from repro.models import model as model_api
from repro.models.common import values_of
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, warmup_cosine
from repro.parallel.sharding import ShardCtx
from repro.checkpoint import save as ckpt_save


def method_config(
    method: str,
    *,
    inner_lr: float,
    total_steps: int,
    warmup: int = 100,
    inner_steps: int | None = None,
    seed: int = 0,
    comm: CommConfig | None = None,
) -> TrainerConfig:
    """Paper §4 hyper-parameters: β=0.7 both; NoLoCo α=0.5, m=50;
    DiLoCo α=0.3, m=100; inner AdamW + clip 1.0 + warmup-cosine.
    ``comm`` selects the gossip wire codec / payload fusing (repro.comm)."""
    sched = warmup_cosine(inner_lr, total_steps, warmup_steps=warmup)
    inner = AdamWConfig(lr=sched, weight_decay=0.1, clip_norm=1.0)
    if method == "noloco":
        outer = OuterConfig(method="noloco", alpha=0.5, beta=0.7,
                            inner_steps=inner_steps or 50, seed=seed)
    elif method == "diloco":
        outer = OuterConfig(method="diloco", alpha=0.3, beta=0.7,
                            inner_steps=inner_steps or 100, seed=seed)
    elif method in ("fsdp", "none"):
        outer = OuterConfig(method="none", inner_steps=10**9)
    else:  # pragma: no cover
        raise ValueError(method)
    return TrainerConfig(outer=outer, inner=inner, comm=comm or CommConfig(),
                         sync_grads=method == "fsdp")


def run_training(
    cfg: ModelConfig,
    *,
    method: str = "noloco",
    replicas: int = 4,
    per_replica_batch: int = 4,
    seq_len: int = 128,
    steps: int = 100,
    inner_lr: float = 3e-3,
    inner_steps: int | None = None,
    warmup: int | None = None,
    eval_every: int = 0,
    eval_batches: int = 2,
    seed: int = 0,
    ckpt_dir: str | None = None,
    log: bool = False,
    codec: str = "none",
    fuse: bool = True,
) -> dict[str, Any]:
    """Train; returns loss/weight-std trajectories and final eval loss.

    ``codec``/``fuse`` configure the gossip wire (repro.comm.CommConfig): the
    stacked simulation applies lossy codecs to the partner's exchanged values
    exactly as the distributed ppermute path would."""
    ctx = ShardCtx.local()

    def loss_fn(params, batch, rng):
        return model_api.loss_fn(params, cfg, batch, ctx)[0]

    tcfg = method_config(
        method, inner_lr=inner_lr, total_steps=steps,
        warmup=warmup if warmup is not None else max(steps // 10, 1),
        inner_steps=inner_steps, seed=seed,
        comm=CommConfig(codec=codec, fuse=fuse),
    )
    trainer = GossipTrainer(tcfg, loss_fn)

    one = values_of(model_api.init_params(jax.random.PRNGKey(seed), cfg))
    stacked = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (replicas,) + v.shape), one
    )
    state = trainer.init(stacked)

    loader = shard_iterator(
        LoaderConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            per_replica_batch=per_replica_batch, replicas=replicas, seed=seed,
        )
    )
    eval_loader = shard_iterator(
        LoaderConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            per_replica_batch=per_replica_batch, replicas=replicas, seed=seed + 777,
        )
    )
    eval_set = [next(eval_loader) for _ in range(eval_batches)]

    inner_jit = jax.jit(trainer.inner_step)
    eval_jit = jax.jit(
        lambda th, b, r: jnp.mean(trainer._vgrad(th, b, r)[0])
    )

    rng = jax.random.PRNGKey(seed + 1)
    losses, stds, evals = [], [], []
    t0 = time.time()
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
        rng, sub = jax.random.split(rng)
        state, metrics = inner_jit(state, batch, sub)
        losses.append(float(jnp.mean(metrics["loss"])))
        if trainer.should_sync(state):
            state = trainer.outer_step(state)
        if eval_every and (t + 1) % eval_every == 0:
            rng, sub = jax.random.split(rng)
            rngs = jax.random.split(sub, replicas)
            ev = float(np.mean([
                float(eval_jit(state.theta, {k: jnp.asarray(v) for k, v in b.items()},
                               rngs))
                for b in eval_set
            ]))
            evals.append((t + 1, ev))
            stds.append((t + 1, float(GossipTrainer.replica_weight_std(state.theta))))
            if log:
                print(f"step {t+1}: train={losses[-1]:.4f} eval={ev:.4f} "
                      f"wstd={stds[-1][1]:.6f} ({time.time()-t0:.0f}s)", flush=True)
    if ckpt_dir:
        ckpt_save(ckpt_dir, steps, {"theta": state.theta, "phi": state.outer.phi,
                                    "delta": state.outer.delta})
    return {
        "losses": losses,
        "evals": evals,
        "weight_stds": stds,
        "final_weight_std": float(GossipTrainer.replica_weight_std(state.theta)),
        "state": state,
        "wall_s": time.time() - t0,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-small-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the arch")
    ap.add_argument("--method", default="noloco",
                    choices=["noloco", "diloco", "fsdp", "none"])
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--inner-steps", type=int, default=None)
    ap.add_argument("--codec", default="none",
                    choices=["none", "fp16", "bf16", "int8"],
                    help="gossip wire codec (repro.comm)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="per-leaf exchange instead of one fused buffer per dtype")
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=min(cfg.vocab_size, 512), remat=False, dtype="float32")
    res = run_training(
        cfg, method=args.method, replicas=args.replicas,
        per_replica_batch=args.batch, seq_len=args.seq, steps=args.steps,
        inner_lr=args.lr, inner_steps=args.inner_steps,
        eval_every=args.eval_every, seed=args.seed, ckpt_dir=args.ckpt_dir,
        log=True, codec=args.codec, fuse=not args.no_fuse,
    )
    summary = {
        "arch": cfg.name, "method": args.method, "codec": args.codec,
        "final_train_loss": res["losses"][-1],
        "final_eval": res["evals"][-1][1] if res["evals"] else None,
        "final_weight_std": res["final_weight_std"],
        "wall_s": round(res["wall_s"], 1),
    }
    print(json.dumps(summary))
    if args.out:
        res.pop("state")
        with open(args.out, "w") as f:
            json.dump({k: v for k, v in res.items()}, f)


if __name__ == "__main__":
    main()
