import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: lower optimization VARIANTS of the three chosen
(arch × shape) pairs and report the roofline-term deltas vs the paper-faithful
baseline.

Variants are selected by name; each encodes one hypothesis from the
EXPERIMENTS.md §Perf log:

  outer_overlap    — NoLoCo outer step with §3.2 φ-prefetch: blocking payload
                     halves (Δ only), φ′ pre-send overlaps inner compute.
  decode_no_zero3  — internvl2 decode: keep weights TP-sharded on `model`
                     only (no per-token ZeRO-3 all-gather); weights fit
                     because decode holds no optimizer state.
  moe_seqshard     — qwen3-moe train: MoE dispatch buffers built on
                     sequence-sharded tokens (already default) vs replicated
                     tokens (ablation: buffers ×tp bigger).
  no_remat         — train_4k: disable full remat (memory for compute trade).
  loss_chunk_512   — smaller CE chunks (memory term of the loss).

    PYTHONPATH=src python -m repro.launch.perf --variant outer_overlap
"""

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.shapes import SHAPES
from repro.core import pairing
from repro.parallel import compat
from repro.core.outer import OuterConfig
from repro.launch import dryrun as dr
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_api
from repro.models.common import unzip
from repro.parallel import plans as plans_lib
from repro.parallel import steps as steps_lib


def outer_variant(arch: str, overlapped: bool, mesh) -> dict:
    """Lower the NoLoCo outer step, baseline vs φ-overlap, report collective
    bytes on the BLOCKING path."""
    cfg = registry.get_config(arch)
    plan = plans_lib.make_plan(registry.get_plan(arch), mesh)
    params_abs = dr.abstract_params(cfg, plan.replicas)
    theta_abs, _ = unzip(params_abs)
    pspecs = plans_lib.param_pspecs(plan, mesh, params_abs)
    perm = pairing.ppermute_pairs(0, plan.replicas)
    perm_next = pairing.ppermute_pairs(1, plan.replicas)
    ocfg = OuterConfig(method="noloco")
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    with compat.set_mesh(mesh):
        rep_sh = jax.ShapeDtypeStruct((plan.replicas,), jnp.int32)
        if not overlapped:
            fn = steps_lib.build_outer_step(plan, mesh, pspecs, ocfg, perm)
            compiled = fn.lower(theta_abs, theta_abs, theta_abs, rep_sh).compile()
        else:
            # the §3.2 overlap is the single-stream streamed program: consume
            # the prefetched φ (block on Δ only) and pre-send φ′ along the
            # next pairing (extra phi_pre input and output)
            from repro.comm import stream_partition

            part = stream_partition(theta_abs, 1)
            fn = steps_lib.build_outer_step(
                plan, mesh, pspecs, ocfg, perm, stream=0, partition=part,
                consume_prefetch=True, perm_presend=perm_next,
            )
            compiled = fn.lower(
                theta_abs, theta_abs, theta_abs, theta_abs, rep_sh
            ).compile()

    stats = rf.collective_bytes(compiled.as_text(), model_size)
    return {
        "variant": "outer_overlap" if overlapped else "outer_baseline",
        "arch": arch,
        "collectives": stats.counts,
        "collective_bytes_total": stats.total_bytes,
        "note": "overlap: the φ′ pre-send permute is overlappable with the next "
                "m inner steps; blocking payload = Δ permute only" if overlapped else
                "blocking payload = Δ AND φ permutes",
    }


def train_variant(arch: str, shape_name: str, mesh, *, remat: bool,
                  seq_parallel: bool, replicate_experts: bool = False) -> dict:
    shape = SHAPES[shape_name]
    cfg = registry.variant_for_shape(registry.get_config(arch), shape)
    cfg = dataclasses.replace(cfg, remat=remat)
    plan = plans_lib.make_plan(
        registry.get_plan(arch), mesh, shape_kind=shape.kind,
        has_global_attention=any(t == "global" for t in cfg.layer_types),
        seq_parallel=seq_parallel, replicate_experts=replicate_experts,
    )
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    c1 = dr._build_lowered(dr._depth_variant(cfg, 1), plan, shape, shape.kind, mesh).compile()
    c2 = dr._build_lowered(dr._depth_variant(cfg, 2), plan, shape, shape.kind, mesh).compile()
    f1, h1, k1 = dr._cost_of(c1, model_size)
    f2, h2, k2 = dr._cost_of(c2, model_size)
    eq = dr._equiv_periods(cfg)
    ext = lambda a, b: a + max(b - a, 0.0) * (eq - 1)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    roof = rf.analyze(
        ext(f1, f2), ext(h1, h2), None, chips=mesh.devices.size,
        model_flops=rf.model_flops_estimate(cfg, tokens, "train" if shape.kind == "train" else "fwd"),
        cross_bytes=ext(k1.cross_replica_bytes, k2.cross_replica_bytes),
        intra_bytes=ext(k1.model_axis_bytes, k2.model_axis_bytes),
    )
    return {"variant": f"remat={remat},seqpar={seq_parallel},repexp={replicate_experts}",
            "arch": arch, "shape": shape_name, "roofline": roof.as_dict()}


def decode_no_zero3(arch: str, shape_name: str, mesh) -> dict:
    """internvl2 decode without per-token ZeRO-3 gathers: weights sharded on
    `model` only (gossip_dp-style specs) for the DECODE step."""
    shape = SHAPES[shape_name]
    cfg = registry.get_config(arch)
    plan = plans_lib.make_plan(
        "gossip_dp", mesh, shape_kind="decode", has_global_attention=True
    )
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    c1 = dr._build_lowered(dr._depth_variant(cfg, 1), plan, shape, "decode", mesh).compile()
    c2 = dr._build_lowered(dr._depth_variant(cfg, 2), plan, shape, "decode", mesh).compile()
    f1, h1, k1 = dr._cost_of(c1, model_size)
    f2, h2, k2 = dr._cost_of(c2, model_size)
    eq = dr._equiv_periods(cfg)
    ext = lambda a, b: a + max(b - a, 0.0) * (eq - 1)
    roof = rf.analyze(
        ext(f1, f2), ext(h1, h2), None, chips=mesh.devices.size,
        model_flops=rf.model_flops_estimate(cfg, shape.global_batch, "fwd"),
        cross_bytes=ext(k1.cross_replica_bytes, k2.cross_replica_bytes),
        intra_bytes=ext(k1.model_axis_bytes, k2.model_axis_bytes),
    )
    return {"variant": "decode_no_zero3", "arch": arch, "shape": shape_name,
            "roofline": roof.as_dict()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", required=True,
                    choices=["outer_baseline", "outer_overlap",
                             "train_baseline", "train_no_remat", "train_seqpar",
                             "moe_replicate", "decode_no_zero3"])
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)

    if args.variant in ("outer_baseline", "outer_overlap"):
        rec = outer_variant(args.arch, args.variant == "outer_overlap", mesh)
    elif args.variant == "train_baseline":
        rec = train_variant(args.arch, args.shape, mesh, remat=True, seq_parallel=False)
    elif args.variant == "train_no_remat":
        rec = train_variant(args.arch, args.shape, mesh, remat=False, seq_parallel=False)
    elif args.variant == "train_seqpar":
        rec = train_variant(args.arch, args.shape, mesh, remat=True, seq_parallel=True)
    elif args.variant == "moe_replicate":
        rec = train_variant(args.arch, args.shape, mesh, remat=True,
                            seq_parallel=False, replicate_experts=True)
    else:
        rec = decode_no_zero3(args.arch, args.shape, mesh)
    print(json.dumps(rec))


if __name__ == "__main__":
    main()
