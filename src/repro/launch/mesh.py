"""Production meshes.

Single pod: TPU v5e-256 as (data=16, model=16).
Multi-pod : 2 pods = 512 chips as (pod=2, data=16, model=16); the "pod" axis
models the slow cross-DCN links where NoLoCo's gossip replaces all-reduce.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init.
"""

from __future__ import annotations

from repro.parallel import compat

__all__ = ["make_production_mesh", "make_test_mesh"]


def _mk(shape, axes):
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2, pod: int | None = None):
    """Small host-device mesh for CPU tests (device count forced upstream)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))
