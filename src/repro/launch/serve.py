"""Serving driver: continuous-batching engine over the paged KV cache.

Generates a synthetic mixed-length request load, optionally promotes a
trained NoLoCo checkpoint (one replica's θ or φ), and serves it through
:class:`repro.serve.ServeEngine` — chunked prefill interleaved with decode,
request-driven admit/evict scheduling, per-request sampling temperatures,
dispatched Pallas/jnp decode kernels.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 8 --max-batch 4 --prompt-lens 4,12 --gen-lens 8,24

    # serve a trained checkpoint (replica 1's outer weights):
    ... --ckpt /tmp/run_ck --replica 1 --weights phi

    # ensemble speculative decode: replica 2 drafts for replica 1
    ... --ckpt /tmp/run_ck --replica 1 --spec-decode --draft-replica 2

JSONL telemetry (--log-jsonl): run_start / streamed ``token`` events
(--stream-every; batched host drains, never per-token syncs) / admit-free
`finish` per request (ttft_s, tokens, spec stats) / run_end (tokens_per_s,
p50/p99 latency, acceptance, parity when --verify).  The final stdout line
is the run_end summary JSON.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import registry
from repro.launch.train import add_engine_flags, kernel_config_from_args
from repro.models import model as M
from repro.models.common import values_of
from repro.serve import (
    Request,
    ServeConfig,
    ServeEngine,
    SpecServeEngine,
    promote,
    truncate_layers,
)


def synth_requests(
    n: int, vocab: int, prompt_lens: list[int], gen_lens: list[int],
    temps: list[float], seed: int,
) -> list[Request]:
    """Synthetic load: prompts/gen budgets cycled from the given buckets so a
    small ``--requests`` already exercises mixed lengths (the workload where
    continuous batching beats static batching)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        pl = prompt_lens[i % len(prompt_lens)]
        gl = gen_lens[i % len(gen_lens)]
        prompt = rng.integers(0, vocab, size=(pl,)).tolist()
        reqs.append(
            Request(rid=i, prompt=[int(t) for t in prompt], max_new=gl,
                    temperature=temps[i % len(temps)])
        )
    return reqs


def serve_run(
    params, cfg, scfg: ServeConfig, requests: list[Request],
    *, verify: bool = False, log=None, draft=None, spec_k: int = 4,
    stream_every: int = 0,
) -> dict:
    """Run one serving load; returns the run_end summary dict.

    ``draft=(draft_params, draft_cfg)`` switches on speculative decode.
    ``--verify`` always re-decodes solo on a PLAIN engine, so with spec on it
    checks the strongest claim: speculative output == target-only output."""
    if draft is not None:
        engine = SpecServeEngine(params, cfg, scfg, draft[0], draft[1], spec_k=spec_k)
    else:
        engine = ServeEngine(params, cfg, scfg)
    token_cb = None
    if log and stream_every:
        def token_cb(rid, index, token, t):
            log({"event": "token", "rid": rid, "index": index,
                 "token": token, "t": round(t, 6)})
    t0 = time.perf_counter()
    finished = engine.run(
        [dataclasses.replace(r) for r in requests],
        token_cb=token_cb, drain_every=stream_every,
    )
    wall = time.perf_counter() - t0
    gen_tokens = sum(len(f.tokens) for f in finished)
    ttfts = sorted(f.ttft_s for f in finished)
    summary = {
        "event": "run_end",
        "policy": scfg.policy,
        "prefill_chunk": scfg.prefill_chunk,
        "requests": len(finished),
        "gen_tokens": gen_tokens,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(gen_tokens / max(wall, 1e-9), 2),
        "decode_steps": engine.decode_steps,
        "ttft_p50_s": round(float(np.percentile(ttfts, 50)), 4),
        "ttft_p99_s": round(float(np.percentile(ttfts, 99)), 4),
    }
    if draft is not None:
        summary["spec_k"] = spec_k
        summary["spec_rounds"] = engine.spec_rounds
        summary["accept_rate"] = round(engine.accept_rate, 4)
    if engine.decode_step_times:
        st = np.asarray(engine.decode_step_times)
        summary["step_p50_s"] = round(float(np.percentile(st, 50)), 5)
        summary["step_p99_s"] = round(float(np.percentile(st, 99)), 5)
    if log:
        for f in sorted(finished, key=lambda f: f.rid):
            ev = {"event": "finish", "rid": f.rid, "prompt_len": len(f.prompt),
                  "gen_len": len(f.tokens), "ttft_s": round(f.ttft_s, 4),
                  "tokens": f.tokens}
            ev.update(f.stats)
            log(ev)
    if verify:
        batched = {f.rid: f.tokens for f in finished}
        mismatches = 0
        for r in requests:
            solo = ServeEngine(params, cfg, scfg)
            [f] = solo.run([dataclasses.replace(r)])
            if f.tokens != batched[r.rid]:
                mismatches += 1
        summary["verify_requests"] = len(requests)
        summary["verify_mismatches"] = mismatches
        summary["parity"] = mismatches == 0
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode slots (concurrent requests)")
    ap.add_argument("--pages", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prompt-lens", default="4,12,24",
                    help="comma-separated prompt-length buckets, cycled")
    ap.add_argument("--gen-lens", default="8,16,32",
                    help="comma-separated generation budgets, cycled")
    ap.add_argument("--temps", default="0.0",
                    help="comma-separated sampling temperatures, cycled (0=greedy)")
    ap.add_argument("--policy", default="continuous", choices=["continuous", "static"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None,
                    help="promote a training checkpoint from this directory")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step (default: latest)")
    ap.add_argument("--replica", type=int, default=0,
                    help="which NoLoCo replica to promote")
    ap.add_argument("--weights", default="theta", choices=["theta", "phi"],
                    help="promote the inner weights (theta) or outer anchor (phi)")
    ap.add_argument("--verify", action="store_true",
                    help="re-decode each request solo and assert exact match")
    ap.add_argument("--sync-each-step", action="store_true",
                    help="block per decode step for per-token latency stats")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunked-prefill width; 0 = single-shot baseline")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="max prefill tokens per tick (0 = unlimited)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="ensemble speculative decode (draft replica/slice)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculative round width (draft steps per round)")
    ap.add_argument("--draft-replica", type=int, default=None,
                    help="promote this replica as the draft (needs --ckpt)")
    ap.add_argument("--draft-layers", type=int, default=None,
                    help="depth-truncate the target to this many layers as "
                         "the draft (default: half, when no --draft-replica)")
    ap.add_argument("--stream-every", type=int, default=0,
                    help="drain streamed `token` JSONL events every N ticks "
                         "(0 = tokens only surface at request finish)")
    add_engine_flags(ap)
    args = ap.parse_args()
    kcfg = kernel_config_from_args(args)

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32", remat=False)
    cfg = dataclasses.replace(cfg, kernels=kcfg)

    promo_info = None
    if args.ckpt:
        params, promo_info = promote(
            args.ckpt, step=args.step, replica=args.replica, source=args.weights
        )
        params = jax.tree.map(jax.numpy.asarray, params)
    else:
        params = values_of(M.init_params(jax.random.PRNGKey(args.seed), cfg))

    jsonl = open(args.log_jsonl, "a") if args.log_jsonl else None

    def log(ev: dict) -> None:
        if jsonl:
            jsonl.write(json.dumps(ev) + "\n")
            jsonl.flush()

    prompt_lens = [int(x) for x in args.prompt_lens.split(",")]
    gen_lens = [int(x) for x in args.gen_lens.split(",")]
    temps = [float(x) for x in args.temps.split(",")]
    scfg = ServeConfig(
        max_slots=args.max_batch, num_pages=args.pages, page_size=args.page_size,
        max_new_cap=max(gen_lens), policy=args.policy,
        sync_each_step=args.sync_each_step,
        prefill_chunk=args.prefill_chunk, prefill_budget=args.prefill_budget,
    )
    draft = None
    draft_info = None
    if args.spec_decode:
        if args.draft_replica is not None:
            if not args.ckpt:
                ap.error("--draft-replica needs --ckpt")
            dparams, dinfo = promote(
                args.ckpt, step=args.step, replica=args.draft_replica,
                source=args.weights,
            )
            draft = (jax.tree.map(jax.numpy.asarray, dparams), cfg)
            draft_info = {"kind": "replica", **dinfo}
        else:
            n = args.draft_layers or max(1, cfg.num_layers // 2)
            draft = truncate_layers(params, cfg, n)
            draft_info = {"kind": "truncated", "layers": n}
    requests = synth_requests(
        args.requests, cfg.vocab_size, prompt_lens, gen_lens, temps, args.seed
    )
    log({"event": "run_start", "arch": cfg.name, "policy": args.policy,
         "requests": args.requests, "max_batch": args.max_batch,
         "pages": args.pages, "page_size": args.page_size,
         "prefill_chunk": args.prefill_chunk,
         "spec_decode": bool(args.spec_decode), "draft": draft_info,
         "impl": kcfg.resolved_impl(), "promoted": promo_info})

    summary = serve_run(
        params, cfg, scfg, requests, verify=args.verify, log=log,
        draft=draft, spec_k=args.spec_k, stream_every=args.stream_every,
    )
    if draft_info:
        summary["draft"] = draft_info
    summary["arch"] = cfg.name
    summary["impl"] = kcfg.resolved_impl()
    if promo_info:
        summary["promoted"] = promo_info
    log(summary)
    if jsonl:
        jsonl.close()
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
