"""Serving driver: prefill + batched greedy decode for any --arch (reduced
variant on CPU; full configs are exercised via the dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --batch 4 --prompt-len 12 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import model as M
from repro.models.common import values_of
from repro.parallel.sharding import ShardCtx


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32", remat=False)
    ctx = ShardCtx.local()
    params = values_of(M.init_params(jax.random.PRNGKey(0), cfg))

    b = args.batch
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "audio":
        batch["encoder_embeds"] = jnp.ones((b, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "vision":
        batch["image_embeds"] = jnp.ones((b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)

    caches = values_of(M.init_cache_tree(cfg, b, args.max_len))
    _, caches = M.prefill(params, cfg, batch, caches, ctx)
    decode = jax.jit(lambda p, t, i, c: M.decode_step(p, cfg, t, i, c, ctx))

    tok = batch["tokens"][:, -1:]
    pos0 = batch["tokens"].shape[1]
    t0 = time.time()
    outs = []
    for i in range(args.gen):
        logits, caches = decode(params, tok, jnp.asarray(pos0 + i), caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} served {b} requests x {args.gen} tokens "
          f"in {dt:.2f}s ({b*args.gen/dt:.1f} tok/s on CPU)")
    print(gen)


if __name__ == "__main__":
    main()
