import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination with ShapeDtypeStruct stand-ins (no allocation), print
memory/cost analysis, and derive the three roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k

XLA's cost_analysis counts while-loop (scan-over-layers) bodies ONCE, so the
roofline FLOPs/bytes are corrected by DEPTH EXTRAPOLATION: the same step is
lowered at 1× and 2× pattern periods (full dims, tiny depth — fast compiles)
and the per-period cost is extrapolated to the real depth.  The FULL-depth
compile is still what proves the combination lowers and what memory_analysis
reads.

Failures here (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system, not in the harness.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.shapes import SHAPES, InputShape, input_specs, shape_skips
from repro.core.outer import OuterConfig
from repro.core import pairing
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_api
from repro.models.common import unzip
from repro.optim import AdamWConfig
from repro.parallel import compat
from repro.parallel import plans as plans_lib
from repro.parallel import steps as steps_lib


def abstract_params(cfg, replicas: int):
    """Param tree of ShapeDtypeStructs (stacked) — no allocation."""
    def build(key):
        p = model_api.init_params(key, cfg)
        return steps_lib.stack_replicas(p, replicas)

    return jax.eval_shape(build, jax.random.PRNGKey(0))


def abstract_caches(cfg, batch: int, length: int):
    return jax.eval_shape(lambda: model_api.init_cache_tree(cfg, batch, length))


def _depth_variant(cfg, periods: int):
    """Full-width model at ``periods`` pattern periods (for cost extrapolation)."""
    reps = {"num_layers": periods * len(cfg.attn_pattern), "unroll_scans": True}
    if cfg.is_encoder_decoder:
        reps["num_encoder_layers"] = periods
    return dataclasses.replace(cfg, **reps)


def _equiv_periods(cfg) -> float:
    return cfg.num_layers / len(cfg.attn_pattern)


def _build_lowered(cfg, plan, shape: InputShape, kind: str, mesh):
    """Build the right step function and .lower() it (no compile)."""
    params_abs = abstract_params(cfg, plan.replicas)
    theta_abs, _ = unzip(params_abs)
    specs = input_specs(cfg, shape)

    with compat.set_mesh(mesh):
        if kind == "train":
            opt_abs = jax.eval_shape(
                lambda v: steps_lib.init_opt_state(v, plan.replicas), theta_abs
            )
            bundle = steps_lib.build_train_step(
                cfg, plan, mesh, params_abs, specs, AdamWConfig(lr=1e-4),
                data_sync=(kind == "train" and getattr(plan, "_data_sync", False)),
            )
            return bundle.step_fn.lower(theta_abs, opt_abs, specs)
        if kind == "prefill":
            caches_abs = abstract_caches(cfg, shape.global_batch, shape.seq_len)
            cvals, _ = unzip(caches_abs)
            fn, _ = steps_lib.build_prefill_step(
                cfg, plan, mesh, params_abs, caches_abs, specs
            )
            return fn.lower(theta_abs, cvals, specs)
        if kind == "decode":
            caches_abs = abstract_caches(cfg, shape.global_batch, shape.seq_len)
            cvals, _ = unzip(caches_abs)
            bspecs = steps_lib.batch_pspecs(plan, specs)
            fn, _ = steps_lib.build_decode_step(
                cfg, plan, mesh, params_abs, caches_abs, bspecs
            )
            return fn.lower(
                theta_abs, cvals, specs["tokens"], jax.ShapeDtypeStruct((), jnp.int32)
            )
        if kind in ("outer_noloco", "outer_diloco"):
            pspecs = plans_lib.param_pspecs(plan, mesh, params_abs)
            method = kind.split("_")[1]
            perm = pairing.ppermute_pairs(0, plan.replicas)
            ocfg = OuterConfig(method=method)
            fn = steps_lib.build_outer_step(plan, mesh, pspecs, ocfg, perm)
            rep_shape = jax.ShapeDtypeStruct((plan.replicas,), jnp.int32)
            return fn.lower(theta_abs, theta_abs, theta_abs, rep_shape)
        raise ValueError(kind)  # pragma: no cover


def _cost_of(compiled, model_size: int):
    try:
        cost_list = compiled.cost_analysis()
        cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    except Exception:
        cost = {}
    flops = float(cost.get("flops", 0.0)) if isinstance(cost, dict) else 0.0
    hbm = float(cost.get("bytes accessed", 0.0)) if isinstance(cost, dict) else 0.0
    coll = rf.collective_bytes(compiled.as_text(), model_size)
    return flops, hbm, coll


def lower_one(
    arch: str,
    shape: InputShape,
    mesh,
    *,
    step_override: str | None = None,
    seq_parallel: bool = False,
    data_sync: bool = False,
    skip_extrapolation: bool = False,
) -> dict[str, Any]:
    """Lower+compile one combination; return a result record."""
    cfg = registry.variant_for_shape(registry.get_config(arch), shape)
    plan_name = registry.get_plan(arch)
    kind = step_override or shape.kind
    has_global = any(t == "global" for t in cfg.layer_types)
    plan = plans_lib.make_plan(
        plan_name, mesh, shape_kind=shape.kind,
        has_global_attention=has_global, seq_parallel=seq_parallel,
    )
    object.__setattr__(plan, "_data_sync", data_sync) if data_sync else None
    chips = mesh.devices.size
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if kind.startswith("outer") and plan.replicas < 2:
        return {"arch": arch, "shape": shape.name, "step": kind, "mesh": "x".join(map(str, mesh.devices.shape)),
                "status": "skip", "reason": "single replica: no outer sync on this mesh"}

    t0 = time.time()
    lowered = _build_lowered(cfg, plan, shape, kind, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_info = {"error": str(e)}

    full_flops, full_hbm, full_coll = _cost_of(compiled, model_size)
    tokens_total = shape.global_batch * (shape.seq_len if kind not in ("decode",) else 1)
    if kind.startswith("outer"):
        tokens_total = 0
    mf = rf.model_flops_estimate(cfg, tokens_total, "train" if kind == "train" else "fwd")

    # ---- depth extrapolation for trip-count-correct costs -----------------
    if kind.startswith("outer") or skip_extrapolation:
        flops, hbm = full_flops, full_hbm
        cross, intra = full_coll.cross_replica_bytes, full_coll.model_axis_bytes
    else:
        c1 = _build_lowered(_depth_variant(cfg, 1), plan, shape, kind, mesh).compile()
        c2 = _build_lowered(_depth_variant(cfg, 2), plan, shape, kind, mesh).compile()
        f1, h1, k1 = _cost_of(c1, model_size)
        f2, h2, k2 = _cost_of(c2, model_size)
        eq = _equiv_periods(cfg)

        def _extrap(a, b):
            # clamp: DCE/fusion noise between the two tiny compiles can make
            # b < a; per-period cost is never negative
            return a + max(b - a, 0.0) * (eq - 1)

        flops = _extrap(f1, f2)
        hbm = _extrap(h1, h2)
        cross = _extrap(k1.cross_replica_bytes, k2.cross_replica_bytes)
        intra = _extrap(k1.model_axis_bytes, k2.model_axis_bytes)

    roof = rf.analyze(
        flops, hbm, None, chips=chips, model_flops=mf,
        cross_bytes=cross, intra_bytes=intra,
    )

    return {
        "arch": arch,
        "shape": shape.name,
        "step": kind,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "plan": plan_name,
        "replicas": plan.replicas,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_info,
        "collectives": full_coll.counts,
        "collective_bytes": full_coll.bytes_by_kind,
        "roofline": roof.as_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--outer", action="store_true", help="also dry-run outer steps")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--data-sync", action="store_true", help="DDP baseline train step")
    ap.add_argument("--fast", action="store_true", help="skip depth extrapolation")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    archs = registry.ASSIGNED if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES.values()) if args.shape is None else [SHAPES[args.shape]]

    results = []

    def emit(rec):
        print(json.dumps({k: v for k, v in rec.items() if k != "trace"}), flush=True)
        if rec.get("status") == "FAIL":
            print(rec["trace"], flush=True)
        results.append(rec)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    for mesh_name, mesh in meshes:
        for arch in archs:
            cfg = registry.get_config(arch)
            for shape in shapes:
                reason = shape_skips(cfg, shape)
                if reason:
                    emit({"arch": arch, "shape": shape.name, "mesh": mesh_name,
                          "status": "skip", "reason": reason})
                    continue
                try:
                    rec = lower_one(
                        arch, shape, mesh,
                        seq_parallel=args.seq_parallel, data_sync=args.data_sync,
                        skip_extrapolation=args.fast,
                    )
                except Exception:
                    rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                           "status": "FAIL", "trace": traceback.format_exc()[-2500:]}
                emit(rec)
            if args.outer:
                for okind in ("outer_noloco", "outer_diloco"):
                    try:
                        rec = lower_one(arch, SHAPES["train_4k"], mesh, step_override=okind)
                    except Exception:
                        rec = {"arch": arch, "step": okind, "mesh": mesh_name,
                               "status": "FAIL", "trace": traceback.format_exc()[-2500:]}
                    emit(rec)

    n_ok = sum(r.get("status") == "ok" for r in results)
    n_fail = sum(r.get("status") == "FAIL" for r in results)
    n_skip = sum(r.get("status") == "skip" for r in results)
    print(f"DRYRUN SUMMARY: ok={n_ok} fail={n_fail} skip={n_skip}")


if __name__ == "__main__":
    main()
