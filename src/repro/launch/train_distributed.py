"""Distributed NoLoCo training driver: the shard_map runtime
(parallel/steps.py) — per-replica inner AdamW steps with ZERO cross-replica
collectives, plus a gossip outer step every m steps from the per-membership-
view :class:`~repro.parallel.steps.OuterProgramPool` (ppermute needs static
permutations; the pool bounds recompiles to ``pairing_pool`` — or log2(world)
with ``--schedule hypercube`` — per membership view, recompiling only at
membership-view boundaries).

:class:`DistributedTrainer` owns the compiled programs and mesh state; the
step loop, eval cadence, telemetry and checkpoint/resume are the unified
engine's (:mod:`repro.train`, via :class:`~repro.train.DistributedProgram`).
Elasticity (drop / rejoin / straggle under ``--fault-plan``) is owned by a
:class:`~repro.core.elastic.ElasticContext` exactly as in the stacked
runtime, replayed by the same :class:`~repro.sim.SimCluster`, with rejoin
warm-start performed over the mesh and the membership epoch riding in the
checkpoint — resume-after-churn reproduces the trajectory exactly.

On this CPU box it runs on forced host devices for validation:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.train_distributed --data 4 --model 2 --steps 40 \
        --ckpt-dir /tmp/dist0 --ckpt-every 20 --resume --log-jsonl /tmp/dist0.jsonl

On TPU the same code drives the production mesh (launch/mesh.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import CommConfig, bytes_model, stream_partition
from repro.configs import registry
from repro.core.elastic import ElasticContext
from repro.core.outer import OuterConfig, StreamSchedule
from repro.kernels.dispatch import KernelConfig
from repro.data import LoaderConfig
from repro.models import model as model_api
from repro.models.common import unzip
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.parallel import compat
from repro.parallel import plans as plans_lib
from repro.parallel import steps as steps_lib

PyTree = Any


@dataclasses.dataclass
class DistributedTrainer:
    """Owns the compiled step functions and the replica-sharded state."""

    cfg: ModelConfig
    mesh: Any
    plan: plans_lib.Plan
    outer_cfg: OuterConfig
    inner_cfg: AdamWConfig
    comm_cfg: CommConfig = dataclasses.field(default_factory=CommConfig)
    kernel_cfg: KernelConfig = dataclasses.field(default_factory=KernelConfig)
    pairing_pool: int = 16        # precompiled random matchings, cycled
    schedule: str = "random"      # "random" pool | "hypercube" (log2 N programs)
    seed: int = 0
    elastic: ElasticContext | None = None  # None: fixed-world (no churn support)

    def __post_init__(self):
        if self.elastic is not None and self.elastic.world != self.plan.replicas:
            raise ValueError(
                f"elastic world {self.elastic.world} != plan replicas "
                f"{self.plan.replicas}"
            )
        self.comm_cfg.validate()
        if self.comm_cfg.streams > 1 and self.outer_cfg.method != "noloco":
            raise ValueError(
                "streams > 1 is a noloco-only feature (gossip pairing)"
            )
        # streaming outer steps (DESIGN.md §2): staggered per-stream syncs,
        # engaged for streams > 1 OR the φ-prefetch overlap (streams=1 +
        # overlap is the legacy §3.2 pre-send expressed as one stream, and —
        # unlike the retired spelling — it composes with elasticity via the
        # membership-epoch fallback)
        self._streaming = self.outer_cfg.method == "noloco" and (
            self.comm_cfg.streams > 1 or self.comm_cfg.overlap
        )
        self._schedule = None
        self._pre_partner = None
        self._pre_epoch = None
        self._stream_cost = None
        if self._streaming:
            s = self.comm_cfg.streams
            self._schedule = StreamSchedule(self.outer_cfg.inner_steps, s)
            self._pre_partner = np.full((s, self.plan.replicas), -1, np.int64)
            self._pre_epoch = np.full((s,), -1, np.int64)
        self.recompile_events: list[dict] = []
        self.stream_events: list[dict] = []

    # -- setup -------------------------------------------------------------

    def init_state(self, batch_example: dict):
        params = model_api.init_params(jax.random.PRNGKey(self.seed), self.cfg)
        stacked = steps_lib.stack_replicas(params, self.plan.replicas)
        vals, _ = unzip(stacked)
        with compat.set_mesh(self.mesh):
            self.bundle = steps_lib.build_train_step(
                self.cfg, self.plan, self.mesh, stacked, batch_example, self.inner_cfg
            )
            theta = jax.device_put(vals, self.bundle.theta_shardings)
            opt = jax.device_put(
                steps_lib.init_opt_state(theta, self.plan.replicas),
                self.bundle.opt_shardings,
            )
            phi = jax.device_put(vals, self.bundle.theta_shardings)
            delta = jax.tree.map(jnp.zeros_like, phi)
            step_c = jax.device_put(
                jnp.zeros((self.plan.replicas,), jnp.int32),
                NamedSharding(self.mesh, P(self.plan.replica_entry)),
            )
        self._theta_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), theta
        )
        partition = None
        if self._streaming:
            # the partitioner's midpoint rule is scale-invariant, so the
            # STACKED struct yields the same leaf->stream assignment the
            # squeezed per-replica view inside shard_map sees
            partition = stream_partition(
                self._theta_struct, self.comm_cfg.streams, fuse=self.comm_cfg.fuse
            )
        self.pool = steps_lib.OuterProgramPool(
            self.plan, self.mesh, self.bundle.pspecs, self.outer_cfg,
            comm_cfg=self.comm_cfg, kernel_cfg=self.kernel_cfg,
            schedule=self.schedule, pairing_pool=self.pairing_pool,
            seed=self.seed, partition=partition,
        )
        self._bspecs = steps_lib.batch_pspecs(self.plan, batch_example)
        state = {"theta": theta, "opt": opt, "phi": phi, "delta": delta,
                 "outer_step": step_c, "inner_step": 0}
        if self.comm_cfg.overlap:
            # Bootstrap for the §3.2 φ-prefetch: all replicas start from the
            # SAME φ_0, so "the partner's φ" for the first outer step is just
            # our own copy — no exchange needed before round 0.
            state["phi_pre"] = jax.tree.map(jnp.copy, phi)
        return state

    # -- elastic helpers ----------------------------------------------------

    @functools.cached_property
    def _take_rows(self):
        """jit: gather the given replica rows of a stacked tree."""
        return jax.jit(lambda tree, ids: jax.tree.map(
            lambda x: jnp.take(x, ids, axis=0), tree
        ))

    @functools.cached_property
    def _put_rows(self):
        """jit: scatter saved replica rows back into a stacked tree."""
        return jax.jit(lambda tree, ids, rows: jax.tree.map(
            lambda x, r: x.at[ids].set(r), tree, rows
        ))

    @functools.cached_property
    def _warm_start_fn(self):
        """jit: rejoin surgery over the mesh — the comeback replica adopts a
        live peer's slow weights as BOTH φ and θ (fresh look-ahead), zero
        outer momentum, zero inner-optimizer moments."""
        def surgery(theta, phi, delta, mu, nu, count, replica, source):
            adopt = lambda x: x.at[replica].set(x[source])
            zero = lambda x: x.at[replica].set(jnp.zeros_like(x[replica]))
            theta = jax.tree.map(
                lambda th, p: th.at[replica].set(p[source]), theta, phi
            )
            return (
                theta,
                jax.tree.map(adopt, phi),
                jax.tree.map(zero, delta),
                jax.tree.map(zero, mu),
                jax.tree.map(zero, nu),
                count.at[replica].set(0),
            )
        return jax.jit(surgery)

    def warm_start(self, state: dict, replica: int, source: int) -> dict:
        theta, phi, delta, mu, nu, count = self._warm_start_fn(
            state["theta"], state["phi"], state["delta"],
            state["opt"].mu, state["opt"].nu, state["opt"].count,
            jnp.asarray(replica), jnp.asarray(source),
        )
        from repro.optim import AdamWState

        return dict(state, theta=theta, phi=phi, delta=delta,
                    opt=AdamWState(mu=mu, nu=nu, count=count))

    def _active_mask(self) -> np.ndarray | None:
        if self.elastic is None:
            return None
        return self.elastic.active_array()

    # -- steps ---------------------------------------------------------------

    def inner_step(self, state, batch):
        mask = self._active_mask()
        snap = None
        if mask is not None:
            # freeze dropped replicas: the step function donates its inputs,
            # so their pre-step rows are snapshotted and written back after
            ids = jnp.asarray(np.nonzero(~mask)[0])
            snap = (
                self._take_rows(state["theta"], ids),
                self._take_rows(state["opt"], ids),
            )
        with compat.set_mesh(self.mesh):
            batch = jax.device_put(batch, plans_lib.shardings(self.mesh, self._bspecs))
            theta, opt, metrics = self.bundle.step_fn(state["theta"], state["opt"], batch)
            if snap is not None:
                theta = self._put_rows(theta, ids, snap[0])
                opt = self._put_rows(opt, ids, snap[1])
        state = dict(state, theta=theta, opt=opt, inner_step=state["inner_step"] + 1)
        return state, metrics

    @staticmethod
    def _table_of(pairs) -> np.ndarray:
        """Partner table (dst indexed by src) of an ordered ppermute pair
        list — the canonical form the consume-vs-fallback check compares."""
        return np.asarray([d for _, d in pairs], dtype=np.int64)

    def _drain_compiles(self, info, t0: float, outer_index: int) -> None:
        if info["compiled"]:
            # first invocation of a fresh program: its wall-clock includes the
            # lazy XLA compile — the churn-induced stall telemetry measures
            for ev in self.pool.drain_events():
                self.recompile_events.append(dict(
                    ev, wall_s=round(time.time() - t0, 4),
                    outer_index=outer_index,
                ))

    def maybe_outer_step(self, state):
        if self._streaming:
            return self._maybe_stream_sync(state)
        if state["inner_step"] % self.outer_cfg.inner_steps:
            return state, False
        outer_index = state["inner_step"] // self.outer_cfg.inner_steps - 1
        if self.elastic is None:
            fn, info = self.pool.program(outer_index)
        else:
            partner_fn = None
            if self.outer_cfg.method == "noloco":
                # the ppermute pairs ARE the audit table: dst indexed by src
                def partner_fn(parts):
                    return self._table_of(self.pool.pairs_for(
                        outer_index, parts, self.elastic.partition
                    )[1])

            plan = self.elastic.plan_round(partner_fn)
            if plan.all_absent:
                fn, info = self._all_absent_program(outer_index)
            else:
                fn, info = self.pool.program(
                    outer_index, plan.participants, self.elastic.partition
                )
        t0 = time.time()
        with compat.set_mesh(self.mesh):
            theta, phi, delta, step_c = fn(
                state["theta"], state["phi"], state["delta"], state["outer_step"]
            )
            new = dict(state, theta=theta, phi=phi, delta=delta,
                       outer_step=step_c)
        self._drain_compiles(info, t0, outer_index)
        return new, True

    def outer_step_async(self, state, *, sync_index: int, due, staleness):
        """One merged sync tick of the asynchronous clock on the compiled
        shard_map path (DESIGN.md §7).

        The ppermute pairing is drawn over ALL round participants at key
        ``sync_index`` (non-due replicas serve as passive sources — their
        in-progress (Δ, φ) shards move, their state stays frozen); only the
        ``due`` set applies the update, and under ``stale="momentum"`` each
        shard's Δ is discounted by its staleness before the exchange.  The
        (update-mask, staleness) pair is baked into the compiled program and
        keyed in the pool alongside the membership view; the
        full-participation / τ=0 tick is the LEGACY pool program — the same
        compiled object, bit for bit."""
        if self.outer_cfg.method != "noloco":
            raise ValueError("asynchronous merged-tick sync is NoLoCo-only")
        if self._streaming:
            raise ValueError(
                "the asynchronous clock does not compose with streaming "
                "outer steps / φ-prefetch yet"
            )
        if self.elastic is None:
            raise ValueError("outer_step_async needs an ElasticContext")

        def partner_fn(parts):
            return self._table_of(self.pool.pairs_for(
                sync_index, parts, self.elastic.partition
            )[1])

        plan = self.elastic.plan_round(partner_fn)
        if plan.all_absent:
            fn, info = self._all_absent_program(sync_index)
        else:
            due = np.asarray(due, dtype=bool)
            tau = np.asarray(staleness)
            update = due.copy()
            if plan.active is not None:
                update &= np.asarray(plan.active, dtype=bool)
            if update.all() and not tau.any():
                # everyone due, nobody late: the legacy synchronous program
                fn, info = self.pool.program(
                    sync_index, plan.participants, self.elastic.partition
                )
            else:
                stale_host = None
                if self.outer_cfg.stale == "momentum" and tau.any():
                    stale_host = tau
                fn, info = self.pool.program(
                    sync_index, plan.participants, self.elastic.partition,
                    update_mask=update, staleness=stale_host,
                )
        t0 = time.time()
        with compat.set_mesh(self.mesh):
            theta, phi, delta, step_c = fn(
                state["theta"], state["phi"], state["delta"], state["outer_step"]
            )
            new = dict(state, theta=theta, phi=phi, delta=delta,
                       outer_step=step_c)
        self._drain_compiles(info, t0, sync_index)
        return new, True

    def _maybe_stream_sync(self, state):
        """One stream's staggered sync on the compiled shard_map path.

        Mirrors the stacked runtime's consume-vs-fallback rule exactly: a
        prefetched φ is consumed only when the pairing it was pre-sent along
        still holds (same membership epoch AND the recorded partner table
        equals this round's actual table) — otherwise that stream alone runs
        the blocking program variant (a pool LOOKUP, not a recompile of an
        existing entry); churn never blocks the other streams."""
        t = state["inner_step"]
        k = self._schedule.due(t)
        if k is None:
            return state, False
        i = self._schedule.sync_index(k, t)
        streams = self._schedule.stream_count
        overlap = self.comm_cfg.overlap
        epoch = 0 if self.elastic is None else self.elastic.epoch
        groups = None if self.elastic is None else self.elastic.partition

        participants = None
        if self.elastic is None:
            partner_table = self._table_of(self.pool.pairs_for(i)[1])
        else:
            def partner_fn(parts):
                return self._table_of(self.pool.pairs_for(i, parts, groups)[1])

            plan = self.elastic.plan_round(partner_fn)
            if plan.all_absent:
                # every live replica timed out: freeze everything, advance the
                # sync counter (the shared whole-payload all-absent program —
                # no per-stream variant needed since nothing moves), and
                # invalidate this stream's prefetch: its pre-send was planned
                # for THIS sync and none was issued for the next one
                fn, info = self._all_absent_program(i)
                t0 = time.time()
                with compat.set_mesh(self.mesh):
                    theta, phi, delta, step_c = fn(
                        state["theta"], state["phi"], state["delta"],
                        state["outer_step"],
                    )
                new = dict(state, theta=theta, phi=phi, delta=delta,
                           outer_step=step_c)
                self._drain_compiles(info, t0, i)
                self._pre_epoch[k] = -1
                self._record_stream_event(k, i, consume=False,
                                          had_prefetch=False)
                return new, True
            participants = plan.participants
            partner_table = np.asarray(plan.partner, dtype=np.int64)

        had_prefetch = bool(self._pre_epoch[k] >= 0)
        consume = bool(
            overlap and "phi_pre" in state
            and self._pre_epoch[k] == epoch
            and np.array_equal(self._pre_partner[k], partner_table)
        )
        presend_index = i + streams if overlap else None
        presend_membership = None if self.elastic is None else self.elastic.membership
        next_table = None
        if overlap:
            next_table = self._table_of(self.pool.pairs_for(
                presend_index, presend_membership, groups
            )[1])

        fn, info = self.pool.program(
            i, participants, groups, stream=k, consume=consume,
            presend_index=presend_index, presend_membership=presend_membership,
        )
        t0 = time.time()
        with compat.set_mesh(self.mesh):
            if overlap:
                theta, phi, delta, phi_pre, step_c = fn(
                    state["theta"], state["phi"], state["delta"],
                    state["phi_pre"], state["outer_step"],
                )
                new = dict(state, theta=theta, phi=phi, delta=delta,
                           phi_pre=phi_pre, outer_step=step_c)
            else:
                theta, phi, delta, step_c = fn(
                    state["theta"], state["phi"], state["delta"],
                    state["outer_step"],
                )
                new = dict(state, theta=theta, phi=phi, delta=delta,
                           outer_step=step_c)
        self._drain_compiles(info, t0, i)
        if overlap:
            self._pre_partner[k] = next_table
            self._pre_epoch[k] = epoch
        self._record_stream_event(k, i, consume=consume,
                                  had_prefetch=had_prefetch)
        return new, True

    def _record_stream_event(self, k: int, i: int, *, consume: bool,
                             had_prefetch: bool) -> None:
        if self._stream_cost is None and self.outer_cfg.method == "noloco":
            one = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                self._theta_struct,
            )
            self._stream_cost = bytes_model.outer_step_cost(
                one, self.comm_cfg, method="noloco", world=self.plan.replicas
            )
        cost = self._stream_cost
        sc = cost.per_stream[k] if cost and cost.per_stream else None
        payload = sc.payload_bytes if sc else 0
        blocking = sc.blocking_bytes if (sc and consume) else payload
        self.stream_events.append({
            "stream": k,
            "offset": self._schedule.offsets[k],
            "sync_index": i,
            "payload_bytes": payload,
            "blocking_bytes": blocking,
            "overlapped_bytes": payload - blocking,
            "blocked": not consume,
            "epoch_fallback": bool(
                self.comm_cfg.overlap and not consume and had_prefetch
            ),
        })

    def _all_absent_program(self, outer_index: int):
        """Every live replica timed out: identity pairing + all-frozen mask,
        cached in the pool (one extra entry total) and telemetered like any
        other program."""
        world = self.plan.replicas
        key = "all-absent"  # identity pairing — the slot is irrelevant
        if key not in self.pool._programs:
            self.pool.misses += 1
            t0 = time.time()
            with compat.set_mesh(self.mesh):
                self.pool._programs[key] = steps_lib.build_outer_step(
                    self.plan, self.mesh, self.bundle.pspecs, self.outer_cfg,
                    [(i, i) for i in range(world)],
                    comm_cfg=self.comm_cfg, kernel_cfg=self.kernel_cfg,
                    active=np.zeros((world,), dtype=bool),
                )
            self.pool.events.append({
                "slot": key, "view": "all-absent", "epoch": None,
                "build_s": round(time.time() - t0, 4),
                "pool_size": len(self.pool._programs),
            })
            return self.pool._programs[key], {
                "key": key, "slot": key, "view": "all-absent",
                "compiled": True, "pool_size": len(self.pool._programs),
            }
        self.pool.hits += 1
        return self.pool._programs[key], {
            "key": key, "slot": key, "view": "all-absent",
            "compiled": False, "pool_size": len(self.pool._programs),
        }

    def eval_loss(self, state, batch):
        """Grad-free per-replica losses (R,) via the bundle's eval program."""
        with compat.set_mesh(self.mesh):
            batch = jax.device_put(batch, plans_lib.shardings(self.mesh, self._bspecs))
            return self.bundle.eval_fn(state["theta"], batch)

    def theta_struct(self):
        """Stacked-theta ShapeDtypeStructs (for static comm costing)."""
        if not hasattr(self, "_theta_struct"):
            raise RuntimeError("init_state must run before theta_struct")
        return self._theta_struct


def main() -> None:
    from repro.launch.train import add_engine_flags, kernel_config_from_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-small-125m")
    ap.add_argument("--data", type=int, default=4)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--inner-steps", type=int, default=10)
    ap.add_argument("--batch-per-replica", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedule", default="random", choices=["random", "hypercube"])
    ap.add_argument("--pairing-pool", type=int, default=16,
                    help="random-schedule matchings per membership view")
    ap.add_argument("--codec", default="none",
                    choices=["none", "fp16", "bf16", "int8"],
                    help="gossip wire codec (repro.comm)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="one ppermute per leaf instead of one fused buffer per dtype")
    ap.add_argument("--overlap", action="store_true",
                    help="§3.2 φ-prefetch: pre-send φ′ along the next pairing "
                         "(auto-enabled by --stream-count > 1)")
    ap.add_argument("--stream-count", type=int, default=1,
                    help="partition the outer payload into N streams synced "
                         "on staggered round offsets (streaming outer steps)")
    ap.add_argument("--fault-plan", default=None,
                    help="JSON FaultPlan (repro.sim.faults): run the shard_map "
                         "runtime elastically under churn")
    ap.add_argument("--reassign-data", action="store_true",
                    help="redistribute dropped replicas' loader streams over "
                         "survivors (repro.core.elastic.stream_assignment)")
    ap.add_argument("--stale", default="naive", choices=["naive", "momentum"],
                    help="async stale-Δ rule for rate-heterogeneous fault "
                         "plans: naive applies a delayed Δ as-is, momentum "
                         "discounts it by 1/(1+τ)")
    add_engine_flags(ap)
    args = ap.parse_args()

    if jax.device_count() < args.data * args.model:
        raise SystemExit(
            f"need {args.data * args.model} devices; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    # --fault-plan + --overlap now compose: a stream whose pre-send pairing
    # went stale (membership epoch advanced) falls back to blocking for that
    # stream only — no hard error anymore
    overlap = args.overlap or args.stream_count > 1
    mesh = compat.make_mesh((args.data, args.model), ("data", "model"))
    kcfg = kernel_config_from_args(args)
    cfg = registry.get_config(args.arch).reduced(
        vocab_size=512, dtype="float32", remat=False, kernels=kcfg
    )
    plan = plans_lib.make_plan("gossip_dp", mesh, shape_kind="train")

    elastic = None
    fault_plan = None
    if args.fault_plan:
        from repro.sim import FaultPlan

        fault_plan = FaultPlan.load(args.fault_plan)
        elastic = ElasticContext(world=plan.replicas)
        anchor = fault_plan.max_anchor_step(args.inner_steps)
        if anchor >= args.steps:
            print(f"WARNING: fault plan extends to step {anchor} but the run "
                  f"stops at {args.steps}; later events never fire", flush=True)
        else:
            horizon = fault_plan.max_effect_step(args.inner_steps)
            if horizon > args.steps:
                print(f"warning: fault-plan effects (straggle debts) extend "
                      f"to step {horizon}, beyond --steps {args.steps}; "
                      f"in-flight debts ride the checkpoint and resume "
                      f"exactly", flush=True)

    trainer = DistributedTrainer(
        cfg=cfg, mesh=mesh, plan=plan,
        outer_cfg=OuterConfig(method="noloco", inner_steps=args.inner_steps,
                              stale=args.stale),
        inner_cfg=AdamWConfig(lr=args.lr, weight_decay=0.0),
        comm_cfg=CommConfig(codec=args.codec, fuse=not args.no_fuse,
                            overlap=overlap, streams=args.stream_count),
        kernel_cfg=kcfg,
        schedule=args.schedule, pairing_pool=args.pairing_pool, seed=args.seed,
        elastic=elastic,
    )

    from repro.train import DistributedProgram, LoopConfig, make_loop

    program: Any = DistributedProgram(trainer)
    if fault_plan is not None:
        from repro.sim import SimCluster

        program = SimCluster(program, fault_plan,
                             reassign_data=args.reassign_data)

    loop = make_loop(
        program,
        LoaderConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            per_replica_batch=args.batch_per_replica, replicas=plan.replicas,
            seed=args.seed,
        ),
        LoopConfig(
            steps=args.steps, eval_every=args.eval_every, seed=args.seed,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            resume=args.resume, log_jsonl=args.log_jsonl, log=True,
            run_name=f"{cfg.name}-dist",
        ),
    )
    res = loop.run()
    pool_stats = trainer.pool.stats()
    out = {
        "arch": cfg.name, "replicas": plan.replicas, "tp": plan.tp,
        "codec": args.codec, "fuse": not args.no_fuse, "overlap": overlap,
        "stream_count": args.stream_count,
        "blocking_fraction": round(res["blocking_fraction"], 4),
        "final_loss": res["losses"][-1] if res["losses"] else None,
        "final_eval": res["evals"][-1][1] if res["evals"] else None,
        "tokens_per_s": round(res["tokens_per_s"], 1),
        "comm_bytes": res["comm_bytes"],
        "wall_s": round(res["wall_s"], 1),
        "pool": pool_stats,
        "recompiles": pool_stats["misses"],
    }
    if fault_plan is not None:
        out["fault_events"] = len(fault_plan.events)
        out["membership"] = {
            "epoch": elastic.epoch, "active": list(elastic.active_ids()),
        }
    print(json.dumps(out))


if __name__ == "__main__":
    main()
