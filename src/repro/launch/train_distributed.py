"""Distributed NoLoCo training driver: the shard_map runtime
(parallel/steps.py) — per-replica inner AdamW steps with ZERO cross-replica
collectives, plus a gossip outer step every m steps from a PRECOMPILED pool
of pairing programs (ppermute needs static permutations).

:class:`DistributedTrainer` owns the compiled programs and mesh state; the
step loop, eval cadence, telemetry and checkpoint/resume are the unified
engine's (:mod:`repro.train`, via :class:`~repro.train.DistributedProgram`).

On this CPU box it runs on forced host devices for validation:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.train_distributed --data 4 --model 2 --steps 40 \
        --ckpt-dir /tmp/dist0 --ckpt-every 20 --resume --log-jsonl /tmp/dist0.jsonl

On TPU the same code drives the production mesh (launch/mesh.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import CommConfig
from repro.configs import registry
from repro.core import pairing
from repro.core.outer import OuterConfig
from repro.kernels.dispatch import KernelConfig
from repro.data import LoaderConfig
from repro.models import model as model_api
from repro.models.common import unzip
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.parallel import compat
from repro.parallel import plans as plans_lib
from repro.parallel import steps as steps_lib

PyTree = Any


@dataclasses.dataclass
class DistributedTrainer:
    """Owns the compiled step functions and the replica-sharded state."""

    cfg: ModelConfig
    mesh: Any
    plan: plans_lib.Plan
    outer_cfg: OuterConfig
    inner_cfg: AdamWConfig
    comm_cfg: CommConfig = dataclasses.field(default_factory=CommConfig)
    kernel_cfg: KernelConfig = dataclasses.field(default_factory=KernelConfig)
    pairing_pool: int = 16        # precompiled random matchings, cycled
    schedule: str = "random"      # "random" pool | "hypercube" (log2 N programs)
    seed: int = 0

    def __post_init__(self):
        self._outer_fns: dict[Any, Any] = {}

    # -- setup -------------------------------------------------------------

    def init_state(self, batch_example: dict):
        params = model_api.init_params(jax.random.PRNGKey(self.seed), self.cfg)
        stacked = steps_lib.stack_replicas(params, self.plan.replicas)
        vals, _ = unzip(stacked)
        with compat.set_mesh(self.mesh):
            self.bundle = steps_lib.build_train_step(
                self.cfg, self.plan, self.mesh, stacked, batch_example, self.inner_cfg
            )
            theta = jax.device_put(vals, self.bundle.theta_shardings)
            opt = jax.device_put(
                steps_lib.init_opt_state(theta, self.plan.replicas),
                self.bundle.opt_shardings,
            )
            phi = jax.device_put(vals, self.bundle.theta_shardings)
            delta = jax.tree.map(jnp.zeros_like, phi)
            rep = self.plan.replica_axes
            rep_entry = rep if len(rep) > 1 else (rep[0] if rep else None)
            step_c = jax.device_put(
                jnp.zeros((self.plan.replicas,), jnp.int32),
                NamedSharding(self.mesh, P(rep_entry)),
            )
        self._bspecs = steps_lib.batch_pspecs(self.plan, batch_example)
        self._theta_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), theta
        )
        state = {"theta": theta, "opt": opt, "phi": phi, "delta": delta,
                 "outer_step": step_c, "inner_step": 0}
        if self.comm_cfg.overlap:
            # Bootstrap for the §3.2 φ-prefetch: all replicas start from the
            # SAME φ_0, so "the partner's φ" for the first outer step is just
            # our own copy — no exchange needed before round 0.
            state["phi_pre"] = jax.tree.map(jnp.copy, phi)
        return state

    def _pool_perm(self, outer_index: int):
        """(pool key, static ppermute pairs) for one outer step index."""
        world = self.plan.replicas
        if self.schedule == "hypercube":
            key = outer_index % max(int(np.log2(world)), 1)
            return key, pairing.hypercube_ppermute_pairs(key, world, seed=self.seed)
        key = outer_index % self.pairing_pool
        return key, pairing.ppermute_pairs(key, world, seed=self.seed)

    def _outer_fn(self, outer_index: int):
        """Compiled gossip program for this outer step (cycled pool).

        With ``comm_cfg.overlap`` the program also pre-sends φ′ along the NEXT
        pairing, so it is keyed by the (this, next) pool-key pair."""
        key, perm = self._pool_perm(outer_index)
        perm_next = None
        if self.comm_cfg.overlap and self.outer_cfg.method == "noloco":
            key_next, perm_next = self._pool_perm(outer_index + 1)
            key = (key, key_next)
        if key not in self._outer_fns:
            with compat.set_mesh(self.mesh):
                self._outer_fns[key] = steps_lib.build_outer_step(
                    self.plan, self.mesh, self.bundle.pspecs, self.outer_cfg, perm,
                    comm_cfg=self.comm_cfg, perm_next=perm_next,
                    kernel_cfg=self.kernel_cfg,
                )
        return self._outer_fns[key]

    # -- steps ---------------------------------------------------------------

    def inner_step(self, state, batch):
        with compat.set_mesh(self.mesh):
            batch = jax.device_put(batch, plans_lib.shardings(self.mesh, self._bspecs))
            theta, opt, metrics = self.bundle.step_fn(state["theta"], state["opt"], batch)
        state = dict(state, theta=theta, opt=opt, inner_step=state["inner_step"] + 1)
        return state, metrics

    def maybe_outer_step(self, state):
        if state["inner_step"] % self.outer_cfg.inner_steps:
            return state, False
        outer_index = state["inner_step"] // self.outer_cfg.inner_steps - 1
        fn = self._outer_fn(outer_index)
        with compat.set_mesh(self.mesh):
            if self.comm_cfg.overlap and self.outer_cfg.method == "noloco":
                theta, phi, delta, phi_pre, step_c = fn(
                    state["theta"], state["phi"], state["delta"],
                    state["phi_pre"], state["outer_step"],
                )
                return dict(state, theta=theta, phi=phi, delta=delta,
                            phi_pre=phi_pre, outer_step=step_c), True
            theta, phi, delta, step_c = fn(
                state["theta"], state["phi"], state["delta"], state["outer_step"]
            )
        return dict(state, theta=theta, phi=phi, delta=delta, outer_step=step_c), True

    def eval_loss(self, state, batch):
        """Grad-free per-replica losses (R,) via the bundle's eval program."""
        with compat.set_mesh(self.mesh):
            batch = jax.device_put(batch, plans_lib.shardings(self.mesh, self._bspecs))
            return self.bundle.eval_fn(state["theta"], batch)

    def theta_struct(self):
        """Stacked-theta ShapeDtypeStructs (for static comm costing)."""
        if not hasattr(self, "_theta_struct"):
            raise RuntimeError("init_state must run before theta_struct")
        return self._theta_struct


def main() -> None:
    from repro.launch.train import add_engine_flags, kernel_config_from_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-small-125m")
    ap.add_argument("--data", type=int, default=4)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--inner-steps", type=int, default=10)
    ap.add_argument("--batch-per-replica", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedule", default="random", choices=["random", "hypercube"])
    ap.add_argument("--codec", default="none",
                    choices=["none", "fp16", "bf16", "int8"],
                    help="gossip wire codec (repro.comm)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="one ppermute per leaf instead of one fused buffer per dtype")
    ap.add_argument("--overlap", action="store_true",
                    help="§3.2 φ-prefetch: pre-send φ′ along the next pairing")
    add_engine_flags(ap)
    args = ap.parse_args()

    if jax.device_count() < args.data * args.model:
        raise SystemExit(
            f"need {args.data * args.model} devices; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    mesh = compat.make_mesh((args.data, args.model), ("data", "model"))
    kcfg = kernel_config_from_args(args)
    cfg = registry.get_config(args.arch).reduced(
        vocab_size=512, dtype="float32", remat=False, kernels=kcfg
    )
    plan = plans_lib.make_plan("gossip_dp", mesh, shape_kind="train")

    trainer = DistributedTrainer(
        cfg=cfg, mesh=mesh, plan=plan,
        outer_cfg=OuterConfig(method="noloco", inner_steps=args.inner_steps),
        inner_cfg=AdamWConfig(lr=args.lr, weight_decay=0.0),
        comm_cfg=CommConfig(codec=args.codec, fuse=not args.no_fuse,
                            overlap=args.overlap),
        kernel_cfg=kcfg,
        schedule=args.schedule, seed=args.seed,
    )

    from repro.train import DistributedProgram, LoopConfig, make_loop

    loop = make_loop(
        DistributedProgram(trainer),
        LoaderConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            per_replica_batch=args.batch_per_replica, replicas=plan.replicas,
            seed=args.seed,
        ),
        LoopConfig(
            steps=args.steps, eval_every=args.eval_every, seed=args.seed,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            resume=args.resume, log_jsonl=args.log_jsonl, log=True,
            run_name=f"{cfg.name}-dist",
        ),
    )
    res = loop.run()
    print(json.dumps({
        "arch": cfg.name, "replicas": plan.replicas, "tp": plan.tp,
        "codec": args.codec, "fuse": not args.no_fuse, "overlap": args.overlap,
        "final_loss": res["losses"][-1] if res["losses"] else None,
        "tokens_per_s": round(res["tokens_per_s"], 1),
        "comm_bytes": res["comm_bytes"],
        "wall_s": round(res["wall_s"], 1),
        "compiled_outer_programs": len(trainer._outer_fns),
    }))


if __name__ == "__main__":
    main()
