"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs            / (chips × 197e12 FLOP/s bf16)
    memory     = HLO_bytes_accessed   / (chips × 819e9  B/s HBM)
    collective = collective_bytes     / (chips × 50e9   B/s ICI per link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are NOT in cost_analysis, so we parse the optimized HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (per-device shapes — the module is SPMD).
"""

from __future__ import annotations

import dataclasses
import math
import re

PEAK_FLOPS = 197e12   # bf16 per chip
HBM_BW = 819e9        # B/s per chip
ICI_BW = 50e9         # B/s per link per chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g. "bf16[16,512,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    if dt == "token" or dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES[dt]


_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*(?:\},\{[^}]*)*)\}\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\[[^\]]*\]<=\[([\d,]+)\]")


def _result_shapes(line: str, kind: str) -> list[str]:
    """Result shapes of an HLO instruction: between '=' and the op name."""
    if "=" not in line:
        return []
    rhs = line.split("=", 1)[1]
    op_pos = rhs.find(kind)
    if op_pos < 0:
        return []
    return [m.group(0) for m in _SHAPE_RE.finditer(rhs[:op_pos])]


def _groups_cross_replica(line: str, model_size: int) -> bool | None:
    """True if any replica group spans multiple model-axis blocks (i.e. the
    collective crosses gossip replicas / the data axis), False if every group
    stays within one contiguous model block (intra-replica TP traffic), None
    if no group info found.

    With mesh (pod, data, model) the model axis is minor, so a TP group is a
    contiguous id range [r*model, (r+1)*model)."""
    m = _GROUPS_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.split(",") if x.strip()]
            if ids and (max(ids) // model_size) != (min(ids) // model_size):
                return True
        return False
    m = _GROUPS_LIST_RE.search(line)
    if m:
        # iota-style groups: replica_groups=[G,N]<=[T] — groups of size N over
        # a transposed iota; N == model_size with trailing minor dim means TP.
        return None
    return None


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    cross_replica_bytes: int = 0   # traffic crossing data/pod axes
    model_axis_bytes: int = 0      # intra-replica TP traffic

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str, model_size: int = 16) -> CollectiveStats:
    """Sum per-device result bytes of every collective op in optimized HLO,
    classified intra-replica (model-axis TP) vs cross-replica (data/pod axes
    — the traffic NoLoCo's gossip design minimizes).

    We use the RESULT shape (what lands on the device): for all-gather that is
    the gathered tensor, for reduce-scatter the scattered shard, for
    collective-permute / all-to-all the moved payload — a reasonable proxy for
    per-chip link traffic in each case."""
    counts: dict = {k: 0 for k in _COLLECTIVES}
    by_kind: dict = {k: 0 for k in _COLLECTIVES}
    cross = intra = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in _COLLECTIVES:
            if re.search(rf"\b{kind}(-start)?\(", s) and "=" in s:
                if f"{kind}-done" in s:
                    continue  # counted at -start
                nbytes = sum(_shape_bytes(sh) for sh in _result_shapes(s, kind))
                by_kind[kind] += nbytes
                counts[kind] += 1
                is_cross = _groups_cross_replica(s, model_size)
                if kind == "collective-permute":
                    # permute partners are replicas by construction here
                    cross += nbytes
                elif is_cross:
                    cross += nbytes
                else:
                    intra += nbytes
                break
    return CollectiveStats(
        counts=counts, bytes_by_kind=by_kind,
        cross_replica_bytes=cross, model_axis_bytes=intra,
    )


@dataclasses.dataclass
class Roofline:
    flops: float            # per-device HLO FLOPs (trip-count corrected)
    hbm_bytes: float        # per-device bytes accessed
    coll_bytes: float       # per-device collective bytes total
    cross_replica_bytes: float  # collective bytes crossing data/pod axes
    model_axis_bytes: float     # intra-replica TP collective bytes
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float      # 6·N_active·tokens (global)
    useful_ratio: float     # model_flops / (hlo_flops × chips)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(
    flops: float,
    hbm_bytes: float,
    coll: CollectiveStats | None,
    *,
    chips: int,
    model_flops: float,
    cross_bytes: float | None = None,
    intra_bytes: float | None = None,
) -> Roofline:
    """Roofline terms from PER-DEVICE cost numbers (XLA cost_analysis on an
    SPMD module is per-device; the dry-run corrects while-loop trip counts by
    depth extrapolation before calling this)."""
    cross = float(coll.cross_replica_bytes if coll else cross_bytes or 0.0)
    intra = float(coll.model_axis_bytes if coll else intra_bytes or 0.0)
    total_coll = cross + intra
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = total_coll / ICI_BW

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * chips, 1.0)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm_bytes,
        coll_bytes=total_coll,
        cross_replica_bytes=cross,
        model_axis_bytes=intra,
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=useful,
    )


def model_flops_estimate(cfg, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (forward-only), with N = ACTIVE
    params for MoE (top-k experts only)."""
    n = active_params(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def active_params(cfg) -> float:
    """Active parameter count (MoE counts top-k of the expert FFNs)."""
    d, l, v = cfg.d_model, cfg.num_layers, cfg.vocab_size
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    n = v * d  # embedding (tied unembed ignored for the estimate)
    if not cfg.tie_embeddings:
        n += v * d
    per_layer_attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    gated = cfg.mlp_variant in ("swiglu", "geglu")
    for kind in cfg.layer_types:
        if kind in ("global", "local", "encoder"):
            n += per_layer_attn
        elif kind == "rglru":
            w = cfg.lru_width or d
            n += 4 * d * w + w * d
        elif kind == "ssd":
            di = cfg.ssm_expand * d
            nh = di // cfg.ssm_head_dim
            n += 2 * d * di + 2 * d * cfg.ssm_state_dim + d * nh + di * d
        if cfg.arch_type == "moe":
            f = cfg.moe_d_ff or cfg.d_ff
            k = cfg.num_experts_per_token
            n += d * cfg.num_experts  # router
            n += k * ((3 if gated else 2) * d * f)
        elif cfg.d_ff > 0 and kind != "ssd":
            n += (3 if gated else 2) * d * cfg.d_ff
    if cfg.is_encoder_decoder:
        n += cfg.num_encoder_layers * (per_layer_attn + (3 if gated else 2) * d * cfg.d_ff)
        n += cfg.num_layers * (per_layer_attn)  # cross attention
    return float(n)


def total_params(cfg) -> float:
    """Total parameter count (all experts)."""
    if cfg.arch_type != "moe":
        return active_params(cfg)
    f = cfg.moe_d_ff or cfg.d_ff
    gated = cfg.mlp_variant in ("swiglu", "geglu")
    k = cfg.num_experts_per_token
    per_layer_active = k * ((3 if gated else 2) * cfg.d_model * f)
    per_layer_total = cfg.num_experts * ((3 if gated else 2) * cfg.d_model * f)
    return active_params(cfg) + cfg.num_layers * (per_layer_total - per_layer_active)
