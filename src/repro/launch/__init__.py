# Launchers: mesh.py (production meshes), dryrun.py (multi-pod dry-run +
# roofline), train.py (training driver), serve.py (decode driver).
