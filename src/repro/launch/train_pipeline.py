"""Routed-pipeline training CLI — the paper's COMPLETE method (§3.1 + §3.2):
dynamic microbatch routing between stage replicas AND the gossip outer
optimizer, driven by the unified engine (:mod:`repro.train`).

    PYTHONPATH=src python -m repro.launch.train_pipeline --arch paper-small-125m \
        --reduced --stages 2 --replicas 4 --method noloco --steps 100 \
        --ckpt-dir /tmp/pipe0 --ckpt-every 25 --resume --log-jsonl /tmp/pipe0.jsonl

``--method none`` is the §5.2 routing-only baseline (no outer step);
``--routing fixed`` is classic pipelining.  Cross-replica weight std is
reported at eval cadence — with ``noloco`` it must stay well below the
``none`` baseline (tested in tests/test_train_engine.py).
"""

from __future__ import annotations

import argparse
import json

from repro.comm import CommConfig
from repro.configs import registry
from repro.core.outer import OuterConfig
from repro.data import LoaderConfig
from repro.optim import AdamWConfig
from repro.pipeline import PipelineTrainer
from repro.train import LoopConfig, PipelineProgram, make_loop


def main() -> None:
    from repro.launch.train import add_engine_flags, kernel_config_from_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-small-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--method", default="noloco",
                    choices=["noloco", "diloco", "none"])
    ap.add_argument("--routing", default="random", choices=["random", "fixed"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--inner-steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--codec", default="none",
                    choices=["none", "fp16", "bf16", "int8"])
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    add_engine_flags(ap)
    args = ap.parse_args()

    import dataclasses

    kcfg = kernel_config_from_args(args)
    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=min(cfg.vocab_size, 512), remat=False,
                          dtype="float32")
    cfg = dataclasses.replace(cfg, kernels=kcfg)
    if cfg.num_layers % args.stages:
        raise SystemExit(
            f"num_layers={cfg.num_layers} must divide into --stages={args.stages}"
        )

    outer = None
    if args.method != "none":
        outer = OuterConfig(method=args.method, inner_steps=args.inner_steps,
                            seed=args.seed)
    trainer = PipelineTrainer(
        cfg, num_stages=args.stages, replicas=args.replicas,
        inner=AdamWConfig(lr=args.lr, weight_decay=0.0),
        routing=args.routing, outer=outer,
        comm=CommConfig(codec=args.codec), kernel_cfg=kcfg, seed=args.seed,
    )

    loop = make_loop(
        PipelineProgram(trainer),
        LoaderConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            per_replica_batch=args.batch, replicas=args.replicas, seed=args.seed,
        ),
        LoopConfig(
            steps=args.steps, eval_every=args.eval_every, seed=args.seed,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            resume=args.resume, log_jsonl=args.log_jsonl, log=True,
            run_name=f"{cfg.name}-pipe-{args.method}",
        ),
    )
    res = loop.run()
    print(json.dumps({
        "arch": cfg.name, "stages": args.stages, "replicas": args.replicas,
        "method": args.method, "routing": args.routing,
        "final_loss": res["losses"][-1] if res["losses"] else None,
        "final_weight_std": res["final_weight_std"],
        "outer_syncs": res["outer_syncs"],
        "comm_bytes": res["comm_bytes"],
        "tokens_per_s": round(res["tokens_per_s"], 1),
        "wall_s": round(res["wall_s"], 1),
    }))


if __name__ == "__main__":
    main()
