"""Elastic-gossip training CLI: the stacked runtime under a fault plan.

    PYTHONPATH=src python -m repro.launch.train_elastic \\
        --arch paper-small-125m --reduced --replicas 8 --steps 50 \\
        --inner-steps 5 --fault-plan plan.json --eval-every 10

``plan.json`` is a :class:`repro.sim.FaultPlan` (see that module for the
schema): node dropout, rejoin-with-warm-start, stragglers, partitions — all
replayed deterministically against the production gossip outer step, so
"no blocking collective" is exercised as a fault-tolerance property, not
just a latency argument.  Without ``--fault-plan`` this is a healthy run of
the same program (the baseline the scenario compares against).

``run_elastic_training`` is the library entry the tests and the CI smoke
job share; it returns the engine's result dict plus the simulator's
round-participation history.
"""

from __future__ import annotations

import argparse
import json
from typing import Any

from repro.comm import CommConfig
from repro.configs import registry
from repro.data import LoaderConfig
from repro.kernels.dispatch import KernelConfig
from repro.launch.train import add_engine_flags, kernel_config_from_args, method_config
from repro.models.config import ModelConfig
from repro.sim import FaultPlan, SimCluster
from repro.train import GossipProgram, LoopConfig, make_loop

import dataclasses


def run_elastic_training(
    cfg: ModelConfig,
    plan: FaultPlan,
    *,
    method: str = "noloco",
    replicas: int = 8,
    per_replica_batch: int = 2,
    seq_len: int = 64,
    steps: int = 50,
    total_steps: int | None = None,
    inner_lr: float = 3e-3,
    inner_steps: int = 5,
    eval_every: int = 0,
    eval_batches: int = 2,
    seed: int = 0,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    resume: bool = False,
    log: bool = False,
    log_jsonl: str | None = None,
    codec: str = "none",
    stream_count: int = 1,
    overlap: bool | None = None,
    impl: str = "auto",
    interpret: bool | None = None,
    reassign_data: bool = False,
    stale: str = "naive",
    async_clock: bool | None = None,
) -> dict[str, Any]:
    """Train under ``plan``; returns the engine result dict plus
    ``rounds`` (the simulator's per-round participation history) and the
    final membership.

    ``reassign_data`` redistributes dropped replicas' loader streams over
    survivors (:func:`repro.core.elastic.stream_assignment` — deterministic,
    resume-safe); the default keeps the seed behavior of skipping them.

    ``stream_count`` partitions the outer payload into staggered streams
    (streaming outer steps); ``overlap`` adds the §3.2 φ-prefetch — it
    defaults ON when ``stream_count > 1`` and composes with churn through
    the membership-epoch fallback (a stream whose pre-send pairing went
    stale blocks once; the other streams stay overlapped).

    ``async_clock`` gives every replica its own round clock (per-replica
    step rates from the plan's ``rate`` events; merged sync ticks exchange
    stale Δs instead of blocking on stragglers — DESIGN.md §7).  It defaults
    ON whenever the plan carries rate events; ``stale`` selects the stale-Δ
    rule (``"naive"`` / ``"momentum"``)."""
    if overlap is None:
        overlap = stream_count > 1
    kcfg = KernelConfig(impl=impl, interpret=interpret)
    cfg = dataclasses.replace(cfg, kernels=kcfg)
    tcfg = method_config(
        method, inner_lr=inner_lr, total_steps=total_steps or steps,
        warmup=max((total_steps or steps) // 10, 1), inner_steps=inner_steps,
        seed=seed,
        comm=CommConfig(codec=codec, streams=stream_count, overlap=overlap),
        kernels=kcfg, stale=stale,
    )
    program = GossipProgram(cfg, tcfg, replicas=replicas, seed=seed)
    sim = SimCluster(program, plan, reassign_data=reassign_data,
                     async_clock=async_clock)
    loop = make_loop(
        sim,
        LoaderConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            per_replica_batch=per_replica_batch, replicas=replicas, seed=seed,
        ),
        LoopConfig(
            steps=steps, eval_every=eval_every, seed=seed,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every, resume=resume,
            log_jsonl=log_jsonl, log=log, run_name=f"{cfg.name}-elastic",
        ),
        n_eval=eval_batches,
    )
    res = loop.run()
    res["rounds"] = sim.rounds()
    res["fault_history"] = sim.history
    res["membership"] = {
        "epoch": sim.membership.epoch,
        "active": list(sim.membership.active_ids),
    }
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-small-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the arch")
    ap.add_argument("--method", default="noloco", choices=["noloco", "diloco"])
    ap.add_argument("--fault-plan", default=None,
                    help="JSON FaultPlan (repro.sim.faults); omit for a healthy run")
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--total-steps", type=int, default=None,
                    help="LR-schedule horizon (pin it for interrupted runs "
                         "that will resume; default: --steps)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--inner-steps", type=int, default=5)
    ap.add_argument("--codec", default="none",
                    choices=["none", "fp16", "bf16", "int8"])
    ap.add_argument("--stream-count", type=int, default=1,
                    help="streaming outer steps: partition the payload into N "
                         "streams synced on staggered round offsets "
                         "(implies the §3.2 overlap when > 1)")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reassign-data", action="store_true",
                    help="redistribute dropped replicas' loader streams over "
                         "survivors (default: skip them)")
    ap.add_argument("--stale", default="naive", choices=["naive", "momentum"],
                    help="async stale-Δ rule: naive applies a delayed Δ as-is, "
                         "momentum discounts it by 1/(1+τ)")
    ap.add_argument("--async-clock", action="store_true", default=None,
                    help="per-replica round clocks (auto-on when the fault "
                         "plan carries rate events)")
    ap.add_argument("--out", default=None)
    add_engine_flags(ap)
    args = ap.parse_args()
    kernel_config_from_args(args)

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=min(cfg.vocab_size, 512), remat=False,
                          dtype="float32")
    plan = FaultPlan.load(args.fault_plan) if args.fault_plan else FaultPlan()
    horizon = plan.max_effect_step(args.inner_steps)
    if horizon > args.steps:
        print(f"warning: fault-plan effects extend to step {horizon}, beyond "
              f"--steps {args.steps}; in-flight straggle debts ride the "
              f"checkpoint and resume exactly", flush=True)
    res = run_elastic_training(
        cfg, plan, method=args.method, replicas=args.replicas,
        per_replica_batch=args.batch, seq_len=args.seq, steps=args.steps,
        total_steps=args.total_steps,
        inner_lr=args.lr, inner_steps=args.inner_steps,
        eval_every=args.eval_every, seed=args.seed,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, resume=args.resume,
        log=True, log_jsonl=args.log_jsonl, codec=args.codec,
        stream_count=args.stream_count,
        impl=args.impl, interpret=args.interpret,
        reassign_data=args.reassign_data,
        stale=args.stale, async_clock=args.async_clock,
    )
    summary = {
        "arch": cfg.name, "method": args.method,
        "fault_events": len(plan.events),
        "outer_syncs": res["outer_syncs"],
        "stream_count": res.get("stream_count", 1),
        "blocking_fraction": round(res["blocking_fraction"], 4),
        "membership": res["membership"],
        "final_train_loss": res["losses"][-1] if res["losses"] else None,
        "final_eval": res["evals"][-1][1] if res["evals"] else None,
        "final_weight_std": res["final_weight_std"],
        "wall_s": round(res["wall_s"], 1),
    }
    if "max_staleness" in res:
        summary["max_staleness"] = res["max_staleness"]
        summary["blocked_syncs"] = res["blocked_syncs"]
    print(json.dumps(summary))
    if args.out:
        res.pop("state")
        with open(args.out, "w") as f:
            json.dump(res, f)


if __name__ == "__main__":
    main()
