"""repro — NoLoCo (no-all-reduce low-communication training) in JAX.

Layers: core/ (gossip outer optimizer, theory, latency), models/ (10-arch
zoo), parallel/ (shard_map runtime), kernels/ (Pallas), data/, checkpoint/,
pipeline/ (random routing), configs/, launch/.
"""

__version__ = "1.0.0"
