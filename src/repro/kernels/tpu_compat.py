"""Pallas-TPU API compat: the compiler-params dataclass was renamed
``TPUCompilerParams`` → ``CompilerParams`` across jax releases; resolve it
once here so every kernel module works on both."""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
