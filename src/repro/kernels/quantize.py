"""Fused int8 per-chunk affine quantize / dequantize Pallas kernels.

The gossip wire codec (:class:`repro.comm.compress.Int8Codec`) maps each
CHUNK-sized group of a packed payload to uint8 with an fp32 (scale, min)
pair.  The jnp expression materializes the padded fp32 buffer, the per-chunk
min/max, AND the normalized intermediate — ≥4 HBM round trips over a buffer
that is the whole model.  The kernels stream (ROWS, CHUNK) tiles through
VMEM and emit the quantized bytes + metadata in one pass (quantize: 1 fp32
read, ~¼ write; dequantize: ¼ read + 1 fp32 write) — LoCo-style low-bit
compression fused on the wire path.

Layout contract (shared with ref.jnp_int8_quantize): input is the
already-padded 2-D (NC, CHUNK) view of the payload; the byte-level wire
packing (values ‖ bitcast metadata) stays in comm/compress.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8  # chunk rows per grid step: (8, 1024) f32 tile = 32 KiB VMEM


def _quant_kernel(x_ref, q_ref, scale_ref, lo_ref):
    x = x_ref[...].astype(jnp.float32)              # (ROWS, CHUNK)
    lo = jnp.min(x, axis=1)
    scale = (jnp.max(x, axis=1) - lo) / 255.0
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round((x - lo[:, None]) / safe[:, None]), 0.0, 255.0)
    q_ref[...] = q.astype(jnp.uint8)
    scale_ref[...] = safe
    lo_ref[...] = lo


def _dequant_kernel(q_ref, scale_ref, lo_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * scale_ref[...][:, None] + lo_ref[...][:, None]


def _pad_rows(x: jax.Array, rows: int) -> tuple[jax.Array, int]:
    nc = x.shape[0]
    pad = (-nc) % rows
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, nc


@functools.partial(jax.jit, static_argnames=("interpret",))
def pallas_int8_quantize(
    x: jax.Array, *, interpret: bool = True
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(NC, CHUNK) f32 → (q uint8 (NC,CHUNK), scale f32 (NC,), lo f32 (NC,))."""
    xp, nc = _pad_rows(x, ROWS)
    chunk = x.shape[1]
    grid = (xp.shape[0] // ROWS,)
    spec2d = pl.BlockSpec((ROWS, chunk), lambda i: (i, 0))
    spec1d = pl.BlockSpec((ROWS,), lambda i: (i,))
    q, scale, lo = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[spec2d],
        out_specs=[spec2d, spec1d, spec1d],
        out_shape=[
            jax.ShapeDtypeStruct(xp.shape, jnp.uint8),
            jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
            jax.ShapeDtypeStruct((xp.shape[0],), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return q[:nc], scale[:nc], lo[:nc]


@functools.partial(jax.jit, static_argnames=("interpret",))
def pallas_int8_dequantize(
    q: jax.Array, scale: jax.Array, lo: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """(q uint8 (NC,CHUNK), scale (NC,), lo (NC,)) → f32 (NC, CHUNK)."""
    qp, nc = _pad_rows(q, ROWS)
    sp, _ = _pad_rows(scale, ROWS)
    lp, _ = _pad_rows(lo, ROWS)
    chunk = q.shape[1]
    grid = (qp.shape[0] // ROWS,)
    spec2d = pl.BlockSpec((ROWS, chunk), lambda i: (i, 0))
    spec1d = pl.BlockSpec((ROWS,), lambda i: (i,))
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[spec2d, spec1d, spec1d],
        out_specs=spec2d,
        out_shape=jax.ShapeDtypeStruct(qp.shape, jnp.float32),
        interpret=interpret,
    )(qp, sp, lp)
    return x[:nc]
