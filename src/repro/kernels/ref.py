"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def reference_attention(
    q: jax.Array,   # (BH, Sq, D)
    k: jax.Array,   # (BH, Sk, D)
    v: jax.Array,   # (BH, Sk, D)
    *,
    mode: str = "causal",
    window: int = 0,
) -> jax.Array:
    """Naive full-softmax attention (O(S²) memory — oracle only)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    sq, sk = q.shape[1], k.shape[1]
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    if mode == "causal":
        valid = kp <= qp
    elif mode == "local":
        valid = (kp <= qp) & (kp > qp - window)
    else:
        valid = jnp.ones((sq, sk), bool)
    s = jnp.where(valid[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def reference_noloco_update(
    theta, phi, delta_mom, theta_partner, phi_partner, *, alpha, beta, gamma
):
    """Eqs. 1–3 with the appendix-consistent +β sign (see core/outer.py)."""
    f = jnp.float32
    d_self = theta.astype(f) - phi.astype(f)
    d_partner = theta_partner.astype(f) - phi_partner.astype(f)
    mean_d = 0.5 * (d_self + d_partner)
    mean_phi = 0.5 * (phi.astype(f) + phi_partner.astype(f))
    new_delta = alpha * delta_mom.astype(f) + beta * mean_d - gamma * (phi.astype(f) - mean_phi)
    new_phi = phi.astype(f) + new_delta
    return new_phi.astype(phi.dtype), new_delta.astype(delta_mom.dtype)


def reference_ssd(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H)
    a: jax.Array,     # (H,) negative rates
    b_mat: jax.Array,  # (B, S, N)
    c_mat: jax.Array,  # (B, S, N)
    initial_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Token-by-token SSM recurrence (the gold semantics of SSD):
        h_t = exp(dt_t·a)·h_{t-1} + dt_t·(B_t ⊗ x_t);   y_t = C_t · h_t
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    f = jnp.float32
    h0 = (
        jnp.zeros((bsz, h, p, n), f)
        if initial_state is None
        else initial_state.astype(f)
    )

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a[None, :])                        # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    xs = (
        x.astype(f).transpose(1, 0, 2, 3),
        dt.astype(f).transpose(1, 0, 2),
        b_mat.astype(f).transpose(1, 0, 2),
        c_mat.astype(f).transpose(1, 0, 2),
    )
    final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
