"""jnp implementations for every Pallas kernel.

Two kinds of function live here, both pure jnp:

  * ``reference_*`` — naive ORACLES (the allclose ground truth for tests;
    O(S²) memory where that is the simplest correct thing).
  * ``jnp_*``       — PRODUCTION fallbacks registered in
    :mod:`repro.kernels.dispatch` as the ``impl="jnp"`` path of each op and
    used as the ``custom_vjp`` backward of the differentiable ops.  These are
    memory-bounded twins of the Pallas kernels (online softmax, chunked
    forms) and must match the kernels' shapes/dtypes exactly.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def reference_attention(
    q: jax.Array,   # (BH, Sq, D)
    k: jax.Array,   # (BH, Sk, D)
    v: jax.Array,   # (BH, Sk, D)
    *,
    mode: str = "causal",
    window: int = 0,
) -> jax.Array:
    """Naive full-softmax attention (O(S²) memory — oracle only)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(d)
    sq, sk = q.shape[1], k.shape[1]
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    if mode == "causal":
        valid = kp <= qp
    elif mode == "local":
        valid = (kp <= qp) & (kp > qp - window)
    else:
        valid = jnp.ones((sq, sk), bool)
    s = jnp.where(valid[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def jnp_flash_attention(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Sk, KV, D)
    v: jax.Array,   # (B, Sk, KV, D)
    *,
    mode: str = "causal",
    window: int = 0,
    block_kv: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Online-softmax attention scanned over KV blocks, GQA-grouped.

    The model-layout twin of :func:`repro.kernels.flash_attention.
    pallas_flash_attention`: same (B, Sq, H, D) signature, same grouped K/V
    (never expanded to query-head width when H % KV == 0), O(S) memory.
    Positions are implicit ``arange`` — the training/prefill case; the cache
    paths with explicit positions live in :mod:`repro.models.attention`.
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    if h % kvh:
        head_map = (jnp.arange(h) * kvh) // h
        k = jnp.take(k, head_map, axis=2)
        v = jnp.take(v, head_map, axis=2)
        kvh = h
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, kvh, g, d)
    qg = qg.transpose(0, 2, 3, 1, 4)                     # (B, KV, G, Sq, D)

    nblk = max(1, math.ceil(sk / block_kv))
    pad = nblk * block_kv - sk
    kv_positions = jnp.arange(sk, dtype=jnp.int32)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-(10**9))
    kb = k.reshape(b, nblk, block_kv, kvh, d).transpose(1, 0, 3, 2, 4)  # (n,B,KV,Bk,D)
    vb = v.reshape(b, nblk, block_kv, kvh, d).transpose(1, 0, 3, 2, 4)
    pb = kv_positions.reshape(nblk, block_kv)

    q_pos = jnp.arange(sq, dtype=jnp.int32)[:, None]

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, kpos = blk
        s = jnp.einsum("bkgqd,bktd->bkgqt", qg, kblk.astype(jnp.float32))
        kp = kpos[None, :]
        valid = kp >= 0
        if mode == "causal":
            valid &= kp <= q_pos
        elif mode == "local":
            valid &= (kp <= q_pos) & (kp > q_pos - window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqt,bktd->bkgqd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, kvh, g, sq), jnp.float32),
        jnp.zeros((b, kvh, g, sq, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, pb), unroll=unroll)
    out = acc / jnp.maximum(l[..., None], 1e-30)          # (B,KV,G,Sq,D)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def jnp_paged_attention(
    q: jax.Array,             # (R, H, D) — one decode token per request slot
    k_pages: jax.Array,       # (NP, BS, KV, D) — fixed-size KV pages (last = trash)
    v_pages: jax.Array,       # (NP, BS, KV, D)
    block_tables: jax.Array,  # (R, MB) int32 page index per logical block
    positions: jax.Array,     # (R,) int32 position of the incoming token
    *,
    mode: str = "causal",
    window: int = 0,
) -> jax.Array:
    """Decode-step paged attention — the jnp twin of
    :func:`repro.kernels.paged_attention.pallas_paged_attention`.

    Gathers each request's K/V pages through its block table into a dense
    (R, MB·BS, KV, D) view and runs one masked softmax per request slot; GQA
    groups the query heads over their kv head like :func:`jnp_flash_attention`
    (non-divisible head counts gather-expand, which the Pallas kernel does not
    support — the ops wrapper falls back here for those).  Valid keys are
    ``kv_pos <= positions[r]`` (and within ``window`` for local layers) — keys
    past the request's context, unallocated table entries and the trash page
    are all masked out by position alone."""
    r, h, d = q.shape
    bs, kvh = k_pages.shape[1], k_pages.shape[2]
    mb = block_tables.shape[1]
    k = jnp.take(k_pages, block_tables, axis=0)          # (R, MB, BS, KV, D)
    v = jnp.take(v_pages, block_tables, axis=0)
    k = k.reshape(r, mb * bs, kvh, d)
    v = v.reshape(r, mb * bs, kvh, d)
    if h % kvh:
        head_map = (jnp.arange(h) * kvh) // h
        k = jnp.take(k, head_map, axis=2)
        v = jnp.take(v, head_map, axis=2)
        kvh = h
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    qg = (q.astype(jnp.float32) * scale).reshape(r, kvh, g, d)

    kv_pos = jnp.arange(mb * bs, dtype=jnp.int32)[None, :]   # (1, T)
    pos = positions[:, None]                                  # (R, 1)
    valid = kv_pos <= pos
    if mode == "local":
        valid &= kv_pos > pos - window
    s = jnp.einsum("rkgd,rtkd->rkgt", qg, k.astype(jnp.float32))
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("rkgt,rtkd->rkgd", p, v.astype(jnp.float32))
    return out.reshape(r, h, d).astype(q.dtype)


def jnp_paged_chunk_attention(
    q: jax.Array,             # (R, C, H, D) — one prefill chunk per slot
    k_pages: jax.Array,       # (NP, BS, KV, D)
    v_pages: jax.Array,       # (NP, BS, KV, D)
    block_tables: jax.Array,  # (R, MB) int32
    positions: jax.Array,     # (R,) int32 — base position of chunk token 0
    *,
    mode: str = "causal",
    window: int = 0,
) -> jax.Array:
    """Chunked paged prefill attention — the jnp twin of
    :func:`repro.kernels.paged_attention.pallas_paged_chunk_attention`.

    Same dense block-table gather as :func:`jnp_paged_attention`, but with C
    query tokens per slot: chunk token c of slot r queries at absolute
    position ``positions[r] + c`` and sees keys ``kv_pos <= positions[r] + c``
    (windowed for local layers).  Ragged chunks need no extra masking here —
    rows past the slot's valid length produce garbage that the caller
    discards, and their K/V were scattered to the trash page."""
    r, c, h, d = q.shape
    bs, kvh = k_pages.shape[1], k_pages.shape[2]
    mb = block_tables.shape[1]
    k = jnp.take(k_pages, block_tables, axis=0)          # (R, MB, BS, KV, D)
    v = jnp.take(v_pages, block_tables, axis=0)
    k = k.reshape(r, mb * bs, kvh, d)
    v = v.reshape(r, mb * bs, kvh, d)
    if h % kvh:
        head_map = (jnp.arange(h) * kvh) // h
        k = jnp.take(k, head_map, axis=2)
        v = jnp.take(v, head_map, axis=2)
        kvh = h
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    qg = (q.astype(jnp.float32) * scale).reshape(r, c, kvh, g, d)

    kv_pos = jnp.arange(mb * bs, dtype=jnp.int32)[None, None, :]      # (1, 1, T)
    q_pos = positions[:, None, None] + jnp.arange(c, dtype=jnp.int32)[None, :, None]
    valid = kv_pos <= q_pos                                           # (R, C, T)
    if mode == "local":
        valid &= kv_pos > q_pos - window
    s = jnp.einsum("rckgd,rtkd->rckgt", qg, k.astype(jnp.float32))
    s = jnp.where(valid[:, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("rckgt,rtkd->rckgd", p, v.astype(jnp.float32))
    return out.reshape(r, c, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# NoLoCo outer update (Eqs. 2–3 over group means)
# ---------------------------------------------------------------------------


def reference_noloco_update(
    phi, delta_mom, mean_delta, mean_phi, *, alpha, beta, gamma
):
    """Eqs. 2–3 given the group statistics, with the appendix-consistent +β
    sign (see core/outer.py).  Shape-agnostic elementwise math — doubles as
    the ``impl="jnp"`` dispatch path of the fused kernel."""
    f = jnp.float32
    new_delta = (
        alpha * delta_mom.astype(f)
        + beta * mean_delta.astype(f)
        - gamma * (phi.astype(f) - mean_phi.astype(f))
    )
    new_phi = phi.astype(f) + new_delta
    return new_phi.astype(phi.dtype), new_delta.astype(delta_mom.dtype)


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------


def jnp_ssd_chunk_intra(
    x: jax.Array,     # (B, NC, Q, H, P)
    dt: jax.Array,    # (B, NC, Q, H)
    a: jax.Array,     # (H,)
    b_mat: jax.Array,  # (B, NC, Q, N)
    c_mat: jax.Array,  # (B, NC, Q, N)
) -> tuple[jax.Array, jax.Array]:
    """Intra-chunk quadratic form + per-chunk end states — the jnp twin of
    :func:`repro.kernels.ssd_scan.ssd_chunk_kernel`.

    Returns ``(y_diag (B,NC,Q,H,P) in x.dtype, states (B,NC,H,N,P) f32)``.
    """
    q = x.shape[2]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    da = dtf * a[None, None, None, :]                   # (B,NC,Q,H)
    cums = jnp.cumsum(da, axis=2)                       # inclusive
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]   # (B,NC,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_kern = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)

    xdt = xf * dtf[..., None]                           # dt_j · x_j
    scores = jnp.einsum("bcin,bcjn->bcij", cf, bf)      # (B,NC,Q,Q)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, l_kern, xdt)

    decay_states = jnp.exp(cums[:, :, -1:, :] - cums)   # (B,NC,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bf, decay_states, xdt)
    return y_diag.astype(x.dtype), states


def reference_ssd(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H)
    a: jax.Array,     # (H,) negative rates
    b_mat: jax.Array,  # (B, S, N)
    c_mat: jax.Array,  # (B, S, N)
    initial_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Token-by-token SSM recurrence (the gold semantics of SSD):
        h_t = exp(dt_t·a)·h_{t-1} + dt_t·(B_t ⊗ x_t);   y_t = C_t · h_t
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    f = jnp.float32
    h0 = (
        jnp.zeros((bsz, h, p, n), f)
        if initial_state is None
        else initial_state.astype(f)
    )

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a[None, :])                        # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    xs = (
        x.astype(f).transpose(1, 0, 2, 3),
        dt.astype(f).transpose(1, 0, 2),
        b_mat.astype(f).transpose(1, 0, 2),
        c_mat.astype(f).transpose(1, 0, 2),
    )
    final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final


# ---------------------------------------------------------------------------
# RG-LRU linear recurrence
# ---------------------------------------------------------------------------


def jnp_rglru_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """Inclusive scan of h_t = a_t · h_{t-1} + b_t over axis 1 (zero h_0) via
    ``jax.lax.associative_scan`` — the jnp twin of
    :func:`repro.kernels.rglru_scan.pallas_rglru_scan`.  a, b: (B, S, W);
    returns f32 like the kernel (its accumulator dtype) for any input dtype."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1
    )
    return h


# ---------------------------------------------------------------------------
# Single-token decode state updates (serving hot loop)
# ---------------------------------------------------------------------------


def jnp_rglru_decode(h: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """One RG-LRU decode step  h' = a ⊙ h + b  over (R, W) slot states — the
    jnp twin of :func:`repro.kernels.decode_update.pallas_rglru_decode`.
    Returns f32 like the training scan kernel's accumulator."""
    return a.astype(jnp.float32) * h.astype(jnp.float32) + b.astype(jnp.float32)


def jnp_ssd_decode(
    state: jax.Array,  # (R, H·P, N) f32 slot states, heads folded into rows
    decay: jax.Array,  # (R, H·P) exp(dt·a) broadcast over P
    dtx: jax.Array,    # (R, H·P) dt-scaled inputs (dt_h · x_{h,p})
    b: jax.Array,      # (R, N)
    c: jax.Array,      # (R, N)
) -> tuple[jax.Array, jax.Array]:
    """One SSD decode step over prepared per-slot operands — the jnp twin of
    :func:`repro.kernels.decode_update.pallas_ssd_decode`:

        state' = decay ⊙ state + dtx ⊗ b;   y = state' · c

    Returns ``(state' (R,H·P,N) f32, y (R,H·P) f32)``.  The model-level
    reshapes (head/dim folding, decay broadcast) live in
    :func:`repro.kernels.ops.ssd_decode`."""
    f = jnp.float32
    st = state.astype(f) * decay.astype(f)[..., None] + (
        dtx.astype(f)[..., None] * b.astype(f)[:, None, :]
    )
    y = jnp.einsum("rkn,rn->rk", st, c.astype(f))
    return st, y


# ---------------------------------------------------------------------------
# int8 per-chunk affine codec
# ---------------------------------------------------------------------------


def jnp_int8_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-row affine uint8 quantization of a (NC, CHUNK) f32 buffer.
    Returns ``(q uint8 (NC,CHUNK), scale f32 (NC,), lo f32 (NC,))`` with
    scale already made safe (1.0 for constant rows)."""
    lo = x.min(axis=1)
    scale = (x.max(axis=1) - lo) / 255.0
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round((x - lo[:, None]) / safe[:, None]), 0.0, 255.0)
    return q.astype(jnp.uint8), safe, lo


def jnp_int8_dequantize(q: jax.Array, scale: jax.Array, lo: jax.Array) -> jax.Array:
    """Inverse of :func:`jnp_int8_quantize`: (NC, CHUNK) f32."""
    return q.astype(jnp.float32) * scale[:, None] + lo[:, None]
