"""RG-LRU linear recurrence  h_t = a_t · h_{t-1} + b_t  as a Pallas kernel.

The recurrence is channelwise (no mixing across the width dim), so the grid
is (batch, width_blocks, seq_chunks) with the sequence dim innermost and
"arbitrary" (sequential): the hidden state at a chunk boundary lives in VMEM
scratch across chunk iterations.  WITHIN a chunk the scan is computed fully
vectorized by log-step doubling on the (a, b) pair representation

    (A_t, B_t) ∘ (A_{t-k}, B_{t-k}) = (A_t·A_{t-k},  A_t·B_{t-k} + B_t)

— ⌈log₂ S_chunk⌉ VPU sweeps over a (S_chunk, block_w) tile instead of an
S-step serial loop, with no dynamic row indexing.  The chunk carry is then
applied as  h_t = B_t + A_t · h_in  (A_t = within-chunk cumprod of a).

VMEM per program ≈ (2 in + 1 out + 2 temps) · S_chunk·block_w·4B
               = 5 · 256·128·4 ≈ 640 KiB  « 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

BLOCK_S = 256   # sequence chunk per grid step
BLOCK_W = 128   # lane-aligned width tile


def _kernel(a_ref, b_ref, h_ref, carry_ref, *, block_s: int):
    sc = pl.program_id(2)

    @pl.when(sc == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)        # (S, W)
    b = b_ref[0].astype(jnp.float32)

    # inclusive scan by doubling: after round k, (A_t, B_t) composes the last
    # min(2^k, t+1) steps ending at t; with zero initial state h_t = B_t.
    big_a, big_b = a, b
    off = 1
    while off < block_s:
        ones = jnp.ones((off,) + big_a.shape[1:], big_a.dtype)
        zeros = jnp.zeros((off,) + big_b.shape[1:], big_b.dtype)
        a_shift = jnp.concatenate([ones, big_a[:-off]], axis=0)
        b_shift = jnp.concatenate([zeros, big_b[:-off]], axis=0)
        big_b = big_a * b_shift + big_b
        big_a = big_a * a_shift
        off *= 2

    h_in = carry_ref[...]                   # (W,) state entering this chunk
    h = big_b + big_a * h_in[None, :]
    h_ref[0] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1]


@functools.partial(
    jax.jit, static_argnames=("block_s", "block_w", "interpret")
)
def pallas_rglru_scan(
    a: jax.Array,   # (B, S, W) per-step decay in (0, 1]
    b: jax.Array,   # (B, S, W) per-step input
    *,
    block_s: int = BLOCK_S,
    block_w: int = BLOCK_W,
    interpret: bool = True,
) -> jax.Array:
    """Inclusive scan of h_t = a_t·h_{t-1} + b_t over axis 1 (zero h_0)."""
    bsz, s, w = a.shape
    ps = (-s) % block_s
    pw = (-w) % block_w
    if ps or pw:
        # zero padding is inert: a=0, b=0 rows hold h at 0 and are sliced off
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pw)))
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pw)))
    nsc = a.shape[1] // block_s
    nw = a.shape[2] // block_w

    spec = pl.BlockSpec((1, block_s, block_w), lambda bi, wi, sc: (bi, sc, wi))
    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s),
        grid=(bsz, nw, nsc),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
    return out[:, :s, :w]
