"""Pallas TPU kernels for the perf-critical compute hot-spots:

  flash_attention  — causal/sliding-window attention (every attention arch)
  noloco_update    — fused NoLoCo outer step Eq. 1-3 (memory-bound)
  ssd_scan         — Mamba-2 SSD intra-chunk quadratic form

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper), ref.py (pure-jnp oracle). Validated with interpret=True on CPU;
TPU v5e is the TARGET (MXU-aligned 128 blocks, VMEM tiling).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
