"""Pallas TPU kernels + the dispatch layer that makes them the production path.

  flash_attention  — causal/sliding-window attention, GQA-native fold
  ssd_scan         — Mamba-2 SSD intra-chunk quadratic form
  rglru_scan       — RG-LRU linear recurrence (log-step doubling scan)
  noloco_update    — fused NoLoCo outer step Eqs. 2–3 (memory-bound)
  quantize         — int8 per-chunk affine wire codec kernels

Layering: <name>.py (pl.pallas_call + BlockSpec, array-level), ref.py
(pure-jnp twins + oracles), dispatch.py (KernelConfig + the op registry),
ops.py (public custom_vjp'd wrappers the models/core/comm consumers call).
Validated with interpret=True on CPU; TPU v5e is the TARGET (MXU-aligned 128
blocks, VMEM tiling).  See DESIGN.md §6 for the dispatch table.
"""

from repro.kernels import dispatch, ops, ref
from repro.kernels.dispatch import KernelConfig

__all__ = ["dispatch", "ops", "ref", "KernelConfig"]
