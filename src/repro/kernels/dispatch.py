"""Kernel dispatch: ONE table from op name to (Pallas impl, jnp fallback).

This is the production compute path's switchboard.  Every perf-critical op
is registered here with two interchangeable implementations:

  * ``pallas`` — the Pallas TPU kernel (array-level, takes ``interpret=``);
  * ``jnp``    — the memory-bounded pure-jnp twin from :mod:`repro.kernels.
    ref` (identical signature minus ``interpret``), which doubles as the
    reference for parity tests and as the ``custom_vjp`` backward of the
    differentiable ops (see :mod:`repro.kernels.ops`).

:class:`KernelConfig` selects between them:

  * ``impl="auto"``   — Pallas when the default jax backend is TPU, jnp
    otherwise (so CPU CI never pays interpret-mode overhead);
  * ``impl="pallas"`` — force the kernels (with ``interpret`` resolving to
    True off-TPU, False on TPU unless pinned);
  * ``impl="jnp"``    — force the fallback everywhere.

The resolved choice is STATIC python control flow: it is fixed at trace
time, so a jitted program contains exactly one of the two lowerings.
Consumers thread a ``KernelConfig`` explicitly (``ModelConfig.kernels``,
``TrainerConfig.kernels``, ``--impl``/``--interpret`` launcher flags); code
without an explicit config uses the process-wide default set by
:func:`set_default_config` (launchers call it once at startup, before any
tracing).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

import jax

from repro.kernels import ref
from repro.kernels import decode_update as decode_update_mod
from repro.kernels import flash_attention as flash_attention_mod
from repro.kernels import noloco_update as noloco_update_mod
from repro.kernels import paged_attention as paged_attention_mod
from repro.kernels import quantize as quantize_mod
from repro.kernels import rglru_scan as rglru_scan_mod
from repro.kernels import ssd_scan as ssd_scan_mod

__all__ = [
    "KernelConfig",
    "KernelOp",
    "register",
    "get_op",
    "registry",
    "dispatch",
    "default_config",
    "set_default_config",
]

IMPLS = ("auto", "pallas", "jnp")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Which implementation each registered op runs with.

    ``impl``:      "auto" | "pallas" | "jnp" (see module docstring).
    ``interpret``: Pallas interpret mode; None resolves to ``not on-TPU`` so
                   forced-pallas runs still work on CPU (tests/CI) while TPU
                   gets compiled kernels.
    """

    impl: str = "auto"
    interpret: bool | None = None

    def validate(self) -> None:
        if self.impl not in IMPLS:
            raise ValueError(f"unknown kernel impl {self.impl!r}; options: {IMPLS}")

    def resolved_impl(self) -> str:
        """"pallas" or "jnp" with "auto" resolved against the jax backend."""
        self.validate()
        if self.impl == "auto":
            return "pallas" if _on_tpu() else "jnp"
        return self.impl

    def resolved_interpret(self) -> bool:
        if self.interpret is not None:
            return bool(self.interpret)
        return not _on_tpu()

    @property
    def use_pallas(self) -> bool:
        return self.resolved_impl() == "pallas"


_DEFAULT_CONFIG = KernelConfig()


def default_config() -> KernelConfig:
    """The process-wide config used when a consumer passes ``config=None``."""
    return _DEFAULT_CONFIG


def set_default_config(cfg: KernelConfig) -> None:
    """Set the process-wide default (launchers, once at startup — the choice
    is baked into traces, so flipping it after compilation has no effect on
    already-jitted programs)."""
    cfg.validate()
    global _DEFAULT_CONFIG
    _DEFAULT_CONFIG = cfg


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelOp:
    """One dispatchable op: a Pallas kernel and its jnp twin.

    ``pallas`` takes the same array arguments/static kwargs as ``jnp`` plus a
    trailing ``interpret`` keyword.  ``consumers`` documents every production
    call site (kept in sync by tests + DESIGN.md §6).
    """

    name: str
    pallas: Callable[..., Any]
    jnp: Callable[..., Any]
    pallas_file: str
    consumers: tuple[str, ...]


_REGISTRY: dict[str, KernelOp] = {}


def register(
    name: str,
    *,
    pallas: Callable[..., Any],
    jnp: Callable[..., Any],
    pallas_file: str,
    consumers: tuple[str, ...] = (),
) -> KernelOp:
    if name in _REGISTRY:
        raise ValueError(f"kernel op {name!r} already registered")
    op = KernelOp(name, pallas, jnp, pallas_file, tuple(consumers))
    _REGISTRY[name] = op
    return op


def get_op(name: str) -> KernelOp:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel op {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registry() -> Mapping[str, KernelOp]:
    return dict(_REGISTRY)


def dispatch(name: str, config: KernelConfig | None = None) -> Callable[..., Any]:
    """The implementation of ``name`` under ``config`` (default config when
    None).  The Pallas branch comes pre-bound with the resolved ``interpret``
    flag; static per-op kwargs (mode, window, ...) are passed by the caller."""
    cfg = config if config is not None else default_config()
    op = get_op(name)
    if cfg.resolved_impl() == "pallas":
        return functools.partial(op.pallas, interpret=cfg.resolved_interpret())
    return op.jnp


# ---------------------------------------------------------------------------
# The production op table
# ---------------------------------------------------------------------------

register(
    "flash_attention",
    pallas=flash_attention_mod.pallas_flash_attention,
    jnp=ref.jnp_flash_attention,
    pallas_file="kernels/flash_attention.py",
    consumers=(
        "models/attention.py::apply_attention (training / encoder / prefill)",
        "kernels/ops.py::flash_attention (custom_vjp wrapper)",
    ),
)

register(
    "ssd_chunk",
    pallas=ssd_scan_mod.ssd_chunk_kernel,
    jnp=ref.jnp_ssd_chunk_intra,
    pallas_file="kernels/ssd_scan.py",
    consumers=(
        "models/ssd.py::ssd_chunked (via kernels/ops.py::ssd_chunk)",
    ),
)

register(
    "rglru_scan",
    pallas=rglru_scan_mod.pallas_rglru_scan,
    jnp=ref.jnp_rglru_scan,
    pallas_file="kernels/rglru_scan.py",
    consumers=(
        "models/rglru.py::apply_rglru (via kernels/ops.py::rglru_scan)",
    ),
)

register(
    "noloco_update",
    pallas=noloco_update_mod.noloco_update_flat,
    jnp=ref.reference_noloco_update,
    pallas_file="kernels/noloco_update.py",
    consumers=(
        "core/outer.py::noloco_momentum_update (via kernels/ops.py::noloco_update_pytree)",
    ),
)

register(
    "paged_attention",
    pallas=paged_attention_mod.pallas_paged_attention,
    jnp=ref.jnp_paged_attention,
    pallas_file="kernels/paged_attention.py",
    consumers=(
        "models/attention.py::apply_attention (paged decode, via kernels/ops.py::paged_attention)",
    ),
)

register(
    "paged_chunk_attention",
    pallas=paged_attention_mod.pallas_paged_chunk_attention,
    jnp=ref.jnp_paged_chunk_attention,
    pallas_file="kernels/paged_attention.py",
    consumers=(
        "models/attention.py::apply_attention (chunked paged prefill, via kernels/ops.py::paged_chunk_attention)",
    ),
)

register(
    "rglru_decode",
    pallas=decode_update_mod.pallas_rglru_decode,
    jnp=ref.jnp_rglru_decode,
    pallas_file="kernels/decode_update.py",
    consumers=(
        "models/rglru.py::apply_rglru (single-token decode, via kernels/ops.py::rglru_decode)",
    ),
)

register(
    "ssd_decode",
    pallas=decode_update_mod.pallas_ssd_decode,
    jnp=ref.jnp_ssd_decode,
    pallas_file="kernels/decode_update.py",
    consumers=(
        "models/ssd.py::ssd_chunked (single-token decode, via kernels/ops.py::ssd_decode)",
    ),
)

register(
    "int8_quantize",
    pallas=quantize_mod.pallas_int8_quantize,
    jnp=ref.jnp_int8_quantize,
    pallas_file="kernels/quantize.py",
    consumers=("comm/compress.py::Int8Codec.encode",),
)

register(
    "int8_dequantize",
    pallas=quantize_mod.pallas_int8_dequantize,
    jnp=ref.jnp_int8_dequantize,
    pallas_file="kernels/quantize.py",
    consumers=("comm/compress.py::Int8Codec.decode",),
)
