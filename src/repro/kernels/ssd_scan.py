"""Mamba-2 SSD intra-chunk kernel (the quadratic hot-spot of the SSD
algorithm) in Pallas.

Per (batch, chunk, head) program:
    inputs  x (Q,P), dt (Q,), B (Q,N), C (Q,N), a (scalar decay rate)
    L[i,j]  = exp(cums_i − cums_j)·[i ≥ j],  cums = cumsum(dt·a)
    y_diag  = (C Bᵀ ∘ L) (dt ∘ x)            — intra-chunk output
    state   = Σ_j exp(cums_Q − cums_j)·dt_j·B_j ⊗ x_j  — chunk end state

The inter-chunk state recurrence is a cheap sequential scan left in jnp
(models/ssd.py); this kernel owns the O(Q²) work.  Q = ssm_chunk (128),
P = head_dim (64), N = d_state (128): VMEM ≈ Q·(P+2N)·4 + Q²·4 ≈ 250 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    a = a_ref[0].astype(jnp.float32)                 # scalar
    b = b_ref[0, 0].astype(jnp.float32)              # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)              # (Q, N)

    q = x.shape[0]
    da = dt * a                                       # (Q,)
    cums = jnp.cumsum(da)                             # inclusive

    diff = cums[:, None] - cums[None, :]              # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_kern = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]                             # (Q, P)
    scores = c @ b.T                                  # (Q, Q)
    y = (scores * l_kern) @ xdt                       # (Q, P)

    decay = jnp.exp(cums[-1] - cums)                  # (Q,)
    state = (b * (decay * dt)[:, None]).T @ x         # (N, P)

    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0, 0] = state.astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_kernel(
    x: jax.Array,    # (B, NC, Q, H, P)
    dt: jax.Array,   # (B, NC, Q, H)
    a: jax.Array,    # (H,)
    b_mat: jax.Array,  # (B, NC, Q, N)
    c_mat: jax.Array,  # (B, NC, Q, N)
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_diag (B,NC,Q,H,P), states (B,NC,H,N,P))."""
    bsz, nc, qlen, h, p = x.shape
    n = b_mat.shape[-1]

    # broadcast B/C over heads at the BlockSpec level (no materialized copy)
    y, states = pl.pallas_call(
        _kernel,
        grid=(bsz, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, qlen, 1, p), lambda b, c, hh: (b, c, 0, hh, 0)),
            pl.BlockSpec((1, 1, qlen, 1), lambda b, c, hh: (b, c, 0, hh)),
            pl.BlockSpec((1,), lambda b, c, hh: (hh,)),
            pl.BlockSpec((1, 1, qlen, n), lambda b, c, hh: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, qlen, n), lambda b, c, hh: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qlen, 1, p), lambda b, c, hh: (b, c, 0, hh, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda b, c, hh: (b, c, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, qlen, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, nc, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, b_mat, c_mat)
    return y, states
