"""Pallas TPU flash attention (causal / sliding-window / full), GQA-native.

Grid: (batch·kv_heads, q_blocks, kv_blocks) with the kv dim innermost and
"arbitrary" (sequential) so the online-softmax state lives in VMEM scratch
across kv iterations.  BlockSpecs tile Q/K/V into (block_q|block_kv, head_dim)
VMEM tiles; MXU-aligned defaults block_q = block_kv = 128.

GQA is handled WITHOUT materializing K/V at query-head width: the G = H/KV
query heads sharing one kv head are folded into the q row dimension
(rows enumerate (group, position) pairs, position = row % ``q_stride``), so
K/V buffers stay at kv-head width all the way into the kernel and each K/V
VMEM tile is reused by all G query heads of its grid row.

VMEM working set per program:
    q (bq, d) + k (bk, d) + v (bk, d) + acc (bq, d) f32 + m/l (bq,) f32
    = 128·128·2·3 + 128·128·4 + 1KB ≈ 164 KiB  « 16 MiB VMEM.

Validated on CPU with interpret=True against kernels/ref.py; the TPU is the
TARGET (see DESIGN.md hardware-adaptation notes).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref,             # VMEM tiles
    o_ref,                            # output tile (revisited over kv grid)
    acc_ref, m_ref, l_ref,            # scratch: f32 accumulators
    *,
    mode: str,
    window: int,
    block_q: int,
    block_kv: int,
    kv_len: int,
    q_stride: int,
    scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = q @ k.T                                       # (bq, bk)

    # rows enumerate (group, position) pairs when GQA groups are folded in;
    # position within the head is row % q_stride (identity when unfolded)
    row = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    q_pos = row % q_stride
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    valid = k_pos < kv_len
    if mode == "causal":
        valid &= k_pos <= q_pos
    elif mode == "local":
        valid &= (k_pos <= q_pos) & (k_pos > q_pos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...][:, None], 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "window", "block_q", "block_kv", "q_stride", "interpret"),
)
def flash_attention_bhsd(
    q: jax.Array,   # (BH, Sq, D)  — batch and (kv) heads flattened
    k: jax.Array,   # (BH, Sk, D)
    v: jax.Array,   # (BH, Sk, D)
    *,
    mode: str = "causal",
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    q_stride: int | None = None,   # per-head q length when GQA groups folded
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)

    pq = (-sq) % block_q
    pk = (-sk) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_kv
    if q_stride is None:
        q_stride = q.shape[1]

    kernel = functools.partial(
        _kernel,
        mode=mode,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        kv_len=sk,
        q_stride=q_stride,
        scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]


def pallas_flash_attention(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Sk, KV, D)
    v: jax.Array,   # (B, Sk, KV, D)
    *,
    mode: str = "causal",
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """GQA flash attention at model layout.

    When H % KV == 0 (all assigned archs) the G = H/KV query heads per kv
    head are FOLDED into the q row dimension: K/V are flattened to
    (B·KV, Sk, D) without any head expansion, and the kernel recovers the
    per-head position as ``row % q_stride``.  The legacy gather-expand path
    remains only for non-divisible head counts.
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]

    if h % kvh == 0:
        g = h // kvh
        sq_pad = sq + (-sq) % block_q
        qt = q.transpose(0, 2, 1, 3)                   # (B, H, Sq, D)
        if sq_pad != sq:
            qt = jnp.pad(qt, ((0, 0), (0, 0), (0, sq_pad - sq), (0, 0)))
        qf = qt.reshape(b * kvh, g * sq_pad, d)
        kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, d)
        vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, d)
        out = flash_attention_bhsd(
            qf, kf, vf, mode=mode, window=window,
            block_q=block_q, block_kv=block_kv, q_stride=sq_pad,
            interpret=interpret,
        )
        out = out.reshape(b, kvh, g, sq_pad, d)[:, :, :, :sq]
        return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)

    # non-divisible head counts: gather-expand K/V to query-head width
    head_map = (jnp.arange(h) * kvh) // h
    ke = jnp.take(k, head_map, axis=2)
    ve = jnp.take(v, head_map, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = ke.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = ve.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    out = flash_attention_bhsd(
        qf, kf, vf, mode=mode, window=window,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
