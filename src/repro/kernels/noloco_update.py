"""Fused NoLoCo outer update (Eqs. 2–3) as a Pallas kernel.

The outer step is purely memory-bound: the naive jnp expression builds the
momentum update and the weight update as separate HBM-materialized temps
(~10 round trips per parameter).  The kernel streams the four operands
tile-by-tile through VMEM and writes (φ′, δ′) in ONE pass — 4 reads + 2
writes, and the update's arithmetic intensity is ~1 FLOP/B so HBM traffic IS
its runtime.

    δ'  = α δ + β·mean(Δ) − γ(φ − mean(φ))
    φ'  = φ + δ'

over the GROUP STATISTICS ``(mean_delta, mean_phi)`` delivered by the gossip
exchange — the same cut every Communicator backend produces, so one kernel
serves the stacked, sharded and pipeline runtimes (with the appendix-
consistent +β sign; see core/outer.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096  # 1-D tile (lane-aligned multiple of 128)


def _kernel(phi_ref, delta_mom_ref, mean_d_ref, mean_phi_ref,
            phi_out_ref, delta_out_ref, *, alpha, beta, gamma):
    phi = phi_ref[...].astype(jnp.float32)
    dmom = delta_mom_ref[...].astype(jnp.float32)
    mean_d = mean_d_ref[...].astype(jnp.float32)
    mean_phi = mean_phi_ref[...].astype(jnp.float32)

    new_delta = alpha * dmom + beta * mean_d - gamma * (phi - mean_phi)
    new_phi = phi + new_delta

    phi_out_ref[...] = new_phi.astype(phi_out_ref.dtype)
    delta_out_ref[...] = new_delta.astype(delta_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "gamma", "interpret")
)
def noloco_update_flat(
    phi: jax.Array,         # (N,) slow weights
    delta_mom: jax.Array,   # (N,) outer momentum
    mean_delta: jax.Array,  # (N,) group-mean outer gradient
    mean_phi: jax.Array,    # (N,) group-mean slow weights
    *,
    alpha: float,
    beta: float,
    gamma: float,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    n = phi.shape[0]
    pad = (-n) % BLOCK
    args = (phi, delta_mom, mean_delta, mean_phi)
    if pad:
        args = tuple(jnp.pad(a, (0, pad)) for a in args)
    grid = (args[0].shape[0] // BLOCK,)
    kernel = functools.partial(_kernel, alpha=alpha, beta=beta, gamma=gamma)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    phi_out, delta_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(args[0].shape, phi.dtype),
            jax.ShapeDtypeStruct(args[1].shape, delta_mom.dtype),
        ],
        interpret=interpret,
    )(*args)
    return phi_out[:n], delta_out[:n]
