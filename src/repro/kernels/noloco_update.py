"""Fused NoLoCo outer update (Eqs. 1–3) as a Pallas kernel.

The outer step is purely memory-bound: the naive jnp expression makes ~7 HBM
round-trips per parameter (Δ_self, group means, momentum update, weight
update).  The kernel streams all five operands tile-by-tile through VMEM and
writes (φ′, δ′) in ONE pass — the update's arithmetic intensity is ~1 FLOP/B,
so HBM traffic IS its runtime.

    Δ_i   = θ_i − φ_i
    δ'    = α δ + β·½(Δ_i + Δ_j) − γ(φ_i − ½(φ_i + φ_j))
    φ'    = φ_i + δ'

(with the appendix-consistent +β sign; see core/outer.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096  # 1-D tile (lane-aligned multiple of 128)


def _kernel(theta_ref, phi_ref, delta_mom_ref, theta_p_ref, phi_p_ref,
            phi_out_ref, delta_out_ref, *, alpha, beta, gamma):
    theta = theta_ref[...].astype(jnp.float32)
    phi = phi_ref[...].astype(jnp.float32)
    dmom = delta_mom_ref[...].astype(jnp.float32)
    theta_p = theta_p_ref[...].astype(jnp.float32)
    phi_p = phi_p_ref[...].astype(jnp.float32)

    d_self = theta - phi
    d_partner = theta_p - phi_p
    mean_d = 0.5 * (d_self + d_partner)
    mean_phi = 0.5 * (phi + phi_p)

    new_delta = alpha * dmom + beta * mean_d - gamma * (phi - mean_phi)
    new_phi = phi + new_delta

    phi_out_ref[...] = new_phi.astype(phi_out_ref.dtype)
    delta_out_ref[...] = new_delta.astype(delta_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "gamma", "interpret")
)
def noloco_update_flat(
    theta: jax.Array,      # (N,) this replica's fast weights
    phi: jax.Array,        # (N,) slow weights
    delta_mom: jax.Array,  # (N,) outer momentum
    theta_partner: jax.Array,
    phi_partner: jax.Array,
    *,
    alpha: float,
    beta: float,
    gamma: float,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    n = theta.shape[0]
    pad = (-n) % BLOCK
    args = (theta, phi, delta_mom, theta_partner, phi_partner)
    if pad:
        args = tuple(jnp.pad(a, (0, pad)) for a in args)
    grid = (args[0].shape[0] // BLOCK,)
    kernel = functools.partial(_kernel, alpha=alpha, beta=beta, gamma=gamma)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    phi_out, delta_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(args[1].shape, phi.dtype),
            jax.ShapeDtypeStruct(args[2].shape, delta_mom.dtype),
        ],
        interpret=interpret,
    )(*args)
    return phi_out[:n], delta_out[:n]
